//! Table emitters matching the paper's reporting format (markdown rows,
//! identical columns to Tables 2–4 / Figure 8 series).

use crate::coordinator::{PipelineReport, ThresholdMode};

/// Nominal CR (the requested operating point) when the run was fixed-CR,
/// else the measured one — table rows quote the paper's nominal axis.
pub fn nominal_cr(r: &PipelineReport) -> f64 {
    match r.mode {
        ThresholdMode::FixedCr(c) => c,
        _ => r.compression_ratio,
    }
}

/// Format a fraction as a percentage with two decimals.
pub fn pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

/// Table 2 row: Method | CR | Acc-top1 | Acc-top5 | Latency | Energy.
pub fn table2_row(method: &str, r: &PipelineReport) -> String {
    format!(
        "| {:<6} | {:>4.0}% | {:>7} | {:>7} | {:>9.3} ms | {:>8.3} mJ |",
        method,
        nominal_cr(r) * 100.0,
        pct(r.accuracy.top1),
        pct(r.accuracy.top5),
        r.cost.latency_ms,
        r.cost.energy.system_mj(),
    )
}

pub fn table2_header() -> String {
    "| Method | CR   | Acc-top1 | Acc-top5 | Latency     | Energy      |\n\
     |--------|------|----------|----------|-------------|-------------|"
        .to_string()
}

/// Table 3 row: CR | Acc | System | ADC | Accumulation | Other.
pub fn table3_row(r: &PipelineReport) -> String {
    let e = &r.cost.energy;
    format!(
        "| {:>4.0}% | {:>7} | {:>8.3} mJ | {:>8.3} mJ | {:>8.3} uJ | {:>8.3} uJ |",
        nominal_cr(r) * 100.0,
        pct(r.accuracy.top1),
        e.system_mj(),
        e.adc_mj,
        e.accumulation_mj * 1e3,
        e.other_mj * 1e3,
    )
}

pub fn table3_header() -> String {
    "| CR    | Acc     | System      | ADC         | Accumulation | Other       |\n\
     |-------|---------|-------------|-------------|--------------|-------------|"
        .to_string()
}

/// Table 4 row: Model/CR | Method | Size | Bit | Utilization | Improvement.
pub fn table4_row(
    model_cr: &str,
    method: &str,
    size: (usize, usize),
    bits: u8,
    util: f64,
    improvement: Option<f64>,
) -> String {
    format!(
        "| {:<14} | {:<6} | {:>3}x{:<3} | {}bit | {:>7} | {:>8} |",
        model_cr,
        method,
        size.0,
        size.1,
        bits,
        pct(util),
        improvement.map_or("-".to_string(), |i| format!("+{:.2}", i * 100.0)),
    )
}

pub fn table4_header() -> String {
    "| Model/CR       | Method | Size    | Bit  | Utilization | Improvement |\n\
     |----------------|--------|---------|------|-------------|-------------|"
        .to_string()
}

/// Figure 8 series row: CR vs accuracy per model.
pub fn fig8_row(model: &str, cr: f64, acc: f64) -> String {
    format!("| {:<9} | {:>4.0}% | {:>7} |", model, cr * 100.0, pct(acc))
}

pub fn fig8_header() -> String {
    "| Model     | CR   | Acc     |\n|-----------|------|---------|".to_string()
}

/// §1/§5 headline deltas between a baseline and ours.
pub fn headline(ours: &PipelineReport, base: &PipelineReport) -> String {
    let lat = 1.0 - ours.cost.latency_ms / base.cost.latency_ms;
    let pow = 1.0 - ours.cost.energy.system_mj() / base.cost.energy.system_mj();
    let adc = 1.0 - ours.cost.energy.adc_mj / base.cost.energy.adc_mj;
    format!(
        "accuracy {} (vs {}), latency -{:.0}%, power -{:.0}%, ADC energy -{:.0}%",
        pct(ours.accuracy.top1),
        pct(base.accuracy.top1),
        lat * 100.0,
        pow * 100.0,
        adc * 100.0
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.8463), "84.63%");
    }

    #[test]
    fn table4_row_shape() {
        let row = table4_row("ResNet50/80%", "OUR", (128, 128), 8, 0.8436, Some(0.4081));
        assert!(row.contains("84.36%"));
        assert!(row.contains("+40.81"));
        assert!(row.contains("128x128"));
    }
}
