//! Fisher-information threshold selection (paper §4.2, Algorithm 1).
//!
//! The FIM diagonal of the compressed model is compared against the fp32
//! reference; Algorithm 1 descends on the threshold `T` (parameterized as a
//! *quantile* of the strip-score distribution so the step size is scale-free)
//! to minimize `‖F(θ_c) − F(θ)‖²_F`. Because clustering is a step function
//! of `T`, the gradient is taken by central finite differences — the
//! smoothed analogue of the paper's `∂F/∂T`.
//!
//! The paper's §5 also describes the deployed variant: a short candidate
//! sweep ranked jointly by FIM distance (accuracy proxy) and an energy
//! proxy, picking a near-Pareto operating point. Both are implemented:
//! [`ThresholdSearch::gradient_descent`] and [`ThresholdSearch::sweep`].

use crate::clustering::{cluster_at_cr, Clustering};
use crate::config::{QuantConfig, ThresholdConfig};
use crate::dataset::CalibSet;
use crate::model::ModelInfo;
use crate::quant;
use crate::runtime::Runtime;
use crate::sensitivity::Sensitivity;
use crate::tensor::Tensor;
use crate::Result;

/// Squared Frobenius distance between two diagonal FIMs.
pub fn fim_distance(f: &[f32], f0: &[f32]) -> f64 {
    assert_eq!(f.len(), f0.len());
    f.iter()
        .zip(f0.iter())
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum()
}

/// Relative ADC-energy proxy of a clustering: hi strips cost 2^8 ADC levels,
/// lo strips 2^4 (per §2.2's exponential ADC scaling), normalized to all-hi.
pub fn energy_proxy(q_hi: usize, total: usize, hi_bits: u8, lo_bits: u8) -> f64 {
    let hi_cost = (1u64 << hi_bits) as f64;
    let lo_cost = (1u64 << lo_bits) as f64;
    let q = q_hi as f64;
    let p = (total - q_hi) as f64;
    (q * hi_cost + p * lo_cost) / (total as f64 * hi_cost)
}

/// One evaluated threshold candidate.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Quantile of the score distribution (fraction of strips in the LOW tier).
    pub quantile: f64,
    pub threshold: f64,
    pub fim_dist: f64,
    pub energy: f64,
    pub q_hi: usize,
}

/// Trace of a threshold search (for reports / EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub best: Candidate,
    pub history: Vec<Candidate>,
    pub evals: usize,
}

/// Drives the `gsq` executable to evaluate FIM diagonals of candidate
/// compressed models.
pub struct ThresholdSearch<'a> {
    pub runtime: &'a Runtime,
    pub model: &'a ModelInfo,
    pub calib: &'a CalibSet,
    pub sens: &'a Sensitivity,
    pub quant_cfg: QuantConfig,
    pub cfg: ThresholdConfig,
}

impl<'a> ThresholdSearch<'a> {
    /// FIM diagonal (conv params) of parameter vector `theta`.
    pub fn fim_diag(&self, theta: &[f32]) -> Result<Vec<f32>> {
        let exe = self
            .model
            .entry
            .executables
            .get("gsq")
            .ok_or_else(|| anyhow::anyhow!("model has no gsq executable"))?
            .clone();
        let theta_t = Tensor::from_vec(theta.to_vec());
        let batches = self.cfg.calib_batches.min(self.calib.num_batches()).max(1);
        let mut acc = vec![0.0f64; self.model.entry.num_conv_params];
        for b in 0..batches {
            let (x, y1h) = self.calib.get(b);
            let out = self.runtime.exec(&exe, &[theta_t.clone(), x, y1h])?;
            for (a, v) in acc.iter_mut().zip(out[0].data()) {
                *a += *v as f64;
            }
        }
        Ok(acc.iter().map(|&a| (a / batches as f64) as f32).collect())
    }

    /// Compress at quantile `q` (fraction of strips in the low tier) and
    /// return the candidate evaluation. Device-variation noise is disabled
    /// for the candidate model: the threshold search measures *systematic*
    /// quantization damage; stochastic conductance noise would jitter the
    /// FIM landscape and break the descent.
    fn eval_quantile(&self, q: f64, theta: &[f32], f0: &[f32]) -> Result<(Candidate, Clustering)> {
        let qc = q.clamp(0.0, 1.0);
        let clustering = cluster_at_cr(
            &self.sens.scores,
            qc,
            self.quant_cfg.hi.bits,
            self.quant_cfg.lo.bits,
        );
        let quant_cfg = crate::config::QuantConfig { device_sigma: 0.0, ..self.quant_cfg };
        let qm = quant::apply(self.model, theta, &clustering.bitmap, &quant_cfg);
        let f = self.fim_diag(&qm.theta)?;
        let cand = Candidate {
            quantile: qc,
            threshold: clustering.threshold,
            fim_dist: fim_distance(&f, f0),
            energy: energy_proxy(
                clustering.q_hi,
                self.sens.scores.len(),
                self.quant_cfg.hi.bits,
                self.quant_cfg.lo.bits,
            ),
            q_hi: clustering.q_hi,
        };
        Ok((cand, clustering))
    }

    /// Algorithm 1: gradient descent on the (quantile-space) threshold.
    ///
    /// Semantics per the paper: start from T0 = maximum compression and
    /// descend on `L = ‖F(θ_c) − F0‖²_F` until the difference falls below
    /// the tolerance ε — i.e. return the *most compressed* operating point
    /// whose Fisher information still matches the original. (Descending L
    /// all the way to its global minimum would trivially land at "no
    /// compression".) ε is interpreted relative to L(T0) since the paper
    /// leaves it unspecified.
    pub fn gradient_descent(&self, theta: &[f32]) -> Result<SearchResult> {
        let f0 = self.fim_diag(theta)?;
        let mut t = self.cfg.t0_quantile; // T0 = 1.0: maximum compression
        let mut history: Vec<Candidate> = Vec::new();
        let mut evals = 1usize;
        let h = self.cfg.fd_step;
        let mut l_ref: Option<f64> = None; // L(T0)

        for k in 0..self.cfg.max_iters {
            let (cand, _) = self.eval_quantile(t, theta, &f0)?;
            evals += 1;
            crate::debug!("alg1 iter={k} t={t:.3} fim={:.4e}", cand.fim_dist);
            history.push(cand.clone());
            let l0 = *l_ref.get_or_insert(cand.fim_dist.max(1e-30));
            // Converged: FIM difference within tolerance of the original.
            if cand.fim_dist <= self.cfg.tolerance * l0 {
                break;
            }
            // Central finite difference of L(t) — the smoothed ∂F/∂T.
            let (cp, _) = self.eval_quantile((t + h).min(1.0), theta, &f0)?;
            let (cm, _) = self.eval_quantile((t - h).max(0.0), theta, &f0)?;
            evals += 2;
            let g = (cp.fim_dist - cm.fim_dist) / ((cp.quantile - cm.quantile).max(1e-9));
            // Sign descent with a decaying quantile-space step; if the
            // gradient points outward at a boundary, step inward anyway
            // (the landscape is noisy at the extremes).
            let step = self.cfg.learning_rate * 0.9f64.powi(k as i32);
            let mut t_new = (t - step * g.signum()).clamp(0.0, 1.0);
            if (t_new - t).abs() < 1e-12 {
                t_new = (t - step).clamp(0.0, 1.0);
            }
            t = t_new;
        }
        // The answer is the last (most-compressed-within-tolerance) iterate.
        let best = history.last().cloned().expect("at least one candidate");
        Ok(SearchResult { best, history, evals })
    }

    /// §5 deployment variant: sweep candidate quantiles, rank jointly by
    /// FIM distance and energy proxy (`score = fim/fim_max + λ·energy`),
    /// return the near-Pareto argmin.
    pub fn sweep(&self, theta: &[f32], candidates: &[f64], lambda: f64) -> Result<SearchResult> {
        let f0 = self.fim_diag(theta)?;
        let mut history = Vec::new();
        for &q in candidates {
            let (cand, _) = self.eval_quantile(q, theta, &f0)?;
            crate::debug!("sweep q={q:.2} fim={:.4e} energy={:.3}", cand.fim_dist, cand.energy);
            history.push(cand);
        }
        let fmax = history
            .iter()
            .map(|c| c.fim_dist)
            .fold(f64::MIN_POSITIVE, f64::max);
        let best = history
            .iter()
            .min_by(|a, b| {
                let sa = a.fim_dist / fmax + lambda * a.energy;
                let sb = b.fim_dist / fmax + lambda * b.energy;
                sa.total_cmp(&sb)
            })
            .expect("non-empty candidate list")
            .clone();
        Ok(SearchResult { best, evals: history.len() + 1, history })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fim_distance_is_squared_frobenius() {
        assert_eq!(fim_distance(&[1.0, 2.0], &[0.0, 0.0]), 5.0);
        assert_eq!(fim_distance(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn energy_proxy_bounds() {
        // all-hi = 1.0
        assert!((energy_proxy(10, 10, 8, 4) - 1.0).abs() < 1e-12);
        // all-lo = 2^4/2^8 = 1/16
        assert!((energy_proxy(0, 10, 8, 4) - 1.0 / 16.0).abs() < 1e-12);
        let mid = energy_proxy(5, 10, 8, 4);
        assert!(mid > 1.0 / 16.0 && mid < 1.0);
    }
}
