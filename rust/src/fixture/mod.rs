//! Synthetic, in-memory model + dataset fixtures.
//!
//! Everything the hermetic (artifact-free) test suite, the simulator
//! example and the sim bench need: a `ModelEntry` whose parameter layout
//! exactly mirrors `python/compile/model.py::param_specs`, an He-initialized
//! flat checkpoint, and random CIFAR-shaped test/calibration splits. No
//! file IO, no AOT artifacts, fully deterministic per seed.

use std::collections::HashMap;

use crate::dataset::{CalibSet, TestSet};
use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry, ModelInfo};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Classes of the synthetic CIFAR stand-in.
pub const NUM_CLASSES: usize = 10;

struct LayoutBuilder {
    layers: Vec<LayerEntry>,
    off: usize,
    conv_off: usize,
}

impl LayoutBuilder {
    fn add(&mut self, name: String, shape: Vec<usize>, kind: &str) {
        let size: usize = shape.iter().product();
        let convflat = (kind == "conv").then_some(self.conv_off);
        self.layers.push(LayerEntry {
            name,
            shape,
            kind: kind.to_string(),
            theta_offset: self.off,
            convflat_offset: convflat,
        });
        self.off += size;
        if kind == "conv" {
            self.conv_off += size;
        }
    }
}

/// Build a strip-conv ResNet `ModelEntry` with the `model.py` layout:
/// stage widths `(width, 2·width, 4·width)`, `blocks[s]` residual blocks
/// per stage, GroupNorm parameters interleaved exactly as the manifest
/// exporter writes them.
pub fn resnet_entry(name: &str, width: usize, blocks: &[usize; 3], batch: BatchSizes) -> ModelEntry {
    let widths = [width, 2 * width, 4 * width];
    let mut b = LayoutBuilder { layers: Vec::new(), off: 0, conv_off: 0 };

    b.add("stem.conv".into(), vec![3, 3, 3, widths[0]], "conv");
    let mut c_in = widths[0];
    for (s, (&nblocks, &c_out)) in blocks.iter().zip(widths.iter()).enumerate() {
        for blk in 0..nblocks {
            let pfx = format!("s{s}.b{blk}");
            b.add(format!("{pfx}.gn1.gamma"), vec![c_in], "gn");
            b.add(format!("{pfx}.gn1.beta"), vec![c_in], "gn");
            b.add(format!("{pfx}.conv1"), vec![3, 3, c_in, c_out], "conv");
            b.add(format!("{pfx}.gn2.gamma"), vec![c_out], "gn");
            b.add(format!("{pfx}.gn2.beta"), vec![c_out], "gn");
            b.add(format!("{pfx}.conv2"), vec![3, 3, c_out, c_out], "conv");
            if c_in != c_out {
                b.add(format!("{pfx}.shortcut"), vec![1, 1, c_in, c_out], "conv");
            }
            c_in = c_out;
        }
    }
    b.add("head.gn.gamma".into(), vec![c_in], "gn");
    b.add("head.gn.beta".into(), vec![c_in], "gn");
    b.add("head.dense.w".into(), vec![c_in, NUM_CLASSES], "dense_w");
    b.add("head.dense.b".into(), vec![NUM_CLASSES], "dense_b");

    let num_params = b.off;
    let num_conv_params = b.conv_off;
    ModelEntry {
        name: name.to_string(),
        num_params,
        num_conv_params,
        fp32_test_acc: 1.0 / NUM_CLASSES as f64, // untrained: chance level
        params: BinEntry {
            file: "<synthetic>".into(),
            shape: vec![num_params],
            dtype: "f32".into(),
        },
        layers: b.layers,
        executables: HashMap::new(),
        batch,
    }
}

/// He-init conv/dense weights, unit gamma / zero beta — `model.py::init_params`.
pub fn he_init(entry: &ModelEntry, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut theta = vec![0.0f32; entry.num_params];
    for l in &entry.layers {
        let size: usize = l.shape.iter().product();
        let dst = &mut theta[l.theta_offset..l.theta_offset + size];
        match l.kind.as_str() {
            "conv" => {
                let fan_in = (l.shape[0] * l.shape[1] * l.shape[2]) as f64;
                let std = (2.0 / fan_in).sqrt() as f32;
                for v in dst.iter_mut() {
                    *v = rng.normal() * std;
                }
            }
            "dense_w" => {
                let std = (1.0 / l.shape[0] as f64).sqrt() as f32;
                for v in dst.iter_mut() {
                    *v = rng.normal() * std;
                }
            }
            _ => {
                if l.name.ends_with("gamma") {
                    dst.fill(1.0);
                }
            }
        }
    }
    theta
}

/// Random test split: `n` images `[n, 32, 32, 3]` + labels.
pub fn synthetic_test_set(n: usize, seed: u64) -> TestSet {
    let mut rng = Rng::seed_from_u64(seed);
    let x = Tensor::new(
        vec![n, 32, 32, 3],
        (0..n * 32 * 32 * 3).map(|_| rng.normal() * 0.5).collect(),
    );
    let y = (0..n).map(|_| rng.below(NUM_CLASSES)).collect();
    TestSet { x, y }
}

/// Random calibration split with one-hot labels.
pub fn synthetic_calib_set(n: usize, batch: usize, seed: u64) -> CalibSet {
    let mut rng = Rng::seed_from_u64(seed);
    let x = Tensor::new(
        vec![n, 32, 32, 3],
        (0..n * 32 * 32 * 3).map(|_| rng.normal() * 0.5).collect(),
    );
    let mut y1h = vec![0.0f32; n * NUM_CLASSES];
    for i in 0..n {
        y1h[i * NUM_CLASSES + rng.below(NUM_CLASSES)] = 1.0;
    }
    CalibSet { x, y1h: Tensor::new(vec![n, NUM_CLASSES], y1h), batch }
}

/// A complete in-memory workload: model + checkpoint + data.
pub struct Fixture {
    pub model: ModelInfo,
    pub theta: Vec<f32>,
    pub test: TestSet,
    pub calib: CalibSet,
}

/// The hermetic test workload: a width-8 / one-block-per-stage strip-conv
/// ResNet (the `resnet8` layout at quarter width, so debug-mode bit-serial
/// simulation stays fast), 16 test images in eval/serve batches of 4.
pub fn tiny(seed: u64) -> Fixture {
    let entry = resnet_entry(
        "simnet-tiny",
        8,
        &[1, 1, 1],
        BatchSizes { eval: 4, serve: 4, calib: 4 },
    );
    let model = ModelInfo::new(entry);
    let theta = he_init(&model.entry, seed);
    let test = synthetic_test_set(16, seed ^ 0xaaaa_5555);
    let calib = synthetic_calib_set(8, 4, seed ^ 0x5555_aaaa);
    Fixture { model, theta, test, calib }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_contiguous_and_conv_covered() {
        let e = resnet_entry("t", 8, &[1, 1, 1], BatchSizes { eval: 4, serve: 4, calib: 4 });
        let mut off = 0usize;
        let mut conv = 0usize;
        for l in &e.layers {
            assert_eq!(l.theta_offset, off, "layer {} misplaced", l.name);
            if l.kind == "conv" {
                assert_eq!(l.convflat_offset, Some(conv));
                conv += l.shape.iter().product::<usize>();
            } else {
                assert_eq!(l.convflat_offset, None);
            }
            off += l.shape.iter().product::<usize>();
        }
        assert_eq!(off, e.num_params);
        assert_eq!(conv, e.num_conv_params);

        // strips cover exactly the conv params (the manifest contract,
        // asserted hermetically)
        let info = ModelInfo::new(e);
        let strip_params: usize = info.strips().iter().map(|s| info.layer(s.layer).d).sum();
        assert_eq!(strip_params, info.entry.num_conv_params);
    }

    #[test]
    fn he_init_is_deterministic_and_scaled() {
        let e = resnet_entry("t", 8, &[1, 1, 1], BatchSizes { eval: 4, serve: 4, calib: 4 });
        let a = he_init(&e, 3);
        let b = he_init(&e, 3);
        assert_eq!(a, b);
        let c = he_init(&e, 4);
        assert_ne!(a, c);
        // gammas are exactly 1
        let gn = e.layers.iter().find(|l| l.name.ends_with("gn1.gamma")).unwrap();
        assert!(a[gn.theta_offset..gn.theta_offset + gn.shape[0]].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn tiny_fixture_shapes_cohere() {
        let fx = tiny(1);
        assert_eq!(fx.theta.len(), fx.model.entry.num_params);
        assert_eq!(fx.test.x.shape(), &[16, 32, 32, 3]);
        assert_eq!(fx.test.num_batches(fx.model.entry.batch.eval), 4);
        assert_eq!(fx.calib.num_batches(), 2);
        assert!(fx.model.num_strips() > 0);
    }
}
