//! Client side of the serving protocol: a blocking request/reply
//! [`ServeClient`] over one connection, and [`bench_client`], the
//! multi-connection load generator used by the CLI `bench-client`
//! subcommand, the loopback tests, and CI's serve-smoke step.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::proto::Frame;
use crate::Result;

/// Blocking request/reply client over one TCP connection.
pub struct ServeClient {
    stream: TcpStream,
    next_id: u64,
}

/// A classify answer as seen by a client: every server reply is typed,
/// including the load-shedding and failure paths.
#[derive(Clone, Debug)]
pub enum ClientReply {
    Ok { id: u64, class: usize, latency_us: u64, logits: Vec<f32> },
    /// Admission control turned the request away; `queue_depth` requests
    /// were already waiting. Back off and retry.
    Rejected { id: u64, queue_depth: u32 },
    /// The server answered a typed error frame (bad request, engine
    /// failure, or reply timeout).
    Error { id: u64, message: String },
}

impl ServeClient {
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let stream =
            TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(ServeClient { stream, next_id: 1 })
    }

    /// Classify one image, blocking for the server's reply.
    pub fn classify(&mut self, image: Vec<f32>) -> Result<ClientReply> {
        let id = self.next_id;
        self.next_id += 1;
        Frame::ClassifyReq { id, image }.write_to(&mut self.stream)?;
        match Frame::read_from(&mut self.stream) {
            Ok(Frame::ClassifyOk { id, class, latency_us, logits }) => {
                Ok(ClientReply::Ok { id, class: class as usize, latency_us, logits })
            }
            Ok(Frame::Rejected { id, queue_depth }) => {
                Ok(ClientReply::Rejected { id, queue_depth })
            }
            Ok(Frame::Error { id, message }) => Ok(ClientReply::Error { id, message }),
            Ok(other) => anyhow::bail!("unexpected reply frame: {}", other.kind_name()),
            Err(e) => anyhow::bail!("reading reply: {e}"),
        }
    }

    /// Fetch the server's plain-text stats snapshot.
    pub fn stats(&mut self) -> Result<String> {
        Frame::StatsReq.write_to(&mut self.stream)?;
        match Frame::read_from(&mut self.stream) {
            Ok(Frame::Stats { text }) => Ok(text),
            Ok(other) => anyhow::bail!("unexpected reply frame: {}", other.kind_name()),
            Err(e) => anyhow::bail!("reading stats: {e}"),
        }
    }

    /// Fetch the server's machine-readable JSON stats snapshot (the
    /// `StatsJsonReq` frame): engine counters, rejected breakdown, latency
    /// histogram, crossbar walk profile, server + batcher counters.
    pub fn stats_json(&mut self) -> Result<String> {
        Frame::StatsJsonReq.write_to(&mut self.stream)?;
        match Frame::read_from(&mut self.stream) {
            Ok(Frame::StatsJson { json }) => Ok(json),
            Ok(other) => anyhow::bail!("unexpected reply frame: {}", other.kind_name()),
            Err(e) => anyhow::bail!("reading stats: {e}"),
        }
    }
}

/// Per-connection latency digest — exact percentiles over that one
/// connection's Ok replies. A wide p99 spread across connections is the
/// classic head-of-line-blocking signature that an aggregate percentile
/// hides.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConnLatency {
    pub p50_us: u64,
    pub p99_us: u64,
}

/// Aggregate outcome of a [`bench_client`] run. Latency percentiles are
/// exact (computed from every Ok reply's client-side round-trip time, not
/// bucketed).
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub requests: usize,
    pub ok: usize,
    pub rejected: usize,
    /// Error frames plus protocol-level failures — the smoke gate asserts
    /// this is zero.
    pub failed: usize,
    pub elapsed: Duration,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Per-connection latency digests, in connection order.
    pub per_conn: Vec<ConnLatency>,
    /// Largest `queue_depth` reported by any `Rejected` frame — how deep
    /// the admission queue got while this run was shedding.
    pub max_queue_depth: u32,
}

impl BenchReport {
    /// Completed-Ok requests per wall-clock second.
    pub fn req_per_s(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.ok as f64 / secs
        }
    }

    /// Summary (the CLI prints this; CI greps ` failed=0 ` on the first
    /// line). The second line breaks the latency down per connection and
    /// reports the deepest admission queue any rejection observed.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} ok={} rejected={} failed={} elapsed={:.3}s req_per_s={:.1} \
             p50_us={} p99_us={}",
            self.requests,
            self.ok,
            self.rejected,
            self.failed,
            self.elapsed.as_secs_f64(),
            self.req_per_s(),
            self.p50_us,
            self.p99_us,
        );
        let join = |f: fn(&ConnLatency) -> u64| {
            self.per_conn.iter().map(|c| f(c).to_string()).collect::<Vec<_>>().join(",")
        };
        s.push_str(&format!(
            "\nconns={} conn_p50_us=[{}] conn_p99_us=[{}] max_queue_depth={}",
            self.per_conn.len(),
            join(|c| c.p50_us),
            join(|c| c.p99_us),
            self.max_queue_depth,
        ));
        s
    }
}

/// Exact percentile by rank over a sorted sample (ceil-rank convention,
/// matching the histogram side's definition).
fn percentile(sorted_us: &[u64], q: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = ((q * sorted_us.len() as f64).ceil() as usize).clamp(1, sorted_us.len());
    sorted_us[rank - 1]
}

/// Drive `requests` classify calls against `addr` from `conns` concurrent
/// connections, round-robining over `images`. Every reply is counted; an
/// unusable connection fails the run (the smoke gate wants hard failures,
/// not silent undercounting).
pub fn bench_client(
    addr: &str,
    conns: usize,
    requests: usize,
    images: &[Vec<f32>],
) -> Result<BenchReport> {
    anyhow::ensure!(!images.is_empty(), "bench_client needs at least one image");
    let conns = conns.max(1).min(requests.max(1));
    let t0 = Instant::now();
    let mut report = BenchReport { requests, ..BenchReport::default() };
    let mut latencies: Vec<u64> = Vec::with_capacity(requests);
    let results = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(conns);
        for c in 0..conns {
            // Split `requests` across connections, remainder to the first.
            let n = requests / conns + usize::from(c < requests % conns);
            handles.push(s.spawn(move || -> Result<(usize, usize, usize, Vec<u64>, u32)> {
                let mut client = ServeClient::connect(addr)?;
                let (mut ok, mut rejected, mut failed) = (0usize, 0usize, 0usize);
                let mut max_qd = 0u32;
                let mut lats = Vec::with_capacity(n);
                for i in 0..n {
                    let image = images[(c + i * conns) % images.len()].clone();
                    let t = Instant::now();
                    match client.classify(image)? {
                        ClientReply::Ok { .. } => {
                            ok += 1;
                            lats.push(t.elapsed().as_micros() as u64);
                        }
                        ClientReply::Rejected { queue_depth, .. } => {
                            rejected += 1;
                            max_qd = max_qd.max(queue_depth);
                        }
                        ClientReply::Error { .. } => failed += 1,
                    }
                }
                Ok((ok, rejected, failed, lats, max_qd))
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("bench connection thread panicked"))
            .collect::<Vec<_>>()
    });
    for r in results {
        let (ok, rejected, failed, mut lats, max_qd) = r?;
        report.ok += ok;
        report.rejected += rejected;
        report.failed += failed;
        report.max_queue_depth = report.max_queue_depth.max(max_qd);
        lats.sort_unstable();
        report
            .per_conn
            .push(ConnLatency { p50_us: percentile(&lats, 0.50), p99_us: percentile(&lats, 0.99) });
        latencies.extend(lats);
    }
    report.elapsed = t0.elapsed();
    latencies.sort_unstable();
    report.p50_us = percentile(&latencies, 0.50);
    report.p99_us = percentile(&latencies, 0.99);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_uses_ceil_rank() {
        let v = [10u64, 20, 30, 40, 50];
        assert_eq!(percentile(&v, 0.50), 30); // rank ceil(2.5)=3
        assert_eq!(percentile(&v, 0.99), 50);
        assert_eq!(percentile(&v, 0.0), 10); // clamped to rank 1
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn report_summary_and_rate() {
        let r = BenchReport {
            requests: 4,
            ok: 2,
            rejected: 1,
            failed: 1,
            elapsed: Duration::from_secs(2),
            p50_us: 5,
            p99_us: 9,
            per_conn: vec![
                ConnLatency { p50_us: 4, p99_us: 8 },
                ConnLatency { p50_us: 6, p99_us: 9 },
            ],
            max_queue_depth: 17,
        };
        assert!((r.req_per_s() - 1.0).abs() < 1e-12);
        let s = r.summary();
        assert!(s.contains(" failed=1 "), "{s}");
        assert!(s.contains("p99_us=9"), "{s}");
        assert!(s.contains("conns=2"), "{s}");
        assert!(s.contains("conn_p50_us=[4,6]"), "{s}");
        assert!(s.contains("conn_p99_us=[8,9]"), "{s}");
        assert!(s.contains("max_queue_depth=17"), "{s}");
        assert_eq!(BenchReport::default().req_per_s(), 0.0);
    }
}
