//! Client side of the serving protocol: a blocking request/reply
//! [`ServeClient`] over one connection, and [`bench_client`], the
//! multi-connection load generator used by the CLI `bench-client`
//! subcommand, the loopback tests, and CI's serve-smoke step.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::proto::Frame;
use crate::Result;

/// Blocking request/reply client over one TCP connection.
pub struct ServeClient {
    stream: TcpStream,
    next_id: u64,
}

/// A classify answer as seen by a client: every server reply is typed,
/// including the load-shedding, degraded, and failure paths.
#[derive(Clone, Debug)]
pub enum ClientReply {
    Ok { id: u64, class: usize, latency_us: u64, logits: Vec<f32> },
    /// Admission control turned the request away; `queue_depth` requests
    /// were already waiting. Back off for `retry_after_ms` and retry.
    Rejected { id: u64, queue_depth: u32, retry_after_ms: u32 },
    /// The request was admitted but not answered with logits — its reply
    /// deadline (`deadline_ms`, 0 when not deadline-related) expired, or
    /// its worker panicked mid-batch and is respawning. Retryable after
    /// the hinted backoff.
    Degraded { id: u64, reason: String, retry_after_ms: u32, deadline_ms: u32 },
    /// The server answered a typed error frame (bad request or engine
    /// failure). Terminal: no retry semantics.
    Error { id: u64, message: String },
}

impl ServeClient {
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let stream =
            TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(ServeClient { stream, next_id: 1 })
    }

    /// Classify one image, blocking for the server's reply.
    pub fn classify(&mut self, image: Vec<f32>) -> Result<ClientReply> {
        let id = self.next_id;
        self.next_id += 1;
        Frame::ClassifyReq { id, image }.write_to(&mut self.stream)?;
        match Frame::read_from(&mut self.stream) {
            Ok(Frame::ClassifyOk { id, class, latency_us, logits }) => {
                Ok(ClientReply::Ok { id, class: class as usize, latency_us, logits })
            }
            Ok(Frame::Rejected { id, queue_depth, retry_after_ms }) => {
                Ok(ClientReply::Rejected { id, queue_depth, retry_after_ms })
            }
            Ok(Frame::Degraded { id, reason, retry_after_ms, deadline_ms }) => {
                Ok(ClientReply::Degraded { id, reason, retry_after_ms, deadline_ms })
            }
            Ok(Frame::Error { id, message }) => Ok(ClientReply::Error { id, message }),
            Ok(other) => anyhow::bail!("unexpected reply frame: {}", other.kind_name()),
            Err(e) => anyhow::bail!("reading reply: {e}"),
        }
    }

    /// Fetch the server's plain-text stats snapshot.
    pub fn stats(&mut self) -> Result<String> {
        Frame::StatsReq.write_to(&mut self.stream)?;
        match Frame::read_from(&mut self.stream) {
            Ok(Frame::Stats { text }) => Ok(text),
            Ok(other) => anyhow::bail!("unexpected reply frame: {}", other.kind_name()),
            Err(e) => anyhow::bail!("reading stats: {e}"),
        }
    }

    /// Fetch the server's machine-readable JSON stats snapshot (the
    /// `StatsJsonReq` frame): engine counters, rejected breakdown, latency
    /// histogram, crossbar walk profile, server + batcher counters.
    pub fn stats_json(&mut self) -> Result<String> {
        Frame::StatsJsonReq.write_to(&mut self.stream)?;
        match Frame::read_from(&mut self.stream) {
            Ok(Frame::StatsJson { json }) => Ok(json),
            Ok(other) => anyhow::bail!("unexpected reply frame: {}", other.kind_name()),
            Err(e) => anyhow::bail!("reading stats: {e}"),
        }
    }
}

/// Per-connection latency digest — exact percentiles over that one
/// connection's Ok replies. A wide p99 spread across connections is the
/// classic head-of-line-blocking signature that an aggregate percentile
/// hides.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConnLatency {
    pub p50_us: u64,
    pub p99_us: u64,
}

/// Aggregate outcome of a [`bench_client`] run. Latency percentiles are
/// exact (computed from every Ok reply's client-side round-trip time, not
/// bucketed).
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub requests: usize,
    pub ok: usize,
    /// Requests whose *final* attempt was turned away at admission.
    pub rejected: usize,
    /// Requests whose *final* attempt got a typed `Degraded` reply
    /// (missed deadline or worker panic).
    pub degraded: usize,
    /// Error frames plus protocol-level failures — the smoke gate asserts
    /// this is zero.
    pub failed: usize,
    /// Extra attempts made beyond each request's first (`Rejected` and
    /// `Degraded` replies retried after their hinted backoff).
    pub retries: usize,
    pub elapsed: Duration,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Per-connection latency digests, in connection order.
    pub per_conn: Vec<ConnLatency>,
    /// Largest `queue_depth` reported by any `Rejected` frame — how deep
    /// the admission queue got while this run was shedding.
    pub max_queue_depth: u32,
}

impl BenchReport {
    /// Completed-Ok requests per wall-clock second.
    pub fn req_per_s(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.ok as f64 / secs
        }
    }

    /// Summary (the CLI prints this; CI greps ` failed=0 ` on the first
    /// line). The second line breaks the latency down per connection and
    /// reports the deepest admission queue any rejection observed.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} ok={} rejected={} failed={} elapsed={:.3}s req_per_s={:.1} \
             p50_us={} p99_us={}",
            self.requests,
            self.ok,
            self.rejected,
            self.failed,
            self.elapsed.as_secs_f64(),
            self.req_per_s(),
            self.p50_us,
            self.p99_us,
        );
        let join = |f: fn(&ConnLatency) -> u64| {
            self.per_conn.iter().map(|c| f(c).to_string()).collect::<Vec<_>>().join(",")
        };
        s.push_str(&format!(
            "\nconns={} conn_p50_us=[{}] conn_p99_us=[{}] max_queue_depth={} \
             degraded={} retries={}",
            self.per_conn.len(),
            join(|c| c.p50_us),
            join(|c| c.p99_us),
            self.max_queue_depth,
            self.degraded,
            self.retries,
        ));
        s
    }
}

/// Exact percentile by rank over a sorted sample (ceil-rank convention,
/// matching the histogram side's definition).
fn percentile(sorted_us: &[u64], q: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = ((q * sorted_us.len() as f64).ceil() as usize).clamp(1, sorted_us.len());
    sorted_us[rank - 1]
}

/// Hard ceiling on one backoff sleep so a deep retry ladder can never
/// stall a bench run for seconds per request.
const MAX_BACKOFF_MS: u64 = 250;

/// Capped exponential backoff for retry attempt `attempt` (1-based),
/// seeded by the server's `retry_after_ms` hint, plus deterministic
/// LCG jitter (up to +50%) so retrying connections don't re-collide in
/// lockstep. No external RNG: `jitter_state` is a per-connection LCG.
fn backoff_ms(hint_ms: u32, attempt: u32, jitter_state: &mut u64) -> u64 {
    let base = (hint_ms.max(1) as u64) << (attempt - 1).min(8);
    let base = base.min(MAX_BACKOFF_MS);
    *jitter_state =
        jitter_state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    let jitter = (*jitter_state >> 33) % (base / 2 + 1);
    (base + jitter).min(MAX_BACKOFF_MS)
}

/// One connection's tallies, accumulated by the [`bench_client`] fan-in.
#[derive(Default)]
struct ConnTally {
    ok: usize,
    rejected: usize,
    degraded: usize,
    failed: usize,
    retries: usize,
    lats: Vec<u64>,
    max_qd: u32,
}

/// Drive `requests` classify calls against `addr` from `conns` concurrent
/// connections, round-robining over `images`. Every reply is counted; an
/// unusable connection fails the run (the smoke gate wants hard failures,
/// not silent undercounting). `Rejected` and `Degraded` replies are
/// retried up to `max_retries` times per request, honoring the server's
/// `retry_after_ms` hint with capped exponential backoff and jitter
/// (pass 0 to count every shed reply as terminal, the pre-retry
/// behavior); `Error` replies are terminal.
pub fn bench_client(
    addr: &str,
    conns: usize,
    requests: usize,
    images: &[Vec<f32>],
    max_retries: usize,
) -> Result<BenchReport> {
    anyhow::ensure!(!images.is_empty(), "bench_client needs at least one image");
    let conns = conns.max(1).min(requests.max(1));
    let t0 = Instant::now();
    let mut report = BenchReport { requests, ..BenchReport::default() };
    let mut latencies: Vec<u64> = Vec::with_capacity(requests);
    let results = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(conns);
        for c in 0..conns {
            // Split `requests` across connections, remainder to the first.
            let n = requests / conns + usize::from(c < requests % conns);
            handles.push(s.spawn(move || -> Result<ConnTally> {
                let mut client = ServeClient::connect(addr)?;
                let mut t = ConnTally { lats: Vec::with_capacity(n), ..ConnTally::default() };
                let mut jitter_state = 0x9e3779b97f4a7c15u64 ^ (c as u64);
                for i in 0..n {
                    let image = &images[(c + i * conns) % images.len()];
                    let mut attempt = 0u32;
                    loop {
                        let t_req = Instant::now();
                        let (terminal_shed, hint) = match client.classify(image.clone())? {
                            ClientReply::Ok { .. } => {
                                t.ok += 1;
                                t.lats.push(t_req.elapsed().as_micros() as u64);
                                break;
                            }
                            ClientReply::Rejected { queue_depth, retry_after_ms, .. } => {
                                t.max_qd = t.max_qd.max(queue_depth);
                                (&mut t.rejected, retry_after_ms)
                            }
                            ClientReply::Degraded { retry_after_ms, .. } => {
                                (&mut t.degraded, retry_after_ms)
                            }
                            ClientReply::Error { .. } => {
                                t.failed += 1;
                                break;
                            }
                        };
                        if attempt as usize >= max_retries {
                            *terminal_shed += 1;
                            break;
                        }
                        attempt += 1;
                        t.retries += 1;
                        std::thread::sleep(Duration::from_millis(backoff_ms(
                            hint,
                            attempt,
                            &mut jitter_state,
                        )));
                    }
                }
                Ok(t)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("bench connection thread panicked"))
            .collect::<Vec<_>>()
    });
    for r in results {
        let mut t = r?;
        report.ok += t.ok;
        report.rejected += t.rejected;
        report.degraded += t.degraded;
        report.failed += t.failed;
        report.retries += t.retries;
        report.max_queue_depth = report.max_queue_depth.max(t.max_qd);
        t.lats.sort_unstable();
        report.per_conn.push(ConnLatency {
            p50_us: percentile(&t.lats, 0.50),
            p99_us: percentile(&t.lats, 0.99),
        });
        latencies.extend(t.lats);
    }
    report.elapsed = t0.elapsed();
    latencies.sort_unstable();
    report.p50_us = percentile(&latencies, 0.50);
    report.p99_us = percentile(&latencies, 0.99);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_uses_ceil_rank() {
        let v = [10u64, 20, 30, 40, 50];
        assert_eq!(percentile(&v, 0.50), 30); // rank ceil(2.5)=3
        assert_eq!(percentile(&v, 0.99), 50);
        assert_eq!(percentile(&v, 0.0), 10); // clamped to rank 1
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn report_summary_and_rate() {
        let r = BenchReport {
            requests: 4,
            ok: 2,
            rejected: 1,
            degraded: 1,
            failed: 1,
            retries: 3,
            elapsed: Duration::from_secs(2),
            p50_us: 5,
            p99_us: 9,
            per_conn: vec![
                ConnLatency { p50_us: 4, p99_us: 8 },
                ConnLatency { p50_us: 6, p99_us: 9 },
            ],
            max_queue_depth: 17,
        };
        assert!((r.req_per_s() - 1.0).abs() < 1e-12);
        let s = r.summary();
        assert!(s.contains(" failed=1 "), "{s}");
        assert!(s.contains("p99_us=9"), "{s}");
        assert!(s.contains("conns=2"), "{s}");
        assert!(s.contains("conn_p50_us=[4,6]"), "{s}");
        assert!(s.contains("conn_p99_us=[8,9]"), "{s}");
        assert!(s.contains("max_queue_depth=17"), "{s}");
        // retry accounting rides on the second line, so the first line's
        // ` failed=0 `-style grep contract is untouched.
        let (first, second) = s.split_once('\n').unwrap();
        assert!(!first.contains("retries="), "{first}");
        assert!(second.contains("degraded=1"), "{second}");
        assert!(second.contains("retries=3"), "{second}");
        assert_eq!(BenchReport::default().req_per_s(), 0.0);
    }

    #[test]
    fn backoff_grows_is_capped_and_is_deterministic() {
        let mut st = 7u64;
        let first = backoff_ms(2, 1, &mut st);
        // attempt 1 from a 2 ms hint: base 2, jitter at most +1.
        assert!((2..=3).contains(&first), "{first}");
        // deep attempts saturate at the cap regardless of jitter
        for attempt in 8..12 {
            assert_eq!(backoff_ms(100, attempt, &mut st), MAX_BACKOFF_MS);
        }
        // a 0 hint still backs off at least 1 ms
        assert!(backoff_ms(0, 1, &mut st) >= 1);
        // same state + inputs -> same schedule
        let (mut a, mut b) = (42u64, 42u64);
        for attempt in 1..6 {
            assert_eq!(backoff_ms(5, attempt, &mut a), backoff_ms(5, attempt, &mut b));
        }
    }
}
