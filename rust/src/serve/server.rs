//! TCP serving front-end: accept loop + one thread per connection, every
//! request funneled through the shared micro-batching [`Batcher`].
//!
//! Each connection is strict request/reply: the connection thread reads one
//! frame, answers it, and only then reads the next — concurrency (and
//! batch fill) comes from the number of connections, which matches how the
//! load client drives traffic. Per request the thread:
//!
//! 1. validates the image size (a typed `Error` frame on mismatch, so one
//!    bad request can never poison a batch inside the engine),
//! 2. asks the batcher for admission — a full queue answers a `Rejected`
//!    frame with the observed queue depth and a `retry_after_ms` backoff
//!    hint scaled by that depth, *without blocking*,
//! 3. waits on the admitted ticket with [`ServeConfig::wait_timeout`] — a
//!    missed deadline or a worker panicking mid-batch becomes a typed
//!    `Degraded` frame (retryable, with its own backoff hint), an engine
//!    batch failure an `Error` frame — never a hung connection.
//!
//! A `StatsReq` frame answers a plain-text snapshot merging the server's
//! own counters, the batcher's admission/coalescing stats, and the engine
//! metrics — including the per-worker deploy-time crossbar-programming cost
//! (`program_ns_mean`/`program_ns_max`) and the p50/p95/p99 latency
//! percentiles. A `StatsJsonReq` frame answers the same snapshot as one
//! machine-readable JSON document (engine counters, rejected breakdown,
//! full latency histogram, crossbar walk profile, server + batcher
//! counters) for dashboards and scripts.
//!
//! When tracing is on ([`crate::trace`]), each request carries a
//! `server.handle` span with `batcher.submit` / `ticket.wait` /
//! `server.reply` children, completing the request-lifecycle picture
//! started by the batcher's `batch.coalesce` and the engine's
//! `engine.dispatch` → `worker.batch` → `backend.forward` spans.

use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::batcher::{Admission, BatchPolicy, Batcher, RejectReason};
use super::proto::{Frame, ProtoError, IMAGE_ELEMS};
use crate::coordinator::engine::{EngineHandle, WaitError};
use crate::Result;

/// Server configuration: the batching policy plus the per-request reply
/// deadline.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    pub policy: BatchPolicy,
    /// Upper bound on one request's end-to-end wait inside the server
    /// (batcher hand-off + engine execution).
    pub wait_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), wait_timeout: Duration::from_secs(30) }
    }
}

/// Server-level counters (all frames, all connections).
#[derive(Default)]
pub struct ServerStats {
    pub connections: AtomicU64,
    pub frames_in: AtomicU64,
    pub ok: AtomicU64,
    pub rejected: AtomicU64,
    /// Admitted requests answered with a typed `Degraded` frame (missed
    /// reply deadline or a worker panic mid-batch).
    pub degraded: AtomicU64,
    pub errors: AtomicU64,
}

/// Backoff hint for a `Rejected` frame: grows with the observed queue
/// depth (a fuller queue needs more time to drain) and stays bounded so a
/// deep queue never tells clients to stall for seconds. Deterministic —
/// jitter is the client's job.
fn retry_after_hint_ms(queue_depth: usize) -> u32 {
    1 + (queue_depth as u32).min(49)
}

/// A running server. Dropping it stops the accept loop (in-flight
/// connections drain on their own).
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
}

impl Server {
    /// Serve `engine` on an already-bound listener (bind with port 0 for an
    /// ephemeral port; [`Server::local_addr`] reports what was assigned).
    pub fn start(listener: TcpListener, engine: EngineHandle, cfg: ServeConfig) -> Result<Server> {
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let batcher = Batcher::start(engine.clone(), cfg.policy);
        let accept_thread = {
            let stop = stop.clone();
            let stats = stats.clone();
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            stats.connections.fetch_add(1, Ordering::Relaxed);
                            let batcher = batcher.clone();
                            let engine = engine.clone();
                            let stats = stats.clone();
                            let wait = cfg.wait_timeout;
                            std::thread::spawn(move || {
                                if let Err(e) = serve_conn(stream, &batcher, &engine, wait, &stats)
                                {
                                    crate::debug!("serve connection ended: {e:#}");
                                }
                            });
                        }
                        Err(e) => crate::warn_!("serve accept failed: {e}"),
                    }
                }
            })
        };
        Ok(Server { addr, stop, accept_thread: Some(accept_thread), stats })
    }

    /// The bound address (resolves port 0 to the assigned ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block the caller on the accept loop forever (CLI `serve --listen`).
    pub fn join(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Stop accepting new connections. Idempotent; called on drop.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the blocking accept with a throwaway connection so the loop
        // observes the stop flag. An unspecified bind address (0.0.0.0/[::])
        // is not connectable everywhere — dial loopback on the bound port
        // instead.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let woke = TcpStream::connect_timeout(&wake, Duration::from_millis(250)).is_ok();
        if let Some(t) = self.accept_thread.take() {
            if woke {
                let _ = t.join();
            }
            // If the wake-up dial failed, leave the accept thread parked on
            // the listener rather than blocking this thread forever; it
            // exits with the process and accepts nothing once stopped.
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One connection's request/reply loop. Returns `Ok` on a clean close and
/// `Err` after an unrecoverable protocol error (answered with a final
/// `Error` frame when the socket still accepts one).
fn serve_conn(
    mut stream: TcpStream,
    batcher: &Batcher,
    engine: &EngineHandle,
    wait_timeout: Duration,
    stats: &ServerStats,
) -> Result<()> {
    let _ = stream.set_nodelay(true);
    loop {
        let frame = match Frame::read_from(&mut stream) {
            Ok(f) => f,
            Err(ProtoError::Closed) => return Ok(()),
            Err(e) => {
                // Framing is unrecoverable after a malformed prefix: answer
                // what we can, then drop the connection.
                stats.errors.fetch_add(1, Ordering::Relaxed);
                engine.metrics.observe_rejected_decode();
                let _ = Frame::Error { id: 0, message: format!("protocol error: {e}") }
                    .write_to(&mut stream);
                anyhow::bail!("protocol error: {e}");
            }
        };
        stats.frames_in.fetch_add(1, Ordering::Relaxed);
        match frame {
            Frame::ClassifyReq { id, image } => {
                let mut span = crate::trace::span("server.handle");
                span.tag("id", || id.to_string());
                if image.len() != IMAGE_ELEMS {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    engine.metrics.observe_rejected_decode();
                    Frame::Error {
                        id,
                        message: format!(
                            "bad image size {} (want {IMAGE_ELEMS})",
                            image.len()
                        ),
                    }
                    .write_to(&mut stream)?;
                    continue;
                }
                let admission = {
                    let _s = crate::trace::span("batcher.submit");
                    batcher.submit(image)
                };
                match admission {
                    Admission::Rejected { queue_depth, reason } => {
                        stats.rejected.fetch_add(1, Ordering::Relaxed);
                        match reason {
                            RejectReason::QueueFull => {
                                engine.metrics.observe_rejected_queue_full()
                            }
                            RejectReason::Shutdown => engine.metrics.observe_rejected_shutdown(),
                        }
                        let _s = crate::trace::span("server.reply");
                        Frame::Rejected {
                            id,
                            queue_depth: queue_depth as u32,
                            retry_after_ms: retry_after_hint_ms(queue_depth),
                        }
                        .write_to(&mut stream)?;
                    }
                    Admission::Accepted(ticket) => {
                        let waited = {
                            let _s = crate::trace::span("ticket.wait");
                            ticket.wait_timeout(wait_timeout)
                        };
                        match waited {
                            Ok(resp) => {
                                stats.ok.fetch_add(1, Ordering::Relaxed);
                                let _s = crate::trace::span("server.reply");
                                Frame::ClassifyOk {
                                    id,
                                    class: resp.class as u16,
                                    latency_us: resp.latency_us,
                                    logits: resp.logits,
                                }
                                .write_to(&mut stream)?;
                            }
                            Err(WaitError::Timeout) => {
                                // The request was admitted but its reply
                                // deadline expired: a typed degraded reply
                                // with the deadline it missed, not a
                                // generic error — the caller may retry.
                                stats.degraded.fetch_add(1, Ordering::Relaxed);
                                engine.metrics.observe_rejected_deadline();
                                engine.metrics.observe_degraded();
                                let deadline_ms = wait_timeout.as_millis().min(u32::MAX as u128);
                                let _s = crate::trace::span("server.reply");
                                Frame::Degraded {
                                    id,
                                    reason: format!(
                                        "reply deadline of {deadline_ms} ms missed"
                                    ),
                                    retry_after_ms: retry_after_hint_ms(batcher.queue_depth()),
                                    deadline_ms: deadline_ms as u32,
                                }
                                .write_to(&mut stream)?;
                            }
                            Err(WaitError::Degraded { reason }) => {
                                // Worker panicked mid-batch; the engine is
                                // respawning it. Retryable.
                                stats.degraded.fetch_add(1, Ordering::Relaxed);
                                let _s = crate::trace::span("server.reply");
                                Frame::Degraded {
                                    id,
                                    reason,
                                    retry_after_ms: retry_after_hint_ms(batcher.queue_depth()),
                                    deadline_ms: 0,
                                }
                                .write_to(&mut stream)?;
                            }
                            Err(e) => {
                                stats.errors.fetch_add(1, Ordering::Relaxed);
                                let _s = crate::trace::span("server.reply");
                                Frame::Error { id, message: e.to_string() }
                                    .write_to(&mut stream)?;
                            }
                        }
                    }
                }
                drop(span);
                crate::trace::flush_thread();
            }
            Frame::StatsReq => {
                Frame::Stats { text: stats_text(stats, batcher, engine) }
                    .write_to(&mut stream)?;
            }
            Frame::StatsJsonReq => {
                Frame::StatsJson { json: stats_json(stats, batcher, engine) }
                    .write_to(&mut stream)?;
            }
            other => {
                // Server-to-client frames arriving at the server are a
                // client bug, not a stream corruption: answer and carry on.
                stats.errors.fetch_add(1, Ordering::Relaxed);
                Frame::Error {
                    id: 0,
                    message: format!("unexpected frame kind: {}", other.kind_name()),
                }
                .write_to(&mut stream)?;
            }
        }
    }
}

/// The plain-text stats payload: server frames, batcher admission, engine
/// execution, self-healing counters, deploy-time programming cost, latency
/// percentiles — one `key=value` line per layer.
fn stats_text(stats: &ServerStats, batcher: &Batcher, engine: &EngineHandle) -> String {
    use crate::coordinator::metrics::fmt_latency_us;
    let m = engine.metrics.snapshot();
    let b = &batcher.stats;
    format!(
        "server: connections={} frames_in={} ok={} rejected={} degraded={} errors={} queue_depth={}\n\
         batcher: accepted={} rejected={} batches={} mean_fill={:.2}\n\
         rejected: queue_full={} decode={} shutdown={} deadline={} total={}\n\
         engine: requests={} batches={} mean_batch_fill={:.2} failed_requests={}\n\
         health: probes={} canary_mismatches={} quarantined={} repairs={} swaps={} \
         reprograms={} respawns={} workers_down={} degraded={}\n\
         program: workers={} program_ns_mean={:.0} program_ns_max={}\n\
         scenario: {}\n\
         latency_us: mean_batch={:.1} max={} p50={} p95={} p99={}\n\
         walk: conv_calls={} strips={} phase_steps={} kernel_simd={} kernel_scalar={} \
         prefetch_staged={} scratch_high_water_bytes={}\n",
        stats.connections.load(Ordering::Relaxed),
        stats.frames_in.load(Ordering::Relaxed),
        stats.ok.load(Ordering::Relaxed),
        stats.rejected.load(Ordering::Relaxed),
        stats.degraded.load(Ordering::Relaxed),
        stats.errors.load(Ordering::Relaxed),
        batcher.queue_depth(),
        b.accepted.load(Ordering::Relaxed),
        b.rejected.load(Ordering::Relaxed),
        b.batches.load(Ordering::Relaxed),
        b.mean_fill(),
        m.rejected_queue_full,
        m.rejected_decode,
        m.rejected_shutdown,
        m.rejected_deadline,
        m.rejected_total(),
        m.requests,
        m.batches,
        m.mean_batch_fill,
        m.failed_requests,
        m.probes,
        m.canary_mismatches,
        m.quarantined,
        m.repairs,
        m.swaps,
        m.reprograms,
        m.respawns,
        m.workers_down,
        m.degraded,
        m.programmed_workers,
        m.program_ns_mean,
        m.program_ns_max,
        engine.metrics.scenario_desc(),
        m.mean_latency_us,
        m.max_latency_us,
        fmt_latency_us(m.p50_latency_us),
        fmt_latency_us(m.p95_latency_us),
        fmt_latency_us(m.p99_latency_us),
        m.walk.conv_calls,
        m.walk.strips_walked,
        m.walk.phase_steps,
        m.walk.kernel_simd,
        m.walk.kernel_scalar,
        m.walk.prefetch_staged,
        m.walk.scratch_high_water_bytes,
    )
}

/// The machine-readable stats payload: the engine's full
/// [`crate::coordinator::Metrics::stats_value`] snapshot (counters,
/// rejected breakdown, program cost, scenario, crossbar walk profile, raw
/// latency histogram) extended with the server's and batcher's own
/// counters. One compact JSON object, parseable with any JSON library.
fn stats_json(stats: &ServerStats, batcher: &Batcher, engine: &EngineHandle) -> String {
    use crate::util::json::{obj, Value};
    let n = |v: u64| Value::Num(v as f64);
    let server = obj(vec![
        ("connections", n(stats.connections.load(Ordering::Relaxed))),
        ("frames_in", n(stats.frames_in.load(Ordering::Relaxed))),
        ("ok", n(stats.ok.load(Ordering::Relaxed))),
        ("rejected", n(stats.rejected.load(Ordering::Relaxed))),
        ("degraded", n(stats.degraded.load(Ordering::Relaxed))),
        ("errors", n(stats.errors.load(Ordering::Relaxed))),
    ]);
    let b = &batcher.stats;
    let batcher_v = obj(vec![
        ("accepted", n(b.accepted.load(Ordering::Relaxed))),
        ("rejected", n(b.rejected.load(Ordering::Relaxed))),
        ("batches", n(b.batches.load(Ordering::Relaxed))),
        ("mean_fill", Value::Num(b.mean_fill())),
        ("queue_depth", n(batcher.queue_depth() as u64)),
    ]);
    let mut root = match engine.metrics.stats_value() {
        Value::Obj(m) => m,
        _ => Default::default(),
    };
    root.insert("server".to_string(), server);
    root.insert("batcher".to_string(), batcher_v);
    Value::Obj(root).to_json()
}
