//! Dynamic micro-batching front-end with admission control.
//!
//! Connection threads [`Batcher::submit`] individual images into a
//! **bounded** admission queue; a single coalescing thread drains it,
//! groups up to [`BatchPolicy::max_batch`] requests (or whatever arrived
//! before the [`BatchPolicy::flush_after`] deadline) and hands the group to
//! the engine through [`EngineHandle::submit_batch`], so the engine's
//! dispatcher sees the whole group back-to-back and executes it as full
//! batches.
//!
//! Admission control is the load-shedding half: `submit` **never blocks**.
//! When the queue is full it answers [`Admission::Rejected`] with the
//! current queue depth immediately — the TCP server turns that into a typed
//! `Rejected` wire frame, so an overloaded deployment degrades into fast,
//! explicit rejections instead of unbounded connection-thread pile-up.
//! Backpressure *inside* the pipeline is still blocking by design: the one
//! coalescing thread may block handing a group to a full engine queue,
//! which is exactly what makes the admission queue fill and shed.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{BatchError, EngineHandle, Pending, Response, WaitError};

/// Coalescing and admission knobs of the serving front-end.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Coalesce at most this many requests into one engine hand-off.
    pub max_batch: usize,
    /// Flush a partial group after this long (measured from its first
    /// request).
    pub flush_after: Duration,
    /// Bounded admission queue length; overflow is rejected, never waited
    /// on.
    pub queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, flush_after: Duration::from_millis(2), queue: 256 }
    }
}

/// Front-end counters (admission + coalescing), separate from the engine's
/// own [`crate::coordinator::Metrics`]: these describe what the *door* did,
/// the engine metrics describe what execution did.
#[derive(Default)]
pub struct BatcherStats {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
}

impl BatcherStats {
    /// Mean requests per engine hand-off (1.0 = no coalescing happened).
    pub fn mean_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// What the coalescing thread eventually gives a ticket holder.
enum Handoff {
    /// The request is inside the engine; wait on this.
    Handed(Pending),
    /// The engine refused the whole group (it stopped).
    Failed(String),
}

/// An admitted request's claim check. The reply crosses two stages — the
/// coalescing hand-off, then engine execution — and
/// [`Ticket::wait_timeout`] bounds the *sum*.
pub struct Ticket {
    rx: Receiver<Handoff>,
}

impl Ticket {
    /// Wait for the engine's reply, bounded end-to-end by `timeout`.
    pub fn wait_timeout(self, timeout: Duration) -> std::result::Result<Response, WaitError> {
        let deadline = Instant::now() + timeout;
        let pending = match self.rx.recv_timeout(timeout) {
            Ok(Handoff::Handed(p)) => p,
            Ok(Handoff::Failed(msg)) => return Err(WaitError::Failed(BatchError(msg))),
            Err(RecvTimeoutError::Timeout) => return Err(WaitError::Timeout),
            Err(RecvTimeoutError::Disconnected) => return Err(WaitError::Dropped),
        };
        pending.wait_timeout(deadline.saturating_duration_since(Instant::now()))
    }
}

/// Why an admission was refused — surfaced per reason in the engine
/// metrics ([`crate::coordinator::Metrics`]) so an operator can tell load
/// shedding from a draining deployment at a glance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded admission queue was full (load shedding).
    QueueFull,
    /// The coalescing thread is gone — the deployment is shutting down.
    Shutdown,
}

/// Admission verdict: a claim check, or an immediate, typed "no".
pub enum Admission {
    Accepted(Ticket),
    /// The request was turned away; `queue_depth` is how many requests
    /// were already waiting at that moment.
    Rejected { queue_depth: usize, reason: RejectReason },
}

struct Item {
    image: Vec<f32>,
    reply: SyncSender<Handoff>,
}

/// The micro-batching front-end over an [`EngineHandle`]. Cloneable and
/// thread-safe: every connection thread submits through its own clone, all
/// feeding the one coalescing thread.
#[derive(Clone)]
pub struct Batcher {
    tx: SyncSender<Item>,
    depth: Arc<AtomicUsize>,
    pub stats: Arc<BatcherStats>,
}

impl Batcher {
    /// Spawn the coalescing thread over `engine`. The thread exits when
    /// every `Batcher` clone is dropped (after flushing what was admitted).
    pub fn start(engine: EngineHandle, policy: BatchPolicy) -> Batcher {
        let max_batch = policy.max_batch.max(1);
        let (tx, rx) = sync_channel::<Item>(policy.queue.max(1));
        let depth = Arc::new(AtomicUsize::new(0));
        let stats = Arc::new(BatcherStats::default());
        {
            let depth = depth.clone();
            let stats = stats.clone();
            let flush_after = policy.flush_after;
            std::thread::spawn(move || {
                batch_loop(rx, engine, max_batch, flush_after, &depth, &stats)
            });
        }
        Batcher { tx, depth, stats }
    }

    /// Admit one request, without ever blocking. A full queue — or a
    /// coalescing thread that is gone — answers [`Admission::Rejected`]
    /// immediately.
    pub fn submit(&self, image: Vec<f32>) -> Admission {
        let (reply, rx) = sync_channel(1);
        // Count before sending: the coalescing thread decrements as it
        // pops, and every popped item must already be counted or the
        // counter could transiently wrap below zero.
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(Item { image, reply }) {
            Ok(()) => {
                self.stats.accepted.fetch_add(1, Ordering::Relaxed);
                Admission::Accepted(Ticket { rx })
            }
            Err(e) => {
                let reason = match e {
                    TrySendError::Full(_) => RejectReason::QueueFull,
                    TrySendError::Disconnected(_) => RejectReason::Shutdown,
                };
                self.depth.fetch_sub(1, Ordering::Relaxed);
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                Admission::Rejected { queue_depth: self.depth.load(Ordering::Relaxed), reason }
            }
        }
    }

    /// Requests currently waiting in the admission queue (approximate —
    /// the counters are relaxed).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

/// The coalescing loop: group, deadline-flush, hand off, repeat.
fn batch_loop(
    rx: Receiver<Item>,
    engine: EngineHandle,
    max_batch: usize,
    flush_after: Duration,
    depth: &AtomicUsize,
    stats: &BatcherStats,
) {
    loop {
        // Block for the first request of a group.
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => break, // every Batcher clone dropped, queue drained
        };
        depth.fetch_sub(1, Ordering::Relaxed);
        let mut span = crate::trace::span("batch.coalesce");
        let mut items = Vec::with_capacity(max_batch);
        items.push(first);
        let deadline = Instant::now() + flush_after;
        while items.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    items.push(item);
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_items.fetch_add(items.len() as u64, Ordering::Relaxed);
        span.tag("size", || items.len().to_string());

        let mut images = Vec::with_capacity(items.len());
        let mut replies = Vec::with_capacity(items.len());
        for item in items {
            images.push(item.image);
            replies.push(item.reply);
        }
        // This send may block on a full engine queue: that is the designed
        // in-pipeline backpressure, and it is what fills the admission
        // queue above so `submit` starts shedding.
        match engine.submit_batch(images) {
            Ok(pendings) => {
                for (pending, reply) in pendings.into_iter().zip(replies) {
                    let _ = reply.send(Handoff::Handed(pending));
                }
            }
            Err(e) => {
                let msg = format!("engine unavailable: {e}");
                for reply in replies {
                    let _ = reply.send(Handoff::Failed(msg.clone()));
                }
            }
        }
        drop(span);
        crate::trace::flush_thread();
    }
}
