//! Wire protocol of the serving front-end: length-prefixed binary frames.
//!
//! Every frame is `[payload_len: u32 LE][payload]`, and every payload opens
//! with `[version: u8][kind: u8]` followed by the kind's body. All integers
//! are little-endian; floats are IEEE-754 `f32` LE bit patterns, so logits
//! survive the wire **bit-exactly** (the loopback parity tests depend on
//! this). Strings are `u32` length + UTF-8 bytes.
//!
//! | kind | frame         | direction | body |
//! |------|---------------|-----------|------|
//! | 1    | `ClassifyReq` | c -> s    | `id:u64`, `n:u32`, `n × f32` pixels |
//! | 2    | `ClassifyOk`  | s -> c    | `id:u64`, `class:u16`, `latency_us:u64`, `k:u32`, `k × f32` logits |
//! | 3    | `StatsReq`    | c -> s    | (empty) |
//! | 4    | `Stats`       | s -> c    | `text:str` (plain-text metrics) |
//! | 5    | `Rejected`    | s -> c    | `id:u64`, `queue_depth:u32`, `retry_after_ms:u32` — admission control said no; retry after the hinted backoff |
//! | 6    | `Error`       | s -> c    | `id:u64`, `message:str` |
//! | 7    | `StatsJsonReq`| c -> s    | (empty) |
//! | 8    | `StatsJson`   | s -> c    | `json:str` — the complete machine-readable snapshot (counters, rejected-by-reason breakdown, health, latency histogram buckets, program cost, scenario, walk profile) |
//! | 9    | `Degraded`    | s -> c    | `id:u64`, `reason:str`, `retry_after_ms:u32`, `deadline_ms:u32` — the request was admitted but not answered with logits (worker panic mid-batch, or the reply deadline `deadline_ms` expired); safe to retry after the hint |
//!
//! `Rejected` and `Degraded` both mean "no logits, but the server is
//! healthy enough to say so": `Rejected` is refused *at admission*
//! (queue full, undecodable frame), `Degraded` is a request that was
//! *accepted* and then could not be answered normally. Both carry a
//! `retry_after_ms` backoff hint; `Error` remains the terminal
//! per-request failure with no retry semantics.
//!
//! Decoding is strict: an unknown version or kind, a truncated body, or
//! trailing bytes after the body are all typed [`ProtoError`]s — a server
//! answers one final `Error` frame and drops the connection rather than
//! resynchronizing on a corrupt stream. A clean close *between* frames is
//! [`ProtoError::Closed`], distinguishable from a mid-frame EOF (an
//! [`ProtoError::Io`]).

use std::io::{self, Read, Write};

/// Protocol version stamped into (and required of) every payload.
/// Version 2 added `retry_after_ms` to `Rejected` and the `Degraded`
/// frame (kind 9); v1 peers are refused with a `Version` error rather
/// than silently misparsing the widened `Rejected` body.
pub const PROTO_VERSION: u8 = 2;

/// Upper bound on a payload length; anything larger is rejected before
/// allocation so a corrupt or hostile length prefix cannot OOM the server.
pub const MAX_FRAME_LEN: u32 = 16 << 20;

/// Pixels per classify request: the 32x32x3 image contract shared with
/// [`crate::coordinator::EngineHandle::classify`].
pub const IMAGE_ELEMS: usize = 32 * 32 * 3;

/// One protocol frame (see the module table for the wire layout).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    ClassifyReq { id: u64, image: Vec<f32> },
    ClassifyOk { id: u64, class: u16, latency_us: u64, logits: Vec<f32> },
    StatsReq,
    Stats { text: String },
    Rejected { id: u64, queue_depth: u32, retry_after_ms: u32 },
    Error { id: u64, message: String },
    StatsJsonReq,
    StatsJson { json: String },
    Degraded { id: u64, reason: String, retry_after_ms: u32, deadline_ms: u32 },
}

/// Why a frame could not be read.
#[derive(Debug)]
pub enum ProtoError {
    /// The peer closed the connection cleanly at a frame boundary.
    Closed,
    /// Socket-level failure, including EOF in the middle of a frame.
    Io(io::Error),
    /// The payload's version byte does not match [`PROTO_VERSION`].
    Version { got: u8 },
    /// Unknown frame kind byte.
    Kind(u8),
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    TooLarge(u32),
    /// The payload did not parse as its declared kind.
    Malformed(&'static str),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Closed => write!(f, "connection closed"),
            ProtoError::Io(e) => write!(f, "io error: {e}"),
            ProtoError::Version { got } => {
                write!(f, "protocol version mismatch: got {got}, want {PROTO_VERSION}")
            }
            ProtoError::Kind(k) => write!(f, "unknown frame kind {k}"),
            ProtoError::TooLarge(n) => {
                write!(f, "frame length {n} exceeds the {MAX_FRAME_LEN}-byte cap")
            }
            ProtoError::Malformed(why) => write!(f, "malformed frame: {why}"),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<io::Error> for ProtoError {
    fn from(e: io::Error) -> Self {
        ProtoError::Io(e)
    }
}

const KIND_CLASSIFY_REQ: u8 = 1;
const KIND_CLASSIFY_OK: u8 = 2;
const KIND_STATS_REQ: u8 = 3;
const KIND_STATS: u8 = 4;
const KIND_REJECTED: u8 = 5;
const KIND_ERROR: u8 = 6;
const KIND_STATS_JSON_REQ: u8 = 7;
const KIND_STATS_JSON: u8 = 8;
const KIND_DEGRADED: u8 = 9;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    put_u32(buf, vs.len() as u32);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Strict little-endian cursor over a payload body.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.off + n > self.b.len() {
            return Err(ProtoError::Malformed("truncated body"));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16, ProtoError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, ProtoError> {
        let n = self.u32()? as usize;
        let bytes = n.checked_mul(4).ok_or(ProtoError::Malformed("vector too long"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn str(&mut self) -> Result<String, ProtoError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| ProtoError::Malformed("string not utf-8"))
    }

    fn done(&self) -> Result<(), ProtoError> {
        if self.off == self.b.len() {
            Ok(())
        } else {
            Err(ProtoError::Malformed("trailing bytes after body"))
        }
    }
}

impl Frame {
    /// Stable name of the frame kind (log lines, error messages).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::ClassifyReq { .. } => "ClassifyReq",
            Frame::ClassifyOk { .. } => "ClassifyOk",
            Frame::StatsReq => "StatsReq",
            Frame::Stats { .. } => "Stats",
            Frame::Rejected { .. } => "Rejected",
            Frame::Error { .. } => "Error",
            Frame::StatsJsonReq => "StatsJsonReq",
            Frame::StatsJson { .. } => "StatsJson",
            Frame::Degraded { .. } => "Degraded",
        }
    }

    /// The complete wire image (length prefix included) as one buffer, so a
    /// frame goes out in a single `write_all`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(32);
        put_u32(&mut p, 0); // length prefix, patched below
        p.push(PROTO_VERSION);
        match self {
            Frame::ClassifyReq { id, image } => {
                p.push(KIND_CLASSIFY_REQ);
                put_u64(&mut p, *id);
                put_f32s(&mut p, image);
            }
            Frame::ClassifyOk { id, class, latency_us, logits } => {
                p.push(KIND_CLASSIFY_OK);
                put_u64(&mut p, *id);
                p.extend_from_slice(&class.to_le_bytes());
                put_u64(&mut p, *latency_us);
                put_f32s(&mut p, logits);
            }
            Frame::StatsReq => p.push(KIND_STATS_REQ),
            Frame::Stats { text } => {
                p.push(KIND_STATS);
                put_str(&mut p, text);
            }
            Frame::Rejected { id, queue_depth, retry_after_ms } => {
                p.push(KIND_REJECTED);
                put_u64(&mut p, *id);
                put_u32(&mut p, *queue_depth);
                put_u32(&mut p, *retry_after_ms);
            }
            Frame::Error { id, message } => {
                p.push(KIND_ERROR);
                put_u64(&mut p, *id);
                put_str(&mut p, message);
            }
            Frame::StatsJsonReq => p.push(KIND_STATS_JSON_REQ),
            Frame::StatsJson { json } => {
                p.push(KIND_STATS_JSON);
                put_str(&mut p, json);
            }
            Frame::Degraded { id, reason, retry_after_ms, deadline_ms } => {
                p.push(KIND_DEGRADED);
                put_u64(&mut p, *id);
                put_str(&mut p, reason);
                put_u32(&mut p, *retry_after_ms);
                put_u32(&mut p, *deadline_ms);
            }
        }
        let len = (p.len() - 4) as u32;
        p[..4].copy_from_slice(&len.to_le_bytes());
        p
    }

    /// Serialize onto a writer (one `write_all` of [`Frame::to_bytes`]).
    pub fn write_to(&self, w: &mut impl Write) -> io::Result<()> {
        w.write_all(&self.to_bytes())
    }

    /// Read exactly one frame. A clean close before the first prefix byte
    /// is [`ProtoError::Closed`]; EOF anywhere later is an IO error.
    pub fn read_from(r: &mut impl Read) -> Result<Frame, ProtoError> {
        let len = match read_prefix(r)? {
            Some(len) => len,
            None => return Err(ProtoError::Closed),
        };
        if len > MAX_FRAME_LEN {
            return Err(ProtoError::TooLarge(len));
        }
        if len < 2 {
            return Err(ProtoError::Malformed("payload shorter than its header"));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        if payload[0] != PROTO_VERSION {
            return Err(ProtoError::Version { got: payload[0] });
        }
        let kind = payload[1];
        let mut cur = Cur { b: &payload[2..], off: 0 };
        let frame = match kind {
            KIND_CLASSIFY_REQ => {
                let id = cur.u64()?;
                let image = cur.f32s()?;
                Frame::ClassifyReq { id, image }
            }
            KIND_CLASSIFY_OK => {
                let id = cur.u64()?;
                let class = cur.u16()?;
                let latency_us = cur.u64()?;
                let logits = cur.f32s()?;
                Frame::ClassifyOk { id, class, latency_us, logits }
            }
            KIND_STATS_REQ => Frame::StatsReq,
            KIND_STATS => Frame::Stats { text: cur.str()? },
            KIND_REJECTED => {
                let id = cur.u64()?;
                let queue_depth = cur.u32()?;
                let retry_after_ms = cur.u32()?;
                Frame::Rejected { id, queue_depth, retry_after_ms }
            }
            KIND_ERROR => {
                let id = cur.u64()?;
                let message = cur.str()?;
                Frame::Error { id, message }
            }
            KIND_STATS_JSON_REQ => Frame::StatsJsonReq,
            KIND_STATS_JSON => Frame::StatsJson { json: cur.str()? },
            KIND_DEGRADED => {
                let id = cur.u64()?;
                let reason = cur.str()?;
                let retry_after_ms = cur.u32()?;
                let deadline_ms = cur.u32()?;
                Frame::Degraded { id, reason, retry_after_ms, deadline_ms }
            }
            other => return Err(ProtoError::Kind(other)),
        };
        cur.done()?;
        Ok(frame)
    }
}

/// Read the 4-byte length prefix; `None` on clean EOF before any byte.
fn read_prefix(r: &mut impl Read) -> Result<Option<u32>, ProtoError> {
    let mut buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(ProtoError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside a frame length prefix",
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
    Ok(Some(u32::from_le_bytes(buf)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let bytes = f.to_bytes();
        let got = Frame::read_from(&mut &bytes[..]).unwrap();
        assert_eq!(got, f);
    }

    #[test]
    fn all_frames_roundtrip_bit_exactly() {
        roundtrip(Frame::ClassifyReq { id: 7, image: vec![0.0, -1.5, f32::MIN_POSITIVE] });
        roundtrip(Frame::ClassifyOk {
            id: u64::MAX,
            class: 9,
            latency_us: 123_456,
            logits: vec![1.0e-30, -0.0, 3.25],
        });
        roundtrip(Frame::StatsReq);
        roundtrip(Frame::Stats { text: "requests=3\nok=3\n".into() });
        roundtrip(Frame::Rejected { id: 1, queue_depth: 42, retry_after_ms: 17 });
        roundtrip(Frame::Error { id: 2, message: "bad image size".into() });
        roundtrip(Frame::StatsJsonReq);
        roundtrip(Frame::StatsJson { json: "{\"server\":{\"ok\":3}}".into() });
        roundtrip(Frame::Degraded {
            id: 11,
            reason: "reply deadline missed".into(),
            retry_after_ms: 250,
            deadline_ms: 30_000,
        });
        // empty vectors / strings are legal
        roundtrip(Frame::ClassifyReq { id: 0, image: vec![] });
        roundtrip(Frame::Error { id: 0, message: String::new() });
        roundtrip(Frame::Degraded {
            id: 0,
            reason: String::new(),
            retry_after_ms: 0,
            deadline_ms: 0,
        });
    }

    #[test]
    fn nan_payloads_survive_the_wire() {
        // PartialEq can't see NaN, so check the bit pattern by hand.
        let f = Frame::ClassifyOk {
            id: 1,
            class: 0,
            latency_us: 0,
            logits: vec![f32::NAN],
        };
        let bytes = f.to_bytes();
        match Frame::read_from(&mut &bytes[..]).unwrap() {
            Frame::ClassifyOk { logits, .. } => {
                assert_eq!(logits.len(), 1);
                assert_eq!(logits[0].to_bits(), f32::NAN.to_bits());
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn clean_close_is_distinguished_from_midframe_eof() {
        // Nothing at all: a clean close.
        match Frame::read_from(&mut &b""[..]) {
            Err(ProtoError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        // Half a prefix, then EOF: an IO error.
        match Frame::read_from(&mut &[1u8, 0][..]) {
            Err(ProtoError::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
        // Full prefix, truncated payload: also IO.
        let mut bytes = Frame::StatsReq.to_bytes();
        bytes.pop();
        match Frame::read_from(&mut &bytes[..]) {
            Err(ProtoError::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn version_kind_and_length_are_enforced() {
        let mut bytes = Frame::StatsReq.to_bytes();
        bytes[4] = PROTO_VERSION + 1; // payload[0]
        match Frame::read_from(&mut &bytes[..]) {
            Err(ProtoError::Version { got }) => assert_eq!(got, PROTO_VERSION + 1),
            other => panic!("expected Version, got {other:?}"),
        }

        let mut bytes = Frame::StatsReq.to_bytes();
        bytes[5] = 250; // payload[1]
        match Frame::read_from(&mut &bytes[..]) {
            Err(ProtoError::Kind(250)) => {}
            other => panic!("expected Kind, got {other:?}"),
        }

        let huge = (MAX_FRAME_LEN + 1).to_le_bytes();
        match Frame::read_from(&mut &huge[..]) {
            Err(ProtoError::TooLarge(n)) => assert_eq!(n, MAX_FRAME_LEN + 1),
            other => panic!("expected TooLarge, got {other:?}"),
        }

        // A one-byte payload can't even hold version + kind.
        let runt = [1u8, 0, 0, 0, PROTO_VERSION];
        match Frame::read_from(&mut &runt[..]) {
            Err(ProtoError::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn truncated_and_padded_bodies_are_malformed() {
        // Chop the last logit float out of the payload but fix the prefix.
        let f = Frame::ClassifyOk { id: 3, class: 1, latency_us: 9, logits: vec![1.0, 2.0] };
        let mut bytes = f.to_bytes();
        bytes.truncate(bytes.len() - 4);
        let len = (bytes.len() - 4) as u32;
        bytes[..4].copy_from_slice(&len.to_le_bytes());
        match Frame::read_from(&mut &bytes[..]) {
            Err(ProtoError::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }

        // Trailing junk after a well-formed body.
        let mut bytes = Frame::Rejected { id: 4, queue_depth: 2, retry_after_ms: 1 }.to_bytes();
        bytes.push(0xab);
        let len = (bytes.len() - 4) as u32;
        bytes[..4].copy_from_slice(&len.to_le_bytes());
        match Frame::read_from(&mut &bytes[..]) {
            Err(ProtoError::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }

        // A v1-shaped Rejected body (no retry_after_ms) is truncated in v2.
        let mut bytes = Frame::Rejected { id: 4, queue_depth: 2, retry_after_ms: 1 }.to_bytes();
        bytes.truncate(bytes.len() - 4);
        let len = (bytes.len() - 4) as u32;
        bytes[..4].copy_from_slice(&len.to_le_bytes());
        match Frame::read_from(&mut &bytes[..]) {
            Err(ProtoError::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn frames_stream_back_to_back() {
        let a = Frame::ClassifyReq { id: 1, image: vec![0.5; 4] };
        let b = Frame::StatsReq;
        let mut stream = a.to_bytes();
        stream.extend_from_slice(&b.to_bytes());
        let mut r = &stream[..];
        assert_eq!(Frame::read_from(&mut r).unwrap(), a);
        assert_eq!(Frame::read_from(&mut r).unwrap(), b);
        match Frame::read_from(&mut r) {
            Err(ProtoError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }
}
