//! Network serving front-end: the subsystem between the sharded engine and
//! the outside world.
//!
//! The engine ([`crate::coordinator::ShardedEngine`]) batches and executes;
//! this module puts a socket in front of it:
//!
//! * [`proto`] — the length-prefixed, versioned binary wire protocol.
//!   Typed request/response/error frames; logits cross the wire as raw
//!   IEEE-754 bits, so a served prediction is **bit-identical** to a direct
//!   [`crate::coordinator::EngineHandle::classify`] call (asserted by the
//!   loopback tests).
//! * [`batcher`] — dynamic micro-batching with admission control: a
//!   bounded queue that coalesces concurrent requests up to
//!   [`batcher::BatchPolicy::max_batch`] (or a `flush_after` deadline) into
//!   single [`crate::coordinator::EngineHandle::submit_batch`] hand-offs,
//!   and answers overflow with an immediate typed rejection instead of
//!   blocking.
//! * [`server`] — `std::net::TcpListener` + per-connection threads. Every
//!   reply is bounded by [`server::ServeConfig::wait_timeout`], so a dead
//!   engine worker degrades into typed `Error` frames, never hung
//!   connections. A `StatsReq` frame returns a plain-text observability
//!   snapshot (server/batcher/engine counters + p50/p95/p99 latency); a
//!   `StatsJsonReq` frame returns the complete snapshot — counters,
//!   rejected breakdown, raw latency histogram, crossbar walk profile — as
//!   one machine-readable JSON document. With [`crate::trace`] enabled,
//!   every request carries lifecycle spans from socket read to reply write.
//! * [`client`] — the blocking protocol client and the multi-connection
//!   load generator behind the CLI `bench-client` subcommand, the loopback
//!   tests, and CI's serve-smoke gate.
//!
//! Backpressure, end to end: connection threads never queue unboundedly —
//! the admission queue is the only place requests wait for a batch slot,
//! the engine queue is the only place formed groups wait for a worker, and
//! when both are full the front door says `Rejected { queue_depth }` in
//! constant time. Load shedding is part of the protocol, not an accident
//! of TCP buffers. Everything is std-only (no tokio, no serde): threads +
//! channels, same as the engine underneath.
//!
//! ```no_run
//! use reram_mpq::serve::{ServeConfig, Server};
//! # fn main() -> reram_mpq::Result<()> {
//! # let handle: reram_mpq::coordinator::EngineHandle = todo!();
//! // `handle` is any deployed engine, e.g. `plan.deploy(..)`.
//! let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
//! let server = Server::start(listener, handle, ServeConfig::default())?;
//! println!("serving on {}", server.local_addr());
//! server.join();
//! # Ok(()) }
//! ```

pub mod batcher;
pub mod client;
pub mod proto;
pub mod server;

pub use batcher::{Admission, BatchPolicy, Batcher, BatcherStats, RejectReason, Ticket};
pub use client::{bench_client, BenchReport, ClientReply, ConnLatency, ServeClient};
pub use proto::{Frame, ProtoError, IMAGE_ELEMS, MAX_FRAME_LEN, PROTO_VERSION};
pub use server::{ServeConfig, Server, ServerStats};
