//! `reram-mpq` CLI — leader entrypoint for the mixed-precision quantization
//! framework. All subcommands run purely from the AOT artifacts (Python is
//! never invoked on the request path) and drive the staged
//! `CompressionPlan` builder. `serve --listen` and `bench-client` expose
//! the network serving front-end (`reram_mpq::serve`): a TCP server with
//! dynamic micro-batching + admission control, and its load generator.

use std::time::Duration;

use reram_mpq::backend::SimXbarConfig;
use reram_mpq::coordinator::{
    EngineConfig, EngineHandle, EvalOpts, Executor, ModelState, ThresholdMode,
};
use reram_mpq::dataset::{CalibSet, TestSet};
use reram_mpq::experiments::{self, ExpOpts, Lab};
use reram_mpq::faults::{HealthSpec, Placement, ScenarioSpec};
use reram_mpq::serve::{bench_client, BatchPolicy, ServeClient, ServeConfig, Server};
use reram_mpq::tuner;
use reram_mpq::util::cli::Args;
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{artifacts_dir, fixture, CompressionPlan, Manifest, Result, RunConfig, Runtime};

const USAGE: &str = "\
reram-mpq — sensitivity-aware mixed-precision quantization for ReRAM CIM

USAGE: reram-mpq [--artifacts DIR] [--config FILE.json] [--backend pjrt|sim]
                 <command> [options]

BACKENDS:
  pjrt (default)  AOT-compiled HLO artifacts through the PJRT runtime
  sim             native bit-serial crossbar simulator (no XLA / compiled
                  HLO needed; sensitivity uses the magnitude proxy and the
                  FIM search modes require pjrt)

COMMANDS:
  hw-config                      print the hardware configuration (Table 1)
  sensitivity [--model M]        Hutchinson sensitivity score distribution
  quantize [--model M] [--cr R] [--search alg1|sweep] [--no-align]
           [--origin] [--eval-batches N] [--json]
                                 run the full compression plan once
  table2   [--eval-batches N] [--json]   regenerate Table 2 (HAP vs OURS)
  table3   [--eval-batches N] [--json]   regenerate Table 3 (CR sweep + energy)
  table4   [--json]                      regenerate Table 4 (crossbar utilization)
  fig8     [--eval-batches N] [--json]   regenerate Figure 8 (accuracy vs CR)
  faults   [--rates R1,R2,..] [--eval-batches N] [--json] [--fixture]
                                 accuracy vs device fault rate (drift,
                                 stuck-at, IR drop, read noise), naive vs
                                 sensitivity-aware strip placement; always
                                 evaluates on the crossbar simulator. With
                                 --backend sim and no artifacts (or
                                 --fixture), sweeps the hermetic in-memory
                                 fixture model.
  tune     [--model M] [--axes cr,bits,align] [--crs R1,R2,..] [--seed N]
           [--workers N] [--budget-evals N] [--budget-ms MS]
           [--eval-batches N] [--state FILE] [--resume] [--json] [--fixture]
           [--trace-out FILE]
                                 parallel Pareto auto-tuner over the staged
                                 plan's cache: fan candidate operating
                                 points across worker threads and report
                                 the accuracy / compression / storage
                                 Pareto frontier plus the stage-cache hit
                                 counters. --axes picks the knobs (cr is
                                 the spine; default CR points are the
                                 Table 3 sweep). With --state FILE the
                                 search is resumable; --resume continues
                                 an existing file bit-identically. Always
                                 evaluates on the crossbar simulator. With
                                 --backend sim and no artifacts (or
                                 --fixture), tunes the hermetic in-memory
                                 fixture model.
  serve    [--model M] [--requests N] [--cr R] [--workers N]
           [--listen ADDR] [--max-batch N] [--flush-ms MS]
           [--admit-queue N] [--wait-timeout-s S] [--deadline-ms MS]
           [--fixture]
           [--stuck R] [--drift-time T] [--drift-rate R] [--ir-drop S]
           [--read-sigma S] [--fault-seed N]
           [--evolve-drift T] [--evolve-stuck R]
           [--canaries N] [--spares N] [--probe-every N]
           [--chaos-panic-after N]
           [--placement naive|sensitivity] [--trace-out FILE]
                                 without --listen: push test images through
                                 the engine in-process and report latency
                                 percentiles; with --listen: run the TCP
                                 serving front-end (micro-batching +
                                 admission control) until killed. With
                                 --backend sim and no artifacts (or
                                 --fixture), serves the hermetic in-memory
                                 fixture model.
  bench-client --addr HOST:PORT [--conns N] [--requests N] [--retries N]
                                 drive load at a running server and report
                                 req/s + latency percentiles (exits
                                 non-zero on any failed frame). Rejected /
                                 degraded replies retry up to --retries
                                 times (default 3) with the server's
                                 backoff hint; --retries 0 counts every
                                 shed reply as terminal.
  stats    --addr HOST:PORT [--json]
                                 fetch a running server's stats frame:
                                 plain text, or the machine-readable
                                 StatsJson document (engine counters,
                                 rejected breakdown, health counters,
                                 latency histogram) with --json.

SELF-HEALING (serve, sim backend, quantized deployments):
  --evolve-drift T / --evolve-stuck R advance the fault scenario per served
  batch (runtime fault evolution on the engine's logical clock).
  --canaries N reserves known-answer canary strips and --spares N spare
  column slots per layer; --probe-every N makes each worker probe its
  canaries every N batches, re-program a repaired standby artifact in the
  background, and hot-swap it at a batch boundary. --deadline-ms bounds one
  request's reply wait (missed deadlines answer a typed Degraded frame).
  --chaos-panic-after N (testing) panics a worker mid-batch on the Nth
  batch to exercise supervision; the worker respawns and re-programs.

TRACING:
  --trace-out FILE (serve --listen, tune) enables request-lifecycle tracing
  and writes a Chrome-trace JSON (load it at https://ui.perfetto.dev or
  chrome://tracing). RERAM_MPQ_TRACE=1 enables the recorder without a dump
  file. Tracing is compiled in but off by default and costs nothing when
  off.
";

fn opts(args: &Args) -> Result<ExpOpts> {
    Ok(ExpOpts {
        eval_batches: args.get_usize("eval-batches")?.unwrap_or(usize::MAX),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["no-align", "origin", "json", "help", "fixture", "resume"])?;
    if args.has("help") || args.subcommand.is_none() {
        print!("{USAGE}");
        return Ok(());
    }

    // Tracing is compiled in, default-off: turned on by RERAM_MPQ_TRACE=1
    // or by asking for a dump file.
    let mut tc = reram_mpq::trace::TraceConfig::from_env();
    if args.get("trace-out").is_some() {
        tc.enabled = true;
    }
    reram_mpq::trace::init(tc);

    // bench-client and stats are pure network clients: no artifacts, no
    // manifest.
    if args.subcommand.as_deref() == Some("bench-client") {
        return bench_client_cmd(&args);
    }
    if args.subcommand.as_deref() == Some("stats") {
        return stats_cmd(&args);
    }

    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let cfg = match args.get("config") {
        Some(p) => RunConfig::from_json(&std::fs::read_to_string(p)?)?,
        None => RunConfig::default(),
    };

    // Hermetic serving: on the sim backend a missing manifest (or an
    // explicit --fixture) serves the in-memory fixture model instead of
    // failing — CI's serve-smoke drives this path on a bare runner.
    if args.subcommand.as_deref() == Some("serve")
        && args.get_or("backend", "pjrt") == "sim"
        && (args.has("fixture") || !dir.join("manifest.json").exists())
    {
        return serve_fixture(&args, &cfg);
    }

    // Same hermetic escape hatch for the fault sweep: the scenario engine
    // only needs the simulator, so a bare runner sweeps the fixture model.
    if args.subcommand.as_deref() == Some("faults")
        && args.get_or("backend", "pjrt") == "sim"
        && (args.has("fixture") || !dir.join("manifest.json").exists())
    {
        return faults_fixture(&args, &cfg);
    }

    // And for the auto-tuner, which always evaluates on the simulator: a
    // bare runner tunes the fixture model (the CI tune smoke drives this).
    if args.subcommand.as_deref() == Some("tune")
        && args.get_or("backend", "pjrt") == "sim"
        && (args.has("fixture") || !dir.join("manifest.json").exists())
    {
        return tune_fixture(&args, &cfg);
    }

    let manifest = Manifest::load(&dir)?;

    // The tuner needs owned model state for its worker threads (and no PJRT
    // runtime — candidates are always evaluated on the simulator), so it
    // branches off before the Lab is built.
    if args.subcommand.as_deref() == Some("tune") {
        return tune_manifest(&manifest, &cfg, &args);
    }

    // The PJRT client only exists for the pjrt backend; the simulator needs
    // no runtime (and no compiled HLO) at all.
    let runtime = match args.get_or("backend", "pjrt").as_str() {
        "pjrt" => Some(Runtime::new(dir)?),
        "sim" => None,
        other => anyhow::bail!("unknown backend '{other}' (expected pjrt|sim)"),
    };
    let exec = match &runtime {
        Some(rt) => Executor::Pjrt(rt),
        None => Executor::Sim(SimXbarConfig::from_xbar(&cfg.xbar)),
    };
    let mut lab = Lab::new_on(exec, &manifest, cfg.clone());
    if let Some(workers) = args.get_usize("workers")? {
        anyhow::ensure!(workers >= 1, "--workers must be >= 1");
        lab = lab.with_workers(workers);
    }

    match args.subcommand.as_deref().unwrap() {
        "hw-config" => {
            println!("Hardware Architecture Configuration (paper Table 1)");
            println!("{}", cfg.xbar.to_value().to_json());
        }
        "sensitivity" => {
            let model = args.get_or("model", "resnet20");
            let plan = lab.plan(&model)?;
            let s = plan.sensitivity_scores()?;
            let sorted = s.sorted_scores();
            println!("strips: {}", sorted.len());
            for q in [0.0, 0.25, 0.5, 0.75, 0.9, 0.99] {
                let idx = ((sorted.len() - 1) as f64 * q) as usize;
                println!("  p{:>4.1}: {:.3e}", q * 100.0, sorted[idx]);
            }
            println!("  max : {:.3e}", sorted[sorted.len() - 1]);
        }
        "quantize" => {
            let model = args.get_or("model", "resnet20");
            let mode = match (args.get_f64("cr")?, args.get_or("search", "sweep").as_str()) {
                (Some(c), _) => ThresholdMode::FixedCr(c),
                (None, "alg1") => ThresholdMode::Alg1,
                _ => ThresholdMode::Sweep,
            };
            let strategy = if args.has("origin") {
                MappingStrategy::Origin
            } else {
                MappingStrategy::Packed
            };
            let eb = args.get_usize("eval-batches")?.unwrap_or(usize::MAX);
            let mut plan = lab.plan(&model)?.threshold(mode).cluster().map(strategy);
            if !args.has("no-align") {
                plan = plan.align_to_capacity();
            }
            let r = plan.evaluate(EvalOpts::batches(eb))?;
            if args.has("json") {
                println!("{}", r.to_value().to_json());
            } else {
                println!(
                    "model={} cr={:.1}% q_hi={}/{} top1={:.2}% top5={:.2}% (fp32 {:.2}%)",
                    r.model,
                    r.compression_ratio * 100.0,
                    r.q_hi,
                    r.total_strips,
                    r.accuracy.top1 * 100.0,
                    r.accuracy.top5 * 100.0,
                    r.fp32_accuracy * 100.0
                );
                println!(
                    "energy={:.3} mJ (ADC {:.3}) latency={:.3} ms util(hi)={:.2}% util(all)={:.2}% fim_evals={}",
                    r.cost.energy.system_mj(),
                    r.cost.energy.adc_mj,
                    r.cost.latency_ms,
                    r.utilization_hi * 100.0,
                    r.utilization_all * 100.0,
                    r.fim_evals
                );
            }
        }
        "table2" => {
            let t = experiments::table2(&lab, opts(&args)?)?;
            if args.has("json") {
                println!("{}", experiments::table2_value(&t).to_json());
            } else {
                println!("{}", experiments::render_table2(&t));
            }
        }
        "table3" => {
            let rows = experiments::table3(&lab, opts(&args)?, experiments::TABLE3_CRS)?;
            if args.has("json") {
                println!("{}", experiments::table3_value(&rows).to_json());
            } else {
                println!("{}", experiments::render_table3(&rows));
            }
        }
        "table4" => {
            let rows = experiments::table4(&lab)?;
            if args.has("json") {
                println!("{}", experiments::table4_value(&rows).to_json());
            } else {
                println!("{}", experiments::render_table4(&rows));
            }
        }
        "fig8" => {
            let rows = experiments::fig8(&lab, opts(&args)?, experiments::FIG8_CRS)?;
            if args.has("json") {
                println!("{}", experiments::fig8_value(&rows).to_json());
            } else {
                println!("{}", experiments::render_fig8(&rows));
            }
        }
        "faults" => {
            let rates = parse_rates(&args)?;
            let rows = experiments::table_faults(&lab, opts(&args)?, &rates)?;
            print_fault_rows(&args, &rows);
        }
        "serve" => {
            let model = args.get_or("model", "resnet8");
            let plan = lab.plan(&model)?;
            deploy_and_serve(&plan, lab.engine_config(), &args)?;
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// `serve` on the sim backend with no AOT artifacts: deploy the hermetic
/// in-memory fixture model (the same workload the sim test suite and the
/// serve bench use) so the front-end runs on a bare machine.
fn serve_fixture(args: &Args, cfg: &RunConfig) -> Result<()> {
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let fx = fixture::tiny(seed);
    println!(
        "no AOT artifacts: serving hermetic fixture model {} ({} params)",
        fx.model.name(),
        fx.model.entry.num_params
    );
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(SimXbarConfig::from_xbar(&cfg.xbar)),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        cfg.clone(),
    );
    let mut ecfg = EngineConfig::default();
    if let Some(workers) = args.get_usize("workers")? {
        anyhow::ensure!(workers >= 1, "--workers must be >= 1");
        ecfg.workers = workers;
    }
    deploy_and_serve(&plan, ecfg, args)
}

/// `faults` on the sim backend with no AOT artifacts: sweep the hermetic
/// in-memory fixture model — the CI fault-sweep gate drives this path.
fn faults_fixture(args: &Args, cfg: &RunConfig) -> Result<()> {
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let fx = fixture::tiny(seed);
    println!(
        "no AOT artifacts: fault sweep on hermetic fixture model {} ({} params)",
        fx.model.name(),
        fx.model.entry.num_params
    );
    let scfg = SimXbarConfig::from_xbar(&cfg.xbar);
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(scfg),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        cfg.clone(),
    );
    let eb = args.get_usize("eval-batches")?.unwrap_or(usize::MAX);
    let rows = experiments::fault_sweep(&plan, scfg, EvalOpts::batches(eb), &parse_rates(args)?)?;
    print_fault_rows(args, &rows);
    Ok(())
}

/// `tune` on the sim backend with no AOT artifacts: search the hermetic
/// in-memory fixture model — the CI tune smoke drives this path. The
/// banner goes to stderr so `--json` stdout stays machine-parseable.
fn tune_fixture(args: &Args, cfg: &RunConfig) -> Result<()> {
    let fx = fixture::tiny(42);
    eprintln!(
        "no AOT artifacts: tuning hermetic fixture model {} ({} params)",
        fx.model.name(),
        fx.model.entry.num_params
    );
    tune_run(tuner::TuneShared::from_fixture(fx, cfg.clone()), args)
}

/// `tune` over a manifest model: load the owned state the tuner's worker
/// threads fan out from (candidates always evaluate on the simulator, so
/// no PJRT runtime is constructed).
fn tune_manifest(manifest: &Manifest, cfg: &RunConfig, args: &Args) -> Result<()> {
    let name = args.get_or("model", "resnet8");
    let model = manifest.model(&name)?;
    let theta = model.load_params(manifest)?;
    let test = TestSet::load(manifest)?;
    let calib = CalibSet::load(manifest, model.entry.batch.calib)?;
    tune_run(tuner::TuneShared { model, theta, test, calib, cfg: cfg.clone() }, args)
}

/// `--crs 0,0.5,1` → threshold-axis CR points; defaults to the paper's
/// Table 3 sweep.
fn parse_crs(args: &Args) -> Result<Vec<f64>> {
    let Some(s) = args.get("crs") else {
        return Ok(tuner::TABLE3_CRS.to_vec());
    };
    let mut crs = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let r: f64 = tok
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --crs entry '{tok}': {e}"))?;
        crs.push(r);
    }
    anyhow::ensure!(!crs.is_empty(), "--crs parsed to an empty list");
    Ok(crs)
}

/// Shared tail of both `tune` paths: build the axes + budgets, create or
/// resume the search state, run the driver, persist, and print.
fn tune_run(shared: tuner::TuneShared, args: &Args) -> Result<()> {
    let seed = args.get_usize("seed")?.unwrap_or(0) as u64;
    let crs = parse_crs(args)?;
    let default_bits = (shared.cfg.quant.hi.bits, shared.cfg.quant.lo.bits);
    let axes = tuner::Axes::parse(&args.get_or("axes", "cr"), &crs, default_bits)?;

    let state_path = args.get("state").map(std::path::PathBuf::from);
    anyhow::ensure!(
        !args.has("resume") || state_path.is_some(),
        "--resume needs --state FILE"
    );
    let mut state = match &state_path {
        Some(p) if p.exists() => {
            anyhow::ensure!(
                args.has("resume"),
                "state file {} already exists; pass --resume to continue it",
                p.display()
            );
            let st = tuner::SearchState::load(p)?;
            anyhow::ensure!(
                st.seed == seed,
                "state file was produced with --seed {} (got --seed {seed})",
                st.seed
            );
            st
        }
        _ => tuner::SearchState::new(seed, axes.fingerprint(seed)),
    };

    let mut tcfg = tuner::TuneConfig {
        sim: SimXbarConfig::from_xbar(&shared.cfg.xbar),
        ..Default::default()
    };
    if let Some(w) = args.get_usize("workers")? {
        anyhow::ensure!(w >= 1, "--workers must be >= 1");
        tcfg.workers = w;
    }
    if let Some(n) = args.get_usize("budget-evals")? {
        tcfg.max_evals = n;
    }
    if let Some(ms) = args.get_usize("budget-ms")? {
        tcfg.budget_ms = ms as u64;
    }
    tcfg.opts = EvalOpts::batches(args.get_usize("eval-batches")?.unwrap_or(usize::MAX));

    let outcome = tuner::run(&shared, &axes, &tcfg, &mut state)?;
    if let Some(p) = &state_path {
        state.save(p)?;
    }

    // One final drain after the scoped workers exited: every tune.eval span
    // is flushed, so the dump is complete. The summary goes to stderr to
    // keep `--json` stdout machine-parseable.
    if let Some(path) = args.get("trace-out").map(std::path::PathBuf::from) {
        reram_mpq::trace::flush_thread();
        let events = reram_mpq::trace::drain();
        reram_mpq::trace::write_chrome_trace(&path, &events)?;
        eprintln!("trace: {} event(s) -> {}", events.len(), path.display());
        eprint!("{}", reram_mpq::trace::summary_table(&events));
    }

    if args.has("json") {
        println!("{}", outcome.to_value(&state).to_json());
        return Ok(());
    }
    println!(
        "tune: {} new eval(s) ({} / {} candidates explored) in {} ms (total {} ms)",
        outcome.evals,
        outcome.explored,
        axes.len(),
        outcome.elapsed_ms,
        state.elapsed_ms
    );
    println!(
        "stage cache: prefix hits {} (sensitivity {}), {} hit(s) / {} run(s) overall",
        outcome.cache.prefix_hits(),
        outcome.cache.sensitivity_hits,
        outcome.cache.total_hits(),
        outcome.cache.total_runs()
    );
    println!("Pareto frontier ({} point(s)):", outcome.frontier.len());
    for p in outcome.frontier.points() {
        println!(
            "  {:<24} top1={:6.2}%  cr={:5.1}%  storage={} B",
            p.key,
            p.objectives.top1 * 100.0,
            p.objectives.compression * 100.0,
            p.objectives.storage_bytes
        );
    }
    Ok(())
}

/// `--rates 0,0.02,0.1` → fault rates; defaults to the paper-style sweep.
fn parse_rates(args: &Args) -> Result<Vec<f64>> {
    let Some(s) = args.get("rates") else {
        return Ok(experiments::FAULT_RATES.to_vec());
    };
    let mut rates = Vec::new();
    for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let r: f64 = tok
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --rates entry '{tok}': {e}"))?;
        anyhow::ensure!((0.0..=1.0).contains(&r), "--rates entries must be in [0,1], got {r}");
        rates.push(r);
    }
    anyhow::ensure!(!rates.is_empty(), "--rates parsed to an empty list");
    Ok(rates)
}

fn print_fault_rows(args: &Args, rows: &[experiments::FaultSweepRow]) {
    if args.has("json") {
        println!("{}", experiments::fault_sweep_value(rows).to_json());
    } else {
        print!("{}", experiments::render_fault_sweep(rows));
    }
}

/// Fault-scenario flags shared by the `serve` paths: compose a
/// [`ScenarioSpec`] from the individual component flags (absent flags leave
/// the component inactive) plus the placement policy.
fn scenario_from_args(args: &Args) -> Result<Option<(ScenarioSpec, Placement)>> {
    let seed = args.get_usize("fault-seed")?.unwrap_or(7) as u64;
    let mut spec = ScenarioSpec::default();
    if let Some(r) = args.get_f64("stuck")? {
        spec = spec.with_stuck(r, seed);
    }
    let (dt, dr) = (args.get_f64("drift-time")?, args.get_f64("drift-rate")?);
    if dt.is_some() || dr.is_some() {
        spec = spec.with_drift(dt.unwrap_or(1.0), dr.unwrap_or(0.05), seed ^ 1);
    }
    if let Some(s) = args.get_f64("ir-drop")? {
        spec = spec.with_ir_drop(s, seed ^ 2);
    }
    if let Some(s) = args.get_f64("read-sigma")? {
        spec = spec.with_read_noise(s, seed ^ 3);
    }
    let (ed, es) = (args.get_f64("evolve-drift")?, args.get_f64("evolve-stuck")?);
    if ed.is_some() || es.is_some() {
        if es.is_some() && !spec.stuck.is_active() {
            // Evolving stuck-at from a zero base still needs a seeded
            // per-site stream; pin the seed without activating the base.
            spec = spec.with_stuck(0.0, seed);
        }
        spec = spec.with_evolution(ed.unwrap_or(0.0), es.unwrap_or(0.0));
    }
    let placement = match args.get_or("placement", "naive").as_str() {
        "naive" => Placement::Naive,
        "sensitivity" => Placement::SensitivityAware,
        other => anyhow::bail!("unknown --placement '{other}' (expected naive|sensitivity)"),
    };
    Ok(if spec.is_active() { Some((spec, placement)) } else { None })
}

/// Health-reservation flags shared by the `serve` paths: canary strips and
/// spare slots per layer (absent flags reserve nothing).
fn health_from_args(args: &Args) -> Result<HealthSpec> {
    Ok(HealthSpec {
        canaries: args.get_usize("canaries")?.unwrap_or(0) as u32,
        spares: args.get_usize("spares")?.unwrap_or(0) as u32,
    })
}

/// Shared tail of both `serve` paths (artifact-backed and fixture):
/// quantize at the requested CR (or serve fp32), deploy, then either run
/// the TCP front-end (`--listen`) or the in-process loop.
fn deploy_and_serve(plan: &CompressionPlan<'_>, mut ecfg: EngineConfig, args: &Args) -> Result<()> {
    let scenario = scenario_from_args(args)?;
    let health = health_from_args(args)?;
    if let Some(n) = args.get_usize("probe-every")? {
        ecfg.probe_every = n as u64;
    }
    if let Some(n) = args.get_usize("chaos-panic-after")? {
        ecfg.chaos_panic_after = n as u64;
    }
    let handle = match args.get_f64("cr")? {
        Some(c) => {
            let mut p = plan.clone().threshold(ThresholdMode::FixedCr(c));
            if let Some((spec, placement)) = scenario {
                p = p.with_scenario(spec, placement);
            }
            if health.is_active() {
                p = p.with_health(health);
            }
            p.deploy(ecfg)?
        }
        None => {
            anyhow::ensure!(
                scenario.is_none() && !health.is_active(),
                "fault scenario / health reservation flags need a quantized deployment: \
                 add --cr R (faults and canaries apply when the crossbars are programmed)"
            );
            plan.deploy_fp32(ecfg)?
        }
    };
    match args.get("listen") {
        Some(addr) => run_server(handle, addr, args),
        None => serve_local(
            plan,
            handle,
            args.get_usize("requests")?.unwrap_or(512),
            ecfg.workers.max(1),
        ),
    }
}

/// `serve --listen`: bind, announce the bound address (the smoke script
/// greps the `serving on` line for the ephemeral port), and block on the
/// accept loop until the process is killed.
fn run_server(handle: EngineHandle, addr: &str, args: &Args) -> Result<()> {
    let mut policy = BatchPolicy::default();
    if let Some(b) = args.get_usize("max-batch")? {
        policy.max_batch = b.max(1);
    }
    if let Some(ms) = args.get_f64("flush-ms")? {
        // Bounded up front: Duration::from_secs_f64 panics on negative,
        // non-finite, or absurdly large inputs.
        anyhow::ensure!(
            (0.0..=86_400_000.0).contains(&ms),
            "--flush-ms must be between 0 and 86400000 (one day)"
        );
        policy.flush_after = Duration::from_secs_f64(ms / 1e3);
    }
    if let Some(q) = args.get_usize("admit-queue")? {
        policy.queue = q.max(1);
    }
    let mut cfg = ServeConfig { policy, ..ServeConfig::default() };
    if let Some(s) = args.get_f64("wait-timeout-s")? {
        anyhow::ensure!(
            (0.0..=86_400.0).contains(&s),
            "--wait-timeout-s must be between 0 and 86400 (one day)"
        );
        cfg.wait_timeout = Duration::from_secs_f64(s);
    }
    if let Some(ms) = args.get_usize("deadline-ms")? {
        anyhow::ensure!(
            (1..=86_400_000).contains(&ms),
            "--deadline-ms must be between 1 and 86400000 (one day)"
        );
        cfg.wait_timeout = Duration::from_millis(ms as u64);
    }
    let listener = std::net::TcpListener::bind(addr)?;
    // Deploy-time crossbar programming already happened inside the engine's
    // readiness handshake (the handle exists, so every worker is ready).
    let m = handle.metrics.snapshot();
    let server = Server::start(listener, handle, cfg)?;

    // Periodic trace dumper: accumulate drained span events and atomically
    // rewrite the full Chrome-trace file, so the dump is complete and
    // B/E-balanced whenever the server is killed after a quiet moment.
    if let Some(path) = args.get("trace-out").map(std::path::PathBuf::from) {
        reram_mpq::trace::write_chrome_trace(&path, &[])?;
        println!("tracing to {}", path.display());
        std::thread::spawn(move || {
            let mut events: Vec<reram_mpq::trace::Event> = Vec::new();
            loop {
                std::thread::sleep(Duration::from_millis(400));
                let fresh = reram_mpq::trace::drain();
                if !fresh.is_empty() {
                    events.extend(fresh);
                    if let Err(e) = reram_mpq::trace::write_chrome_trace(&path, &events) {
                        eprintln!("trace dump failed: {e}");
                    }
                }
            }
        });
    }

    println!("serving on {}", server.local_addr());
    println!(
        "policy: max_batch={} flush_after={:?} admit_queue={} wait_timeout={:?}",
        cfg.policy.max_batch, cfg.policy.flush_after, cfg.policy.queue, cfg.wait_timeout
    );
    println!(
        "programmed: {} worker(s), program_ns mean={:.0} max={}",
        m.programmed_workers, m.program_ns_mean, m.program_ns_max
    );
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    server.join();
    Ok(())
}

/// `bench-client`: drive load at a running server, print the summary, and
/// exit non-zero on any failed frame (the CI smoke gate).
fn bench_client_cmd(args: &Args) -> Result<()> {
    let addr = args.require("addr")?;
    let conns = args.get_usize("conns")?.unwrap_or(4).max(1);
    let requests = args.get_usize("requests")?.unwrap_or(200);
    let retries = args.get_usize("retries")?.unwrap_or(3);
    // Deterministic synthetic traffic: the server classifies, the client
    // counts frames — labels are irrelevant here.
    let test = fixture::synthetic_test_set(64, 7);
    let elems = 32 * 32 * 3;
    let images: Vec<Vec<f32>> = (0..test.len())
        .map(|j| test.x.data()[j * elems..(j + 1) * elems].to_vec())
        .collect();
    let report = bench_client(addr, conns, requests, &images, retries)?;
    println!("{}", report.summary());
    if report.failed > 0 {
        std::process::exit(1);
    }
    Ok(())
}

/// `stats`: fetch a running server's stats frame and print it. `--json`
/// asks for the full StatsJson document (the CI chaos smoke parses the
/// health counters out of it); the default is the human-readable text.
fn stats_cmd(args: &Args) -> Result<()> {
    let addr = args.require("addr")?;
    let mut client = ServeClient::connect(addr)?;
    if args.has("json") {
        println!("{}", client.stats_json()?);
    } else {
        print!("{}", client.stats()?);
    }
    Ok(())
}

/// `serve` without `--listen`: push test images through the batching engine
/// in-process and report throughput + latency percentiles + accuracy.
fn serve_local(
    plan: &CompressionPlan<'_>,
    handle: EngineHandle,
    requests: usize,
    workers: usize,
) -> Result<()> {
    // Warm the executable before timing.
    let _ = handle.classify(vec![0.0; 32 * 32 * 3])?;

    let test = plan.test();
    let n = requests.min(test.len());
    let elems = 32 * 32 * 3;
    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    // Submit in flights of 64 to keep the batcher busy.
    let mut i = 0;
    while i < n {
        let hi = (i + 64).min(n);
        let pendings: Vec<_> = (i..hi)
            .map(|j| {
                let img = test.x.data()[j * elems..(j + 1) * elems].to_vec();
                handle.submit(img)
            })
            .collect::<Result<_>>()?;
        for (j, p) in (i..hi).zip(pendings) {
            if p.wait()?.class == test.y[j] {
                correct += 1;
            }
        }
        i = hi;
    }
    let dt = t0.elapsed();
    let m = handle.metrics.snapshot();
    println!(
        "served {n} requests in {:.3}s  ({:.1} req/s, {} worker(s))  acc={:.2}%",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64(),
        workers,
        correct as f64 / n as f64 * 100.0
    );
    println!(
        "batches={} mean_fill={:.2} mean_batch_latency={:.1}us max={}us failed={}",
        m.batches, m.mean_batch_fill, m.mean_latency_us, m.max_latency_us, m.failed_requests
    );
    println!(
        "request latency: p50={}us p95={}us p99={}us ({} observed)",
        reram_mpq::coordinator::fmt_latency_us(m.p50_latency_us),
        reram_mpq::coordinator::fmt_latency_us(m.p95_latency_us),
        reram_mpq::coordinator::fmt_latency_us(m.p99_latency_us),
        m.observed_requests
    );
    Ok(())
}
