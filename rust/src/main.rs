//! `reram-mpq` CLI — leader entrypoint for the mixed-precision quantization
//! framework. All subcommands run purely from the AOT artifacts (Python is
//! never invoked on the request path) and drive the staged
//! `CompressionPlan` builder.

use reram_mpq::backend::SimXbarConfig;
use reram_mpq::coordinator::{EvalOpts, Executor, ThresholdMode};
use reram_mpq::experiments::{self, ExpOpts, Lab};
use reram_mpq::util::cli::Args;
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{artifacts_dir, Manifest, Result, RunConfig, Runtime};

const USAGE: &str = "\
reram-mpq — sensitivity-aware mixed-precision quantization for ReRAM CIM

USAGE: reram-mpq [--artifacts DIR] [--config FILE.json] [--backend pjrt|sim]
                 <command> [options]

BACKENDS:
  pjrt (default)  AOT-compiled HLO artifacts through the PJRT runtime
  sim             native bit-serial crossbar simulator (no XLA / compiled
                  HLO needed; sensitivity uses the magnitude proxy and the
                  FIM search modes require pjrt)

COMMANDS:
  hw-config                      print the hardware configuration (Table 1)
  sensitivity [--model M]        Hutchinson sensitivity score distribution
  quantize [--model M] [--cr R] [--search alg1|sweep] [--no-align]
           [--origin] [--eval-batches N] [--json]
                                 run the full compression plan once
  table2   [--eval-batches N] [--json]   regenerate Table 2 (HAP vs OURS)
  table3   [--eval-batches N] [--json]   regenerate Table 3 (CR sweep + energy)
  table4   [--json]                      regenerate Table 4 (crossbar utilization)
  fig8     [--eval-batches N] [--json]   regenerate Figure 8 (accuracy vs CR)
  serve    [--model M] [--requests N] [--cr R] [--workers N]
                                 run the sharded batching engine over test
                                 images (N backend workers; default 1)
";

fn opts(args: &Args) -> Result<ExpOpts> {
    Ok(ExpOpts {
        eval_batches: args.get_usize("eval-batches")?.unwrap_or(usize::MAX),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["no-align", "origin", "json", "help"])?;
    if args.has("help") || args.subcommand.is_none() {
        print!("{USAGE}");
        return Ok(());
    }

    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let cfg = match args.get("config") {
        Some(p) => RunConfig::from_json(&std::fs::read_to_string(p)?)?,
        None => RunConfig::default(),
    };

    let manifest = Manifest::load(&dir)?;
    // The PJRT client only exists for the pjrt backend; the simulator needs
    // no runtime (and no compiled HLO) at all.
    let runtime = match args.get_or("backend", "pjrt").as_str() {
        "pjrt" => Some(Runtime::new(dir)?),
        "sim" => None,
        other => anyhow::bail!("unknown backend '{other}' (expected pjrt|sim)"),
    };
    let exec = match &runtime {
        Some(rt) => Executor::Pjrt(rt),
        None => Executor::Sim(SimXbarConfig::from_xbar(&cfg.xbar)),
    };
    let mut lab = Lab::new_on(exec, &manifest, cfg.clone());
    if let Some(workers) = args.get_usize("workers")? {
        anyhow::ensure!(workers >= 1, "--workers must be >= 1");
        lab = lab.with_workers(workers);
    }

    match args.subcommand.as_deref().unwrap() {
        "hw-config" => {
            println!("Hardware Architecture Configuration (paper Table 1)");
            println!("{}", cfg.xbar.to_value().to_json());
        }
        "sensitivity" => {
            let model = args.get_or("model", "resnet20");
            let plan = lab.plan(&model)?;
            let s = plan.sensitivity_scores()?;
            let sorted = s.sorted_scores();
            println!("strips: {}", sorted.len());
            for q in [0.0, 0.25, 0.5, 0.75, 0.9, 0.99] {
                let idx = ((sorted.len() - 1) as f64 * q) as usize;
                println!("  p{:>4.1}: {:.3e}", q * 100.0, sorted[idx]);
            }
            println!("  max : {:.3e}", sorted[sorted.len() - 1]);
        }
        "quantize" => {
            let model = args.get_or("model", "resnet20");
            let mode = match (args.get_f64("cr")?, args.get_or("search", "sweep").as_str()) {
                (Some(c), _) => ThresholdMode::FixedCr(c),
                (None, "alg1") => ThresholdMode::Alg1,
                _ => ThresholdMode::Sweep,
            };
            let strategy = if args.has("origin") {
                MappingStrategy::Origin
            } else {
                MappingStrategy::Packed
            };
            let eb = args.get_usize("eval-batches")?.unwrap_or(usize::MAX);
            let mut plan = lab.plan(&model)?.threshold(mode).cluster().map(strategy);
            if !args.has("no-align") {
                plan = plan.align_to_capacity();
            }
            let r = plan.evaluate(EvalOpts::batches(eb))?;
            if args.has("json") {
                println!("{}", r.to_value().to_json());
            } else {
                println!(
                    "model={} cr={:.1}% q_hi={}/{} top1={:.2}% top5={:.2}% (fp32 {:.2}%)",
                    r.model,
                    r.compression_ratio * 100.0,
                    r.q_hi,
                    r.total_strips,
                    r.accuracy.top1 * 100.0,
                    r.accuracy.top5 * 100.0,
                    r.fp32_accuracy * 100.0
                );
                println!(
                    "energy={:.3} mJ (ADC {:.3}) latency={:.3} ms util(hi)={:.2}% util(all)={:.2}% fim_evals={}",
                    r.cost.energy.system_mj(),
                    r.cost.energy.adc_mj,
                    r.cost.latency_ms,
                    r.utilization_hi * 100.0,
                    r.utilization_all * 100.0,
                    r.fim_evals
                );
            }
        }
        "table2" => {
            let t = experiments::table2(&lab, opts(&args)?)?;
            if args.has("json") {
                println!("{}", experiments::table2_value(&t).to_json());
            } else {
                println!("{}", experiments::render_table2(&t));
            }
        }
        "table3" => {
            let rows = experiments::table3(&lab, opts(&args)?, experiments::TABLE3_CRS)?;
            if args.has("json") {
                println!("{}", experiments::table3_value(&rows).to_json());
            } else {
                println!("{}", experiments::render_table3(&rows));
            }
        }
        "table4" => {
            let rows = experiments::table4(&lab)?;
            if args.has("json") {
                println!("{}", experiments::table4_value(&rows).to_json());
            } else {
                println!("{}", experiments::render_table4(&rows));
            }
        }
        "fig8" => {
            let rows = experiments::fig8(&lab, opts(&args)?, experiments::FIG8_CRS)?;
            if args.has("json") {
                println!("{}", experiments::fig8_value(&rows).to_json());
            } else {
                println!("{}", experiments::render_fig8(&rows));
            }
        }
        "serve" => {
            let model = args.get_or("model", "resnet8");
            let requests = args.get_usize("requests")?.unwrap_or(512);
            let cr = args.get_f64("cr")?;
            serve(&lab, &model, requests, cr)?;
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Push test images through the batching engine from the plan's `deploy`
/// terminal and report throughput + latency + accuracy.
fn serve(lab: &Lab, model: &str, requests: usize, cr: Option<f64>) -> Result<()> {
    let plan = lab.plan(model)?;
    let ecfg = lab.engine_config();
    // Quantize at the requested CR (or serve fp32).
    let handle = match cr {
        Some(c) => plan
            .clone()
            .threshold(ThresholdMode::FixedCr(c))
            .deploy(ecfg)?,
        None => plan.deploy_fp32(ecfg)?,
    };
    // Warm the executable before timing.
    let _ = handle.classify(vec![0.0; 32 * 32 * 3])?;

    let test = plan.test();
    let n = requests.min(test.len());
    let elems = 32 * 32 * 3;
    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    // Submit in flights of 64 to keep the batcher busy.
    let mut i = 0;
    while i < n {
        let hi = (i + 64).min(n);
        let pendings: Vec<_> = (i..hi)
            .map(|j| {
                let img = test.x.data()[j * elems..(j + 1) * elems].to_vec();
                handle.submit(img)
            })
            .collect::<Result<_>>()?;
        for (j, p) in (i..hi).zip(pendings) {
            if p.wait()?.class == test.y[j] {
                correct += 1;
            }
        }
        i = hi;
    }
    let dt = t0.elapsed();
    let m = handle.metrics.snapshot();
    println!(
        "served {n} requests in {:.3}s  ({:.1} req/s, {} worker(s))  acc={:.2}%",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64(),
        ecfg.workers.max(1),
        correct as f64 / n as f64 * 100.0
    );
    println!(
        "batches={} mean_fill={:.2} mean_batch_latency={:.1}us max={}us failed={}",
        m.batches, m.mean_batch_fill, m.mean_latency_us, m.max_latency_us, m.failed_requests
    );
    Ok(())
}
