//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation section (shared by the CLI, the examples and the criterion
//! benches). See DESIGN.md §4 for the experiment index.

use crate::baselines;
use crate::coordinator::{Pipeline, PipelineReport, ThresholdMode};
use crate::model::Manifest;
use crate::report;
use crate::runtime::Runtime;
use crate::xbar::{self, MappingStrategy, XbarConfig};
use crate::{RunConfig, Result};

/// How many eval batches the experiments use (full test set by default;
/// benches shrink this for iteration speed).
#[derive(Clone, Copy, Debug)]
pub struct ExpOpts {
    pub eval_batches: usize,
}

impl Default for ExpOpts {
    fn default() -> Self {
        Self { eval_batches: usize::MAX }
    }
}

/// Table 2: HAP vs OURS on the ResNet20 backbone at 74% CR.
pub struct Table2 {
    pub hap: PipelineReport,
    pub ours: PipelineReport,
}

pub fn table2(
    runtime: &Runtime,
    manifest: &Manifest,
    cfg: &RunConfig,
    opts: ExpOpts,
) -> Result<Table2> {
    let cr = 0.74;
    let mut pipe = Pipeline::new(runtime, manifest, "resnet20", cfg.clone())?;

    // HAP: prune `cr` of strips by the same Hessian score, 8-bit survivors,
    // unstructured (ORIGIN) mapping.
    let sens = pipe.sensitivity()?.clone();
    let hap_bm = baselines::hap_bitmap(&sens, cr, cfg.quant.hi.bits);
    let hap = pipe.report_for_bitmap(
        &hap_bm,
        ThresholdMode::FixedCr(cr),
        f64::NAN,
        0,
        MappingStrategy::Origin,
        opts.eval_batches,
    )?;

    // OURS: mixed precision at the same CR, aligned + packed mapping.
    let ours = pipe.run(
        ThresholdMode::FixedCr(cr),
        true,
        MappingStrategy::Packed,
        opts.eval_batches,
    )?;
    Ok(Table2 { hap, ours })
}

pub fn render_table2(t: &Table2) -> String {
    let mut out = String::new();
    out.push_str("Table 2: Comparison of ResNet20 between HAP and our method\n");
    out.push_str(&report::table2_header());
    out.push('\n');
    out.push_str(&report::table2_row("HAP", &t.hap));
    out.push('\n');
    out.push_str(&report::table2_row("OURS", &t.ours));
    out.push('\n');
    out.push_str(&format!("headline: {}\n", report::headline(&t.ours, &t.hap)));
    out
}

/// Table 3: CR sweep on the ResNet18 stand-in with energy breakdown.
pub fn table3(
    runtime: &Runtime,
    manifest: &Manifest,
    cfg: &RunConfig,
    opts: ExpOpts,
    crs: &[f64],
) -> Result<Vec<PipelineReport>> {
    let mut pipe = Pipeline::new(runtime, manifest, "resnet8", cfg.clone())?;
    let mut rows = Vec::new();
    for &cr in crs {
        let r = pipe.run(
            ThresholdMode::FixedCr(cr),
            true,
            MappingStrategy::Packed,
            opts.eval_batches,
        )?;
        rows.push(r);
    }
    Ok(rows)
}

pub const TABLE3_CRS: &[f64] = &[0.0, 0.1, 0.5, 0.7, 0.9, 1.0];

pub fn render_table3(rows: &[PipelineReport]) -> String {
    let mut out = String::new();
    out.push_str("Table 3: Impact of Compression Ratio on Accuracy and Energy (resnet8 = ResNet18 stand-in)\n");
    out.push_str(&report::table3_header());
    out.push('\n');
    for r in rows {
        out.push_str(&report::table3_row(r));
        out.push('\n');
    }
    out
}

/// Table 4: bit utilization, ORIGIN vs OUR mapper, two array sizes.
pub struct Table4Row {
    pub method: &'static str,
    pub size: (usize, usize),
    pub utilization: f64,
    pub improvement: Option<f64>,
}

pub fn table4(
    runtime: &Runtime,
    manifest: &Manifest,
    cfg: &RunConfig,
) -> Result<Vec<Table4Row>> {
    let cr = 0.8;
    let mut rows = Vec::new();
    let mut pipe = Pipeline::new(runtime, manifest, "resnet14", cfg.clone())?;
    let sens = pipe.sensitivity()?.clone();
    let clustering = crate::clustering::cluster_at_cr(
        &sens.scores,
        cr,
        cfg.quant.hi.bits,
        cfg.quant.lo.bits,
    );

    for xcfg in [XbarConfig::default(), XbarConfig::small()] {
        let size = (xcfg.rows, xcfg.cols);
        // ORIGIN: raw clustering, natural mapping.
        let mo = xbar::map_model(&pipe.model, &clustering.bitmap, &xcfg, MappingStrategy::Origin);
        let uo = mo.utilization(cfg.quant.hi.bits);
        rows.push(Table4Row { method: "ORIGIN", size, utilization: uo, improvement: None });

        // OUR: capacity-aligned clustering + packed mapping.
        let caps: Vec<usize> = pipe
            .model
            .conv_layers()
            .iter()
            .map(|l| xcfg.capacity_strips(l.d, cfg.quant.hi.bits))
            .collect();
        let aligned = crate::clustering::align_to_capacity(
            &pipe.model,
            &sens.scores,
            &clustering,
            cfg.quant.hi.bits,
            cfg.quant.lo.bits,
            |li| caps[li],
        );
        let mp = xbar::map_model(&pipe.model, &aligned.bitmap, &xcfg, MappingStrategy::Packed);
        let up = mp.utilization(cfg.quant.hi.bits);
        rows.push(Table4Row {
            method: "OUR",
            size,
            utilization: up,
            improvement: Some(up - uo),
        });
    }
    Ok(rows)
}

pub fn render_table4(rows: &[Table4Row]) -> String {
    let mut out = String::new();
    out.push_str("Table 4: Bit Utilization on ResNet50 stand-in (80% CR, 8-bit arrays)\n");
    out.push_str(&report::table4_header());
    out.push('\n');
    for r in rows {
        out.push_str(&report::table4_row(
            "ResNet50/80%",
            r.method,
            r.size,
            8,
            r.utilization,
            r.improvement,
        ));
        out.push('\n');
    }
    out
}

/// Figure 8: accuracy vs CR for the shallow vs deep backbone.
pub fn fig8(
    runtime: &Runtime,
    manifest: &Manifest,
    cfg: &RunConfig,
    opts: ExpOpts,
    crs: &[f64],
) -> Result<Vec<(String, f64, PipelineReport)>> {
    let mut out = Vec::new();
    for (name, label) in [("resnet8", "ResNet18*"), ("resnet14", "ResNet50*")] {
        let mut pipe = Pipeline::new(runtime, manifest, name, cfg.clone())?;
        for &cr in crs {
            let r = pipe.run(
                ThresholdMode::FixedCr(cr),
                true,
                MappingStrategy::Packed,
                opts.eval_batches,
            )?;
            out.push((label.to_string(), cr, r));
        }
    }
    Ok(out)
}

pub const FIG8_CRS: &[f64] = &[0.0, 0.2, 0.4, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 1.0];

pub fn render_fig8(rows: &[(String, f64, PipelineReport)]) -> String {
    let mut out = String::new();
    out.push_str("Figure 8: Accuracy degradation under increasing compression ratio\n");
    out.push_str(&report::fig8_header());
    out.push('\n');
    for (label, cr, r) in rows {
        out.push_str(&report::fig8_row(label, *cr, r.accuracy.top1));
        out.push('\n');
    }
    out
}
