//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation section (shared by the CLI, the examples and the benches).
//!
//! All drivers run over a [`Lab`]: one [`CompressionPlan`] root per model,
//! so every table/figure drawing on the same model shares the computed
//! stage prefix (sensitivity, thresholds, clusterings) through the plan's
//! stage cache instead of recomputing it per table.
//!
//! The CR sweeps (Table 3, Figure 8) are thin wrappers over the auto-tuner's
//! degenerate single-axis case ([`crate::tuner::sweep_cr`]); the sweep
//! points themselves ([`TABLE3_CRS`]) are defined once in [`crate::tuner`]
//! and shared with the `table3_cr_sweep` bench and the `tune` CLI.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::backend::SimXbarConfig;
use crate::baselines;
use crate::coordinator::{
    CompressionPlan, EngineConfig, EvalOpts, Executor, PipelineReport, ThresholdMode,
};
use crate::faults::{Placement, ScenarioSpec};
use crate::model::Manifest;
use crate::report;
use crate::runtime::Runtime;
use crate::tuner;
use crate::util::json::{obj, Value};
use crate::xbar::{MappingStrategy, XbarConfig};
use crate::{Result, RunConfig};

/// Backwards-friendly alias: experiment options are exactly the evaluate
/// terminal's options.
pub type ExpOpts = EvalOpts;

/// A set of compression plans sharing one execution backend + configuration.
/// Tables and figures over the same model reuse its loaded state and stage
/// cache.
pub struct Lab<'a> {
    /// Execution backend every plan in this lab roots on.
    pub exec: Executor<'a>,
    /// Artifact manifest models/datasets are loaded from.
    pub manifest: &'a Manifest,
    /// Stage configuration shared by every plan in this lab.
    pub cfg: RunConfig,
    engine: EngineConfig,
    plans: RefCell<HashMap<String, CompressionPlan<'a>>>,
}

impl<'a> Lab<'a> {
    /// A lab over the PJRT runtime (the pre-backend API shape).
    pub fn new(runtime: &'a Runtime, manifest: &'a Manifest, cfg: RunConfig) -> Self {
        Self::new_on(Executor::Pjrt(runtime), manifest, cfg)
    }

    /// A lab over an explicit execution backend (`--backend sim` runs every
    /// table/figure on the native crossbar simulator).
    pub fn new_on(exec: Executor<'a>, manifest: &'a Manifest, cfg: RunConfig) -> Self {
        Self {
            exec,
            manifest,
            cfg,
            engine: EngineConfig::default(),
            plans: RefCell::new(HashMap::new()),
        }
    }

    /// Serving-engine configuration for deployments driven from this lab
    /// (the CLI `serve` command passes it to the plan's deploy terminal).
    pub fn engine_config(&self) -> EngineConfig {
        self.engine
    }

    /// Replace the serving-engine configuration (queue, batching deadline,
    /// sharded worker count) used by subsequent deploys.
    pub fn with_engine_config(mut self, engine: EngineConfig) -> Self {
        self.engine = engine;
        self
    }

    /// Shorthand: shard subsequent deploys across `workers` engine workers.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.engine.workers = workers;
        self
    }

    /// A plan rooted at `model` (loaded once per lab; every returned clone
    /// shares the model state and stage cache).
    pub fn plan(&self, model: &str) -> Result<CompressionPlan<'a>> {
        let mut plans = self.plans.borrow_mut();
        if !plans.contains_key(model) {
            let plan =
                CompressionPlan::for_model_on(self.exec, self.manifest, model, self.cfg.clone())?;
            plans.insert(model.to_string(), plan);
        }
        Ok(plans.get(model).unwrap().clone())
    }
}

/// Table 2: HAP vs OURS on the ResNet20 backbone at 74% CR.
pub struct Table2 {
    /// The HAP structured-pruning baseline row.
    pub hap: PipelineReport,
    /// The paper's mixed-precision method at the same CR.
    pub ours: PipelineReport,
}

/// Regenerate Table 2: both methods at 74% CR over the same sensitivity
/// scores (HAP enters as an explicit bitmap, OURS through the threshold /
/// clustering stages).
pub fn table2(lab: &Lab, opts: ExpOpts) -> Result<Table2> {
    let cr = 0.74;
    let base = lab.plan("resnet20")?;

    // HAP: prune `cr` of strips by the same Hessian score, 8-bit survivors,
    // unstructured (ORIGIN) mapping — an explicit bit-allocation stage.
    let sens = base.sensitivity_scores()?;
    let hap_bm = baselines::hap_bitmap(&sens, cr, lab.cfg.quant.hi.bits);
    let hap = base
        .clone()
        .bitmap_from(hap_bm)
        .nominal(ThresholdMode::FixedCr(cr))
        .map(MappingStrategy::Origin)
        .evaluate(opts)?;

    // OURS: mixed precision at the same CR, aligned + packed mapping.
    let ours = base
        .threshold(ThresholdMode::FixedCr(cr))
        .cluster()
        .align_to_capacity()
        .map(MappingStrategy::Packed)
        .evaluate(opts)?;
    Ok(Table2 { hap, ours })
}

/// Render Table 2 as the paper-style fixed-width text table.
pub fn render_table2(t: &Table2) -> String {
    let mut out = String::new();
    out.push_str("Table 2: Comparison of ResNet20 between HAP and our method\n");
    out.push_str(&report::table2_header());
    out.push('\n');
    out.push_str(&report::table2_row("HAP", &t.hap));
    out.push('\n');
    out.push_str(&report::table2_row("OURS", &t.ours));
    out.push('\n');
    out.push_str(&format!("headline: {}\n", report::headline(&t.ours, &t.hap)));
    out
}

/// Table 2 as a JSON value (`--json` output shape).
pub fn table2_value(t: &Table2) -> Value {
    obj(vec![("hap", t.hap.to_value()), ("ours", t.ours.to_value())])
}

/// Table 3: CR sweep on the ResNet18 stand-in with energy breakdown.
///
/// A thin wrapper over the tuner's degenerate single-axis case
/// ([`tuner::sweep_cr`]): each CR runs the full threshold → cluster →
/// align → packed-map → evaluate chain against the lab's shared stage
/// cache, exactly as a `cr`-only `tune` run would.
pub fn table3(lab: &Lab, opts: ExpOpts, crs: &[f64]) -> Result<Vec<PipelineReport>> {
    tuner::sweep_cr(&lab.plan("resnet8")?, crs, opts)
}

pub use crate::tuner::TABLE3_CRS;

/// Render Table 3 as the paper-style fixed-width text table.
pub fn render_table3(rows: &[PipelineReport]) -> String {
    let mut out = String::new();
    out.push_str("Table 3: Impact of Compression Ratio on Accuracy and Energy (resnet8 = ResNet18 stand-in)\n");
    out.push_str(&report::table3_header());
    out.push('\n');
    for r in rows {
        out.push_str(&report::table3_row(r));
        out.push('\n');
    }
    out
}

/// Table 3 as a JSON array (`--json` output shape).
pub fn table3_value(rows: &[PipelineReport]) -> Value {
    Value::Arr(rows.iter().map(PipelineReport::to_value).collect())
}

/// Table 4: bit utilization, ORIGIN vs OUR mapper, two array sizes.
pub struct Table4Row {
    /// Mapping method label (`ORIGIN` or `OUR`).
    pub method: &'static str,
    /// Crossbar array geometry (rows, cols).
    pub size: (usize, usize),
    /// Fraction of array bit-cells holding weight bits.
    pub utilization: f64,
    /// Utilization gain over the ORIGIN row at the same geometry.
    pub improvement: Option<f64>,
}

/// Regenerate Table 4: map the ResNet50 stand-in at 80% CR with both
/// mappers at two array geometries and compare bit utilization.
pub fn table4(lab: &Lab) -> Result<Vec<Table4Row>> {
    let cr = 0.8;
    let base = lab.plan("resnet14")?;
    let hi_bits = lab.cfg.quant.hi.bits;
    let mut rows = Vec::new();

    for xcfg in [XbarConfig::default(), XbarConfig::small()] {
        let size = (xcfg.rows, xcfg.cols);
        let mut cfg = lab.cfg.clone();
        cfg.xbar = xcfg;

        // ORIGIN: raw clustering, natural mapping.
        let origin = base
            .clone()
            .with_config(cfg.clone())
            .threshold(ThresholdMode::FixedCr(cr))
            .cluster()
            .map(MappingStrategy::Origin);
        let uo = origin.mapping()?.utilization(hi_bits);
        rows.push(Table4Row { method: "ORIGIN", size, utilization: uo, improvement: None });

        // OUR: capacity-aligned clustering + packed mapping.
        let ours = base
            .clone()
            .with_config(cfg)
            .threshold(ThresholdMode::FixedCr(cr))
            .cluster()
            .align_to_capacity()
            .map(MappingStrategy::Packed);
        let up = ours.mapping()?.utilization(hi_bits);
        rows.push(Table4Row {
            method: "OUR",
            size,
            utilization: up,
            improvement: Some(up - uo),
        });
    }
    Ok(rows)
}

/// Render Table 4 as the paper-style fixed-width text table.
pub fn render_table4(rows: &[Table4Row]) -> String {
    let mut out = String::new();
    out.push_str("Table 4: Bit Utilization on ResNet50 stand-in (80% CR, 8-bit arrays)\n");
    out.push_str(&report::table4_header());
    out.push('\n');
    for r in rows {
        out.push_str(&report::table4_row(
            "ResNet50/80%",
            r.method,
            r.size,
            8,
            r.utilization,
            r.improvement,
        ));
        out.push('\n');
    }
    out
}

/// Table 4 as a JSON array (`--json` output shape).
pub fn table4_value(rows: &[Table4Row]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("method", Value::Str(r.method.to_string())),
                    ("rows", Value::Num(r.size.0 as f64)),
                    ("cols", Value::Num(r.size.1 as f64)),
                    ("utilization", Value::Num(r.utilization)),
                    (
                        "improvement",
                        r.improvement.map_or(Value::Null, Value::Num),
                    ),
                ])
            })
            .collect(),
    )
}

/// Figure 8: accuracy vs CR for the shallow vs deep backbone — the Table 3
/// sweep ([`tuner::sweep_cr`]) run per model, labelled with the paper's
/// backbone names.
pub fn fig8(lab: &Lab, opts: ExpOpts, crs: &[f64]) -> Result<Vec<(String, f64, PipelineReport)>> {
    let mut out = Vec::new();
    for (name, label) in [("resnet8", "ResNet18*"), ("resnet14", "ResNet50*")] {
        let rows = tuner::sweep_cr(&lab.plan(name)?, crs, opts)?;
        for (&cr, r) in crs.iter().zip(rows) {
            out.push((label.to_string(), cr, r));
        }
    }
    Ok(out)
}

/// CR points swept by Figure 8 (denser than Table 3 around the knee).
pub const FIG8_CRS: &[f64] = &[0.0, 0.2, 0.4, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 1.0];

/// Render Figure 8 as a fixed-width text table of (model, CR, top-1) rows.
pub fn render_fig8(rows: &[(String, f64, PipelineReport)]) -> String {
    let mut out = String::new();
    out.push_str("Figure 8: Accuracy degradation under increasing compression ratio\n");
    out.push_str(&report::fig8_header());
    out.push('\n');
    for (label, cr, r) in rows {
        out.push_str(&report::fig8_row(label, *cr, r.accuracy.top1));
        out.push('\n');
    }
    out
}

/// Figure 8 as a JSON array (`--json` output shape).
pub fn fig8_value(rows: &[(String, f64, PipelineReport)]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|(label, cr, r)| {
                obj(vec![
                    ("model", Value::Str(label.clone())),
                    ("cr", Value::Num(*cr)),
                    ("report", r.to_value()),
                ])
            })
            .collect(),
    )
}

/// One row of the fault-sweep table: the same compressed plan evaluated
/// under the same fault scenario with naive vs sensitivity-aware placement.
pub struct FaultSweepRow {
    /// Scenario fault rate (drives [`fault_scenario`]).
    pub rate: f64,
    /// Evaluation with strips placed in natural order.
    pub naive: PipelineReport,
    /// Evaluation with sensitivity-aware strip placement.
    pub aware: PipelineReport,
}

/// Fault rates swept by the paper-style device-variability table.
pub const FAULT_RATES: &[f64] = &[0.0, 0.01, 0.02, 0.05, 0.1];

/// The sweep's composite scenario at fault rate `r`: stuck-at cells at rate
/// `r`, a per-column IR-drop gradient scaled with `r` (the lever the
/// placement policy exploits — healthy low-drop columns go to sensitive
/// strips), and a small conductance drift. `r = 0` is the healthy device
/// (bit-identical to the unfaulted programmed path).
pub fn fault_scenario(rate: f64) -> ScenarioSpec {
    if rate <= 0.0 {
        return ScenarioSpec::default();
    }
    ScenarioSpec::default()
        .with_stuck(rate, 101)
        .with_ir_drop((4.0 * rate).min(0.8), 202)
        .with_drift(1.0, 0.1 * rate, 303)
}

/// Accuracy vs fault rate on an explicit plan + simulator config — the
/// manifest-free core (the hermetic CLI `faults --fixture` path calls this
/// directly on a fixture-rooted plan).
pub fn fault_sweep(
    plan: &CompressionPlan,
    scfg: SimXbarConfig,
    opts: ExpOpts,
    rates: &[f64],
) -> Result<Vec<FaultSweepRow>> {
    let base = plan
        .clone()
        .threshold(ThresholdMode::FixedCr(0.5))
        .cluster()
        .align_to_capacity()
        .map(MappingStrategy::Packed);
    let mut rows = Vec::new();
    for &rate in rates {
        let spec = fault_scenario(rate);
        let naive = base
            .clone()
            .with_scenario(spec, Placement::Naive)
            .evaluate_on(Executor::Sim(scfg), opts)?;
        let aware = base
            .clone()
            .with_scenario(spec, Placement::SensitivityAware)
            .evaluate_on(Executor::Sim(scfg), opts)?;
        rows.push(FaultSweepRow { rate, naive, aware });
    }
    Ok(rows)
}

/// Fault-sweep table over a lab (manifest models). Faults only exist on a
/// programmed device, so evaluation always runs on the simulator — a
/// PJRT-rooted lab still contributes its Hutchinson sensitivity scores to
/// the placement stage but executes the faulted forward passes on the
/// default simulator geometry.
pub fn table_faults(lab: &Lab, opts: ExpOpts, rates: &[f64]) -> Result<Vec<FaultSweepRow>> {
    let scfg = match lab.exec {
        Executor::Sim(c) => c,
        Executor::Pjrt(_) => SimXbarConfig::default(),
    };
    fault_sweep(&lab.plan("resnet8")?, scfg, opts, rates)
}

/// Render the fault sweep as a fixed-width text table.
pub fn render_fault_sweep(rows: &[FaultSweepRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "Fault sweep: accuracy vs device fault rate, naive vs sensitivity-aware placement\n",
    );
    out.push_str(&format!(
        "{:<7} {:<52} {:>8} {:>8} {:>8}\n",
        "rate", "scenario", "naive%", "aware%", "delta"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<7.3} {:<52} {:>8.2} {:>8.2} {:>+8.2}\n",
            r.rate,
            fault_scenario(r.rate).describe(),
            r.naive.accuracy.top1 * 100.0,
            r.aware.accuracy.top1 * 100.0,
            (r.aware.accuracy.top1 - r.naive.accuracy.top1) * 100.0,
        ));
    }
    out
}

/// Fault sweep as a JSON array (`--json` output shape).
pub fn fault_sweep_value(rows: &[FaultSweepRow]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("rate", Value::Num(r.rate)),
                    ("scenario", Value::Str(fault_scenario(r.rate).describe())),
                    ("naive", r.naive.to_value()),
                    ("aware", r.aware.to_value()),
                ])
            })
            .collect(),
    )
}
