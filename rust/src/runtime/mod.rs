//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 CPU):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. One compiled executable per artifact,
//! cached by name. Interchange is HLO *text* — jax ≥ 0.5 serialized protos
//! carry 64-bit instruction ids this XLA rejects.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use crate::tensor::Tensor;
use crate::Result;

/// Execution statistics for one executable.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

/// A compiled-executable cache over a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl Runtime {
    /// Create a runtime rooted at the artifacts directory.
    pub fn new(dir: PathBuf) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        crate::info!("pjrt up: platform={} devices={}", client.platform_name(), client.device_count());
        Ok(Self {
            client,
            dir,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifacts directory this runtime loads from.
    pub fn artifacts(&self) -> &std::path::Path {
        &self.dir
    }

    /// Load + compile (or fetch from cache) the HLO-text artifact `file`.
    pub fn load(&self, file: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        crate::info!("compiled {file} in {:.2}s", t0.elapsed().as_secs_f64());
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with the given inputs; returns the tuple of
    /// outputs as tensors. All exported graphs return a tuple
    /// (`return_tuple=True` at lowering).
    pub fn exec(&self, file: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.load(file)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data())
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape input {:?}: {e}", t.shape()))
            })
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {file}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result {file}: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut st = self.stats.lock().unwrap();
            let e = st.entry(file.to_string()).or_default();
            e.calls += 1;
            e.total_secs += dt;
        }

        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {file}: {e}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("output shape: {e}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("output data: {e}"))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }

    /// Per-executable call statistics (for the perf pass / metrics).
    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Number of compiled executables held in cache.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
