//! Hutchinson sensitivity driver (paper §4.1).
//!
//! The L3 side of the Hessian analysis: generates Rademacher probe vectors,
//! drives the AOT-compiled `hvp` executable (`v ⊙ Hv` over conv params),
//! averages the diagonal estimate over probes and calibration batches, and
//! reduces it to the paper's per-strip sensitivity score
//!
//!   s_i = Trace(H_strip) / (2 · p_strip) · ‖w_strip‖²
//!
//! (HAP's loss-perturbation form, applied at strip granularity.)

use crate::config::SensitivityConfig;
use crate::dataset::CalibSet;
use crate::model::ModelInfo;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::Result;

/// Per-strip sensitivity analysis output.
#[derive(Clone, Debug)]
pub struct Sensitivity {
    /// One score per strip, `ModelInfo::strips()` order.
    pub scores: Vec<f64>,
    /// Per-strip Hessian-trace estimates (before the ‖w‖² weighting).
    pub traces: Vec<f64>,
    /// Hutchinson probes used.
    pub probes: usize,
}

impl Sensitivity {
    /// Scores sorted ascending — the clustering threshold domain.
    pub fn sorted_scores(&self) -> Vec<f64> {
        let mut s = self.scores.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        s
    }

    /// Value at quantile q ∈ [0,1] of the score distribution (q=1 → above
    /// the max, i.e. "everything low-bit" — the paper's T0).
    pub fn quantile(&self, q: f64) -> f64 {
        let s = self.sorted_scores();
        if q >= 1.0 {
            return s[s.len() - 1] * (1.0 + 1e-9) + 1e-300;
        }
        let idx = ((s.len() as f64) * q.max(0.0)) as usize;
        s[idx.min(s.len() - 1)]
    }
}

/// Drives the HVP executable to estimate per-strip Hessian traces.
pub struct Analyzer<'a> {
    pub runtime: &'a Runtime,
    pub model: &'a ModelInfo,
    pub calib: &'a CalibSet,
    pub cfg: SensitivityConfig,
}

impl<'a> Analyzer<'a> {
    /// Run Hutchinson estimation with the fp32 checkpoint `theta`.
    pub fn run(&self, theta: &[f32]) -> Result<Sensitivity> {
        let pc = self.model.entry.num_conv_params;
        let exe = self
            .model
            .entry
            .executables
            .get("hvp")
            .ok_or_else(|| anyhow::anyhow!("model has no hvp executable"))?
            .clone();
        let mut rng = Rng::seed_from_u64(self.cfg.seed);
        let theta_t = Tensor::from_vec(theta.to_vec());

        let mut diag = vec![0.0f64; pc];
        let batches = self.cfg.calib_batches.min(self.calib.num_batches()).max(1);
        let mut total = 0usize;
        for _probe in 0..self.cfg.probes {
            // Rademacher probe: ±1 per conv weight.
            let v: Vec<f32> = (0..pc).map(|_| rng.rademacher()).collect();
            let v_t = Tensor::from_vec(v);
            for b in 0..batches {
                let (x, y1h) = self.calib.get(b);
                let out = self
                    .runtime
                    .exec(&exe, &[theta_t.clone(), x, y1h, v_t.clone()])?;
                let est = &out[0];
                anyhow::ensure!(est.len() == pc, "hvp output length mismatch");
                for (d, e) in diag.iter_mut().zip(est.data()) {
                    *d += *e as f64;
                }
                total += 1;
            }
        }
        for d in diag.iter_mut() {
            *d /= total as f64;
        }

        // Per-strip trace = sum of diagonal estimates within the strip.
        let diag_f32: Vec<f32> = diag.iter().map(|&d| d as f32).collect();
        let traces = self.model.reduce_convflat_per_strip(&diag_f32);

        // Score: Trace(H_strip)/(2 p_strip) * ||w_strip||^2, clamped at 0
        // (negative curvature estimates carry no pruning signal — HAP does
        // the same).
        let mut scores = Vec::with_capacity(traces.len());
        for (s, tr) in self.model.strips().iter().zip(traces.iter()) {
            let p = self.model.layer(s.layer).d as f64;
            let l2 = self.model.strip_l2sq(theta, *s);
            scores.push((tr.max(0.0) / (2.0 * p)) * l2);
        }
        Ok(Sensitivity { scores, traces, probes: self.cfg.probes })
    }
}

/// Artifact-free sensitivity proxy used by the native simulator backend
/// (which has no HVP executable to drive): unit curvature per strip, so the
/// score reduces to the HAP loss form with magnitude only,
/// `s_i = ‖w_strip‖² / (2 · p_strip)`. Coarser than the Hutchinson estimate
/// but order-preserving enough to exercise the clustering/alignment/mapping
/// tail hermetically.
pub fn magnitude_proxy(model: &ModelInfo, theta: &[f32]) -> Sensitivity {
    let traces = vec![1.0f64; model.num_strips()];
    let scores = score_strips(model, theta, &traces);
    Sensitivity { scores, traces, probes: 0 }
}

/// Indices of `scores` sorted by descending score, ties broken by index —
/// a fully deterministic ranking. The fault-placement stage
/// ([`crate::faults::assign_slots`]) uses it to put the most sensitive
/// strips on the healthiest crossbar slots.
pub fn rank_desc(scores: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    idx
}

/// Pure scoring helper (exposed for tests and the HAP baseline): combines
/// externally-computed traces with weight norms.
pub fn score_strips(model: &ModelInfo, theta: &[f32], traces: &[f64]) -> Vec<f64> {
    model
        .strips()
        .iter()
        .zip(traces.iter())
        .map(|(s, tr)| {
            let p = model.layer(s.layer).d as f64;
            (tr.max(0.0) / (2.0 * p)) * model.strip_l2sq(theta, *s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry};
    use std::collections::HashMap;

    fn toy_model() -> ModelInfo {
        ModelInfo::new(ModelEntry {
            name: "toy".into(),
            num_params: 1 * 1 * 2 * 3,
            num_conv_params: 6,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![6], dtype: "f32".into() },
            layers: vec![LayerEntry {
                name: "c".into(),
                shape: vec![1, 1, 2, 3],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            }],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        })
    }

    #[test]
    fn score_weights_trace_by_norm() {
        let m = toy_model();
        // theta laid out [d, n]: strip n gathers column n
        let theta = vec![1.0, 0.0, 2.0, /* d=1 */ 3.0, 0.0, 0.0];
        // strips: n=0 -> {1,3}, n=1 -> {0,0}, n=2 -> {2,0}
        let traces = vec![2.0, 2.0, 2.0];
        let s = score_strips(&m, &theta, &traces);
        // p = d = 2 -> factor trace/(2*2) = 0.5
        assert!((s[0] - 0.5 * 10.0).abs() < 1e-12);
        assert!((s[1] - 0.0).abs() < 1e-12);
        assert!((s[2] - 0.5 * 4.0).abs() < 1e-12);
    }

    #[test]
    fn negative_trace_clamped() {
        let m = toy_model();
        let theta = vec![1.0; 6];
        let s = score_strips(&m, &theta, &[-5.0, 1.0, 1.0]);
        assert_eq!(s[0], 0.0);
        assert!(s[1] > 0.0);
    }

    #[test]
    fn rank_desc_is_deterministic_with_stable_ties() {
        assert_eq!(rank_desc(&[0.5, 2.0, 0.5, 3.0]), vec![3, 1, 0, 2]);
        assert_eq!(rank_desc(&[]), Vec::<usize>::new());
        assert_eq!(rank_desc(&[1.0, 1.0, 1.0]), vec![0, 1, 2]);
    }

    #[test]
    fn quantile_endpoints() {
        let sens = Sensitivity { scores: vec![1.0, 2.0, 3.0, 4.0], traces: vec![], probes: 1 };
        assert_eq!(sens.quantile(0.0), 1.0);
        assert!(sens.quantile(1.0) > 4.0); // T0: everything below threshold
        assert_eq!(sens.quantile(0.5), 3.0);
    }
}
