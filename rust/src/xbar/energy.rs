//! Energy / latency cost model (NeuroSim-lite).
//!
//! Accounting philosophy (matches the paper's §2.2 claims): ADCs dominate;
//! their energy scales exponentially with resolution, their time linearly
//! (SAR). Costs are charged per *provisioned* crossbar resource — zeros
//! left by unstructured sparsity still burn read phases and conversions,
//! which is exactly why the structured mapping wins.
//!
//! Latency model: word-line reads are pipelined behind the conversion wall
//! (the chip has a fixed ADC lane budget), so end-to-end latency is
//! conversion-bound: `Σ conversions × t_sar(bits) / adc_lanes`.
//! All figures are per image.


use super::mapper::ModelMapping;
use super::XbarConfig;

/// Energy breakdown mirroring the paper's Table 3 columns (per image).
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    /// ADC conversions (mJ).
    pub adc_mj: f64,
    /// Cell read currents (mJ).
    pub cell_mj: f64,
    /// DAC / word-line drivers (mJ).
    pub dac_mj: f64,
    /// Shift-and-add merge of bit-sliced columns (mJ).
    pub shift_add_mj: f64,
    /// Digital partial-sum accumulation incl. the mixed-precision
    /// expand-add (mJ) — Table 3 "Accumulation".
    pub accumulation_mj: f64,
    /// Buffers / interconnect (mJ) — Table 3 "Other".
    pub other_mj: f64,
}

impl EnergyBreakdown {
    /// Table 3 "System" column.
    pub fn system_mj(&self) -> f64 {
        self.adc_mj + self.cell_mj + self.dac_mj + self.shift_add_mj
            + self.accumulation_mj + self.other_mj
    }

    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj(vec![
            ("adc_mj", Value::Num(self.adc_mj)),
            ("cell_mj", Value::Num(self.cell_mj)),
            ("dac_mj", Value::Num(self.dac_mj)),
            ("shift_add_mj", Value::Num(self.shift_add_mj)),
            ("accumulation_mj", Value::Num(self.accumulation_mj)),
            ("other_mj", Value::Num(self.other_mj)),
            ("system_mj", Value::Num(self.system_mj())),
        ])
    }

    fn add(&mut self, o: &EnergyBreakdown) {
        self.adc_mj += o.adc_mj;
        self.cell_mj += o.cell_mj;
        self.dac_mj += o.dac_mj;
        self.shift_add_mj += o.shift_add_mj;
        self.accumulation_mj += o.accumulation_mj;
        self.other_mj += o.other_mj;
    }
}

/// Per-layer cost detail.
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub name: String,
    pub energy: EnergyBreakdown,
    pub latency_ms: f64,
    pub conversions: u64,
}

/// Whole-model per-image cost.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub energy: EnergyBreakdown,
    pub latency_ms: f64,
    pub conversions: u64,
    pub layers: Vec<LayerCost>,
}

impl CostReport {
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj(vec![
            ("energy", self.energy.to_value()),
            ("latency_ms", Value::Num(self.latency_ms)),
            ("conversions", Value::Num(self.conversions as f64)),
            (
                "layers",
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            obj(vec![
                                ("name", Value::Str(l.name.clone())),
                                ("energy", l.energy.to_value()),
                                ("latency_ms", Value::Num(l.latency_ms)),
                                ("conversions", Value::Num(l.conversions as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

const PJ_TO_MJ: f64 = 1e-9;
const NS_TO_MS: f64 = 1e-6;

/// Evaluate the cost model over a mapping (per image).
pub fn cost(mapping: &ModelMapping, cfg: &XbarConfig) -> CostReport {
    let mut layers = Vec::new();
    let mut total = EnergyBreakdown::default();
    let mut latency_ns = 0.0f64;
    let mut conv_total = 0u64;

    for lm in &mapping.layers {
        let px = lm.out_pixels as u64;
        let mut e = EnergyBreakdown::default();
        let mut lat = 0.0f64;
        let mut conv_layer = 0u64;
        let n_tiers = lm.tiers.iter().filter(|t| t.cellcols > 0).count() as u64;

        for t in &lm.tiers {
            if t.cellcols == 0 {
                continue;
            }
            let adc_bits = cfg.adc_bits(t.bits);
            let phases = cfg.input_bits as u64;
            // Every provisioned cell column converts once per phase.
            let conversions = t.cellcols(cfg) * phases * px;
            conv_layer += conversions;

            e.adc_mj += conversions as f64 * cfg.e_adc_pj(adc_bits) * PJ_TO_MJ;
            e.cell_mj += (t.used_cells * phases * px) as f64 * cfg.e_cell_pj * PJ_TO_MJ;
            e.dac_mj += (t.driven_rows * phases * px) as f64 * cfg.e_dac_pj * PJ_TO_MJ;
            e.shift_add_mj += conversions as f64 * cfg.e_shift_add_pj * PJ_TO_MJ;

            // Digital merge work scales with converted cell columns (each
            // conversion's sample is shifted-and-added into a partial sum).
            let accum_ops = t.cellcols * px;
            e.accumulation_mj += accum_ops as f64 * cfg.e_accum_pj * PJ_TO_MJ;

            // Buffers: ADC samples moved out (adc_bits wide) + activation
            // bits streamed in.
            let buf_bits = conversions * adc_bits as u64 + t.driven_rows * phases * px;
            e.other_mj += buf_bits as f64 * cfg.e_buffer_pj_per_bit * PJ_TO_MJ;

            // Conversion-bound latency contribution of this tier.
            lat += conversions as f64 * cfg.t_adc_ns(adc_bits) / cfg.adc_lanes as f64;
        }

        // Mixed-precision stepwise accumulation: one expand-add per output
        // value when both tiers are live (paper §4.3).
        if n_tiers > 1 {
            let n_out = lm.tiers.iter().map(|t| t.strips as u64).max().unwrap_or(1);
            let adds = px * n_out;
            e.accumulation_mj += adds as f64 * cfg.e_accum_pj * PJ_TO_MJ;
        }

        total.add(&e);
        latency_ns += lat;
        conv_total += conv_layer;
        layers.push(LayerCost {
            name: lm.name.clone(),
            energy: e,
            latency_ms: lat * NS_TO_MS,
            conversions: conv_layer,
        });
    }

    CostReport {
        energy: total,
        latency_ms: latency_ns * NS_TO_MS,
        conversions: conv_total,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitMap;
    use crate::xbar::mapper::{map_model, MappingStrategy};
    use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry, ModelInfo};
    use std::collections::HashMap;

    fn model_1layer(k: usize, d: usize, n: usize) -> ModelInfo {
        ModelInfo::new(ModelEntry {
            name: "toy".into(),
            num_params: k * k * d * n,
            num_conv_params: k * k * d * n,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![k * k * d * n], dtype: "f32".into() },
            layers: vec![LayerEntry {
                name: "s1.b0.conv1".into(),
                shape: vec![k, k, d, n],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            }],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        })
    }

    #[test]
    fn all_4bit_is_cheaper_than_all_8bit() {
        let m = model_1layer(3, 32, 64);
        let cfg = XbarConfig::default();
        let c8 = cost(
            &map_model(&m, &BitMap::uniform(m.num_strips(), 8), &cfg, MappingStrategy::Packed),
            &cfg,
        );
        let c4 = cost(
            &map_model(&m, &BitMap::uniform(m.num_strips(), 4), &cfg, MappingStrategy::Packed),
            &cfg,
        );
        assert!(c4.energy.system_mj() < c8.energy.system_mj() * 0.25,
            "4-bit {:.4} should be ≲ 1/4 the 8-bit energy {:.4} (½ columns × 1/16 ADC)",
            c4.energy.system_mj(), c8.energy.system_mj());
        assert!(c4.latency_ms < c8.latency_ms);
        // ADC dominates (paper §2.2 / Table 3)
        assert!(c8.energy.adc_mj / c8.energy.system_mj() > 0.8);
    }

    #[test]
    fn mixed_sits_between_uniform_tiers() {
        let m = model_1layer(3, 32, 64);
        let cfg = XbarConfig::default();
        let mut bits = vec![4u8; m.num_strips()];
        for b in bits.iter_mut().step_by(4) {
            *b = 8;
        }
        let cm = cost(&map_model(&m, &BitMap { bits }, &cfg, MappingStrategy::Packed), &cfg);
        let c8 = cost(
            &map_model(&m, &BitMap::uniform(m.num_strips(), 8), &cfg, MappingStrategy::Packed),
            &cfg,
        );
        let c4 = cost(
            &map_model(&m, &BitMap::uniform(m.num_strips(), 4), &cfg, MappingStrategy::Packed),
            &cfg,
        );
        let (s4, sm, s8) = (c4.energy.system_mj(), cm.energy.system_mj(), c8.energy.system_mj());
        assert!(s4 < sm && sm < s8, "{s4} < {sm} < {s8}");
    }

    #[test]
    fn origin_mapping_costs_more_when_sparse() {
        let m = model_1layer(3, 32, 64);
        let cfg = XbarConfig::default();
        let mut bits = vec![4u8; m.num_strips()];
        for b in bits.iter_mut().step_by(5) {
            *b = 8;
        }
        let bm = BitMap { bits };
        let co = cost(&map_model(&m, &bm, &cfg, MappingStrategy::Origin), &cfg);
        let cp = cost(&map_model(&m, &bm, &cfg, MappingStrategy::Packed), &cfg);
        assert!(cp.energy.system_mj() < co.energy.system_mj());
        assert!(cp.latency_ms <= co.latency_ms + 1e-12);
    }
}
