//! Hardware architecture configuration (paper Table 1) + 32 nm energy/
//! timing constants calibrated to the NeuroSim/ISAAC numbers the paper
//! cites (§2.2: one ADC bit ≈ 87% energy; ADC dominates array energy).


/// ReRAM crossbar + periphery configuration. Defaults reproduce Table 1.
#[derive(Clone, Copy, Debug)]
pub struct XbarConfig {
    /// Synaptic array rows (word lines).
    pub rows: usize,
    /// Synaptic array columns (bit lines / cell columns).
    pub cols: usize,
    /// Bits stored per ReRAM cell (device precision).
    pub cell_bits: u8,
    /// Cell columns sharing one ADC (column mux).
    pub cols_per_adc: usize,
    /// Input (activation) bits streamed bit-serially through the DACs.
    pub input_bits: u8,
    /// Chip-level ADC lane budget (fixed periphery; the bandwidth wall that
    /// makes latency proportional to total conversions).
    pub adc_lanes: usize,

    // --- 32 nm energy/timing constants (per-op, picojoules / nanoseconds) ---
    /// SAR ADC energy at 4-bit resolution; scales ×2 per extra bit
    /// (exponential ADC cost, §2.2).
    pub e_adc4_pj: f64,
    /// Cell read energy per active (programmed) cell per input-bit phase.
    pub e_cell_pj: f64,
    /// DAC/wordline driver energy per row per input-bit phase.
    pub e_dac_pj: f64,
    /// Shift-and-add merge energy per ADC sample.
    pub e_shift_add_pj: f64,
    /// Digital partial-sum accumulation energy per add (the paper's
    /// "Accumulation" column).
    pub e_accum_pj: f64,
    /// Buffer/interconnect energy per bit moved (the paper's "Other").
    pub e_buffer_pj_per_bit: f64,
    /// SAR cycle time (one bit-decision) in ns.
    pub t_sar_cycle_ns: f64,
    /// Array read-pulse phase time in ns.
    pub t_read_ns: f64,
}

impl Default for XbarConfig {
    fn default() -> Self {
        // Table 1: 32 nm, 128×128 array, 2-bit cells, 2 columns per ADC,
        // 4-/8-bit weights, 16-/256-level ADC.
        Self {
            rows: 128,
            cols: 128,
            cell_bits: 2,
            cols_per_adc: 2,
            input_bits: 8,
            adc_lanes: 128,
            e_adc4_pj: 0.8,
            e_cell_pj: 0.005,
            e_dac_pj: 0.02,
            e_shift_add_pj: 0.023,
            e_accum_pj: 0.2,
            e_buffer_pj_per_bit: 0.05,
            t_sar_cycle_ns: 1.0,
            t_read_ns: 35.0,
        }
    }
}

impl XbarConfig {
    /// A 32×32 array variant (Table 4's small-array column).
    pub fn small() -> Self {
        Self { rows: 32, cols: 32, adc_lanes: 128, ..Self::default() }
    }

    /// Cell columns occupied by one weight of `bits` precision.
    pub fn cells_per_weight(&self, bits: u8) -> usize {
        ((bits + self.cell_bits - 1) / self.cell_bits) as usize
    }

    /// Weight columns (output channels) that fit side-by-side in one array.
    pub fn weight_cols_per_array(&self, bits: u8) -> usize {
        self.cols / self.cells_per_weight(bits)
    }

    /// ADC resolution (bits) paired with a weight precision — Table 1 pairs
    /// 4-bit weights with 16-level (4-bit) and 8-bit with 256-level (8-bit).
    pub fn adc_bits(&self, weight_bits: u8) -> u8 {
        weight_bits.max(self.cell_bits)
    }

    /// SAR ADC energy at `bits` resolution (pJ): ×2 per bit above 4.
    pub fn e_adc_pj(&self, bits: u8) -> f64 {
        self.e_adc4_pj * 2f64.powi(bits as i32 - 4)
    }

    /// SAR conversion time at `bits` resolution (ns).
    pub fn t_adc_ns(&self, bits: u8) -> f64 {
        bits as f64 * self.t_sar_cycle_ns
    }

    /// Crossbar capacity C for the dynamic-alignment rule (paper §4.2):
    /// high-bit strips per array for a layer with strip depth `d`.
    pub fn capacity_strips(&self, d: usize, bits: u8) -> usize {
        let vert = (self.rows / d.min(self.rows)).max(1);
        self.weight_cols_per_array(bits) * vert
    }

    /// Parse a (possibly partial) JSON object over the given defaults.
    pub fn from_value(v: &crate::util::json::Value, default: Self) -> crate::Result<Self> {
        let mut c = default;
        macro_rules! field {
            ($name:ident, usize) => {
                if let Some(x) = v.opt(stringify!($name)) {
                    c.$name = x.usize()?;
                }
            };
            ($name:ident, u8) => {
                if let Some(x) = v.opt(stringify!($name)) {
                    c.$name = x.usize()? as u8;
                }
            };
            ($name:ident, f64) => {
                if let Some(x) = v.opt(stringify!($name)) {
                    c.$name = x.num()?;
                }
            };
        }
        field!(rows, usize);
        field!(cols, usize);
        field!(cell_bits, u8);
        field!(cols_per_adc, usize);
        field!(input_bits, u8);
        field!(adc_lanes, usize);
        field!(e_adc4_pj, f64);
        field!(e_cell_pj, f64);
        field!(e_dac_pj, f64);
        field!(e_shift_add_pj, f64);
        field!(e_accum_pj, f64);
        field!(e_buffer_pj_per_bit, f64);
        field!(t_sar_cycle_ns, f64);
        field!(t_read_ns, f64);
        Ok(c)
    }

    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj(vec![
            ("rows", Value::Num(self.rows as f64)),
            ("cols", Value::Num(self.cols as f64)),
            ("cell_bits", Value::Num(self.cell_bits as f64)),
            ("cols_per_adc", Value::Num(self.cols_per_adc as f64)),
            ("input_bits", Value::Num(self.input_bits as f64)),
            ("adc_lanes", Value::Num(self.adc_lanes as f64)),
            ("e_adc4_pj", Value::Num(self.e_adc4_pj)),
            ("e_cell_pj", Value::Num(self.e_cell_pj)),
            ("e_dac_pj", Value::Num(self.e_dac_pj)),
            ("e_shift_add_pj", Value::Num(self.e_shift_add_pj)),
            ("e_accum_pj", Value::Num(self.e_accum_pj)),
            ("e_buffer_pj_per_bit", Value::Num(self.e_buffer_pj_per_bit)),
            ("t_sar_cycle_ns", Value::Num(self.t_sar_cycle_ns)),
            ("t_read_ns", Value::Num(self.t_read_ns)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let c = XbarConfig::default();
        assert_eq!((c.rows, c.cols), (128, 128));
        assert_eq!(c.cell_bits, 2);
        assert_eq!(c.cols_per_adc, 2);
        assert_eq!(c.cells_per_weight(8), 4);
        assert_eq!(c.cells_per_weight(4), 2);
        assert_eq!(c.weight_cols_per_array(8), 32);
        assert_eq!(c.weight_cols_per_array(4), 64);
        // 16-level / 256-level ADC pairing
        assert_eq!(c.adc_bits(4), 4);
        assert_eq!(c.adc_bits(8), 8);
    }

    #[test]
    fn adc_energy_doubles_per_bit() {
        let c = XbarConfig::default();
        let e4 = c.e_adc_pj(4);
        let e5 = c.e_adc_pj(5);
        let e8 = c.e_adc_pj(8);
        assert!((e5 / e4 - 2.0).abs() < 1e-12);
        assert!((e8 / e4 - 16.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_counts_vertical_slots() {
        let c = XbarConfig::default();
        // d=32: 4 vertical slots × 32 columns = 128 strips per 8-bit array
        assert_eq!(c.capacity_strips(32, 8), 128);
        assert_eq!(c.capacity_strips(128, 8), 32);
        // deeper than the array: still one (split) slot
        assert_eq!(c.capacity_strips(256, 8), 32);
    }
}
