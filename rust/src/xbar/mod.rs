//! NeuroSim-lite ReRAM crossbar simulator — the paper's hardware substrate
//! (DNN+NeuroSim replacement; see DESIGN.md §5 for the substitution
//! rationale and §8 for the cost-model constants).

mod config;
pub mod energy;
pub mod mapper;

pub use config::XbarConfig;
pub use energy::{cost, CostReport, EnergyBreakdown, LayerCost};
pub use mapper::{map_model, out_pixels, LayerMapping, MappingStrategy, ModelMapping, TierMapping};
