//! Strip-to-crossbar mapping (paper §4.2/§5.4).
//!
//! Physical model: a strip of depth D occupies D rows × `cells_per_weight`
//! cell-columns. Arrays are provisioned whole; idle rows/columns inside a
//! provisioned array are the unstructured-sparsity waste of §2.2.
//!
//! Two strategies:
//!
//! * [`MappingStrategy::Origin`] — the paper's ORIGIN baseline: strips stay
//!   at their natural (kernel-order) positions, each layer tiles its own
//!   arrays, and every provisioned array converts *all* of its columns each
//!   phase (holes cannot be skipped).
//! * [`MappingStrategy::Packed`] — the paper's dynamic-clustering mapping:
//!   partial sums merge digitally (§4.3), so array row-slots activate in
//!   time-multiplexed phases and any strip can occupy any free slot — of
//!   any layer. Per precision tier, strip slots from all layers are packed
//!   into array columns first-fit-decreasing by slot height; only each
//!   layer's own slots convert during its phases. Residual waste is the
//!   `rows mod D` stub no slot can cover plus the ragged final array —
//!   which is why packed utilization saturates below 100% (the paper's
//!   ~84%), not at it.

use crate::model::ModelInfo;
use crate::quant::BitMap;

use super::XbarConfig;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingStrategy {
    Origin,
    Packed,
}

/// Per-(layer, tier) accounting consumed by the energy model.
#[derive(Clone, Debug, Default)]
pub struct TierMapping {
    pub bits: u8,
    /// Cell columns converted per output pixel per input-bit phase.
    pub cellcols: u64,
    /// Programmed (weight-bearing) cells of this layer's strips.
    pub used_cells: u64,
    /// Word lines driven per pixel per phase.
    pub driven_rows: u64,
    /// Strips placed.
    pub strips: usize,
    /// Arrays this layer provisions on its own (Origin); 0 under Packed
    /// (arrays are pooled per tier — see `ModelMapping::summary`).
    pub arrays_local: usize,
}

impl TierMapping {
    /// Backwards-compatible helper used by the cost model.
    pub fn cellcols(&self, _cfg: &XbarConfig) -> u64 {
        self.cellcols
    }
}

/// Whole-tier provisioning summary (arrays are pooled across layers under
/// the packed strategy).
#[derive(Clone, Debug)]
pub struct TierSummary {
    pub bits: u8,
    pub arrays: usize,
    pub used_cells: u64,
    pub provisioned_cells: u64,
}

impl TierSummary {
    pub fn utilization(&self) -> f64 {
        if self.provisioned_cells == 0 {
            0.0
        } else {
            self.used_cells as f64 / self.provisioned_cells as f64
        }
    }
}

/// Mapping of one conv layer (both tiers).
#[derive(Clone, Debug)]
pub struct LayerMapping {
    pub layer: usize,
    pub name: String,
    pub out_pixels: usize,
    pub tiers: Vec<TierMapping>,
}

/// Whole-model mapping.
#[derive(Clone, Debug)]
pub struct ModelMapping {
    pub strategy: MappingStrategy,
    pub layers: Vec<LayerMapping>,
    pub summary: Vec<TierSummary>,
}

impl ModelMapping {
    /// Bit utilization over arrays of a given weight precision (Table 4).
    pub fn utilization(&self, bits: u8) -> f64 {
        self.summary
            .iter()
            .find(|t| t.bits == bits)
            .map(TierSummary::utilization)
            .unwrap_or(0.0)
    }

    /// Overall utilization across all tiers.
    pub fn utilization_all(&self) -> f64 {
        let used: u64 = self.summary.iter().map(|t| t.used_cells).sum();
        let prov: u64 = self.summary.iter().map(|t| t.provisioned_cells).sum();
        if prov == 0 {
            0.0
        } else {
            used as f64 / prov as f64
        }
    }

    pub fn total_arrays(&self) -> usize {
        self.summary.iter().map(|t| t.arrays).sum()
    }

    /// Machine-readable stage-artifact summary (per-tier provisioning).
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj(vec![
            (
                "strategy",
                Value::Str(
                    match self.strategy {
                        MappingStrategy::Origin => "origin",
                        MappingStrategy::Packed => "packed",
                    }
                    .to_string(),
                ),
            ),
            (
                "tiers",
                Value::Arr(
                    self.summary
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("bits", Value::Num(t.bits as f64)),
                                ("arrays", Value::Num(t.arrays as f64)),
                                ("used_cells", Value::Num(t.used_cells as f64)),
                                ("provisioned_cells", Value::Num(t.provisioned_cells as f64)),
                                ("utilization", Value::Num(t.utilization())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Output pixels of a conv layer on the 32×32 CIFAR geometry, derived from
/// the layer naming convention of `python/compile/model.py`.
pub fn out_pixels(name: &str) -> usize {
    if name.starts_with("stem") {
        return 32 * 32;
    }
    if let Some(rest) = name.strip_prefix('s') {
        if let Some(stage) = rest.chars().next().and_then(|c| c.to_digit(10)) {
            let hw = 32usize >> stage.min(2);
            return hw * hw;
        }
    }
    32 * 32
}

fn tier_widths(model: &ModelInfo, bitmap: &BitMap) -> Vec<u8> {
    let mut widths: Vec<u8> = Vec::new();
    for &b in &bitmap.bits {
        if b != 0 && !widths.contains(&b) {
            widths.push(b);
        }
    }
    widths.sort_unstable_by(|a, b| b.cmp(a));
    let _ = model;
    widths
}

/// Map every conv layer of `model` under `bitmap` onto crossbars.
pub fn map_model(
    model: &ModelInfo,
    bitmap: &BitMap,
    cfg: &XbarConfig,
    strategy: MappingStrategy,
) -> ModelMapping {
    assert_eq!(bitmap.bits.len(), model.num_strips());
    let widths = tier_widths(model, bitmap);

    // Per-layer strip counts per tier + occupancy matrices for Origin.
    let mut layers = Vec::new();
    let mut strip_base = 0usize;
    // accumulate global packing inputs: per tier -> chunk heights + used cells
    let mut per_tier: Vec<(u8, Vec<usize>, u64)> =
        widths.iter().map(|&b| (b, Vec::new(), 0u64)).collect();

    for (li, layer) in model.conv_layers().iter().enumerate() {
        let nstrips = layer.num_strips();
        let segs = (layer.d + cfg.rows - 1) / cfg.rows;
        let d_sub = (layer.d + segs - 1) / segs;
        let mut tiers = Vec::new();

        for &bits in &widths {
            let cpw = cfg.cells_per_weight(bits);
            // occupancy over (sub-group, channel) for this tier
            let g_total = layer.k * layer.k * segs;
            let mut occ = vec![vec![false; layer.n]; g_total];
            let mut strips = 0usize;
            for (i, s) in model.strips()[strip_base..strip_base + nstrips].iter().enumerate() {
                if bitmap.bits[strip_base + i] == bits {
                    strips += 1;
                    for seg in 0..segs {
                        occ[s.g * segs + seg][s.n] = true;
                    }
                }
            }
            if strips == 0 {
                continue;
            }
            let used_cells = (strips * layer.d * cpw) as u64;
            let tm = match strategy {
                MappingStrategy::Origin => {
                    let (arrays, driven_rows) = origin_arrays(&occ, d_sub, bits, cfg);
                    TierMapping {
                        bits,
                        // every provisioned column converts each phase
                        cellcols: (arrays * cfg.cols) as u64,
                        used_cells,
                        driven_rows,
                        strips,
                        arrays_local: arrays,
                    }
                }
                MappingStrategy::Packed => {
                    // Channel-group analog summation: the strips of one
                    // output channel stack in a column and their currents
                    // sum natively (they belong to the same dot product).
                    // One ADC conversion per *chunk* (a channel's slots up
                    // to the column height); distinct chunks in a column
                    // are time-multiplexed.
                    let spc = (cfg.rows / d_sub).max(1); // sub-slots per chunk
                    let mut conversions = 0usize;
                    let mut chunk_heights: Vec<usize> = Vec::new();
                    for n in 0..layer.n {
                        let c_n = (0..layer.k * layer.k)
                            .filter(|&g| occ[g * segs][n])
                            .count();
                        if c_n == 0 {
                            continue;
                        }
                        let sub_slots = c_n * segs;
                        let full = sub_slots / spc;
                        let rem = sub_slots % spc;
                        conversions += full + usize::from(rem > 0);
                        for _ in 0..full {
                            chunk_heights.push(spc * d_sub);
                        }
                        if rem > 0 {
                            chunk_heights.push(rem * d_sub);
                        }
                    }
                    let entry = per_tier.iter_mut().find(|(b, _, _)| *b == bits).unwrap();
                    entry.1.extend(chunk_heights);
                    entry.2 += used_cells;
                    TierMapping {
                        bits,
                        cellcols: (conversions * cpw) as u64,
                        used_cells,
                        driven_rows: (strips * segs * d_sub) as u64,
                        strips,
                        arrays_local: 0,
                    }
                }
            };
            if strategy == MappingStrategy::Origin {
                let entry = per_tier.iter_mut().find(|(b, _, _)| *b == bits).unwrap();
                entry.2 += used_cells;
            }
            tiers.push(tm);
        }
        layers.push(LayerMapping {
            layer: li,
            name: layer.name.clone(),
            out_pixels: out_pixels(&layer.name),
            tiers,
        });
        strip_base += nstrips;
    }

    // Global per-tier provisioning summary.
    let mut summary = Vec::new();
    for (bits, chunks, used_cells) in per_tier {
        let arrays = match strategy {
            MappingStrategy::Origin => layers
                .iter()
                .flat_map(|l| &l.tiers)
                .filter(|t| t.bits == bits)
                .map(|t| t.arrays_local)
                .sum(),
            MappingStrategy::Packed => pack_columns(chunks, bits, cfg),
        };
        if used_cells == 0 && arrays == 0 {
            continue;
        }
        summary.push(TierSummary {
            bits,
            arrays,
            used_cells,
            provisioned_cells: (arrays * cfg.rows * cfg.cols) as u64,
        });
    }

    ModelMapping { strategy, layers, summary }
}

/// Natural-order tiling: group-blocks and channels in kernel order; an array
/// is provisioned whenever any of its cells is used. Returns (arrays,
/// driven_rows).
fn origin_arrays(occ: &[Vec<bool>], d_sub: usize, bits: u8, cfg: &XbarConfig) -> (usize, u64) {
    let g_total = occ.len();
    let n_total = occ[0].len();
    let wcols = cfg.weight_cols_per_array(bits).max(1);
    let gpa = (cfg.rows / d_sub).max(1);

    let mut arrays = 0usize;
    let mut driven_rows = 0u64;
    for g0 in (0..g_total).step_by(gpa) {
        for n0 in (0..n_total).step_by(wcols) {
            let mut any = false;
            let mut max_g_used = 0usize;
            for (gi, row) in occ.iter().enumerate().skip(g0).take(gpa.min(g_total - g0)) {
                for cell in row.iter().skip(n0).take(wcols.min(n_total - n0)) {
                    if *cell {
                        any = true;
                        max_g_used = max_g_used.max(gi - g0 + 1);
                    }
                }
            }
            if any {
                arrays += 1;
                driven_rows += (max_g_used * d_sub) as u64;
            }
        }
    }
    (arrays, driven_rows)
}

/// Global first-fit-decreasing column packing for the packed strategy:
/// channel-group chunks (heights in rows, from any layer of this tier) fill
/// columns of height `rows`; arrays hold `weight_cols_per_array` columns.
fn pack_columns(mut chunks: Vec<usize>, bits: u8, cfg: &XbarConfig) -> usize {
    if chunks.is_empty() {
        return 0;
    }
    let wcols = cfg.weight_cols_per_array(bits).max(1);
    chunks.sort_unstable_by(|a, b| b.cmp(a));
    let mut columns: Vec<usize> = Vec::new(); // remaining heights
    for h in chunks {
        let h = h.min(cfg.rows);
        match columns.iter_mut().find(|rem| **rem >= h) {
            Some(rem) => *rem -= h,
            None => columns.push(cfg.rows - h),
        }
    }
    let ncols = columns.len();
    (ncols + wcols - 1) / wcols
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry};
    use std::collections::HashMap;

    fn model_1layer(k: usize, d: usize, n: usize) -> ModelInfo {
        ModelInfo::new(ModelEntry {
            name: "toy".into(),
            num_params: k * k * d * n,
            num_conv_params: k * k * d * n,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![k * k * d * n], dtype: "f32".into() },
            layers: vec![LayerEntry {
                name: "s1.b0.conv1".into(),
                shape: vec![k, k, d, n],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            }],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        })
    }

    #[test]
    fn out_pixels_by_stage() {
        assert_eq!(out_pixels("stem.conv"), 1024);
        assert_eq!(out_pixels("s0.b0.conv1"), 1024);
        assert_eq!(out_pixels("s1.b0.conv2"), 256);
        assert_eq!(out_pixels("s2.b2.shortcut"), 64);
    }

    #[test]
    fn dense_8bit_layer_full_packing() {
        // K²D = 288 rows of strips, N=64 channels at 4 cells/weight.
        let m = model_1layer(3, 32, 64);
        let bm = BitMap::uniform(m.num_strips(), 8);
        let cfg = XbarConfig::default();
        let packed = map_model(&m, &bm, &cfg, MappingStrategy::Packed);
        let origin = map_model(&m, &bm, &cfg, MappingStrategy::Origin);
        // used cells identical under both strategies (same weights stored)
        assert_eq!(packed.summary[0].used_cells, origin.summary[0].used_cells);
        // 576 strips × 32 rows = 18432 slot-rows; column=128 rows holds 4
        // slots -> 144 columns -> ceil(144/32) = 5 arrays (origin: 3×2=6)
        assert_eq!(packed.summary[0].arrays, 5);
        assert_eq!(origin.summary[0].arrays, 6);
        assert!(packed.utilization(8) > 0.85, "{}", packed.utilization(8));
        assert!(packed.utilization(8) >= origin.utilization(8));
    }

    #[test]
    fn packed_beats_origin_on_sparse_tier() {
        let m = model_1layer(3, 32, 64);
        // 20% of strips hi (every 5th strip), rest lo — the Table 4 regime.
        let mut bits = vec![4u8; m.num_strips()];
        for i in (0..bits.len()).step_by(5) {
            bits[i] = 8;
        }
        let bm = BitMap { bits };
        let cfg = XbarConfig::default();
        let packed = map_model(&m, &bm, &cfg, MappingStrategy::Packed);
        let origin = map_model(&m, &bm, &cfg, MappingStrategy::Origin);
        let (pu, ou) = (packed.utilization(8), origin.utilization(8));
        assert!(pu > ou, "packed {pu} should beat origin {ou}");
        assert!(pu > 0.5, "packed should be dense, got {pu}");
    }

    #[test]
    fn deep_strips_split_vertically() {
        let m = model_1layer(1, 64, 8);
        let bm = BitMap::uniform(m.num_strips(), 8);
        let cfg = XbarConfig::small(); // 32 rows: D=64 -> 2 segments
        let mm = map_model(&m, &bm, &cfg, MappingStrategy::Packed);
        // every cell of every strip placed: 64 rows × 4 cells × 8 strips
        assert_eq!(mm.summary[0].used_cells, (64 * 4 * 8) as u64);
        // 16 sub-strips of height 32 = 16 columns; 8 weight cols/array (32/4)
        assert_eq!(mm.summary[0].arrays, 2);
        assert!((mm.utilization(8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pruned_strips_are_not_mapped() {
        let m = model_1layer(3, 16, 4);
        let mut bits = vec![0u8; m.num_strips()];
        bits[0] = 8;
        let bm = BitMap { bits };
        let mm = map_model(&m, &bm, &XbarConfig::default(), MappingStrategy::Packed);
        assert_eq!(mm.layers[0].tiers.len(), 1);
        assert_eq!(mm.layers[0].tiers[0].strips, 1);
        assert_eq!(mm.summary.len(), 1);
    }

    #[test]
    fn packed_conversions_count_channel_chunks() {
        // K=3, D=16, N=8, dense: each channel has 9 strips; a 128-row
        // column holds 8 -> 2 chunks per channel; 8 channels × 2 × 4 cells.
        let m = model_1layer(3, 16, 8);
        let bm = BitMap::uniform(m.num_strips(), 8);
        let cfg = XbarConfig::default();
        let packed = map_model(&m, &bm, &cfg, MappingStrategy::Packed);
        let t = &packed.layers[0].tiers[0];
        assert_eq!(t.cellcols, (8 * 2 * 4) as u64);
        let origin = map_model(&m, &bm, &cfg, MappingStrategy::Origin);
        let to = &origin.layers[0].tiers[0];
        assert_eq!(to.cellcols, (to.arrays_local * cfg.cols) as u64);
    }

    #[test]
    fn conversion_tradeoff_dense_vs_sparse() {
        // Dense tier with a column-filling channel count: packed equals
        // origin conversions (same analog summation, no wasted columns).
        // Sparse tier: origin pays for holes; packed only for live chunks.
        let m = model_1layer(3, 16, 32); // N = weight_cols_per_array(8)
        let cfg = XbarConfig::default();
        let dense = BitMap::uniform(m.num_strips(), 8);
        let od = map_model(&m, &dense, &cfg, MappingStrategy::Origin).layers[0].tiers[0].cellcols;
        let pd = map_model(&m, &dense, &cfg, MappingStrategy::Packed).layers[0].tiers[0].cellcols;
        assert_eq!(od, pd, "dense full-width layer: origin {od} == packed {pd}");

        let mut bits = vec![4u8; m.num_strips()];
        for b in bits.iter_mut().step_by(9) {
            *b = 8; // 1-in-9 hi strips
        }
        let sparse = BitMap { bits };
        let os = map_model(&m, &sparse, &cfg, MappingStrategy::Origin).layers[0].tiers[0].cellcols;
        let ps = map_model(&m, &sparse, &cfg, MappingStrategy::Packed).layers[0].tiers[0].cellcols;
        assert!(ps < os, "sparse: packed {ps} should be < origin {os}");
    }

    #[test]
    fn cross_layer_pooling_shares_arrays() {
        // two small layers, each needing half an array, share one.
        let l = 1 * 1 * 32 * 8; // 8 strips × 32 rows = 8 columns at 4 slots...
        let m = ModelInfo::new(ModelEntry {
            name: "two".into(),
            num_params: 2 * l,
            num_conv_params: 2 * l,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![2 * l], dtype: "f32".into() },
            layers: vec![
                LayerEntry {
                    name: "s1.a".into(),
                    shape: vec![1, 1, 32, 8],
                    kind: "conv".into(),
                    theta_offset: 0,
                    convflat_offset: Some(0),
                },
                LayerEntry {
                    name: "s1.b".into(),
                    shape: vec![1, 1, 32, 8],
                    kind: "conv".into(),
                    theta_offset: l,
                    convflat_offset: Some(l),
                },
            ],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        });
        let bm = BitMap::uniform(m.num_strips(), 8);
        let cfg = XbarConfig::default();
        let packed = map_model(&m, &bm, &cfg, MappingStrategy::Packed);
        // 16 slots of height 32: 4 per column -> 4 columns -> 1 array (32 cols)
        assert_eq!(packed.summary[0].arrays, 1);
        let origin = map_model(&m, &bm, &cfg, MappingStrategy::Origin);
        assert_eq!(origin.summary[0].arrays, 2, "origin cannot share across layers");
    }
}
