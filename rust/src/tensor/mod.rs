//! Minimal dense f32 tensor + the binary artifact IO contract.
//!
//! Artifacts are raw little-endian f32 buffers; shapes live in
//! `manifest.json` (see `python/compile/aot.py::write_bin`).

use std::io::Read;
use std::path::Path;

use crate::Result;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Rows `lo..hi` along the leading axis.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        assert!(!self.shape.is_empty() && hi <= self.shape[0] && lo <= hi);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor::new(shape, self.data[lo * row..hi * row].to_vec())
    }

    /// Argmax along the last axis; returns indices, flattened over leading axes.
    pub fn argmax_last(&self) -> Vec<usize> {
        let k = *self.shape.last().expect("rank >= 1");
        self.data
            .chunks_exact(k)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Load a raw little-endian f32 file with the given shape.
    pub fn load_bin(path: &Path, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
        let mut bytes = Vec::with_capacity(n * 4);
        f.read_to_end(&mut bytes)?;
        anyhow::ensure!(
            bytes.len() == n * 4,
            "{}: expected {} bytes for shape {shape:?}, got {}",
            path.display(),
            n * 4,
            bytes.len()
        );
        let data = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Tensor::new(shape, data))
    }

    pub fn save_bin(&self, path: &Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_contract() {
        let t = Tensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 5]);
    }

    #[test]
    fn slice_rows_takes_leading_axis() {
        let t = Tensor::new(vec![3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let s = t.slice_rows(1, 3);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[10., 11., 20., 21.]);
    }

    #[test]
    fn argmax_last_rowwise() {
        let t = Tensor::new(vec![2, 3], vec![0., 5., 2., 9., 1., 1.]);
        assert_eq!(t.argmax_last(), vec![1, 0]);
    }

    fn temp_path(name: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "reram_mpq_test_{}_{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn bin_roundtrip() {
        let p = temp_path("roundtrip.bin");
        let t = Tensor::new(vec![2, 2], vec![1.5, -2.0, 3.25, 0.0]);
        t.save_bin(&p).unwrap();
        let u = Tensor::load_bin(&p, vec![2, 2]).unwrap();
        assert_eq!(t, u);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn bin_size_mismatch_errors() {
        let p = temp_path("mismatch.bin");
        std::fs::write(&p, [0u8; 12]).unwrap();
        assert!(Tensor::load_bin(&p, vec![2, 2]).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
