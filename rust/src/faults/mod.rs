//! Device-variability fault scenarios for the crossbar simulator.
//!
//! Real ReRAM arrays do not stay where you programmed them: conductances
//! drift over time, individual cells get stuck at G_on/G_off, long bit-lines
//! lose drive to IR drop, and every read adds noise. The simulator's only
//! non-ideality so far was program-time Gaussian noise
//! (`SimXbarConfig::noise_sigma`); this module adds the composable failure
//! modes the RRAM open-issues literature enumerates, so the paper's central
//! claim — Hutchinson sensitivity scores predict which strips tolerate
//! device non-idealities — becomes testable end to end.
//!
//! ## Scenario composition
//!
//! A [`ScenarioSpec`] bundles four independently seeded components, each
//! inactive at its zero value and freely combinable:
//!
//! * **drift** — every programmed cell decays multiplicatively over a
//!   virtual time axis, `v ← round(v · exp(−time · rate · u))` with a
//!   per-cell jitter `u ∈ [0.5, 1.5)`;
//! * **stuck** — each cell is independently stuck, with probability
//!   `rate`, at G_on (full-scale) or G_off (zero), coin-flipped per cell;
//! * **ir_drop** — a per-column multiplicative loss on the strip scale,
//!   growing linearly with the column's physical slot position (`strength ·
//!   slot/(nslots−1) · u`), the classic far-end-of-the-bit-line gradient;
//! * **read_noise** — additive Gaussian noise on each read-out lane,
//!   rounded into code space.
//!
//! Faults are injected by [`crate::backend::ProgrammedModel::program_with`]
//! as a **post-programming transform on integer weight codes and strip
//! scales** — before the per-mode store encoding — so the
//! `ExecMode::{Exact, Packed, Analog}` paths all see the *same* injected
//! faults by construction, and the zero-alloc `walk_channels` hot path
//! stays a read-only walk over (faulted) tiles. Every random draw is keyed
//! by `(component seed, layer, physical slot, cell, polarity)` through
//! fresh [`Rng`] streams, never by evaluation order, so injection is
//! bit-deterministic per `(spec, seed)` under any shard count.
//!
//! ## Sensitivity-aware placement
//!
//! Because fault severity is a property of the *physical slot* (its column
//! position, its stuck-cell draws) while importance is a property of the
//! *strip*, the mapping between them is a free parameter. With
//! [`Placement::SensitivityAware`], [`assign_slots`] permutes the
//! strip→slot assignment so the highest-sensitivity strips land on the
//! healthiest slots ([`slot_damage`] ranks slots by replaying exactly the
//! per-slot fault draws injection will use). The permutation is a bijection
//! over the live strips of each layer, is recorded per strip in the
//! programmed index (`ProgrammedStrip::slot`), and only remaps *fault*
//! draws — walk order, channel ranges and accumulation order are untouched,
//! so a zero-fault scenario is bit-identical to the unfaulted path no
//! matter the placement mode.
//!
//! ## Runtime evolution and self-healing
//!
//! Real devices keep degrading *after* programming. An [`EvolutionSpec`]
//! adds a logical-clock time axis to a spec: drift time and the stuck-at
//! probability advance per served batch ([`ScenarioSpec::at_tick`] derives
//! the effective static spec at tick `t`, reusing the same per-site seeded
//! streams). A [`HealthSpec`] on the bound [`Scenario`] reserves
//! known-answer *canary* strips and *spare* column slots per layer at
//! programming time; the serving-side [`crate::health`] monitor replays the
//! canaries against the evolved spec to detect damage, and repairs by
//! re-programming a standby artifact at the current tick —
//! [`assign_slots_spares`] then moves the highest-sensitivity strips onto
//! the healthiest of the live+spare slot pool, exactly the
//! [`slot_damage`]-ranked placement used at deploy time. [`Scenario::tick`]
//! carries the logical programming time and enters the fingerprint, so
//! artifacts programmed at different ticks never alias in any cache.

use std::sync::Arc;

use crate::util::rng::Rng;

/// The per-(seed, layer, strip) conductance-noise stream shared — by
/// construction, and now by code — between the programmed artifact
/// ([`crate::backend::ProgrammedModel::program`]) and the
/// re-quantize-per-call reference path (`conv_bitserial_reference`). A given
/// strip programs the same array state regardless of which path derives it,
/// which shard evaluates it, or in what order — the invariant behind the
/// programmed-vs-reference bit-identity property tests.
pub struct NoiseStream;

impl NoiseStream {
    /// Fresh stream for one strip's analog programming noise.
    pub fn for_strip(seed: u64, layer_index: usize, strip: usize) -> Rng {
        Rng::seed_from_u64(
            seed ^ (layer_index as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ (strip as u64 + 1).wrapping_mul(0xbf58_476d_1ce4_e5b9),
        )
    }
}

const DRIFT_SALT: u64 = 0xd21f_7a11_5eed_0001;
const STUCK_SALT: u64 = 0xd21f_7a11_5eed_0002;
const IR_SALT: u64 = 0xd21f_7a11_5eed_0003;
const READ_SALT: u64 = 0xd21f_7a11_5eed_0004;

fn fnv(vals: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in vals {
        h = (h ^ v).wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Fresh stream for one fault site. Keyed per (component, spec seed, layer,
/// physical slot, site), where `site` encodes whatever sub-structure the
/// component faults over (cell slice × polarity for drift/stuck, 0 for the
/// per-slot ir-drop/read-noise streams). Site-keyed seeding — rather than
/// one long per-slot stream — is what lets [`slot_damage`] replay a slot's
/// draws exactly even before it knows the cell count of the strip that
/// placement will put there.
fn site_rng(salt: u64, seed: u64, layer_index: usize, slot: usize, site: u64) -> Rng {
    Rng::seed_from_u64(fnv(&[salt, seed, layer_index as u64, slot as u64, site]))
}

/// Conductance drift over a virtual time axis.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DriftSpec {
    /// Virtual elapsed time since programming (arbitrary units).
    pub time: f64,
    /// Mean decay rate per unit time.
    pub rate: f64,
    pub seed: u64,
}

impl DriftSpec {
    pub fn is_active(&self) -> bool {
        self.time > 0.0 && self.rate > 0.0
    }
}

/// Stuck-at-G_on / stuck-at-G_off cells at a given rate.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StuckSpec {
    /// Per-cell probability of being stuck (G_on or G_off, coin-flipped).
    pub rate: f64,
    pub seed: u64,
}

impl StuckSpec {
    pub fn is_active(&self) -> bool {
        self.rate > 0.0
    }
}

/// Per-column IR drop: a multiplicative loss on the strip scale growing
/// with the column's physical slot position.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct IrDropSpec {
    /// Loss at the far end of the bit-line (slot `nslots-1`), before the
    /// per-column jitter; clamped so a strip never loses its full scale.
    pub strength: f64,
    pub seed: u64,
}

impl IrDropSpec {
    pub fn is_active(&self) -> bool {
        self.strength > 0.0
    }
}

/// Additive Gaussian read noise per output lane, rounded into code space.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReadNoiseSpec {
    /// Standard deviation in integer-code units.
    pub sigma: f64,
    pub seed: u64,
}

impl ReadNoiseSpec {
    pub fn is_active(&self) -> bool {
        self.sigma > 0.0
    }
}

/// Runtime fault evolution: how much the spec's drift time and stuck-at
/// probability advance per logical serving tick (one tick = one served
/// batch, counted per engine worker). The zero value is inactive: the
/// device stays exactly where programming left it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvolutionSpec {
    /// Added to `drift.time` per tick (drift needs `drift.rate > 0` and a
    /// drift seed to act, exactly like the static axis).
    pub drift_time_per_tick: f64,
    /// Added to `stuck.rate` per tick, saturating at 1.0.
    pub stuck_rate_per_tick: f64,
}

impl EvolutionSpec {
    pub fn is_active(&self) -> bool {
        self.drift_time_per_tick > 0.0 || self.stuck_rate_per_tick > 0.0
    }
}

/// Per-layer health reservation programmed alongside the live strips:
/// known-answer canary strips (damage detectors) and spare column slots
/// (repair targets). Both live on slot indices past every walkable strip,
/// so inference never reads them and the zero value changes nothing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthSpec {
    /// Known-answer canary strips reserved per layer.
    pub canaries: u32,
    /// Spare column slots reserved per layer for hot repair.
    pub spares: u32,
}

impl HealthSpec {
    pub fn is_active(&self) -> bool {
        self.canaries > 0 || self.spares > 0
    }
}

/// A composable device-variability scenario. `Default` is the inactive
/// (zero-fault) scenario, which is bit-identical to not injecting at all.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ScenarioSpec {
    pub drift: DriftSpec,
    pub stuck: StuckSpec,
    pub ir_drop: IrDropSpec,
    pub read_noise: ReadNoiseSpec,
    /// Per-tick runtime degradation (inactive = static program-time faults
    /// only, today's behavior).
    pub evolution: EvolutionSpec,
}

impl ScenarioSpec {
    pub fn with_drift(mut self, time: f64, rate: f64, seed: u64) -> Self {
        self.drift = DriftSpec { time, rate, seed };
        self
    }

    pub fn with_stuck(mut self, rate: f64, seed: u64) -> Self {
        self.stuck = StuckSpec { rate, seed };
        self
    }

    pub fn with_ir_drop(mut self, strength: f64, seed: u64) -> Self {
        self.ir_drop = IrDropSpec { strength, seed };
        self
    }

    pub fn with_read_noise(mut self, sigma: f64, seed: u64) -> Self {
        self.read_noise = ReadNoiseSpec { sigma, seed };
        self
    }

    pub fn with_evolution(mut self, drift_time_per_tick: f64, stuck_rate_per_tick: f64) -> Self {
        self.evolution = EvolutionSpec { drift_time_per_tick, stuck_rate_per_tick };
        self
    }

    /// The effective static spec after `tick` logical serving ticks: drift
    /// time and the stuck-at rate advanced per [`EvolutionSpec`], everything
    /// else (seeds included) untouched. Identity at tick 0 or when evolution
    /// is inactive, so static scenarios are exactly the `tick == 0` slice.
    pub fn at_tick(&self, tick: u64) -> ScenarioSpec {
        if tick == 0 || !self.evolution.is_active() {
            return *self;
        }
        let t = tick as f64;
        let mut s = *self;
        s.drift.time += self.evolution.drift_time_per_tick * t;
        s.stuck.rate = (s.stuck.rate + self.evolution.stuck_rate_per_tick * t).min(1.0);
        s
    }

    /// True when any component would perturb a programmed strip, now or at
    /// a later tick (an evolving spec is active even if its tick-0 slice is
    /// a no-op — programming must reserve the placement machinery up front).
    pub fn is_active(&self) -> bool {
        self.drift.is_active()
            || self.stuck.is_active()
            || self.ir_drop.is_active()
            || self.read_noise.is_active()
            || self.evolution.is_active()
    }

    /// Stable content hash, mixed into programming-artifact and eval-memo
    /// cache keys so faulted and unfaulted artifacts never alias.
    pub fn fingerprint(&self) -> u64 {
        fnv(&[
            self.drift.time.to_bits(),
            self.drift.rate.to_bits(),
            self.drift.seed,
            self.stuck.rate.to_bits(),
            self.stuck.seed,
            self.ir_drop.strength.to_bits(),
            self.ir_drop.seed,
            self.read_noise.sigma.to_bits(),
            self.read_noise.seed,
            self.evolution.drift_time_per_tick.to_bits(),
            self.evolution.stuck_rate_per_tick.to_bits(),
        ])
    }

    /// Human-readable one-liner of the active components ("none" when
    /// inactive) — the payload of the serving stats `scenario:` line.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.drift.is_active() {
            parts.push(format!("drift(t={},rate={})", self.drift.time, self.drift.rate));
        }
        if self.stuck.is_active() {
            parts.push(format!("stuck(rate={})", self.stuck.rate));
        }
        if self.ir_drop.is_active() {
            parts.push(format!("ir_drop(strength={})", self.ir_drop.strength));
        }
        if self.read_noise.is_active() {
            parts.push(format!("read_noise(sigma={})", self.read_noise.sigma));
        }
        if self.evolution.is_active() {
            parts.push(format!(
                "evolve(drift/tick={},stuck/tick={})",
                self.evolution.drift_time_per_tick, self.evolution.stuck_rate_per_tick
            ));
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(" ")
        }
    }
}

/// How live strips are assigned to physical column slots.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Placement {
    /// Identity: strip `i` lives on slot `i` (today's behavior).
    #[default]
    Naive,
    /// Highest-sensitivity strips on the healthiest slots (needs scores).
    SensitivityAware,
}

impl Placement {
    pub fn name(&self) -> &'static str {
        match self {
            Placement::Naive => "naive",
            Placement::SensitivityAware => "sensitivity",
        }
    }
}

/// A scenario bound to a placement policy and (optionally) the sensitivity
/// scores that drive it — the value carried through `SimXbar`,
/// `BackendSpec::Sim` and the plan terminals to programming time. Scores
/// are in [`crate::model::ModelInfo::strips`] order.
#[derive(Clone, Debug, Default)]
pub struct Scenario {
    pub spec: ScenarioSpec,
    pub placement: Placement,
    pub scores: Option<Arc<Vec<f64>>>,
    /// Canary/spare reservation programmed into every layer (zero = none).
    pub health: HealthSpec,
    /// Logical serving tick this scenario programs at: the spec is evaluated
    /// as [`ScenarioSpec::at_tick`]`(tick)`. 0 = deploy time.
    pub tick: u64,
}

impl Scenario {
    pub fn new(spec: ScenarioSpec) -> Self {
        Scenario {
            spec,
            placement: Placement::Naive,
            scores: None,
            health: HealthSpec::default(),
            tick: 0,
        }
    }

    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    pub fn with_scores(mut self, scores: Arc<Vec<f64>>) -> Self {
        self.scores = Some(scores);
        self
    }

    /// Reserve canary strips and spare slots per layer.
    pub fn with_health(mut self, health: HealthSpec) -> Self {
        self.health = health;
        self
    }

    /// The same scenario advanced to logical tick `tick` (the standby
    /// re-programming path: base scenario + current serving clock).
    pub fn with_tick(mut self, tick: u64) -> Self {
        self.tick = tick;
        self
    }

    /// The static spec this scenario actually programs: the base spec
    /// evolved to [`Scenario::tick`].
    pub fn effective_spec(&self) -> ScenarioSpec {
        self.spec.at_tick(self.tick)
    }

    /// Active when the spec perturbs anything (now or later) *or* a health
    /// reservation is requested — canaries and spares must be programmed
    /// even on an otherwise healthy device so probes have something to read.
    pub fn is_active(&self) -> bool {
        self.spec.is_active() || self.health.is_active()
    }

    /// Content hash over spec, placement, scores, health reservation and
    /// tick (cache-key grade): artifacts programmed at different ticks or
    /// with different reservations never alias.
    pub fn fingerprint(&self) -> u64 {
        let mut vals = vec![
            self.spec.fingerprint(),
            match self.placement {
                Placement::Naive => 1,
                Placement::SensitivityAware => 2,
            },
            self.health.canaries as u64,
            self.health.spares as u64,
            self.tick,
        ];
        if let Some(s) = &self.scores {
            vals.push(s.len() as u64);
            vals.extend(s.iter().map(|v| v.to_bits()));
        }
        fnv(&vals)
    }

    /// The serving stats `scenario:` line: active spec + placement mode,
    /// plus the health reservation and tick when present.
    pub fn describe(&self) -> String {
        if !self.is_active() {
            return "none".to_string();
        }
        let mut s = format!("{} placement={}", self.spec.describe(), self.placement.name());
        if self.health.is_active() {
            s.push_str(&format!(
                " health(canaries={},spares={})",
                self.health.canaries, self.health.spares
            ));
        }
        if self.tick > 0 {
            s.push_str(&format!(" tick={}", self.tick));
        }
        s
    }
}

/// Inject one strip's faults in place: drift and stuck-at on the
/// sign-magnitude cell decomposition of the integer weight codes, read
/// noise on the assembled codes, IR drop on the strip scale. `slot` is the
/// strip's *physical* column slot (the placement-assigned one), `nslots`
/// the layer's slot count, `ncells` the strip's cell-slice count.
///
/// Faults act in code space: a stuck cell collapses into the signed lane
/// value, so all three `ExecMode` stores encode identical faulted weights.
/// Inactive components draw nothing, so the zero scenario is a no-op.
#[allow(clippy::too_many_arguments)]
pub fn apply_to_strip(
    spec: &ScenarioSpec,
    layer_index: usize,
    slot: usize,
    nslots: usize,
    cell_bits: u8,
    ncells: usize,
    codes_w: &mut [i32],
    sw: &mut f32,
) {
    let cb = cell_bits as u32;
    let mask = (1u32 << cb) - 1;
    let d = codes_w.len();

    if spec.drift.is_active() || spec.stuck.is_active() {
        let mut tot = vec![0i64; d];
        for pol in 0..2u64 {
            for j in 0..ncells {
                let site = (pol << 8) | j as u64;
                let mut drift_rng = spec
                    .drift
                    .is_active()
                    .then(|| site_rng(DRIFT_SALT, spec.drift.seed, layer_index, slot, site));
                let mut stuck_rng = spec
                    .stuck
                    .is_active()
                    .then(|| site_rng(STUCK_SALT, spec.stuck.seed, layer_index, slot, site));
                for (dd, t) in tot.iter_mut().enumerate() {
                    let c = codes_w[dd];
                    let mag = if pol == 0 { c.max(0) } else { (-c).max(0) } as u32;
                    let mut v = (mag >> (j as u32 * cb)) & mask;
                    if let Some(rng) = drift_rng.as_mut() {
                        let u = rng.range(0.5, 1.5);
                        let decay = (-spec.drift.time * spec.drift.rate * u).exp();
                        v = (v as f64 * decay).round() as u32;
                    }
                    if let Some(rng) = stuck_rng.as_mut() {
                        if rng.uniform() < spec.stuck.rate {
                            v = if rng.bool() { mask } else { 0 };
                        }
                    }
                    let sv = (v as i64) << (j as u32 * cb);
                    *t += if pol == 0 { sv } else { -sv };
                }
            }
        }
        for (c, t) in codes_w.iter_mut().zip(&tot) {
            *c = *t as i32;
        }
    }

    if spec.read_noise.is_active() {
        let cap = (1i64 << (ncells as u32 * cb)) - 1;
        let mut rng = site_rng(READ_SALT, spec.read_noise.seed, layer_index, slot, 0);
        for c in codes_w.iter_mut() {
            let delta = (rng.normal() as f64 * spec.read_noise.sigma).round() as i64;
            *c = (*c as i64 + delta).clamp(-cap, cap) as i32;
        }
    }

    if spec.ir_drop.is_active() {
        *sw *= (1.0 - ir_drop_of(spec, layer_index, slot, nslots)) as f32;
    }
}

/// The deterministic per-slot IR-drop fraction (0 when inactive).
fn ir_drop_of(spec: &ScenarioSpec, layer_index: usize, slot: usize, nslots: usize) -> f64 {
    if !spec.ir_drop.is_active() {
        return 0.0;
    }
    let col_frac = if nslots > 1 { slot as f64 / (nslots - 1) as f64 } else { 0.0 };
    let mut rng = site_rng(IR_SALT, spec.ir_drop.seed, layer_index, slot, 0);
    (spec.ir_drop.strength * col_frac * rng.range(0.5, 1.5)).clamp(0.0, 0.95)
}

/// Expected damage a strip of `ncells` cell slices and `d` lanes would
/// suffer on physical slot `slot`, in (approximate) integer-code units.
/// Replays exactly the per-slot draws [`apply_to_strip`] will consume —
/// same site streams — so a slot whose stuck-cell draws happen to hit
/// high-significance cells ranks as damaged *before* anything is placed on
/// it. Placement sorts slots by this value.
pub fn slot_damage(
    spec: &ScenarioSpec,
    layer_index: usize,
    slot: usize,
    nslots: usize,
    cell_bits: u8,
    ncells: usize,
    d: usize,
) -> f64 {
    let cb = cell_bits as u32;
    let mask = (1u32 << cb) - 1;
    let mid = mask as f64 * 0.5;
    let mut damage = 0.0;

    if spec.drift.is_active() || spec.stuck.is_active() {
        for pol in 0..2u64 {
            for j in 0..ncells {
                let site = (pol << 8) | j as u64;
                let w = (1u64 << (j as u32 * cb)) as f64;
                let mut drift_rng = spec
                    .drift
                    .is_active()
                    .then(|| site_rng(DRIFT_SALT, spec.drift.seed, layer_index, slot, site));
                let mut stuck_rng = spec
                    .stuck
                    .is_active()
                    .then(|| site_rng(STUCK_SALT, spec.stuck.seed, layer_index, slot, site));
                for _ in 0..d {
                    if let Some(rng) = drift_rng.as_mut() {
                        let u = rng.range(0.5, 1.5);
                        let decay = (-spec.drift.time * spec.drift.rate * u).exp();
                        damage += (1.0 - decay) * mid * w;
                    }
                    if let Some(rng) = stuck_rng.as_mut() {
                        if rng.uniform() < spec.stuck.rate {
                            let target = if rng.bool() { mask as f64 } else { 0.0 };
                            damage += (target - mid).abs() * w;
                        }
                    }
                }
            }
        }
    }

    if spec.read_noise.is_active() {
        let mut rng = site_rng(READ_SALT, spec.read_noise.seed, layer_index, slot, 0);
        for _ in 0..d {
            damage += (rng.normal() as f64 * spec.read_noise.sigma).abs();
        }
    }

    // IR drop scales the whole strip: weight it by the strip's full-scale
    // magnitude so a strong column gradient dominates per-cell effects.
    let drop = ir_drop_of(spec, layer_index, slot, nslots);
    if drop > 0.0 {
        let full = ((1u64 << (ncells as u32 * cb)) - 1) as f64 / mask as f64;
        damage += drop * 2.0 * d as f64 * mid * full;
    }

    damage
}

/// Assign each live strip a physical slot. `live` lists the layer's live
/// local slot indices in ascending order; `scores` (per live strip, same
/// order) and `damage` (per entry of `live`, the damage of that physical
/// slot) drive the sensitivity-aware mode. Returns the assigned slot per
/// live strip — always a bijection onto `live`, and the identity for
/// [`Placement::Naive`] or when scores are absent.
pub fn assign_slots(
    placement: Placement,
    scores: Option<&[f64]>,
    damage: &[f64],
    live: &[usize],
) -> Vec<usize> {
    assign_slots_spares(placement, scores, damage, live, live.len())
}

/// Generalization of [`assign_slots`] with a candidate pool larger than the
/// strip count: `candidates` holds `nstrips` natural slots *plus* reserved
/// spares, with per-candidate `damage`. Sensitivity-aware placement maps the
/// `nstrips` strips onto the healthiest `nstrips` candidates; the most
/// damaged `candidates.len() - nstrips` slots are left unused — that is the
/// quarantine. With no spares (`candidates.len() == nstrips`) this is
/// exactly [`assign_slots`]. Naive placement (or missing scores) ignores the
/// spares and keeps the natural assignment, preserving bit-identity with the
/// spare-less path.
pub fn assign_slots_spares(
    placement: Placement,
    scores: Option<&[f64]>,
    damage: &[f64],
    candidates: &[usize],
    nstrips: usize,
) -> Vec<usize> {
    debug_assert_eq!(damage.len(), candidates.len());
    debug_assert!(nstrips <= candidates.len());
    let scores = match (placement, scores) {
        (Placement::SensitivityAware, Some(s)) if s.len() == nstrips => s,
        _ => return candidates[..nstrips.min(candidates.len())].to_vec(),
    };
    let strip_order = crate::sensitivity::rank_desc(scores);
    let healthiest_first = {
        let neg: Vec<f64> = damage.iter().map(|v| -v).collect();
        crate::sensitivity::rank_desc(&neg)
    };
    let mut out = vec![0usize; nstrips];
    for (rank, &strip) in strip_order.iter().enumerate() {
        out[strip] = candidates[healthiest_first[rank]];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_spec() -> ScenarioSpec {
        ScenarioSpec::default()
            .with_drift(5.0, 0.05, 11)
            .with_stuck(0.3, 22)
            .with_ir_drop(0.4, 33)
            .with_read_noise(1.5, 44)
    }

    #[test]
    fn zero_spec_is_inactive_and_a_noop() {
        let spec = ScenarioSpec::default();
        assert!(!spec.is_active());
        assert_eq!(spec.describe(), "none");
        let mut codes = vec![3, -7, 0, 120];
        let orig = codes.clone();
        let mut sw = 0.25f32;
        apply_to_strip(&spec, 2, 5, 9, 2, 4, &mut codes, &mut sw);
        assert_eq!(codes, orig);
        assert_eq!(sw, 0.25);
    }

    #[test]
    fn injection_is_deterministic_per_spec_and_seed() {
        let spec = busy_spec();
        let mut a = vec![3i32, -7, 0, 120, -128, 64];
        let mut b = a.clone();
        let (mut swa, mut swb) = (0.5f32, 0.5f32);
        apply_to_strip(&spec, 1, 3, 8, 2, 4, &mut a, &mut swa);
        apply_to_strip(&spec, 1, 3, 8, 2, 4, &mut b, &mut swb);
        assert_eq!(a, b);
        assert_eq!(swa, swb);

        // A different component seed reroutes every draw.
        let other = ScenarioSpec { stuck: StuckSpec { rate: 0.3, seed: 99 }, ..spec };
        let mut c = vec![3i32, -7, 0, 120, -128, 64];
        let mut swc = 0.5f32;
        apply_to_strip(&other, 1, 3, 8, 2, 4, &mut c, &mut swc);
        assert_ne!(a, c);
        assert_ne!(spec.fingerprint(), other.fingerprint());
    }

    #[test]
    fn faulted_codes_stay_within_cell_capacity() {
        let spec = busy_spec();
        let (cell_bits, ncells) = (2u8, 4usize);
        let cap = (1i32 << (ncells as u32 * cell_bits as u32)) - 1;
        for slot in 0..32 {
            let mut codes = vec![cap, -cap, 0, 1, -1, cap / 2];
            let mut sw = 1.0f32;
            apply_to_strip(&spec, 0, slot, 32, cell_bits, ncells, &mut codes, &mut sw);
            for &c in &codes {
                assert!(c.abs() <= cap, "slot {slot}: code {c} exceeds cap {cap}");
            }
            assert!(sw > 0.0 && sw <= 1.0);
        }
    }

    #[test]
    fn slot_damage_matches_injection_streams() {
        // A slot whose damage estimate is far above another's must also
        // perturb an actual strip more (same draws, so stuck cells land on
        // the same sites). Compare total |delta| on a mid-scale strip.
        let spec = ScenarioSpec::default().with_stuck(0.25, 7);
        let (cb, nc, d, nslots) = (2u8, 3usize, 16usize, 24usize);
        let mut by_damage: Vec<(f64, f64)> = (0..nslots)
            .map(|slot| {
                let est = slot_damage(&spec, 0, slot, nslots, cb, nc, d);
                let mut codes = vec![21i32; d];
                let mut sw = 1.0f32;
                apply_to_strip(&spec, 0, slot, nslots, cb, nc, &mut codes, &mut sw);
                let actual: f64 = codes.iter().map(|&c| (c - 21).abs() as f64).sum();
                (est, actual)
            })
            .collect();
        by_damage.sort_by(|a, b| a.0.total_cmp(&b.0));
        // Rank correlation, loosely: the healthiest quartile must have less
        // actual damage than the most-damaged quartile.
        let q = nslots / 4;
        let low: f64 = by_damage[..q].iter().map(|x| x.1).sum();
        let high: f64 = by_damage[nslots - q..].iter().map(|x| x.1).sum();
        assert!(low < high, "low={low} high={high}");
    }

    #[test]
    fn assign_slots_is_identity_for_naive_and_bijective_for_aware() {
        let live = vec![0usize, 2, 3, 7, 8];
        let scores = vec![0.1, 5.0, 0.3, 2.0, 0.2];
        let damage = vec![3.0, 0.5, 4.0, 0.0, 1.0];
        assert_eq!(assign_slots(Placement::Naive, Some(&scores), &damage, &live), live);
        assert_eq!(assign_slots(Placement::SensitivityAware, None, &damage, &live), live);

        let out = assign_slots(Placement::SensitivityAware, Some(&scores), &damage, &live);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, live, "assignment must be a bijection onto live slots");
        // Top-score strip (index 1) on the healthiest slot (damage 0.0 →
        // slot 7); runner-up (index 3, score 2.0) on slot 2 (damage 0.5).
        assert_eq!(out[1], 7);
        assert_eq!(out[3], 2);
    }

    #[test]
    fn describe_lists_active_components_and_placement() {
        let sc = Scenario::new(ScenarioSpec::default().with_stuck(0.05, 1))
            .with_placement(Placement::SensitivityAware);
        let d = sc.describe();
        assert!(d.contains("stuck(rate=0.05)"), "{d}");
        assert!(d.contains("placement=sensitivity"), "{d}");
        assert_eq!(Scenario::default().describe(), "none");
    }

    #[test]
    fn scenario_fingerprint_tracks_placement_and_scores() {
        let base = Scenario::new(ScenarioSpec::default().with_stuck(0.05, 1));
        let aware = base.clone().with_placement(Placement::SensitivityAware);
        assert_ne!(base.fingerprint(), aware.fingerprint());
        let scored = aware.clone().with_scores(Arc::new(vec![1.0, 2.0]));
        assert_ne!(aware.fingerprint(), scored.fingerprint());
    }

    #[test]
    fn at_tick_is_identity_without_evolution_and_advances_with_it() {
        let spec = busy_spec();
        assert_eq!(spec.at_tick(0), spec);
        assert_eq!(spec.at_tick(1000), spec, "no evolution -> static forever");

        let evo = spec.with_evolution(0.5, 0.001);
        assert!(evo.is_active());
        assert_eq!(evo.at_tick(0), evo, "tick 0 is the programmed state");
        let t10 = evo.at_tick(10);
        assert_eq!(t10.drift.time, spec.drift.time + 5.0);
        assert!((t10.stuck.rate - (spec.stuck.rate + 0.01)).abs() < 1e-12);
        // Evolution params ride along unchanged; stuck rate saturates at 1.
        assert_eq!(t10.evolution, evo.evolution);
        assert_eq!(evo.at_tick(u64::MAX / 2).stuck.rate, 1.0);

        // Evolution alone activates an otherwise-empty spec…
        let only_evo = ScenarioSpec::default().with_evolution(0.1, 0.0);
        assert!(only_evo.is_active());
        // …but its tick-0 slice is still a no-op on codes.
        let mut codes = vec![5i32, -9, 0];
        let orig = codes.clone();
        let mut sw = 1.0f32;
        apply_to_strip(&only_evo.at_tick(0), 0, 0, 4, 2, 4, &mut codes, &mut sw);
        assert_eq!(codes, orig);
        assert_eq!(sw, 1.0);
    }

    #[test]
    fn fingerprint_tracks_evolution_health_and_tick() {
        let spec = busy_spec();
        assert_ne!(spec.fingerprint(), spec.with_evolution(0.5, 0.0).fingerprint());

        let base = Scenario::new(spec);
        let healthy = base.clone().with_health(HealthSpec { canaries: 2, spares: 3 });
        assert_ne!(base.fingerprint(), healthy.fingerprint());
        let ticked = healthy.clone().with_tick(7);
        assert_ne!(healthy.fingerprint(), ticked.fingerprint());
        assert_eq!(ticked.effective_spec(), spec.at_tick(7));

        // A health reservation activates a scenario even with an empty spec.
        let only_health =
            Scenario::new(ScenarioSpec::default()).with_health(HealthSpec { canaries: 1, spares: 0 });
        assert!(only_health.is_active());
        let d = ticked.describe();
        assert!(d.contains("health(canaries=2,spares=3)"), "{d}");
        assert!(d.contains("tick=7"), "{d}");
    }

    #[test]
    fn assign_slots_spares_quarantines_most_damaged_candidates() {
        // 3 strips, 5 candidates (slots 0..3 natural + 10,11 spare).
        let candidates = vec![0usize, 1, 2, 10, 11];
        let scores = vec![1.0, 1.0, 1.0];
        let damage = vec![5.0, 0.0, 7.0, 0.0, 0.0];
        let out =
            assign_slots_spares(Placement::SensitivityAware, Some(&scores), &damage, &candidates, 3);
        assert_eq!(out.len(), 3);
        // The two most-damaged candidates (slots 0 and 2) must be unused.
        assert!(!out.contains(&0), "{out:?}");
        assert!(!out.contains(&2), "{out:?}");
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "assignment must be injective: {out:?}");

        // Naive placement ignores the spares entirely.
        let naive =
            assign_slots_spares(Placement::Naive, Some(&scores), &damage, &candidates, 3);
        assert_eq!(naive, vec![0, 1, 2]);

        // Zero damage + uniform scores is the identity over the natural
        // slots — the bit-identity guarantee for healthy devices.
        let zero = vec![0.0; 5];
        let id =
            assign_slots_spares(Placement::SensitivityAware, Some(&scores), &zero, &candidates, 3);
        assert_eq!(id, vec![0, 1, 2]);
    }
}
