//! Program-once crossbar artifact: the deploy-time weight-side state of the
//! simulated ReRAM arrays.
//!
//! In a real CIM deployment the crossbar is *programmed once* and then only
//! driven. [`ProgrammedModel::program`] performs every weight-side step of
//! [`crate::backend::SimXbar`]'s bit-serial conv ahead of time, per strip:
//! integer weight codes (re-derived from the quantized parameters and the
//! per-strip scale), pre-packed `u64` weight bit-planes (one per cell slice
//! × cell bit × polarity, interleaved word-major so the SIMD walk loads 4
//! consecutive rows of a segment word at once — see
//! [`pack_weight_rows_into`]), or the analog
//! differential conductance columns (with the seeded per-strip noise draw
//! already applied) — whichever the configured [`ExecMode`] reads at
//! inference time. Pruned (`bits == 0`) and zero-scale strips are dropped
//! from the index entirely, so the inference walk never branches on dead
//! strips.
//!
//! ## Artifact lifetime and cache key
//!
//! The artifact is a pure function of `(ModelInfo, theta, StripPrecision,
//! SimXbarConfig)`. `SimXbar` memoizes one artifact per instance, keyed by
//! an FNV-1a fingerprint over the model identity, the parameter vector,
//! the per-strip bits/scales and the config's fidelity knobs (`threads` is
//! excluded — sharding is bit-identical and shares the artifact). Engine
//! workers program eagerly inside the readiness handshake
//! ([`crate::backend::ExecBackend::ready_check`]); each worker owns its
//! backend — and therefore its own programmed copy, mirroring per-worker
//! crossbar hardware — so programming cost lands at deploy time, never on
//! a request, and scales with the worker count like the arrays themselves
//! would.
//!
//! ## Bit-identity
//!
//! Programming performs exactly the computations the re-quantize-per-call
//! path ([`crate::backend::SimXbar::conv_bitserial_reference`]) performs
//! per conv call, with the same rounding and the same per-(seed, layer,
//! strip) noise stream — so the programmed walk is **bit-identical** to the
//! on-the-fly path for every config corner (property-tested in
//! `tests/properties.rs`).
//!
//! ## Health reservations: canaries and spares
//!
//! When the scenario carries an active [`crate::faults::HealthSpec`], each
//! layer reserves extra physical column slots past its natural `K²·N`
//! range: first `spares` repair slots (candidates for sensitivity-aware
//! placement alongside the natural slots — the most damaged slots of the
//! pooled candidate set are left unused, which is the quarantine), then
//! `canaries` known-answer strips programmed with a deterministic
//! pseudo-random code pattern at unit scale. The serving-side health
//! monitor ([`crate::health`]) replays each canary's expected codes through
//! the *evolved* fault spec and compares against what a fresh programming
//! pass would store — a mismatch means the device has drifted from the
//! programmed artifact. Reserved slots extend the fault-key slot space
//! (`nslots_ext`), so an artifact with reservations draws IR-drop column
//! fractions over the wider array; with health off, `nslots_ext == K²·N`
//! and the artifact is bit-identical to the reservation-free one.
//!
//! ## Fault scenarios
//!
//! [`ProgrammedModel::program_with`] additionally accepts a
//! [`crate::faults::Scenario`]: a composable device-variability spec
//! (conductance drift, stuck-at cells, per-column IR drop, read noise)
//! injected as a post-programming transform on the integer weight codes and
//! the strip scale — *before* the per-mode store encoding — so all three
//! [`ExecMode`]s see identical faults and the read-only inference walk is
//! untouched. Fault draws are keyed by each strip's *physical slot*
//! ([`ProgrammedStrip::slot`]); sensitivity-aware placement permutes the
//! strip→slot assignment per layer so high-sensitivity strips land on
//! healthy slots. An inactive scenario injects nothing and assigns the
//! identity placement, keeping the artifact bit-identical to
//! [`ProgrammedModel::program`].

use std::time::Instant;

use crate::backend::simxbar::{SimXbarConfig, StripPrecision};
use crate::faults::{self, Scenario};
use crate::model::ModelInfo;
use crate::quant;
use crate::Result;

/// u64 words covering a `len`-lane row segment.
#[inline]
pub(crate) fn words_of(len: usize) -> usize {
    len.div_ceil(64)
}

/// Row-segment partition of `d` word lines into ranges of at most `rows`
/// lanes: (lane start, lane count, u64-word offset) per segment, plus the
/// total packed word count. Each segment packs into its own words so
/// popcounts never cross a conversion boundary.
pub(crate) fn segments(d: usize, rows: usize) -> (Vec<(usize, usize, usize)>, usize) {
    let mut segs = Vec::new();
    let mut start = 0usize;
    let mut woff = 0usize;
    while start < d {
        let len = rows.min(d - start);
        segs.push((start, len, woff));
        woff += words_of(len);
        start += len;
    }
    (segs, woff)
}

/// Packed rows (column bit-planes) of one strip: one per (cell slice ×
/// cell bit × polarity), in row order `(j·cell_bits + b)·2 + polarity`.
#[inline]
pub(crate) fn packed_rows(ncells: usize, cell_bits: u8) -> usize {
    ncells * cell_bits as usize * 2
}

/// Row count of the *interleaved* packed layout, padded so a 4-lane SIMD
/// load of consecutive rows never reads past the strip's storage and never
/// splits a 64-bit lane. Both the packer below and the inference walk
/// derive the pad from this one function, so they can never disagree.
#[inline]
pub(crate) fn packed_rows_pad(ncells: usize, cell_bits: u8) -> usize {
    packed_rows(ncells, cell_bits).next_multiple_of(4)
}

/// Pack one strip's integer weight codes into u64 cell-bit planes: one
/// plane per (cell slice × cell bit × polarity), segmented like the row
/// partition. Layout: `[cell slice × cell bit][polarity][segment words]`.
pub(crate) fn pack_weight_planes_into(
    planes: &mut Vec<u64>,
    codes_w: &[i32],
    cell_bits: u8,
    ncells: usize,
    segs: &[(usize, usize, usize)],
    total_words: usize,
) {
    let cb = cell_bits as usize;
    let mask = (1i32 << cell_bits) - 1;
    planes.clear();
    planes.resize(ncells * cb * 2 * total_words, 0);
    for &(start, len, woff) in segs {
        for l in 0..len {
            let cwv = codes_w[start + l];
            if cwv == 0 {
                continue;
            }
            let (p, q) = (cwv.max(0), (-cwv).max(0));
            let bit = 1u64 << (l % 64);
            let w = woff + l / 64;
            for j in 0..ncells {
                let sh = (j as u32) * cell_bits as u32;
                let pv = (p >> sh) & mask;
                let qv = (q >> sh) & mask;
                for b in 0..cb {
                    let cellbit = 1i32 << b;
                    let row = (j * cb + b) * 2;
                    if pv & cellbit != 0 {
                        planes[row * total_words + w] |= bit;
                    }
                    if qv & cellbit != 0 {
                        planes[(row + 1) * total_words + w] |= bit;
                    }
                }
            }
        }
    }
}

/// Pack one strip's integer weight codes into the *interleaved* word-major
/// layout the SIMD-widened walk consumes: the word index is the **major**
/// axis and the packed row the **minor** one, `planes[w·rows_pad + row]`
/// with `row = (j·cell_bits + b)·2 + polarity`, rows padded to
/// [`packed_rows_pad`]. One unaligned vector load then covers 4 consecutive
/// rows of the *same* segment word — the whole differential pair (and, at
/// `cell_bits >= 2`, a full cell slice) in a single instruction — and the
/// pad rows stay all-zero so lanes past `packed_rows` contribute nothing.
/// Bit contents per row are identical to [`pack_weight_planes_into`]; only
/// the axis order differs.
pub(crate) fn pack_weight_rows_into(
    planes: &mut Vec<u64>,
    codes_w: &[i32],
    cell_bits: u8,
    ncells: usize,
    segs: &[(usize, usize, usize)],
    total_words: usize,
) {
    let cb = cell_bits as usize;
    let mask = (1i32 << cell_bits) - 1;
    let rp = packed_rows_pad(ncells, cell_bits);
    planes.clear();
    planes.resize(total_words * rp, 0);
    for &(start, len, woff) in segs {
        for l in 0..len {
            let cwv = codes_w[start + l];
            if cwv == 0 {
                continue;
            }
            let (p, q) = (cwv.max(0), (-cwv).max(0));
            let bit = 1u64 << (l % 64);
            let wb = (woff + l / 64) * rp;
            for j in 0..ncells {
                let sh = (j as u32) * cell_bits as u32;
                let pv = (p >> sh) & mask;
                let qv = (q >> sh) & mask;
                if pv == 0 && qv == 0 {
                    continue;
                }
                for b in 0..cb {
                    let cellbit = 1i32 << b;
                    let row = (j * cb + b) * 2;
                    if pv & cellbit != 0 {
                        planes[wb + row] |= bit;
                    }
                    if qv & cellbit != 0 {
                        planes[wb + row + 1] |= bit;
                    }
                }
            }
        }
    }
}

/// Which execution strategy the artifact was programmed for — the same
/// decision the per-call path makes from the config, frozen at program
/// time so the programmed store and the inference walk can never disagree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Ideal converters: integer codes, phase decomposition telescoped to
    /// the plain integer dot product.
    Exact,
    /// Faithful phase loop over packed u64 bit-planes (integral cells).
    Packed,
    /// Scalar lane scan over real-valued (possibly noisy) conductances.
    Analog,
}

impl ExecMode {
    /// The mode `cfg` executes.
    pub fn of(cfg: &SimXbarConfig) -> Self {
        if cfg.adc_bits == 0 && cfg.noise_sigma == 0.0 && !cfg.force_phase_loop {
            ExecMode::Exact
        } else if cfg.noise_sigma == 0.0 && !cfg.scalar_lanes {
            ExecMode::Packed
        } else {
            ExecMode::Analog
        }
    }
}

/// Weight-side state of one programmed strip, in the representation the
/// configured [`ExecMode`] reads.
pub enum StripStore {
    /// Integer weight codes (ideal-converter fast path).
    Exact { codes: Vec<i32> },
    /// Packed weight bit-planes in the SIMD-friendly interleaved layout
    /// `[segment word][packed row]` (row = (cell slice × cell bit) × 2 +
    /// polarity, padded to [`packed_rows_pad`]; see
    /// [`pack_weight_rows_into`]).
    Packed { planes: Vec<u64>, ncells: usize },
    /// Differential conductance columns `[cell slice][lane]`, noise already
    /// programmed in.
    Analog { gpos: Vec<f64>, gneg: Vec<f64>, ncells: usize },
}

/// One live (non-pruned, non-zero-scale) strip of a programmed layer.
pub struct ProgrammedStrip {
    /// Kernel tap `g = kh·K + kw` this strip belongs to.
    pub g: u32,
    /// Per-strip quantization scale (LSB), including any injected IR drop.
    pub sw: f32,
    /// Physical column slot this strip was programmed to (layer-local;
    /// fault draws are keyed by it). Equals the strip's own local index
    /// `g·N + ch` unless sensitivity-aware placement permuted it.
    pub slot: u32,
    pub store: StripStore,
}

/// One reserved known-answer strip: a deterministic code pattern programmed
/// at unit scale whose post-fault state the health monitor can re-derive at
/// any logical tick and compare against [`CanaryStrip::programmed`].
#[derive(Clone, Debug)]
pub struct CanaryStrip {
    /// Physical slot the canary occupies (past the spare range).
    pub slot: u32,
    /// Cell slices the canary's codes span (the layer's canonical depth).
    pub ncells: usize,
    /// The fault-free code pattern (pure function of lane index and canary
    /// ordinal — re-derivable without the artifact).
    pub expected: Vec<i32>,
    /// `expected` after the programming-time fault injection — what the
    /// device actually holds. A probe at tick `t` replays `expected`
    /// through the spec evolved to `t` and compares against this.
    pub programmed: Vec<i32>,
    /// Canary scale after injection (IR drop perturbs it like any strip).
    pub sw: f32,
}

/// One conv layer's programmed tiles plus the compact live-strip index.
pub struct ProgrammedLayer {
    /// Fault-key layer index (`ConvLayer::index`), kept so health probes
    /// can replay this layer's fault streams without the `ModelInfo`.
    pub index: usize,
    /// Input depth D (strip length).
    pub d: usize,
    /// Output channels N.
    pub n: usize,
    /// Kernel taps K².
    pub kk: usize,
    /// Live strips, channel-major then kernel-tap-ascending — the same
    /// per-(sample, channel) accumulation order as the on-the-fly loop.
    pub strips: Vec<ProgrammedStrip>,
    /// Per output channel: (start, len) range into `strips`. Channels whose
    /// strips are all dropped have an empty range.
    pub chan: Vec<(u32, u32)>,
    /// Row-segment partition of the layer depth.
    pub segs: Vec<(usize, usize, usize)>,
    /// Packed u64 words per (phase/cell-bit × polarity) plane.
    pub total_words: usize,
    /// Fault-key slot-space width: `K²·N` natural slots plus any reserved
    /// spare and canary slots. Equals `kk·n` when health is off.
    pub nslots_ext: usize,
    /// Reserved known-answer strips (empty when health is off).
    pub canaries: Vec<CanaryStrip>,
}

/// The programmed-crossbar artifact for one `(model, theta, strips,
/// config)` tuple: every conv layer's tiles, ready for read-only inference.
pub struct ProgrammedModel {
    /// Execution strategy the tiles were programmed for.
    pub mode: ExecMode,
    /// Per conv layer, `ModelInfo::conv_layers()` order.
    pub layers: Vec<ProgrammedLayer>,
    /// Strips actually programmed (bits > 0 and scale > 0).
    pub live_strips: usize,
    /// Pruned or zero-scale strips dropped from the index.
    pub dropped_strips: usize,
    /// Bytes of programmed weight-side storage (codes, packed planes or
    /// analog conductances, whichever the mode stores).
    pub planes_bytes: usize,
    /// Wall-clock nanoseconds spent programming (always >= 1).
    pub program_ns: u64,
    /// The *effective* fault spec injected at programming time — the
    /// scenario's base spec evolved to [`ProgrammedModel::tick`] (`None`
    /// when the artifact is fault-free).
    pub scenario: Option<faults::ScenarioSpec>,
    /// Cell bit width the tiles were programmed with (needed to replay
    /// canary fault streams at probe time).
    pub cell_bits: u8,
    /// Logical serving tick the artifact was programmed at (0 = deploy).
    pub tick: u64,
    /// Canary/spare reservation the artifact was programmed with.
    pub health: faults::HealthSpec,
}

impl ProgrammedModel {
    /// Program every conv layer's crossbar tiles ahead of time. Validates
    /// the config and the strip metadata up front, so a malformed
    /// deployment fails at programming time, not on the first request.
    pub fn program(
        model: &ModelInfo,
        theta: &[f32],
        sp: &StripPrecision,
        cfg: &SimXbarConfig,
    ) -> Result<ProgrammedModel> {
        Self::program_with(model, theta, sp, cfg, None)
    }

    /// [`ProgrammedModel::program`], with an optional device-variability
    /// [`Scenario`] injected post-programming (see the module docs). An
    /// absent or inactive scenario is bit-identical to `program`.
    pub fn program_with(
        model: &ModelInfo,
        theta: &[f32],
        sp: &StripPrecision,
        cfg: &SimXbarConfig,
        scenario: Option<&Scenario>,
    ) -> Result<ProgrammedModel> {
        let t0 = Instant::now();
        anyhow::ensure!(cfg.rows >= 1, "sim rows must be >= 1");
        anyhow::ensure!(
            (1..=8).contains(&cfg.cell_bits),
            "sim cell_bits {} out of range 1..=8",
            cfg.cell_bits
        );
        anyhow::ensure!(
            (2..=24).contains(&cfg.input_bits),
            "sim input_bits {} out of range 2..=24",
            cfg.input_bits
        );
        anyhow::ensure!(cfg.adc_bits <= 16, "sim adc_bits {} out of range 0..=16", cfg.adc_bits);
        anyhow::ensure!(
            sp.bits.len() == model.num_strips() && sp.scales.len() == sp.bits.len(),
            "strip precision covers {} strips, model has {}",
            sp.bits.len(),
            model.num_strips()
        );
        anyhow::ensure!(
            theta.len() == model.entry.num_params,
            "theta length {} does not match model ({} params)",
            theta.len(),
            model.entry.num_params
        );

        let scn = scenario.filter(|s| s.is_active());
        if let Some(sc) = scn {
            if let Some(s) = &sc.scores {
                anyhow::ensure!(
                    s.len() == model.num_strips(),
                    "scenario scores cover {} strips, model has {}",
                    s.len(),
                    model.num_strips()
                );
            }
        }
        // The spec the device actually experiences at programming time: the
        // base spec evolved to the scenario's logical tick. Tick 0 (deploy
        // time) is the base spec itself.
        let eff_spec: Option<faults::ScenarioSpec> = scn.map(|sc| sc.effective_spec());
        let health = scn.map(|sc| sc.health).unwrap_or_default();

        let mode = ExecMode::of(cfg);
        let mask = (1i32 << cfg.cell_bits) - 1;
        let mut layers = Vec::with_capacity(model.conv_layers().len());
        let (mut live, mut dropped) = (0usize, 0usize);
        let mut planes_bytes = 0usize;
        let mut base = 0usize;
        let mut codes_w: Vec<i32> = Vec::new();
        for layer in model.conv_layers() {
            let d = layer.d;
            let (segs, total_words) = segments(d, cfg.rows);
            let kk = layer.k * layer.k;
            codes_w.clear();
            codes_w.resize(d, 0);

            // Fault draws are keyed by *physical slot*. With an active
            // scenario, decide each live strip's slot up front: rank the
            // layer's candidate slots (natural live slots plus reserved
            // spares) by the damage the *effective* spec deals them
            // (exactly the draws injection will consume) and, under
            // sensitivity-aware placement, put the highest-scoring strips
            // on the healthiest candidates — leaving the most damaged
            // candidates quarantined. Identity otherwise.
            let nslots = kk * layer.n;
            let spares = health.spares as usize;
            let ncanaries = health.canaries as usize;
            let nslots_ext = nslots + spares + ncanaries;
            let mut live_slots = Vec::new();
            let mut max_bits = 0u8;
            for local in 0..nslots {
                let idx = base + local;
                if sp.bits[idx] > 0 && sp.scales[idx] > 0.0 {
                    live_slots.push(local);
                    max_bits = max_bits.max(sp.bits[idx]);
                }
            }
            let canon_ncells = max_bits.max(1).div_ceil(cfg.cell_bits) as usize;
            let slot_of: Option<Vec<u32>> = scn.map(|sc| {
                let eff = eff_spec.expect("active scenario has an effective spec");
                let mut candidates = live_slots.clone();
                candidates.extend(nslots..nslots + spares);
                let mut scores: Option<Vec<f64>> = sc
                    .scores
                    .as_ref()
                    .map(|s| live_slots.iter().map(|&l| s[base + l]).collect());
                if scores.is_none()
                    && spares > 0
                    && matches!(sc.placement, faults::Placement::SensitivityAware)
                {
                    // Spares reserved but no sensitivity profile: damage
                    // avoidance should still work, so rank strips uniformly.
                    // rank_desc's ascending-index tie-break makes this the
                    // identity assignment on an undamaged device.
                    scores = Some(vec![0.0; live_slots.len()]);
                }
                let damage: Vec<f64> = candidates
                    .iter()
                    .map(|&l| {
                        faults::slot_damage(
                            &eff,
                            layer.index,
                            l,
                            nslots_ext,
                            cfg.cell_bits,
                            canon_ncells,
                            d,
                        )
                    })
                    .collect();
                let assigned = faults::assign_slots_spares(
                    sc.placement,
                    scores.as_deref(),
                    &damage,
                    &candidates,
                    live_slots.len(),
                );
                let mut map = vec![u32::MAX; nslots];
                for (i, &l) in live_slots.iter().enumerate() {
                    map[l] = assigned[i] as u32;
                }
                map
            });

            let mut strips = Vec::new();
            let mut chan = Vec::with_capacity(layer.n);
            for ch in 0..layer.n {
                let start = strips.len() as u32;
                for g in 0..kk {
                    let idx = base + g * layer.n + ch;
                    let bits = sp.bits[idx];
                    if bits == 0 {
                        dropped += 1;
                        continue; // pruned strip: no cells programmed
                    }
                    anyhow::ensure!(
                        (1..=16).contains(&bits),
                        "strip {idx} has unsupported bit width {bits}"
                    );
                    let mut sw = sp.scales[idx];
                    if sw <= 0.0 {
                        dropped += 1;
                        continue;
                    }
                    let q_w = quant::qmax(bits);
                    for (dd, cwv) in codes_w.iter_mut().enumerate() {
                        let wv = theta[layer.theta_index(g, dd, ch)];
                        *cwv = (wv / sw).round().clamp(-q_w, q_w) as i32;
                    }
                    let ncells = bits.div_ceil(cfg.cell_bits) as usize;
                    let local = g * layer.n + ch;
                    let slot = slot_of.as_ref().map_or(local as u32, |m| m[local]);
                    if let Some(eff) = &eff_spec {
                        faults::apply_to_strip(
                            eff,
                            layer.index,
                            slot as usize,
                            nslots_ext,
                            cfg.cell_bits,
                            ncells,
                            &mut codes_w,
                            &mut sw,
                        );
                    }
                    let store = match mode {
                        ExecMode::Exact => {
                            planes_bytes += codes_w.len() * std::mem::size_of::<i32>();
                            StripStore::Exact { codes: codes_w.clone() }
                        }
                        ExecMode::Packed => {
                            // Interleaved word-major layout: one SIMD load
                            // covers 4 consecutive packed rows of a word
                            // (pad rows included in the byte count — they
                            // are real programmed-storage overhead).
                            let mut planes = Vec::new();
                            pack_weight_rows_into(
                                &mut planes,
                                &codes_w,
                                cfg.cell_bits,
                                ncells,
                                &segs,
                                total_words,
                            );
                            planes_bytes += planes.len() * std::mem::size_of::<u64>();
                            StripStore::Packed { planes, ncells }
                        }
                        ExecMode::Analog => {
                            // Program the differential, bit-sliced cell
                            // columns, with the same per-(seed, layer,
                            // strip) noise stream as the per-call path.
                            let mut gpos = vec![0.0f64; ncells * d];
                            let mut gneg = vec![0.0f64; ncells * d];
                            for (dd, &cwv) in codes_w.iter().enumerate() {
                                let (p, q) = (cwv.max(0), (-cwv).max(0));
                                for j in 0..ncells {
                                    let sh = (j as u32) * cfg.cell_bits as u32;
                                    gpos[j * d + dd] = ((p >> sh) & mask) as f64;
                                    gneg[j * d + dd] = ((q >> sh) & mask) as f64;
                                }
                            }
                            if cfg.noise_sigma > 0.0 {
                                // Keyed by the *logical* strip index, not
                                // the placement slot, to stay bit-identical
                                // with the re-quantize-per-call reference
                                // path.
                                let mut rng =
                                    faults::NoiseStream::for_strip(cfg.seed, layer.index, idx);
                                for v in gpos.iter_mut().chain(gneg.iter_mut()) {
                                    *v += rng.normal() as f64 * cfg.noise_sigma;
                                }
                            }
                            planes_bytes +=
                                (gpos.len() + gneg.len()) * std::mem::size_of::<f64>();
                            StripStore::Analog { gpos, gneg, ncells }
                        }
                    };
                    strips.push(ProgrammedStrip { g: g as u32, sw, slot, store });
                    live += 1;
                }
                chan.push((start, strips.len() as u32 - start));
            }

            // Program the known-answer canary strips into the reserved
            // slots past the spare range. The expected pattern is a pure
            // function of (lane, canary ordinal) so a probe can re-derive
            // it; the stored `programmed` codes carry whatever the
            // programming-time fault spec did to them.
            let mut canaries = Vec::with_capacity(ncanaries);
            if ncanaries > 0 {
                let cap = ((1i64 << (canon_ncells as u32 * cfg.cell_bits as u32)) - 1)
                    .min(i32::MAX as i64);
                for c in 0..ncanaries {
                    let slot = nslots + spares + c;
                    let expected: Vec<i32> = (0..d)
                        .map(|dd| {
                            ((dd as i64 * 7919 + c as i64 * 104_729).rem_euclid(2 * cap + 1)
                                - cap) as i32
                        })
                        .collect();
                    let mut programmed = expected.clone();
                    let mut csw = 1.0f32;
                    if let Some(eff) = &eff_spec {
                        faults::apply_to_strip(
                            eff,
                            layer.index,
                            slot,
                            nslots_ext,
                            cfg.cell_bits,
                            canon_ncells,
                            &mut programmed,
                            &mut csw,
                        );
                    }
                    planes_bytes +=
                        (expected.len() + programmed.len()) * std::mem::size_of::<i32>();
                    canaries.push(CanaryStrip {
                        slot: slot as u32,
                        ncells: canon_ncells,
                        expected,
                        programmed,
                        sw: csw,
                    });
                }
            }

            layers.push(ProgrammedLayer {
                index: layer.index,
                d,
                n: layer.n,
                kk,
                strips,
                chan,
                segs,
                total_words,
                nslots_ext,
                canaries,
            });
            base += layer.num_strips();
        }
        Ok(ProgrammedModel {
            mode,
            layers,
            live_strips: live,
            dropped_strips: dropped,
            planes_bytes,
            program_ns: (t0.elapsed().as_nanos() as u64).max(1),
            scenario: eff_spec,
            cell_bits: cfg.cell_bits,
            tick: scn.map_or(0, |s| s.tick),
            health,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_mode_matches_the_per_call_decision_table() {
        let base = SimXbarConfig::default();
        assert_eq!(ExecMode::of(&base), ExecMode::Exact);
        assert_eq!(ExecMode::of(&base.with_adc(4)), ExecMode::Packed);
        assert_eq!(
            ExecMode::of(&SimXbarConfig { force_phase_loop: true, ..base }),
            ExecMode::Packed
        );
        assert_eq!(ExecMode::of(&base.with_noise(0.1, 1)), ExecMode::Analog);
        assert_eq!(
            ExecMode::of(&SimXbarConfig { scalar_lanes: true, force_phase_loop: true, ..base }),
            ExecMode::Analog
        );
        // scalar_lanes alone does not disturb the exact fast path
        assert_eq!(
            ExecMode::of(&SimXbarConfig { scalar_lanes: true, ..base }),
            ExecMode::Exact
        );
    }

    #[test]
    fn interleaved_weight_rows_match_the_reference_plane_layout() {
        // Same bits, transposed axes: interleaved[w·rows_pad + r] must equal
        // the reference layout's planes[r·total_words + w], with every pad
        // row all-zero. 19 lanes over 4-row segments exercises a remainder
        // segment; codes span negative/zero/positive.
        let codes: Vec<i32> = (0..19).map(|i| ((i * 7) % 11) as i32 - 5).collect();
        let (segs, total_words) = segments(19, 4);
        let (cell_bits, ncells) = (2u8, 3usize);
        let mut flat = Vec::new();
        pack_weight_planes_into(&mut flat, &codes, cell_bits, ncells, &segs, total_words);
        let mut inter = Vec::new();
        pack_weight_rows_into(&mut inter, &codes, cell_bits, ncells, &segs, total_words);
        let nrows = packed_rows(ncells, cell_bits);
        let rp = packed_rows_pad(ncells, cell_bits);
        assert_eq!(inter.len(), total_words * rp);
        assert!(rp >= nrows && rp % 4 == 0);
        for w in 0..total_words {
            for r in 0..rp {
                let want = if r < nrows { flat[r * total_words + w] } else { 0 };
                assert_eq!(inter[w * rp + r], want, "word {w} row {r}");
            }
        }
    }

    #[test]
    fn segments_partition_and_word_offsets() {
        let (segs, words) = segments(19, 4);
        assert_eq!(segs.len(), 5);
        assert_eq!(segs[0], (0, 4, 0));
        assert_eq!(segs[4], (16, 3, 4));
        assert_eq!(words, 5);
        let (segs, words) = segments(128, 128);
        assert_eq!(segs, vec![(0, 128, 0)]);
        assert_eq!(words, 2);
    }
}
