//! `SimXbar` — native bit-serial crossbar MVM simulator.
//!
//! Models what the paper's ReRAM substrate physically computes, per strip:
//!
//! * **Weight storage** — each strip's integer codes (re-derived from the
//!   quantized parameter vector and the per-strip scale) are stored on a
//!   *differential column pair* (G⁺/G⁻ for positive/negative code parts),
//!   each sliced across `ceil(bits / cell_bits)` multi-bit cells.
//! * **Input streaming** — activations are DAC-quantized to `input_bits`
//!   symmetric codes (per conversion window, i.e. per output pixel — so a
//!   sample's result never depends on what else shares its batch) and
//!   streamed bit-serially; each input-bit phase drives the word lines with
//!   a binary vector.
//! * **Column currents** — one analog current per (input-bit phase × cell
//!   slice × polarity × row segment of at most `rows` word lines). With
//!   `adc_bits > 0` every current is quantized by a SAR ADC of that
//!   resolution before the shift-and-add merge; with `noise_sigma > 0`
//!   zero-mean Gaussian conductance noise (in cell-level units, seeded and
//!   deterministic) perturbs every programmed cell.
//! * **Digital merge** — phase/slice partial sums are shift-added and
//!   scaled by `sa·sw`, exactly the paper's §4.3 stepwise accumulation.
//!
//! With ideal converters (`adc_bits == 0`, `noise_sigma == 0`) the phase
//! decomposition telescopes back to the exact integer dot product, so the
//! simulator takes an algebraically identical fast path (property-tested
//! against the explicit phase loop). Non-conv layers (GroupNorm, ReLU,
//! residual adds, pooling, dense head) run in exact f32 — the paper
//! quantizes conv weights only.

use std::sync::Mutex;

use crate::backend::nn::{self, ConvExec, ExactConv, NetSpec};
use crate::backend::{ExecBackend, FwdKind};
use crate::model::{ConvLayer, ModelInfo};
use crate::quant::{self, QuantizedModel};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::xbar::XbarConfig;
use crate::Result;

/// Crossbar fidelity knobs for the simulator.
#[derive(Clone, Copy, Debug)]
pub struct SimXbarConfig {
    /// Word lines per array: strips deeper than this split into row
    /// segments, each converted (and ADC-quantized) separately.
    pub rows: usize,
    /// Bits stored per ReRAM cell.
    pub cell_bits: u8,
    /// DAC resolution for the bit-serial activation stream.
    pub input_bits: u8,
    /// SAR ADC resolution applied to every column current; 0 = ideal
    /// (lossless) conversion.
    pub adc_bits: u8,
    /// Zero-mean Gaussian conductance noise per programmed cell, in units
    /// of one cell level; 0 = noise-free.
    pub noise_sigma: f64,
    /// Seed for the conductance-noise draw (deterministic per seed).
    pub seed: u64,
    /// Testing knob: run the explicit phase/slice loop even when ideal
    /// converters would permit the algebraically equal integer fast path.
    pub force_phase_loop: bool,
}

impl Default for SimXbarConfig {
    fn default() -> Self {
        Self {
            rows: 128,
            cell_bits: 2,
            input_bits: 8,
            adc_bits: 0,
            noise_sigma: 0.0,
            seed: 0x51b,
            force_phase_loop: false,
        }
    }
}

impl SimXbarConfig {
    /// Inherit the array geometry from the hardware cost-model config
    /// (ideal converters; opt into ADC/noise with the builder helpers).
    pub fn from_xbar(x: &XbarConfig) -> Self {
        Self {
            rows: x.rows,
            cell_bits: x.cell_bits,
            input_bits: x.input_bits,
            ..Self::default()
        }
    }

    /// Near-lossless DAC for reference comparisons: 20-bit input codes keep
    /// the activation-quantization error below ~1e-5 relative.
    pub fn high_fidelity() -> Self {
        Self { input_bits: 20, ..Self::default() }
    }

    pub fn with_adc(mut self, bits: u8) -> Self {
        self.adc_bits = bits;
        self
    }

    pub fn with_noise(mut self, sigma: f64, seed: u64) -> Self {
        self.noise_sigma = sigma;
        self.seed = seed;
        self
    }
}

/// Per-strip weight precision feeding the simulator (bit widths + scales,
/// exactly the quantization stage's artifact).
#[derive(Clone, Debug)]
pub struct StripPrecision {
    /// Bits per strip, `ModelInfo::strips()` order; 0 = pruned.
    pub bits: Vec<u8>,
    /// Per-strip quantization scale (LSB).
    pub scales: Vec<f32>,
}

impl StripPrecision {
    pub fn from_quantized(qm: &QuantizedModel) -> Self {
        Self { bits: qm.bits.clone(), scales: qm.scales.clone() }
    }
}

/// The simulator backend. Without strip metadata every conv runs in exact
/// f32 (fp32 reference deployments); with it, conv layers execute on the
/// simulated crossbars at their assigned per-strip precision.
pub struct SimXbar {
    pub cfg: SimXbarConfig,
    strips: Option<StripPrecision>,
    /// Parsed network graph of the last model seen, so the eval loop and the
    /// serving hot path don't re-parse the manifest layout on every batch.
    spec: Mutex<Option<(String, usize, NetSpec)>>,
}

impl SimXbar {
    pub fn new(cfg: SimXbarConfig) -> Self {
        Self { cfg, strips: None, spec: Mutex::new(None) }
    }

    /// Graph for `model`, parsed once per (name, param-count) and cached.
    fn spec_for(&self, model: &ModelInfo) -> Result<NetSpec> {
        let mut guard = self.spec.lock().unwrap();
        if let Some((name, params, spec)) = guard.as_ref() {
            if name == model.name() && *params == model.entry.num_params {
                return Ok(spec.clone());
            }
        }
        let spec = NetSpec::parse(model)?;
        *guard = Some((model.name().to_string(), model.entry.num_params, spec.clone()));
        Ok(spec)
    }

    pub fn with_strips(mut self, strips: StripPrecision) -> Self {
        self.strips = Some(strips);
        self
    }

    pub fn from_quantized(cfg: SimXbarConfig, qm: &QuantizedModel) -> Self {
        Self::new(cfg).with_strips(StripPrecision::from_quantized(qm))
    }

    /// Bit-serial conv of one layer over im2col patches (the crossbar hot
    /// path). Exposed for the property tests.
    pub fn conv_bitserial(
        &self,
        model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
        sp: &StripPrecision,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        anyhow::ensure!(cfg.rows >= 1, "sim rows must be >= 1");
        anyhow::ensure!(
            (1..=8).contains(&cfg.cell_bits),
            "sim cell_bits {} out of range 1..=8",
            cfg.cell_bits
        );
        anyhow::ensure!(
            (2..=24).contains(&cfg.input_bits),
            "sim input_bits {} out of range 2..=24",
            cfg.input_bits
        );
        anyhow::ensure!(cfg.adc_bits <= 16, "sim adc_bits {} out of range 0..=16", cfg.adc_bits);
        anyhow::ensure!(
            sp.bits.len() == model.num_strips() && sp.scales.len() == sp.bits.len(),
            "strip precision covers {} strips, model has {}",
            sp.bits.len(),
            model.num_strips()
        );
        let d = layer.d;
        let n = layer.n;
        let kk = layer.k * layer.k;
        let cols = kk * d;
        let base: usize = model.conv_layers()[..layer.index]
            .iter()
            .map(ConvLayer::num_strips)
            .sum();

        // ---- DAC: symmetric input codes, scaled per conversion window ----
        let q_in = ((1i64 << (cfg.input_bits - 1)) - 1) as f32;
        let mut codes_a = vec![0i32; t * cols];
        let mut sa = vec![1.0f32; t];
        for ti in 0..t {
            let row = &patches[ti * cols..(ti + 1) * cols];
            let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if amax > 0.0 {
                let s = amax / q_in;
                sa[ti] = s;
                for (c, &v) in codes_a[ti * cols..(ti + 1) * cols].iter_mut().zip(row) {
                    *c = (v / s).round().clamp(-q_in, q_in) as i32;
                }
            }
        }

        let exact = cfg.adc_bits == 0 && cfg.noise_sigma == 0.0 && !cfg.force_phase_loop;
        // Conductance noise is drawn per programmed cell in a fixed
        // (strip-major) order from a per-layer stream, so a given
        // (seed, layer) pair always programs the same array state.
        let mut rng = Rng::seed_from_u64(
            cfg.seed ^ (layer.index as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15),
        );

        let mut out = vec![0.0f32; t * n];
        let mut codes_w = vec![0i32; d];
        for g in 0..kk {
            for ch in 0..n {
                let idx = base + g * n + ch;
                let bits = sp.bits[idx];
                if bits == 0 {
                    continue; // pruned strip: no cells programmed
                }
                anyhow::ensure!(
                    (1..=16).contains(&bits),
                    "strip {idx} has unsupported bit width {bits}"
                );
                let sw = sp.scales[idx];
                if sw <= 0.0 {
                    continue;
                }
                let q_w = quant::qmax(bits);
                for (dd, cw) in codes_w.iter_mut().enumerate() {
                    let wv = theta[layer.theta_index(g, dd, ch)];
                    *cw = (wv / sw).round().clamp(-q_w, q_w) as i32;
                }

                if exact {
                    // Ideal converters: the phase/slice decomposition
                    // telescopes to the plain integer dot product.
                    for ti in 0..t {
                        let arow = &codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                        let mut acc = 0i64;
                        for (&a, &cw) in arow.iter().zip(codes_w.iter()) {
                            acc += a as i64 * cw as i64;
                        }
                        out[ti * n + ch] += (acc as f64 * sa[ti] as f64 * sw as f64) as f32;
                    }
                    continue;
                }

                // ---- program the differential, bit-sliced cell columns ----
                let ncells = ((bits + cfg.cell_bits - 1) / cfg.cell_bits) as usize;
                let mask = (1i32 << cfg.cell_bits) - 1;
                let mut gpos = vec![0.0f64; ncells * d];
                let mut gneg = vec![0.0f64; ncells * d];
                for (dd, &cw) in codes_w.iter().enumerate() {
                    let (p, q) = (cw.max(0), (-cw).max(0));
                    for j in 0..ncells {
                        let sh = (j as u32) * cfg.cell_bits as u32;
                        gpos[j * d + dd] = ((p >> sh) & mask) as f64;
                        gneg[j * d + dd] = ((q >> sh) & mask) as f64;
                    }
                }
                if cfg.noise_sigma > 0.0 {
                    for v in gpos.iter_mut().chain(gneg.iter_mut()) {
                        *v += rng.normal() as f64 * cfg.noise_sigma;
                    }
                }

                // ---- input-bit phases × cell slices × row segments ----
                let adc = |i_raw: f64, seg_rows: usize| -> f64 {
                    if cfg.adc_bits == 0 {
                        return i_raw;
                    }
                    let fs = seg_rows as f64 * mask as f64;
                    if fs <= 0.0 {
                        return i_raw;
                    }
                    let levels = (1u64 << cfg.adc_bits) as f64 - 1.0;
                    let step = (fs / levels).max(1.0);
                    (i_raw / step).round().clamp(0.0, levels) * step
                };
                for ti in 0..t {
                    let arow = &codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                    let mut total = 0.0f64;
                    let mut seg_start = 0usize;
                    while seg_start < d {
                        let seg_end = (seg_start + cfg.rows).min(d);
                        let seg_rows = seg_end - seg_start;
                        for p in 0..(cfg.input_bits - 1) as u32 {
                            let pbit = 1i32 << p;
                            for j in 0..ncells {
                                // four currents: input polarity × column
                                let (mut ipp, mut ipn) = (0.0f64, 0.0f64);
                                let (mut inp, mut inn) = (0.0f64, 0.0f64);
                                for dd in seg_start..seg_end {
                                    let a = arow[dd];
                                    if a == 0 || (a.abs() & pbit) == 0 {
                                        continue;
                                    }
                                    let gp = gpos[j * d + dd];
                                    let gm = gneg[j * d + dd];
                                    if a > 0 {
                                        ipp += gp;
                                        ipn += gm;
                                    } else {
                                        inp += gp;
                                        inn += gm;
                                    }
                                }
                                let w2 = 2.0f64.powi(p as i32 + (j as i32) * cfg.cell_bits as i32);
                                total += w2
                                    * ((adc(ipp, seg_rows) + adc(inn, seg_rows))
                                        - (adc(ipn, seg_rows) + adc(inp, seg_rows)));
                            }
                        }
                        seg_start = seg_end;
                    }
                    out[ti * n + ch] += (total * sa[ti] as f64 * sw as f64) as f32;
                }
            }
        }
        Ok(out)
    }
}

impl ConvExec for SimXbar {
    fn conv(
        &self,
        model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
    ) -> Result<Vec<f32>> {
        match &self.strips {
            None => ExactConv.conv(model, layer, theta, patches, t),
            Some(sp) => self.conv_bitserial(model, layer, theta, patches, t, sp),
        }
    }
}

impl ExecBackend for SimXbar {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn forward(
        &self,
        model: &ModelInfo,
        _kind: FwdKind,
        theta: &Tensor,
        x: &Tensor,
    ) -> Result<Tensor> {
        let spec = self.spec_for(model)?;
        nn::forward(model, &spec, theta.data(), x, self)
    }

    fn ready_check(&self, model: &ModelInfo, _theta: &Tensor) -> Result<()> {
        if let Some(sp) = &self.strips {
            anyhow::ensure!(
                sp.bits.len() == model.num_strips() && sp.scales.len() == sp.bits.len(),
                "strip precision covers {} strips, model has {}",
                sp.bits.len(),
                model.num_strips()
            );
        }
        self.spec_for(model)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry};
    use std::collections::HashMap;

    fn layer_model(k: usize, d: usize, n: usize) -> ModelInfo {
        ModelInfo::new(ModelEntry {
            name: "sim-layer".into(),
            num_params: k * k * d * n,
            num_conv_params: k * k * d * n,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![k * k * d * n], dtype: "f32".into() },
            layers: vec![LayerEntry {
                name: "stem.conv".into(),
                shape: vec![k, k, d, n],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            }],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        })
    }

    fn quantized_layer(m: &ModelInfo, seed: u64, bits: u8) -> (Vec<f32>, StripPrecision) {
        let mut rng = Rng::seed_from_u64(seed);
        let theta: Vec<f32> = (0..m.entry.num_params).map(|_| rng.normal() * 0.3).collect();
        let bm = crate::quant::BitMap::uniform(m.num_strips(), bits);
        let cfg = crate::config::QuantConfig {
            device_sigma: 0.0,
            ..crate::config::QuantConfig::default()
        };
        let qm = quant::apply(m, &theta, &bm, &cfg);
        (qm.theta, StripPrecision::from_quantized(&qm))
    }

    #[test]
    fn sim_phase_loop_equals_integer_fast_path() {
        let m = layer_model(1, 19, 3);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 7, 8);
        let mut rng = Rng::seed_from_u64(9);
        let t = 5;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        // rows=4 forces multi-segment conversion on the 19-row strips
        let base = SimXbarConfig { rows: 4, input_bits: 6, ..SimXbarConfig::default() };
        let fast = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let phased = SimXbar::new(SimXbarConfig { force_phase_loop: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        for (a, b) in fast.iter().zip(&phased) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn sim_pruned_and_zero_scale_strips_contribute_nothing() {
        let m = layer_model(1, 4, 2);
        let layer = m.layer(0).clone();
        let theta = vec![1.0f32; m.entry.num_params];
        let sp = StripPrecision { bits: vec![0, 8], scales: vec![0.0, 0.5] };
        let patches = vec![1.0f32; 4];
        let out = SimXbar::new(SimXbarConfig::default())
            .conv_bitserial(&m, &layer, &theta, &patches, 1, &sp)
            .unwrap();
        assert_eq!(out[0], 0.0, "pruned channel must stay silent");
        assert!(out[1] > 0.0);
    }

    #[test]
    fn sim_adc_and_noise_are_deterministic_per_seed() {
        let m = layer_model(3, 8, 4);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 21, 8);
        let mut rng = Rng::seed_from_u64(2);
        let t = 3;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let cfg = SimXbarConfig::default().with_adc(4).with_noise(0.05, 99);
        let run = || {
            SimXbar::new(cfg)
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap()
        };
        assert_eq!(run(), run(), "fixed seed must reproduce bit-identically");
        let other = SimXbar::new(cfg.with_noise(0.05, 100))
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_ne!(run(), other, "different seed must redraw the noise");
    }
}
