//! `SimXbar` — native bit-serial crossbar MVM simulator.
//!
//! Models what the paper's ReRAM substrate physically computes, per strip:
//!
//! * **Weight storage** — each strip's integer codes (re-derived from the
//!   quantized parameter vector and the per-strip scale) are stored on a
//!   *differential column pair* (G⁺/G⁻ for positive/negative code parts),
//!   each sliced across `ceil(bits / cell_bits)` multi-bit cells.
//! * **Input streaming** — activations are DAC-quantized to `input_bits`
//!   symmetric codes (per conversion window, i.e. per output pixel — so a
//!   sample's result never depends on what else shares its batch) and
//!   streamed bit-serially; each input-bit phase drives the word lines with
//!   a binary vector.
//! * **Column currents** — one analog current per (input-bit phase × cell
//!   slice × polarity × row segment of at most `rows` word lines). With
//!   `adc_bits > 0` every current is quantized by a SAR ADC of that
//!   resolution before the shift-and-add merge; with `noise_sigma > 0`
//!   zero-mean Gaussian conductance noise (in cell-level units, seeded per
//!   (seed, layer, strip) and deterministic) perturbs every programmed cell.
//! * **Digital merge** — phase/slice partial sums are shift-added and
//!   scaled by `sa·sw`, exactly the paper's §4.3 stepwise accumulation.
//!
//! With ideal converters (`adc_bits == 0`, `noise_sigma == 0`) the phase
//! decomposition telescopes back to the exact integer dot product, so the
//! simulator takes an algebraically identical fast path (property-tested
//! against the explicit phase loop). Non-conv layers (GroupNorm, ReLU,
//! residual adds, pooling, dense head) run in exact f32 — the paper
//! quantizes conv weights only.
//!
//! ## Execution strategy: program-once tiles + bit-plane packing + sharding
//!
//! Three orthogonal optimizations keep the simulation faithful *and* fast,
//! all **bit-identical** to the scalar re-quantize-per-call reference by
//! construction:
//!
//! * **Program-once crossbars.** Real CIM arrays are programmed once and
//!   then only driven. All weight-side work — per-strip quantization to
//!   integer codes, `u64` bit-plane packing, analog conductance programming
//!   with the seeded noise draw — happens a single time per `(model, theta,
//!   strips, config)` in a [`ProgrammedModel`] artifact
//!   ([`crate::backend::programmed`]); the conv hot path is a read-only
//!   walk over programmed tiles through a compact index that skips pruned
//!   and zero-scale strips entirely. Engine workers program inside the
//!   readiness handshake, so the cost lands at deploy time, never on a
//!   request. The pre-artifact path is kept as
//!   [`SimXbar::conv_bitserial_reference`] for property tests and the
//!   `xbar_programmed` bench.
//! * **Bit-plane packing.** The phase loop's word-line drive vectors are
//!   packed into `u64` bit-plane words (one plane per input-bit phase ×
//!   polarity, one per stored cell bit × polarity), and each column current
//!   becomes a popcount/shift accumulation over the packed lanes instead of
//!   a branchy per-lane scan. Currents are sums of small non-negative
//!   integers, so the popcount total equals the scalar `f64` sum exactly;
//!   the SAR-ADC transfer function sees identical inputs either way. The
//!   packed path engages whenever cell conductances are integral
//!   (`noise_sigma == 0`); conductance noise makes them real-valued, which
//!   falls back to the scalar lane scan (`scalar_lanes` forces the fallback
//!   for benchmarking).
//! * **SIMD-widened cache-blocked walk.** The programmed packed walk
//!   consumes 4 interleaved weight rows per step through `std::arch`
//!   intrinsics — AVX2 on x86_64 (runtime-detected), NEON on aarch64 —
//!   with the scalar u64 loop as the portable fallback
//!   ([`SimXbarConfig::simd`] forces either path; `RERAM_MPQ_SIMD=off`
//!   kills vector dispatch from the environment). The walk is tiled along
//!   the sample axis and double-buffered (the next strip's planes are
//!   staged while the current strip accumulates), and activation planes
//!   are packed **once per batch** in a single fused pass. Kernels produce
//!   exact integer currents, so every path is bit-identical.
//! * **Tile sharding.** The per-tile (row-segment × column-strip) MVM loop
//!   is sharded over `threads` scoped worker threads
//!   (`std::thread::scope`), each owning a contiguous output-channel range
//!   and a private accumulator. Per-(sample, channel) accumulation order is
//!   the same as the sequential loop and the conductance-noise stream is
//!   seeded per strip (not per evaluation order), so any worker count
//!   produces bit-identical results.

use std::sync::{mpsc, Arc, Mutex};

use crate::backend::nn::{self, ConvExec, ExactConv, NetSpec};
use crate::backend::programmed::{
    pack_weight_planes_into, packed_rows_pad, segments, words_of, ExecMode, ProgrammedLayer,
    ProgrammedModel, ProgrammedStrip, StripStore,
};
use crate::backend::profile::{WalkProfile, WalkProfileAtomic};
use crate::backend::scratch::{ConvScratch, Scratch};
use crate::backend::{ExecBackend, FwdKind};
use crate::faults::{NoiseStream, Scenario};
use crate::model::{ConvLayer, ModelInfo};
use crate::quant::{self, QuantizedModel};
use crate::tensor::Tensor;
use crate::xbar::XbarConfig;
use crate::Result;

/// SIMD widening policy for the programmed packed bit-plane walk.
///
/// Orthogonal to [`SimXbarConfig::scalar_lanes`]: `scalar_lanes` opts out
/// of u64 bit-plane *packing* altogether (Analog lane scan), while this
/// knob selects how many packed weight rows a walk step consumes — 4 per
/// vector (AVX2/NEON) or 1 per scalar word. Every setting is bit-identical:
/// the kernels produce exact integer column currents either way.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Runtime-detect the widest supported kernel (AVX2 on x86_64, NEON on
    /// aarch64, scalar elsewhere). Honours the `RERAM_MPQ_SIMD=off`
    /// environment kill switch, so CI can exercise the portable fallback
    /// on hardware that would auto-select a vector kernel.
    Auto,
    /// Force the portable scalar u64 kernel.
    Off,
    /// Use the widest kernel the host supports, ignoring the environment
    /// kill switch; still falls back to scalar when the host has none.
    Force,
}

/// Crossbar fidelity knobs for the simulator.
#[derive(Clone, Copy, Debug)]
pub struct SimXbarConfig {
    /// Word lines per array: strips deeper than this split into row
    /// segments, each converted (and ADC-quantized) separately.
    pub rows: usize,
    /// Bits stored per ReRAM cell.
    pub cell_bits: u8,
    /// DAC resolution for the bit-serial activation stream.
    pub input_bits: u8,
    /// SAR ADC resolution applied to every column current; 0 = ideal
    /// (lossless) conversion.
    pub adc_bits: u8,
    /// Zero-mean Gaussian conductance noise per programmed cell, in units
    /// of one cell level; 0 = noise-free.
    pub noise_sigma: f64,
    /// Seed for the conductance-noise draw (deterministic per seed; the
    /// stream is derived per (seed, layer, strip) so programmed array state
    /// does not depend on evaluation order or thread sharding).
    pub seed: u64,
    /// Testing knob: run the explicit phase/slice loop even when ideal
    /// converters would permit the algebraically equal integer fast path.
    pub force_phase_loop: bool,
    /// Worker threads sharding the per-tile (row-segment × column-strip)
    /// MVM loop; 0 = one per available core, 1 = sequential. Results are
    /// bit-identical for every value (see the module docs).
    pub threads: usize,
    /// Testing/bench knob: disable the packed u64 bit-plane popcount path
    /// inside the phase loop and use the scalar per-lane scan instead
    /// (numerically identical; this only trades speed).
    pub scalar_lanes: bool,
    /// SIMD widening policy for the programmed packed walk (bit-identical
    /// for every value; excluded from the artifact cache key like
    /// `threads`). See [`SimdMode`].
    pub simd: SimdMode,
}

impl Default for SimXbarConfig {
    fn default() -> Self {
        Self {
            rows: 128,
            cell_bits: 2,
            input_bits: 8,
            adc_bits: 0,
            noise_sigma: 0.0,
            seed: 0x51b,
            force_phase_loop: false,
            threads: 0,
            scalar_lanes: false,
            simd: SimdMode::Auto,
        }
    }
}

impl SimXbarConfig {
    /// Inherit the array geometry from the hardware cost-model config
    /// (ideal converters; opt into ADC/noise with the builder helpers).
    pub fn from_xbar(x: &XbarConfig) -> Self {
        Self {
            rows: x.rows,
            cell_bits: x.cell_bits,
            input_bits: x.input_bits,
            ..Self::default()
        }
    }

    /// Near-lossless DAC for reference comparisons: 20-bit input codes keep
    /// the activation-quantization error below ~1e-5 relative.
    pub fn high_fidelity() -> Self {
        Self { input_bits: 20, ..Self::default() }
    }

    pub fn with_adc(mut self, bits: u8) -> Self {
        self.adc_bits = bits;
        self
    }

    pub fn with_noise(mut self, sigma: f64, seed: u64) -> Self {
        self.noise_sigma = sigma;
        self.seed = seed;
        self
    }

    /// Pin the tile-sharding worker count (0 = auto, 1 = sequential).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Pin the SIMD widening policy of the programmed packed walk.
    pub fn with_simd(mut self, simd: SimdMode) -> Self {
        self.simd = simd;
        self
    }
}

/// Per-strip weight precision feeding the simulator (bit widths + scales,
/// exactly the quantization stage's artifact).
#[derive(Clone, Debug)]
pub struct StripPrecision {
    /// Bits per strip, `ModelInfo::strips()` order; 0 = pruned.
    pub bits: Vec<u8>,
    /// Per-strip quantization scale (LSB).
    pub scales: Vec<f32>,
}

impl StripPrecision {
    pub fn from_quantized(qm: &QuantizedModel) -> Self {
        Self { bits: qm.bits.clone(), scales: qm.scales.clone() }
    }
}

/// SAR ADC transfer function over one row segment's column current.
#[inline]
fn adc_transfer(cfg: &SimXbarConfig, i_raw: f64, seg_rows: usize) -> f64 {
    if cfg.adc_bits == 0 {
        return i_raw;
    }
    let mask = (1i32 << cfg.cell_bits) - 1;
    let fs = seg_rows as f64 * mask as f64;
    if fs <= 0.0 {
        return i_raw;
    }
    let levels = (1u64 << cfg.adc_bits) as f64 - 1.0;
    let step = (fs / levels).max(1.0);
    (i_raw / step).round().clamp(0.0, levels) * step
}

/// DAC stage: symmetric input codes + per-conversion-window scales, into
/// reusable buffers.
fn dac_quantize(
    cfg: &SimXbarConfig,
    patches: &[f32],
    t: usize,
    cols: usize,
    codes_a: &mut Vec<i32>,
    sa: &mut Vec<f32>,
) {
    let q_in = ((1i64 << (cfg.input_bits - 1)) - 1) as f32;
    codes_a.clear();
    codes_a.resize(t * cols, 0);
    sa.clear();
    sa.resize(t, 1.0);
    for ti in 0..t {
        let row = &patches[ti * cols..(ti + 1) * cols];
        let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if amax > 0.0 {
            let s = amax / q_in;
            sa[ti] = s;
            for (c, &v) in codes_a[ti * cols..(ti + 1) * cols].iter_mut().zip(row) {
                *c = (v / s).round().clamp(-q_in, q_in) as i32;
            }
        }
    }
}

/// Pack kernel tap `g`'s DAC codes into u64 bit-plane words: one plane per
/// (input-bit phase × polarity), segmented like the row partition so a
/// popcount never crosses a conversion boundary. Layout per sample:
/// `[phase][polarity][segment words]`. `out` must be zeroed, length
/// `t · phases · 2 · total_words`.
#[allow(clippy::too_many_arguments)]
fn pack_activation_planes_into(
    out: &mut [u64],
    codes_a: &[i32],
    cols: usize,
    d: usize,
    g: usize,
    segs: &[(usize, usize, usize)],
    total_words: usize,
    phases: usize,
    t: usize,
) {
    let stride_ti = phases * 2 * total_words;
    for ti in 0..t {
        let arow = &codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
        let tb = ti * stride_ti;
        for &(start, len, woff) in segs {
            for l in 0..len {
                let a = arow[start + l];
                if a == 0 {
                    continue;
                }
                let pol = usize::from(a < 0);
                let bit = 1u64 << (l % 64);
                let w = woff + l / 64;
                let mut m = a.unsigned_abs();
                let mut p = 0usize;
                while m != 0 {
                    if m & 1 != 0 {
                        out[tb + (p * 2 + pol) * total_words + w] |= bit;
                    }
                    m >>= 1;
                    p += 1;
                }
            }
        }
    }
}

/// Pack **every** kernel tap's DAC codes into u64 activation bit-planes in
/// a single pass over the code matrix — once per batch (a conv call covers
/// the whole batch), never per sample or per tap. The planes are then
/// shared read-only by every channel shard and re-read by every strip of
/// the blocked walk. Flat layout `[tap][ti][phase][polarity][segment
/// words]`, identical per-tap contents to [`pack_activation_planes_into`].
#[allow(clippy::too_many_arguments)]
fn pack_activation_planes_batch_into(
    out: &mut Vec<u64>,
    codes_a: &[i32],
    cols: usize,
    d: usize,
    kk: usize,
    segs: &[(usize, usize, usize)],
    total_words: usize,
    phases: usize,
    t: usize,
) {
    let stride_ti = phases * 2 * total_words;
    let tap_stride = t * stride_ti;
    out.clear();
    out.resize(kk * tap_stride, 0);
    for ti in 0..t {
        let row = &codes_a[ti * cols..(ti + 1) * cols];
        for (g, arow) in row.chunks_exact(d).enumerate() {
            let tb = g * tap_stride + ti * stride_ti;
            for &(start, len, woff) in segs {
                for l in 0..len {
                    let a = arow[start + l];
                    if a == 0 {
                        continue;
                    }
                    let pol = usize::from(a < 0);
                    let bit = 1u64 << (l % 64);
                    let w = woff + l / 64;
                    let mut m = a.unsigned_abs();
                    let mut p = 0usize;
                    while m != 0 {
                        if m & 1 != 0 {
                            out[tb + (p * 2 + pol) * total_words + w] |= bit;
                        }
                        m >>= 1;
                        p += 1;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD-widened packed-walk kernels
//
// Each kernel computes the four exact integer column currents (input
// polarity × differential column) of every cell slice for one (row segment,
// input-bit phase), reading the strip's interleaved weight planes
// (`[word][packed row]`, see `programmed::pack_weight_rows_into`). All
// arithmetic up to the ADC is integral, so every kernel — scalar, AVX2,
// NEON — produces the same `u64` currents and the shared outer loop applies
// the ADC transfer and the f64 shift-and-add in one fixed order:
// bit-identity across kernels holds by construction, not by tolerance.
// ---------------------------------------------------------------------------

/// Upper bound on a strip's current-accumulator slots: `ncells ≤ 16`
/// (bits ≤ 16, cell_bits ≥ 1) × 4 currents each.
const MAX_STRIP_CURRENTS: usize = 64;

/// Packed-row decode: `row = (j·cell_bits + b)·2 + pol` → (cell slice j,
/// cell bit b, polarity).
#[inline]
fn decode_row(r: usize, cell_bits: usize) -> (usize, usize, usize) {
    let pair = r / 2;
    (pair / cell_bits, pair % cell_bits, r & 1)
}

/// The kernel the packed walk dispatches to, resolved once per conv call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SimdKernel {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// Widest kernel this host supports (runtime-detected on x86_64; NEON is
/// architecturally mandatory on aarch64, so no detection is needed there).
fn host_kernel() -> SimdKernel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            SimdKernel::Avx2
        } else {
            SimdKernel::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        SimdKernel::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        SimdKernel::Scalar
    }
}

/// The `RERAM_MPQ_SIMD=off|0|scalar` environment kill switch (read once;
/// lets CI pin the portable fallback on hosts whose runtime detection
/// would pick a vector kernel).
fn env_simd_off() -> bool {
    static OFF: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *OFF.get_or_init(|| {
        std::env::var("RERAM_MPQ_SIMD")
            .map(|v| {
                let v = v.to_ascii_lowercase();
                v == "off" || v == "0" || v == "scalar"
            })
            .unwrap_or(false)
    })
}

/// Resolve the configured [`SimdMode`] to a concrete kernel for this host.
fn simd_kernel(cfg: &SimXbarConfig) -> SimdKernel {
    match cfg.simd {
        SimdMode::Off => SimdKernel::Scalar,
        SimdMode::Force => host_kernel(),
        SimdMode::Auto => {
            if env_simd_off() {
                SimdKernel::Scalar
            } else {
                host_kernel()
            }
        }
    }
}

/// Portable scalar kernel: one packed u64 word per step, differential pair
/// by differential pair — the exact per-word popcount/shift accumulation of
/// the pre-SIMD walk, re-read from the interleaved layout.
fn currents_scalar(
    planes: &[u64],
    rows_pad: usize,
    nrows: usize,
    cell_bits: usize,
    app: &[u64],
    apn: &[u64],
    cur: &mut [u64],
) {
    for (w, (&ap_w, &an_w)) in app.iter().zip(apn.iter()).enumerate() {
        let base = w * rows_pad;
        let mut r = 0usize;
        while r < nrows {
            let gp = planes[base + r];
            let gm = planes[base + r + 1];
            let (j, b, _) = decode_row(r, cell_bits);
            let c = j * 4;
            cur[c] += ((ap_w & gp).count_ones() as u64) << b;
            cur[c + 1] += ((ap_w & gm).count_ones() as u64) << b;
            cur[c + 2] += ((an_w & gp).count_ones() as u64) << b;
            cur[c + 3] += ((an_w & gm).count_ones() as u64) << b;
            r += 2;
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Per-64-bit-lane popcount (Mula's nibble-LUT method widened to AVX2:
    /// two table lookups per byte, SAD against zero to sum each lane —
    /// AVX2 has no native 64-lane popcount, that arrived with AVX-512).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        let low = _mm256_set1_epi8(0x0f);
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3,
            2, 3, 3, 4,
        );
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// AVX2 kernel: 4 consecutive packed weight rows per unaligned 256-bit
    /// load (the interleaved layout's row pad guarantees the load is always
    /// in bounds), chunk-outer / word-inner so the two per-chunk vector
    /// accumulators live in registers across the whole word loop. Words
    /// with no driven lanes in either polarity are skipped — they add an
    /// exact integer zero either way.
    ///
    /// # Safety
    /// Caller must ensure the host supports AVX2 and that `planes` holds
    /// `app.len() · rows_pad` words with `rows_pad % 4 == 0`, `nrows <=
    /// rows_pad`, and `cur` at least `4 · ceil(nrows / (2·cell_bits))`
    /// slots (see `packed_currents`).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn currents(
        planes: &[u64],
        rows_pad: usize,
        nrows: usize,
        cell_bits: usize,
        app: &[u64],
        apn: &[u64],
        cur: &mut [u64],
    ) {
        let mut r = 0usize;
        while r < nrows {
            let mut accp = _mm256_setzero_si256();
            let mut accn = _mm256_setzero_si256();
            for (w, (&ap_w, &an_w)) in app.iter().zip(apn.iter()).enumerate() {
                if (ap_w | an_w) == 0 {
                    continue;
                }
                let v = _mm256_loadu_si256(planes.as_ptr().add(w * rows_pad + r).cast());
                if ap_w != 0 {
                    let m = _mm256_and_si256(v, _mm256_set1_epi64x(ap_w as i64));
                    accp = _mm256_add_epi64(accp, popcnt_epi64(m));
                }
                if an_w != 0 {
                    let m = _mm256_and_si256(v, _mm256_set1_epi64x(an_w as i64));
                    accn = _mm256_add_epi64(accn, popcnt_epi64(m));
                }
            }
            let mut lp = [0u64; 4];
            let mut ln = [0u64; 4];
            _mm256_storeu_si256(lp.as_mut_ptr().cast(), accp);
            _mm256_storeu_si256(ln.as_mut_ptr().cast(), accn);
            let end = (r + 4).min(nrows);
            for rr in r..end {
                let (j, b, pol) = super::decode_row(rr, cell_bits);
                let c = j * 4 + pol;
                cur[c] += lp[rr - r] << b;
                cur[c + 2] += ln[rr - r] << b;
            }
            r += 4;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Per-64-bit-lane popcount: byte counts (`vcnt`) pairwise-widened up
    /// to one sum per 64-bit lane.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn popcnt_u64x2(v: uint64x2_t) -> uint64x2_t {
        vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(v)))))
    }

    /// NEON kernel: 4 consecutive packed weight rows per step as two
    /// 128-bit loads (row pad keeps them in bounds), chunk-outer /
    /// word-inner like the AVX2 twin. Undriven words are skipped — an
    /// exact integer no-op.
    ///
    /// # Safety
    /// Same contract as the AVX2 kernel (`planes` sized `app.len() ·
    /// rows_pad`, `rows_pad % 4 == 0`, `nrows <= rows_pad`, `cur` large
    /// enough); NEON itself is always available on aarch64.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn currents(
        planes: &[u64],
        rows_pad: usize,
        nrows: usize,
        cell_bits: usize,
        app: &[u64],
        apn: &[u64],
        cur: &mut [u64],
    ) {
        let mut r = 0usize;
        while r < nrows {
            let mut accp0 = vdupq_n_u64(0);
            let mut accp1 = vdupq_n_u64(0);
            let mut accn0 = vdupq_n_u64(0);
            let mut accn1 = vdupq_n_u64(0);
            for (w, (&ap_w, &an_w)) in app.iter().zip(apn.iter()).enumerate() {
                if (ap_w | an_w) == 0 {
                    continue;
                }
                let p0 = vld1q_u64(planes.as_ptr().add(w * rows_pad + r));
                let p1 = vld1q_u64(planes.as_ptr().add(w * rows_pad + r + 2));
                if ap_w != 0 {
                    let va = vdupq_n_u64(ap_w);
                    accp0 = vaddq_u64(accp0, popcnt_u64x2(vandq_u64(p0, va)));
                    accp1 = vaddq_u64(accp1, popcnt_u64x2(vandq_u64(p1, va)));
                }
                if an_w != 0 {
                    let vn = vdupq_n_u64(an_w);
                    accn0 = vaddq_u64(accn0, popcnt_u64x2(vandq_u64(p0, vn)));
                    accn1 = vaddq_u64(accn1, popcnt_u64x2(vandq_u64(p1, vn)));
                }
            }
            let mut lp = [0u64; 4];
            let mut ln = [0u64; 4];
            vst1q_u64(lp.as_mut_ptr(), accp0);
            vst1q_u64(lp.as_mut_ptr().add(2), accp1);
            vst1q_u64(ln.as_mut_ptr(), accn0);
            vst1q_u64(ln.as_mut_ptr().add(2), accn1);
            let end = (r + 4).min(nrows);
            for rr in r..end {
                let (j, b, pol) = super::decode_row(rr, cell_bits);
                let c = j * 4 + pol;
                cur[c] += lp[rr - r] << b;
                cur[c + 2] += ln[rr - r] << b;
            }
            r += 4;
        }
    }
}

/// Dispatch one (segment, phase) current computation to the resolved
/// kernel. `cur[j·4 ..][..4]` receives cell slice `j`'s four currents in
/// the order (G⁺ driven by +phase, G⁻ by +phase, G⁺ by −phase, G⁻ by
/// −phase); slots beyond `ncells·4` are left untouched.
#[allow(clippy::too_many_arguments)]
fn packed_currents(
    kern: SimdKernel,
    planes: &[u64],
    rows_pad: usize,
    nrows: usize,
    cell_bits: usize,
    ncells: usize,
    app: &[u64],
    apn: &[u64],
    cur: &mut [u64],
) {
    cur[..ncells * 4].fill(0);
    match kern {
        SimdKernel::Scalar => {
            currents_scalar(planes, rows_pad, nrows, cell_bits, app, apn, cur)
        }
        #[cfg(target_arch = "x86_64")]
        SimdKernel::Avx2 => unsafe {
            // Safe: the variant is only ever constructed after
            // `is_x86_feature_detected!("avx2")` succeeded, and the caller
            // slices `planes` to exactly `app.len() · rows_pad` words.
            avx2::currents(planes, rows_pad, nrows, cell_bits, app, apn, cur)
        },
        #[cfg(target_arch = "aarch64")]
        SimdKernel::Neon => unsafe {
            // Safe: NEON is architecturally mandatory on aarch64.
            neon::currents(planes, rows_pad, nrows, cell_bits, app, apn, cur)
        },
    }
}

/// Double-buffer staging: touch one byte per cache line of the *next*
/// strip's programmed words so they stream toward L1 while the current
/// strip's popcounts retire. Portable (plain volatile reads — no consumer,
/// so the loads only warm the cache); bounded, so a huge strip never turns
/// staging into a second full pass.
fn stage_strip(s: &ProgrammedStrip) {
    fn touch<T>(ptr: *const T, len: usize, step: usize) {
        let mut acc = 0u8;
        let mut i = 0usize;
        // one byte per 64-byte line, at most 64 lines (4 KiB) ahead
        while i < len && i < step * 64 {
            // in bounds: i < len elements of a live slice
            acc |= unsafe { std::ptr::read_volatile(ptr.add(i).cast::<u8>()) };
            i += step;
        }
        std::hint::black_box(acc);
    }
    match &s.store {
        StripStore::Exact { codes } => touch(codes.as_ptr(), codes.len(), 16),
        StripStore::Packed { planes, .. } => touch(planes.as_ptr(), planes.len(), 8),
        StripStore::Analog { gpos, .. } => touch(gpos.as_ptr(), gpos.len(), 8),
    }
}

/// Immutable per-call state of one *reference-path* bit-serial conv, shared
/// by every channel shard (everything here is read-only during the sharded
/// MVM loop).
struct ConvCtx<'a> {
    layer: &'a ConvLayer,
    theta: &'a [f32],
    /// DAC codes, `[t, k²·d]`.
    codes_a: &'a [i32],
    /// Per-conversion-window activation scales, `[t]`.
    sa: &'a [f32],
    t: usize,
    sp: &'a StripPrecision,
    /// Strip-index base of this layer in `ModelInfo::strips()` order.
    base: usize,
    /// Row-segment partition of the layer depth `d`.
    segs: Vec<(usize, usize, usize)>,
    /// Packed u64 words per (phase/cell-bit × polarity) plane.
    total_words: usize,
    /// Ideal converters: take the integer-dot-product fast path.
    exact: bool,
    /// Run the phase loop on packed bit-planes (decided once here so the
    /// plane builder below and the shard readers can never disagree).
    use_packed: bool,
    /// Input-bit phases (`input_bits - 1`).
    phases: usize,
    /// Packed activation bit-planes per kernel tap (empty unless
    /// `use_packed`). Built once per conv call and shared read-only by
    /// every channel shard — the planes are channel-independent.
    a_planes: Vec<Vec<u64>>,
}

/// The simulator backend. Without strip metadata every conv runs in exact
/// f32 (fp32 reference deployments); with it, conv layers execute on
/// programmed crossbar tiles at their assigned per-strip precision.
pub struct SimXbar {
    pub cfg: SimXbarConfig,
    strips: Option<StripPrecision>,
    /// Device-variability scenario injected at programming time (faults +
    /// placement; see [`crate::faults`]). `None` or inactive = today's
    /// fault-free artifact, bit for bit.
    scenario: Option<Scenario>,
    /// Parsed network graph of the last model seen, so the eval loop and the
    /// serving hot path don't re-parse the manifest layout on every batch.
    spec: Mutex<Option<(String, usize, NetSpec)>>,
    /// Program-once crossbar artifact of the last `(model, theta, strips,
    /// config)` seen, keyed by an FNV fingerprint. One entry suffices: a
    /// deployment drives one checkpoint.
    programmed: Mutex<Option<(u64, Arc<ProgrammedModel>)>>,
    /// Per-instance scratch arena for the zero-alloc inference path (one
    /// backend instance per engine worker, so the lock is uncontended).
    scratch: Mutex<Scratch>,
    /// Always-on walk profiling counters, bumped arithmetically once per
    /// conv call (never in the per-sample loops) and surfaced through
    /// [`ExecBackend::walk_profile`].
    walk: WalkProfileAtomic,
    /// Serving-time self-healing state (see [`crate::health`]): the logical
    /// tick the installed artifact was programmed at, plus the channel of
    /// an in-flight background re-programming pass. Each engine worker owns
    /// its backend, so the lock is uncontended.
    health: Mutex<HealthState>,
}

/// Health-monitor state of one backend instance.
#[derive(Default)]
struct HealthState {
    /// Logical tick the currently installed artifact was programmed at.
    /// Folded into the effective scenario so the artifact cache key always
    /// names the installed generation.
    installed_tick: u64,
    /// Receiver for a standby artifact being programmed on a background
    /// thread: `Some((tick, artifact))` on success, `None` if programming
    /// failed (the monitor retries on a later step).
    pending: Option<mpsc::Receiver<Option<(u64, Arc<ProgrammedModel>)>>>,
}

/// FNV-1a over the programmed artifact's inputs: model identity, parameter
/// bits, per-strip bits and scale bits, and the fidelity knobs of the
/// config (`cfg` is a public field, so a caller mutating it between
/// forwards must invalidate the artifact; `threads` and `simd` are
/// deliberately excluded — sharding and kernel width are bit-identical and
/// the interleaved plane layout is the same either way, so they share the
/// artifact). The fault
/// scenario's fingerprint (spec + placement + scores + health reservation
/// + tick) is mixed in so faulted and fault-free artifacts — and distinct
/// repair generations — never alias.
fn prog_key(
    model: &ModelInfo,
    theta: &[f32],
    sp: &StripPrecision,
    cfg: &SimXbarConfig,
    scenario: Option<&Scenario>,
) -> u64 {
    #[inline]
    fn mix(h: &mut u64, v: u64) {
        *h ^= v;
        *h = h.wrapping_mul(0x100000001b3);
    }
    let mut h = 0xcbf29ce484222325u64;
    match scenario {
        Some(sc) => {
            mix(&mut h, 1);
            mix(&mut h, sc.fingerprint());
        }
        None => mix(&mut h, 0),
    }
    mix(&mut h, cfg.rows as u64);
    mix(&mut h, cfg.cell_bits as u64);
    mix(&mut h, cfg.input_bits as u64);
    mix(&mut h, cfg.adc_bits as u64);
    mix(&mut h, cfg.noise_sigma.to_bits());
    mix(&mut h, cfg.seed);
    mix(&mut h, cfg.force_phase_loop as u64);
    mix(&mut h, cfg.scalar_lanes as u64);
    for b in model.name().bytes() {
        mix(&mut h, b as u64);
    }
    mix(&mut h, model.entry.num_params as u64);
    mix(&mut h, theta.len() as u64);
    for v in theta {
        mix(&mut h, v.to_bits() as u64);
    }
    mix(&mut h, sp.bits.len() as u64);
    for &b in &sp.bits {
        mix(&mut h, b as u64);
    }
    for v in &sp.scales {
        mix(&mut h, v.to_bits() as u64);
    }
    h
}

impl SimXbar {
    pub fn new(cfg: SimXbarConfig) -> Self {
        Self {
            cfg,
            strips: None,
            scenario: None,
            spec: Mutex::new(None),
            programmed: Mutex::new(None),
            scratch: Mutex::new(Scratch::default()),
            walk: WalkProfileAtomic::default(),
            health: Mutex::new(HealthState::default()),
        }
    }

    /// Graph for `model`, parsed once per (name, param-count) and cached.
    fn spec_for(&self, model: &ModelInfo) -> Result<NetSpec> {
        let mut guard = self.spec.lock().unwrap();
        if let Some((name, params, spec)) = guard.as_ref() {
            if name == model.name() && *params == model.entry.num_params {
                return Ok(spec.clone());
            }
        }
        let spec = NetSpec::parse(model)?;
        *guard = Some((model.name().to_string(), model.entry.num_params, spec.clone()));
        Ok(spec)
    }

    pub fn with_strips(mut self, strips: StripPrecision) -> Self {
        self.strips = Some(strips);
        self
    }

    pub fn from_quantized(cfg: SimXbarConfig, qm: &QuantizedModel) -> Self {
        Self::new(cfg).with_strips(StripPrecision::from_quantized(qm))
    }

    /// Inject a device-variability scenario at programming time (faults +
    /// placement). An inactive scenario leaves the artifact bit-identical.
    pub fn with_scenario(mut self, scenario: Scenario) -> Self {
        self.scenario = Some(scenario);
        self
    }

    /// The active scenario's stats description ("none" when absent).
    pub fn scenario_desc(&self) -> String {
        self.scenario.as_ref().map_or_else(|| "none".to_string(), |s| s.describe())
    }

    /// The scenario the *installed* artifact generation programs under: the
    /// base scenario advanced to the tick the health monitor last swapped
    /// at. The tick enters [`Scenario::fingerprint`], so every repair
    /// generation gets its own cache key.
    fn effective_scenario(&self) -> Option<Scenario> {
        let tick = self.health.lock().unwrap().installed_tick;
        self.scenario.clone().map(|sc| sc.with_tick(tick))
    }

    /// The kernel the programmed packed walk will dispatch to on this host
    /// under the configured [`SimdMode`]: `"avx2"`, `"neon"` or `"scalar"`.
    pub fn simd_kernel_name(&self) -> &'static str {
        match simd_kernel(&self.cfg) {
            SimdKernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            SimdKernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            SimdKernel::Neon => "neon",
        }
    }

    /// The program-once crossbar artifact for `(model, theta, sp)` on this
    /// instance's config: programmed on first use, then reused as long as
    /// the fingerprint matches (steady-state serving hits the cache on
    /// every call). The fingerprint re-hashes `theta` per call — pointer
    /// identity could go stale through a realloc, and the O(params) hash
    /// is noise next to a bit-serial forward — so the cache can never
    /// serve a wrong artifact.
    pub fn programmed_for(
        &self,
        model: &ModelInfo,
        theta: &[f32],
        sp: &StripPrecision,
    ) -> Result<Arc<ProgrammedModel>> {
        let scn = self.effective_scenario();
        let key = prog_key(model, theta, sp, &self.cfg, scn.as_ref());
        {
            let guard = self.programmed.lock().unwrap();
            if let Some((k, p)) = guard.as_ref() {
                if *k == key {
                    return Ok(p.clone());
                }
            }
        }
        // Program outside the lock (it can take a while); if two threads
        // race, both computed the same artifact for the same key.
        let p = Arc::new(ProgrammedModel::program_with(model, theta, sp, &self.cfg, scn.as_ref())?);
        *self.programmed.lock().unwrap() = Some((key, p.clone()));
        Ok(p)
    }

    /// One health-monitor step at logical tick `tick` (the worker's
    /// served-batch count): install any standby artifact that finished
    /// programming, probe the canary strips against the evolved fault spec,
    /// and kick off a background re-programming pass when the device has
    /// drifted from the installed artifact. Returns `None` when the backend
    /// has no active fault scenario or no programmed artifact — nothing to
    /// monitor. Runs between batches on the worker thread; only the probe
    /// (O(canaries × depth)) runs inline, programming happens on a spawned
    /// thread.
    pub fn run_health_step(
        &self,
        model: &ModelInfo,
        theta: &[f32],
        tick: u64,
    ) -> Option<crate::health::StepReport> {
        let sp = self.strips.as_ref()?;
        let sc = self.scenario.as_ref().filter(|s| s.is_active())?;
        let mut report = crate::health::StepReport { tick, ..Default::default() };

        // 1. Install a standby artifact if background programming finished.
        //    Lock order is health → programmed, matching nothing else (no
        //    other path holds both).
        {
            let mut hs = self.health.lock().unwrap();
            if let Some(rx) = &hs.pending {
                match rx.try_recv() {
                    Ok(Some((newtick, fresh))) => {
                        hs.pending = None;
                        let cur =
                            self.programmed.lock().unwrap().as_ref().map(|(_, p)| p.clone());
                        if let Some(cur) = &cur {
                            let (repairs, quarantined) =
                                crate::health::repair_diff(cur, &fresh);
                            report.repairs = repairs;
                            report.quarantined = quarantined;
                        }
                        let scn = self.scenario.clone().map(|s| s.with_tick(newtick));
                        let key = prog_key(model, theta, sp, &self.cfg, scn.as_ref());
                        *self.programmed.lock().unwrap() = Some((key, fresh));
                        hs.installed_tick = newtick;
                        report.swapped = true;
                    }
                    // Programming failed (or the thread died): clear and
                    // let a later step retry from scratch.
                    Ok(None) | Err(mpsc::TryRecvError::Disconnected) => hs.pending = None,
                    Err(mpsc::TryRecvError::Empty) => {}
                }
            }
        }

        // 2. Probe the canaries against the spec evolved to *now*.
        let cur = self.programmed.lock().unwrap().as_ref().map(|(_, p)| p.clone())?;
        let eff = sc.spec.at_tick(tick);
        {
            let mut span = crate::trace::span("health.probe");
            span.tag("tick", || tick.to_string());
            let (probes, mismatches) = crate::health::probe_canaries(&cur, &eff);
            report.probes = probes;
            report.canary_mismatches = mismatches;
        }

        // 3. Re-program in the background when the device has evolved away
        //    from the installed artifact and the damage is detectable — a
        //    canary reported mismatched lanes, or the deployment reserved
        //    no canaries at all and must trust the clock blindly.
        let evolved = cur.scenario != Some(eff);
        let detected = report.probes == 0 || report.canary_mismatches > 0;
        let reprogram_in_flight = self.health.lock().unwrap().pending.is_some();
        if evolved && detected && !reprogram_in_flight {
            let (tx, rx) = mpsc::sync_channel(1);
            let model = model.clone();
            let theta = theta.to_vec();
            let sp = sp.clone();
            let cfg = self.cfg;
            let scn = sc.clone().with_tick(tick);
            let spawned = std::thread::Builder::new()
                .name("health-reprogram".into())
                .spawn(move || {
                    let mut span = crate::trace::span("health.reprogram");
                    span.tag("tick", || tick.to_string());
                    let res = ProgrammedModel::program_with(&model, &theta, &sp, &cfg, Some(&scn))
                        .ok()
                        .map(|p| (tick, Arc::new(p)));
                    let _ = tx.send(res);
                    drop(span);
                    crate::trace::flush_thread();
                })
                .is_ok();
            if spawned {
                self.health.lock().unwrap().pending = Some(rx);
                report.reprogram_started = true;
            }
        }
        Some(report)
    }

    /// Accumulate the always-on walk-profile counters for one programmed
    /// conv call. Everything is derived arithmetically from the layer's
    /// live-strip index — O(live strips) per call, nothing in the
    /// per-sample/per-word inner loops — so the counters cannot perturb
    /// the bit-identical walk and cost nothing measurable.
    fn profile_walk(&self, pl: &ProgrammedLayer, t: usize, phases: usize, kern: SimdKernel) {
        let (mut exact, mut packed, mut analog) = (0u64, 0u64, 0u64);
        let mut staged_per_block = 0u64;
        let mut phase_steps = 0u64;
        let mut kern_calls = 0u64;
        // per packed/analog strip, the walk runs t × segs × phases steps
        let steps = t as u64 * pl.segs.len() as u64 * phases as u64;
        for &(s0, slen) in &pl.chan {
            let strips = &pl.strips[s0 as usize..s0 as usize + slen as usize];
            staged_per_block += (slen as u64).saturating_sub(1);
            for s in strips {
                match &s.store {
                    StripStore::Exact { .. } => exact += 1,
                    StripStore::Packed { .. } => {
                        packed += 1;
                        phase_steps += steps;
                        kern_calls += steps;
                    }
                    StripStore::Analog { .. } => {
                        analog += 1;
                        phase_steps += steps;
                    }
                }
            }
        }
        let simd = !matches!(kern, SimdKernel::Scalar);
        self.walk.add(&WalkProfile {
            conv_calls: 1,
            strips_walked: exact + packed + analog,
            exact_strips: exact,
            packed_strips: packed,
            analog_strips: analog,
            phase_steps,
            kernel_simd: if simd { kern_calls } else { 0 },
            kernel_scalar: if simd { 0 } else { kern_calls },
            // staging fires once per strip-with-successor per TI block
            prefetch_staged: staged_per_block * t.div_ceil(TI_BLOCK) as u64,
            scratch_high_water_bytes: 0,
        });
    }

    /// Effective shard count for a layer with `n` output channels.
    fn effective_threads(&self, n: usize) -> usize {
        let req = if self.cfg.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.cfg.threads
        };
        req.min(n).max(1)
    }

    /// Bit-serial conv of one layer over im2col patches (the crossbar hot
    /// path): a read-only walk over the programmed tiles (programmed — and
    /// cached — on first use). Exposed for the property tests; the serving
    /// path resolves the artifact once per forward instead.
    pub fn conv_bitserial(
        &self,
        model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
        sp: &StripPrecision,
    ) -> Result<Vec<f32>> {
        let prog = self.programmed_for(model, theta, sp)?;
        let mut scratch = self.scratch.lock().unwrap();
        let mut out = Vec::new();
        self.conv_programmed(&prog, layer, patches, t, &mut scratch.conv, &mut out)?;
        Ok(out)
    }

    /// One conv layer over the programmed artifact: DAC the activations,
    /// pack their bit-planes (packed mode only), then walk the layer's live
    /// tiles — no weight quantization, no weight packing, no dead-strip
    /// branching, no allocation beyond first-use scratch growth.
    pub fn conv_programmed(
        &self,
        prog: &ProgrammedModel,
        layer: &ConvLayer,
        patches: &[f32],
        t: usize,
        cs: &mut ConvScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let _span = crate::trace::span("xbar.conv");
        let pl = prog
            .layers
            .get(layer.index)
            .ok_or_else(|| anyhow::anyhow!("layer {} not in programmed artifact", layer.name))?;
        anyhow::ensure!(
            pl.d == layer.d && pl.n == layer.n && pl.kk == layer.k * layer.k,
            "programmed artifact does not match layer {} geometry",
            layer.name
        );
        let cfg = &self.cfg;
        let (d, n, kk) = (pl.d, pl.n, pl.kk);
        let cols = kk * d;
        dac_quantize(cfg, patches, t, cols, &mut cs.codes_a, &mut cs.sa);

        let phases = (cfg.input_bits - 1) as usize;
        if prog.mode == ExecMode::Packed {
            // One fused pass over the whole batch's DAC codes — per-sample
            // or per-tap re-packing never happens; the walk (and every
            // shard of it) only re-reads these shared planes.
            pack_activation_planes_batch_into(
                &mut cs.a_planes,
                &cs.codes_a,
                cols,
                d,
                kk,
                &pl.segs,
                pl.total_words,
                phases,
                t,
            );
        } else {
            cs.a_planes.clear();
        }

        // Resolve the SIMD kernel once per conv call (runtime detection is
        // cached); every shard dispatches to the same kernel.
        let kern = simd_kernel(cfg);
        self.profile_walk(pl, t, phases, kern);
        out.clear();
        out.resize(t * n, 0.0);
        let threads = self.effective_threads(n);
        if threads <= 1 {
            walk_channels(cfg, kern, pl, &cs.codes_a, &cs.sa, &cs.a_planes, t, 0, n, out);
            return Ok(());
        }
        // Shard the column-strip loop: each worker owns a contiguous
        // channel range and a private [t, width] accumulator, so the
        // per-(sample, channel) accumulation order is exactly the
        // sequential loop's and the merged result is bit-identical for
        // every worker count.
        let chunk = n.div_ceil(threads);
        let ranges: Vec<(usize, usize)> = (0..threads)
            .map(|w| (w * chunk, ((w + 1) * chunk).min(n)))
            .filter(|(c0, c1)| c1 > c0)
            .collect();
        if cs.parts.len() < ranges.len() {
            cs.parts.resize_with(ranges.len(), Vec::new);
        }
        let codes_a: &[i32] = &cs.codes_a;
        let sa: &[f32] = &cs.sa;
        let a_planes: &[u64] = &cs.a_planes;
        std::thread::scope(|scope| {
            for (&(c0, c1), part) in ranges.iter().zip(cs.parts.iter_mut()) {
                scope.spawn(move || {
                    part.clear();
                    part.resize(t * (c1 - c0), 0.0);
                    walk_channels(cfg, kern, pl, codes_a, sa, a_planes, t, c0, c1, part);
                });
            }
        });
        for (&(c0, c1), part) in ranges.iter().zip(cs.parts.iter()) {
            let w = c1 - c0;
            for ti in 0..t {
                out[ti * n + c0..ti * n + c1].copy_from_slice(&part[ti * w..(ti + 1) * w]);
            }
        }
        Ok(())
    }

    /// The pre-artifact reference path: re-derives weight codes and re-packs
    /// weight bit-planes on **every call**, exactly as deployed before the
    /// program-once refactor. Kept for the bit-identity property tests and
    /// the `xbar_programmed` bench's before/after row — not used by
    /// serving.
    pub fn conv_bitserial_reference(
        &self,
        model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
        sp: &StripPrecision,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        anyhow::ensure!(cfg.rows >= 1, "sim rows must be >= 1");
        anyhow::ensure!(
            (1..=8).contains(&cfg.cell_bits),
            "sim cell_bits {} out of range 1..=8",
            cfg.cell_bits
        );
        anyhow::ensure!(
            (2..=24).contains(&cfg.input_bits),
            "sim input_bits {} out of range 2..=24",
            cfg.input_bits
        );
        anyhow::ensure!(cfg.adc_bits <= 16, "sim adc_bits {} out of range 0..=16", cfg.adc_bits);
        anyhow::ensure!(
            sp.bits.len() == model.num_strips() && sp.scales.len() == sp.bits.len(),
            "strip precision covers {} strips, model has {}",
            sp.bits.len(),
            model.num_strips()
        );
        let d = layer.d;
        let n = layer.n;
        let kk = layer.k * layer.k;
        let cols = kk * d;
        let base: usize = model.conv_layers()[..layer.index]
            .iter()
            .map(ConvLayer::num_strips)
            .sum();

        let mut codes_a = Vec::new();
        let mut sa = Vec::new();
        dac_quantize(cfg, patches, t, cols, &mut codes_a, &mut sa);

        let (segs, total_words) = segments(d, cfg.rows);
        let exact = cfg.adc_bits == 0 && cfg.noise_sigma == 0.0 && !cfg.force_phase_loop;
        let mut ctx = ConvCtx {
            layer,
            theta,
            codes_a: &codes_a,
            sa: &sa,
            t,
            sp,
            base,
            segs,
            total_words,
            exact,
            use_packed: !exact && cfg.noise_sigma == 0.0 && !cfg.scalar_lanes,
            phases: (cfg.input_bits - 1) as usize,
            a_planes: Vec::new(),
        };
        if ctx.use_packed {
            let planes: Vec<Vec<u64>> = (0..kk)
                .map(|g| {
                    let mut p = vec![0u64; ctx.t * ctx.phases * 2 * ctx.total_words];
                    pack_activation_planes_into(
                        &mut p,
                        ctx.codes_a,
                        cols,
                        d,
                        g,
                        &ctx.segs,
                        ctx.total_words,
                        ctx.phases,
                        ctx.t,
                    );
                    p
                })
                .collect();
            ctx.a_planes = planes;
        }

        let mut out = vec![0.0f32; t * n];
        let threads = self.effective_threads(n);
        if threads <= 1 {
            self.conv_channel_range(&ctx, 0, n, &mut out)?;
        } else {
            let chunk = n.div_ceil(threads);
            let ranges: Vec<(usize, usize)> = (0..threads)
                .map(|w| (w * chunk, ((w + 1) * chunk).min(n)))
                .filter(|(c0, c1)| c1 > c0)
                .collect();
            let parts: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
                let ctx = &ctx;
                let handles: Vec<_> = ranges
                    .iter()
                    .map(|&(c0, c1)| {
                        scope.spawn(move || {
                            let mut part = vec![0.0f32; t * (c1 - c0)];
                            self.conv_channel_range(ctx, c0, c1, &mut part)?;
                            Ok(part)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("sim shard thread panicked"))
                    .collect()
            });
            for (&(c0, c1), part) in ranges.iter().zip(parts) {
                let part = part?;
                let w = c1 - c0;
                for ti in 0..t {
                    out[ti * n + c0..ti * n + c1].copy_from_slice(&part[ti * w..(ti + 1) * w]);
                }
            }
        }
        Ok(out)
    }

    /// Reference path: execute every strip whose output channel lies in
    /// `[c0, c1)` over all conversion windows, re-quantizing and re-packing
    /// each strip's weights in place, accumulating into `out` of shape
    /// `[t, c1 - c0]`.
    fn conv_channel_range(
        &self,
        ctx: &ConvCtx<'_>,
        c0: usize,
        c1: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let cfg = &self.cfg;
        let layer = ctx.layer;
        let d = layer.d;
        let n = layer.n;
        let kk = layer.k * layer.k;
        let cols = kk * d;
        let cw = c1 - c0;
        let t = ctx.t;
        let (exact, use_packed, phases) = (ctx.exact, ctx.use_packed, ctx.phases);
        let mask = (1i32 << cfg.cell_bits) - 1;
        let total_words = ctx.total_words;
        let segs = &ctx.segs;

        let mut codes_w = vec![0i32; d];
        // Packed weight planes of the current strip, layout
        // [cell slice × cell bit][polarity][segment words].
        let mut w_planes: Vec<u64> = Vec::new();

        for g in 0..kk {
            // Activation planes for this kernel tap, layout
            // [ti][phase][polarity][segment words] — packed once per conv
            // call in `ctx`, shared read-only across channel shards.
            let a_planes: &[u64] = if use_packed { &ctx.a_planes[g] } else { &[] };
            for ch in c0..c1 {
                let idx = ctx.base + g * n + ch;
                let bits = ctx.sp.bits[idx];
                if bits == 0 {
                    continue; // pruned strip: no cells programmed
                }
                anyhow::ensure!(
                    (1..=16).contains(&bits),
                    "strip {idx} has unsupported bit width {bits}"
                );
                let sw = ctx.sp.scales[idx];
                if sw <= 0.0 {
                    continue;
                }
                let q_w = quant::qmax(bits);
                for (dd, cwv) in codes_w.iter_mut().enumerate() {
                    let wv = ctx.theta[layer.theta_index(g, dd, ch)];
                    *cwv = (wv / sw).round().clamp(-q_w, q_w) as i32;
                }

                if exact {
                    // Ideal converters: the phase/slice decomposition
                    // telescopes to the plain integer dot product.
                    for ti in 0..t {
                        let arow = &ctx.codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                        let mut acc = 0i64;
                        for (&a, &cwv) in arow.iter().zip(codes_w.iter()) {
                            acc += a as i64 * cwv as i64;
                        }
                        out[ti * cw + (ch - c0)] +=
                            (acc as f64 * ctx.sa[ti] as f64 * sw as f64) as f32;
                    }
                    continue;
                }

                let ncells = bits.div_ceil(cfg.cell_bits) as usize;

                if use_packed {
                    // ---- packed bit-plane phase loop (integral cells) ----
                    pack_weight_planes_into(
                        &mut w_planes,
                        &codes_w,
                        cfg.cell_bits,
                        ncells,
                        segs,
                        total_words,
                    );
                    let cell_bits = cfg.cell_bits as usize;
                    let stride_ti = phases * 2 * total_words;
                    for ti in 0..t {
                        let tb = ti * stride_ti;
                        let mut total = 0.0f64;
                        for &(_, len, woff) in segs {
                            let nw = words_of(len);
                            for p in 0..phases {
                                let app = &a_planes[tb + (p * 2) * total_words + woff..][..nw];
                                let apn = &a_planes[tb + (p * 2 + 1) * total_words + woff..][..nw];
                                for j in 0..ncells {
                                    // four currents: input polarity × column
                                    let (mut ipp, mut ipn) = (0u64, 0u64);
                                    let (mut inp, mut inn) = (0u64, 0u64);
                                    for b in 0..cell_bits {
                                        let row = (j * cell_bits + b) * 2;
                                        let gp = &w_planes[row * total_words + woff..][..nw];
                                        let gm = &w_planes[(row + 1) * total_words + woff..][..nw];
                                        let (mut cpp, mut cpn) = (0u32, 0u32);
                                        let (mut cnp, mut cnn) = (0u32, 0u32);
                                        for w in 0..nw {
                                            cpp += (app[w] & gp[w]).count_ones();
                                            cpn += (app[w] & gm[w]).count_ones();
                                            cnp += (apn[w] & gp[w]).count_ones();
                                            cnn += (apn[w] & gm[w]).count_ones();
                                        }
                                        ipp += (cpp as u64) << b;
                                        ipn += (cpn as u64) << b;
                                        inp += (cnp as u64) << b;
                                        inn += (cnn as u64) << b;
                                    }
                                    let w2 =
                                        2.0f64.powi(p as i32 + (j as i32) * cfg.cell_bits as i32);
                                    total += w2
                                        * ((adc_transfer(cfg, ipp as f64, len)
                                            + adc_transfer(cfg, inn as f64, len))
                                            - (adc_transfer(cfg, ipn as f64, len)
                                                + adc_transfer(cfg, inp as f64, len)));
                                }
                            }
                        }
                        out[ti * cw + (ch - c0)] += (total * ctx.sa[ti] as f64 * sw as f64) as f32;
                    }
                    continue;
                }

                // ---- scalar lane scan (noisy cells, or packing disabled) --
                // program the differential, bit-sliced cell columns
                let mut gpos = vec![0.0f64; ncells * d];
                let mut gneg = vec![0.0f64; ncells * d];
                for (dd, &cwv) in codes_w.iter().enumerate() {
                    let (p, q) = (cwv.max(0), (-cwv).max(0));
                    for j in 0..ncells {
                        let sh = (j as u32) * cfg.cell_bits as u32;
                        gpos[j * d + dd] = ((p >> sh) & mask) as f64;
                        gneg[j * d + dd] = ((q >> sh) & mask) as f64;
                    }
                }
                if cfg.noise_sigma > 0.0 {
                    // Per-strip stream: a given (seed, layer, strip) always
                    // programs the same array state, independent of which
                    // shard evaluates it or in what order — the same
                    // [`NoiseStream`] the programmed artifact draws from.
                    let mut rng = NoiseStream::for_strip(cfg.seed, layer.index, idx);
                    for v in gpos.iter_mut().chain(gneg.iter_mut()) {
                        *v += rng.normal() as f64 * cfg.noise_sigma;
                    }
                }

                // ---- input-bit phases × cell slices × row segments ----
                for ti in 0..t {
                    let arow = &ctx.codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                    let mut total = 0.0f64;
                    for &(seg_start, len, _) in segs {
                        let seg_end = seg_start + len;
                        for p in 0..phases as u32 {
                            let pbit = 1i32 << p;
                            for j in 0..ncells {
                                // four currents: input polarity × column
                                let (mut ipp, mut ipn) = (0.0f64, 0.0f64);
                                let (mut inp, mut inn) = (0.0f64, 0.0f64);
                                for dd in seg_start..seg_end {
                                    let a = arow[dd];
                                    if a == 0 || (a.abs() & pbit) == 0 {
                                        continue;
                                    }
                                    let gp = gpos[j * d + dd];
                                    let gm = gneg[j * d + dd];
                                    if a > 0 {
                                        ipp += gp;
                                        ipn += gm;
                                    } else {
                                        inp += gp;
                                        inn += gm;
                                    }
                                }
                                let w2 = 2.0f64.powi(p as i32 + (j as i32) * cfg.cell_bits as i32);
                                total += w2
                                    * ((adc_transfer(cfg, ipp, len) + adc_transfer(cfg, inn, len))
                                        - (adc_transfer(cfg, ipn, len)
                                            + adc_transfer(cfg, inp, len)));
                            }
                        }
                    }
                    out[ti * cw + (ch - c0)] += (total * ctx.sa[ti] as f64 * sw as f64) as f32;
                }
            }
        }
        Ok(())
    }
}

/// Cache-block size of the conversion-window (sample) axis of the walk:
/// strips consume the shared activation planes block by block, so a
/// block's planes stay cache-resident while *every* strip of the channel
/// range reads them, and one strip's packed weight planes stay hot across
/// all samples of a block. 32 windows × a typical per-window plane
/// footprint of a few hundred bytes keeps a block comfortably inside L1/L2
/// next to one strip's weight planes.
const TI_BLOCK: usize = 32;

/// The programmed-tile walk over channels `[c0, c1)`: every live strip of
/// every channel in the range, per-strip state read straight from its
/// [`StripStore`]. The walk is **cache-blocked and double-buffered**: the
/// sample axis is tiled by [`TI_BLOCK`], the next strip's programmed words
/// are staged toward cache while the current strip accumulates, and the
/// packed branch dispatches to the resolved SIMD kernel (`kern`). For any
/// fixed (sample, channel) output cell, contributions still arrive in the
/// exact per-strip order of the re-pack-per-call loop and every kernel
/// feeds the ADC identical integer currents, so the result is
/// bit-identical to the reference path for every blocking, kernel, and
/// thread count.
#[allow(clippy::too_many_arguments)]
fn walk_channels(
    cfg: &SimXbarConfig,
    kern: SimdKernel,
    pl: &ProgrammedLayer,
    codes_a: &[i32],
    sa: &[f32],
    a_planes: &[u64],
    t: usize,
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    let (d, kk) = (pl.d, pl.kk);
    let cols = kk * d;
    let cw = c1 - c0;
    let cell_bits = cfg.cell_bits as usize;
    let phases = (cfg.input_bits - 1) as usize;
    let total_words = pl.total_words;
    let stride_ti = phases * 2 * total_words;
    let tap_stride = t * stride_ti;
    let segs = &pl.segs;
    let mut cur = [0u64; MAX_STRIP_CURRENTS];

    let mut t0 = 0usize;
    while t0 < t {
        let t1 = (t0 + TI_BLOCK).min(t);
        for ch in c0..c1 {
            let (s0, slen) = pl.chan[ch];
            let strips = &pl.strips[s0 as usize..s0 as usize + slen as usize];
            for (si, s) in strips.iter().enumerate() {
                // Double-buffer staging: queue the next strip's programmed
                // words into cache while this strip's accumulation retires.
                if let Some(next) = strips.get(si + 1) {
                    stage_strip(next);
                }
                let g = s.g as usize;
                let sw = s.sw;
                match &s.store {
                    StripStore::Exact { codes } => {
                        for ti in t0..t1 {
                            let arow = &codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                            let mut acc = 0i64;
                            for (&a, &cwv) in arow.iter().zip(codes.iter()) {
                                acc += a as i64 * cwv as i64;
                            }
                            out[ti * cw + (ch - c0)] +=
                                (acc as f64 * sa[ti] as f64 * sw as f64) as f32;
                        }
                    }
                    StripStore::Packed { planes: w_planes, ncells } => {
                        let ncells = *ncells;
                        let nrows = ncells * cell_bits * 2;
                        let rp = packed_rows_pad(ncells, cfg.cell_bits);
                        let ap = &a_planes[g * tap_stride..(g + 1) * tap_stride];
                        for ti in t0..t1 {
                            let tb = ti * stride_ti;
                            let mut total = 0.0f64;
                            for &(_, len, woff) in segs {
                                let nw = words_of(len);
                                // this segment's interleaved weight words
                                let seg_planes = &w_planes[woff * rp..(woff + nw) * rp];
                                for p in 0..phases {
                                    let app = &ap[tb + (p * 2) * total_words + woff..][..nw];
                                    let apn =
                                        &ap[tb + (p * 2 + 1) * total_words + woff..][..nw];
                                    packed_currents(
                                        kern, seg_planes, rp, nrows, cell_bits, ncells, app,
                                        apn, &mut cur,
                                    );
                                    for (j, c4) in cur[..ncells * 4].chunks_exact(4).enumerate()
                                    {
                                        let w2 = 2.0f64
                                            .powi(p as i32 + (j as i32) * cfg.cell_bits as i32);
                                        total += w2
                                            * ((adc_transfer(cfg, c4[0] as f64, len)
                                                + adc_transfer(cfg, c4[3] as f64, len))
                                                - (adc_transfer(cfg, c4[1] as f64, len)
                                                    + adc_transfer(cfg, c4[2] as f64, len)));
                                    }
                                }
                            }
                            out[ti * cw + (ch - c0)] +=
                                (total * sa[ti] as f64 * sw as f64) as f32;
                        }
                    }
                    StripStore::Analog { gpos, gneg, ncells } => {
                        let ncells = *ncells;
                        for ti in t0..t1 {
                            let arow = &codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                            let mut total = 0.0f64;
                            for &(seg_start, len, _) in segs {
                                let seg_end = seg_start + len;
                                for p in 0..phases as u32 {
                                    let pbit = 1i32 << p;
                                    for j in 0..ncells {
                                        // four currents: input polarity × column
                                        let (mut ipp, mut ipn) = (0.0f64, 0.0f64);
                                        let (mut inp, mut inn) = (0.0f64, 0.0f64);
                                        for dd in seg_start..seg_end {
                                            let a = arow[dd];
                                            if a == 0 || (a.abs() & pbit) == 0 {
                                                continue;
                                            }
                                            let gp = gpos[j * d + dd];
                                            let gm = gneg[j * d + dd];
                                            if a > 0 {
                                                ipp += gp;
                                                ipn += gm;
                                            } else {
                                                inp += gp;
                                                inn += gm;
                                            }
                                        }
                                        let w2 = 2.0f64
                                            .powi(p as i32 + (j as i32) * cfg.cell_bits as i32);
                                        total += w2
                                            * ((adc_transfer(cfg, ipp, len)
                                                + adc_transfer(cfg, inn, len))
                                                - (adc_transfer(cfg, ipn, len)
                                                    + adc_transfer(cfg, inp, len)));
                                    }
                                }
                            }
                            out[ti * cw + (ch - c0)] +=
                                (total * sa[ti] as f64 * sw as f64) as f32;
                        }
                    }
                }
            }
        }
        t0 = t1;
    }
}

/// [`ConvExec`] adapter binding a resolved programmed artifact: the forward
/// pass resolves (or programs) the artifact once, then every conv layer is
/// a read-only tile walk.
struct ProgrammedConv<'a> {
    sim: &'a SimXbar,
    prog: &'a ProgrammedModel,
}

impl ConvExec for ProgrammedConv<'_> {
    fn conv(
        &self,
        _model: &ModelInfo,
        layer: &ConvLayer,
        _theta: &[f32],
        patches: &[f32],
        t: usize,
        scratch: &mut ConvScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.sim.conv_programmed(self.prog, layer, patches, t, scratch, out)
    }
}

impl ExecBackend for SimXbar {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn forward(
        &self,
        model: &ModelInfo,
        _kind: FwdKind,
        theta: &Tensor,
        x: &Tensor,
    ) -> Result<Tensor> {
        let spec = self.spec_for(model)?;
        let prog = match &self.strips {
            Some(sp) => Some(self.programmed_for(model, theta.data(), sp)?),
            None => None,
        };
        let mut scratch = self.scratch.lock().unwrap();
        let out = match prog.as_deref() {
            Some(p) => {
                let exec = ProgrammedConv { sim: self, prog: p };
                nn::forward(model, &spec, theta.data(), x, &exec, &mut scratch)
            }
            None => nn::forward(model, &spec, theta.data(), x, &ExactConv, &mut scratch),
        };
        self.walk.observe_scratch_bytes(scratch.bytes());
        out
    }

    fn ready_check(&self, model: &ModelInfo, theta: &Tensor) -> Result<()> {
        if let Some(sp) = &self.strips {
            anyhow::ensure!(
                sp.bits.len() == model.num_strips() && sp.scales.len() == sp.bits.len(),
                "strip precision covers {} strips, model has {}",
                sp.bits.len(),
                model.num_strips()
            );
            // Program the crossbars now, inside the readiness handshake:
            // deploy-time cost, never request-time.
            self.programmed_for(model, theta.data(), sp)?;
        }
        self.spec_for(model)?;
        Ok(())
    }

    fn program_ns(&self) -> u64 {
        self.programmed
            .lock()
            .unwrap()
            .as_ref()
            .map(|(_, p)| p.program_ns)
            .unwrap_or(0)
    }

    fn walk_profile(&self) -> Option<WalkProfile> {
        Some(self.walk.snapshot())
    }

    fn health_step(
        &self,
        model: &ModelInfo,
        theta: &Tensor,
        tick: u64,
    ) -> Option<crate::health::StepReport> {
        self.run_health_step(model, theta.data(), tick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry};
    use crate::util::rng::Rng;
    use std::collections::HashMap;

    fn layer_model(k: usize, d: usize, n: usize) -> ModelInfo {
        ModelInfo::new(ModelEntry {
            name: "sim-layer".into(),
            num_params: k * k * d * n,
            num_conv_params: k * k * d * n,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![k * k * d * n], dtype: "f32".into() },
            layers: vec![LayerEntry {
                name: "stem.conv".into(),
                shape: vec![k, k, d, n],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            }],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        })
    }

    fn quantized_layer(m: &ModelInfo, seed: u64, bits: u8) -> (Vec<f32>, StripPrecision) {
        let mut rng = Rng::seed_from_u64(seed);
        let theta: Vec<f32> = (0..m.entry.num_params).map(|_| rng.normal() * 0.3).collect();
        let bm = crate::quant::BitMap::uniform(m.num_strips(), bits);
        let cfg = crate::config::QuantConfig {
            device_sigma: 0.0,
            ..crate::config::QuantConfig::default()
        };
        let qm = quant::apply(m, &theta, &bm, &cfg);
        (qm.theta, StripPrecision::from_quantized(&qm))
    }

    #[test]
    fn sim_phase_loop_equals_integer_fast_path() {
        let m = layer_model(1, 19, 3);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 7, 8);
        let mut rng = Rng::seed_from_u64(9);
        let t = 5;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        // rows=4 forces multi-segment conversion on the 19-row strips
        let base = SimXbarConfig { rows: 4, input_bits: 6, ..SimXbarConfig::default() };
        let fast = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let phased = SimXbar::new(SimXbarConfig { force_phase_loop: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        for (a, b) in fast.iter().zip(&phased) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn sim_pruned_and_zero_scale_strips_contribute_nothing() {
        let m = layer_model(1, 4, 2);
        let layer = m.layer(0).clone();
        let theta = vec![1.0f32; m.entry.num_params];
        let sp = StripPrecision { bits: vec![0, 8], scales: vec![0.0, 0.5] };
        let patches = vec![1.0f32; 4];
        let out = SimXbar::new(SimXbarConfig::default())
            .conv_bitserial(&m, &layer, &theta, &patches, 1, &sp)
            .unwrap();
        assert_eq!(out[0], 0.0, "pruned channel must stay silent");
        assert!(out[1] > 0.0);
    }

    #[test]
    fn sim_adc_and_noise_are_deterministic_per_seed() {
        let m = layer_model(3, 8, 4);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 21, 8);
        let mut rng = Rng::seed_from_u64(2);
        let t = 3;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let cfg = SimXbarConfig::default().with_adc(4).with_noise(0.05, 99);
        let run = || {
            SimXbar::new(cfg)
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap()
        };
        assert_eq!(run(), run(), "fixed seed must reproduce bit-identically");
        let other = SimXbar::new(cfg.with_noise(0.05, 100))
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_ne!(run(), other, "different seed must redraw the noise");
    }

    #[test]
    fn sim_packed_adc_phase_loop_matches_scalar_lanes_exactly() {
        // The packed popcount path and the scalar lane scan feed identical
        // currents to the ADC — outputs must match bit for bit.
        let m = layer_model(3, 10, 4);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 5, 8);
        let mut rng = Rng::seed_from_u64(55);
        let t = 3;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let base = SimXbarConfig { rows: 4, ..SimXbarConfig::default() }.with_adc(5);
        let packed = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let scalar = SimXbar::new(SimXbarConfig { scalar_lanes: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_eq!(packed, scalar);
    }

    #[test]
    fn sim_simd_walk_matches_forced_scalar_kernel_exactly() {
        // Whatever kernel this host resolves (AVX2 / NEON / scalar), the
        // widened walk must feed the ADC the same integer currents as the
        // forced-scalar kernel and the scalar lane scan; d=19 over rows=4
        // exercises a remainder segment. The exhaustive grid lives in
        // tests/properties.rs.
        let m = layer_model(3, 19, 5);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 31, 8);
        let mut rng = Rng::seed_from_u64(41);
        let t = 3;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let base = SimXbarConfig { rows: 4, ..SimXbarConfig::default() }.with_adc(4);
        let auto = SimXbar::new(base.with_simd(SimdMode::Force));
        let widened = auto.conv_bitserial(&m, &layer, &theta, &patches, t, &sp).unwrap();
        let portable = SimXbar::new(base.with_simd(SimdMode::Off))
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let lanes = SimXbar::new(SimXbarConfig { scalar_lanes: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_eq!(widened, portable, "kernel {} diverged", auto.simd_kernel_name());
        assert_eq!(widened, lanes);
        assert!(["avx2", "neon", "scalar"].contains(&auto.simd_kernel_name()));
        assert_eq!(
            SimXbar::new(base.with_simd(SimdMode::Off)).simd_kernel_name(),
            "scalar"
        );
    }

    #[test]
    fn sim_thread_sharding_is_bit_identical_even_with_noise() {
        // The noise stream is seeded per strip, so any shard count programs
        // the same array state and sums in the same per-channel order.
        let m = layer_model(3, 8, 6);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 8, 8);
        let mut rng = Rng::seed_from_u64(77);
        let t = 2;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let noisy = SimXbarConfig { threads: 1, ..SimXbarConfig::default() }
            .with_adc(4)
            .with_noise(0.05, 11);
        let single = SimXbar::new(noisy)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        for threads in [2usize, 3, 8] {
            let got = SimXbar::new(SimXbarConfig { threads, ..noisy })
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap();
            assert_eq!(single, got, "{threads}-way shard must not change results");
        }
    }

    #[test]
    fn sim_programming_is_cached_per_model_theta_and_strips() {
        let m = layer_model(3, 8, 4);
        let (theta, sp) = quantized_layer(&m, 21, 8);
        let sim = SimXbar::new(SimXbarConfig::default());
        let a = sim.programmed_for(&m, &theta, &sp).unwrap();
        let b = sim.programmed_for(&m, &theta, &sp).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same inputs must reuse the programmed artifact");
        assert!(a.program_ns >= 1);
        assert_eq!(a.live_strips, m.num_strips());
        // a different checkpoint must reprogram
        let mut theta2 = theta.clone();
        theta2[0] += 1.0;
        let c = sim.programmed_for(&m, &theta2, &sp).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "changed theta must invalidate the artifact");
    }

    #[test]
    fn sim_zero_scenario_is_bit_identical_and_faults_change_the_artifact() {
        use crate::faults::{Scenario, ScenarioSpec};
        let m = layer_model(3, 8, 4);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 21, 8);
        let mut rng = Rng::seed_from_u64(5);
        let t = 2;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let cfg = SimXbarConfig::default();
        let clean = SimXbar::new(cfg)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let zero = SimXbar::new(cfg)
            .with_scenario(Scenario::default())
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_eq!(clean, zero, "inactive scenario must not perturb the artifact");
        let faulted = SimXbar::new(cfg)
            .with_scenario(Scenario::new(ScenarioSpec::default().with_stuck(0.5, 7)))
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_ne!(clean, faulted, "stuck-at cells must change conv outputs");
    }

    #[test]
    fn sim_programmed_walk_matches_reference_path_spot_check() {
        // Quick corner spot-check; the full {mode} × {threads} grid lives
        // in tests/properties.rs.
        let m = layer_model(3, 10, 5);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 13, 8);
        let mut rng = Rng::seed_from_u64(17);
        let t = 3;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        for cfg in [
            SimXbarConfig::default(),
            SimXbarConfig { rows: 4, ..SimXbarConfig::default() }.with_adc(4),
            SimXbarConfig::default().with_adc(4).with_noise(0.05, 3),
        ] {
            let sim = SimXbar::new(cfg);
            let programmed = sim
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap();
            let reference = sim
                .conv_bitserial_reference(&m, &layer, &theta, &patches, t, &sp)
                .unwrap();
            assert_eq!(programmed, reference);
        }
    }
}
