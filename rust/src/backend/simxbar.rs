//! `SimXbar` — native bit-serial crossbar MVM simulator.
//!
//! Models what the paper's ReRAM substrate physically computes, per strip:
//!
//! * **Weight storage** — each strip's integer codes (re-derived from the
//!   quantized parameter vector and the per-strip scale) are stored on a
//!   *differential column pair* (G⁺/G⁻ for positive/negative code parts),
//!   each sliced across `ceil(bits / cell_bits)` multi-bit cells.
//! * **Input streaming** — activations are DAC-quantized to `input_bits`
//!   symmetric codes (per conversion window, i.e. per output pixel — so a
//!   sample's result never depends on what else shares its batch) and
//!   streamed bit-serially; each input-bit phase drives the word lines with
//!   a binary vector.
//! * **Column currents** — one analog current per (input-bit phase × cell
//!   slice × polarity × row segment of at most `rows` word lines). With
//!   `adc_bits > 0` every current is quantized by a SAR ADC of that
//!   resolution before the shift-and-add merge; with `noise_sigma > 0`
//!   zero-mean Gaussian conductance noise (in cell-level units, seeded per
//!   (seed, layer, strip) and deterministic) perturbs every programmed cell.
//! * **Digital merge** — phase/slice partial sums are shift-added and
//!   scaled by `sa·sw`, exactly the paper's §4.3 stepwise accumulation.
//!
//! With ideal converters (`adc_bits == 0`, `noise_sigma == 0`) the phase
//! decomposition telescopes back to the exact integer dot product, so the
//! simulator takes an algebraically identical fast path (property-tested
//! against the explicit phase loop). Non-conv layers (GroupNorm, ReLU,
//! residual adds, pooling, dense head) run in exact f32 — the paper
//! quantizes conv weights only.
//!
//! ## Execution strategy: bit-plane packing + tile sharding
//!
//! Two orthogonal optimizations keep the simulation faithful *and* fast,
//! both **bit-identical** to the scalar reference by construction:
//!
//! * **Bit-plane packing.** The phase loop's word-line drive vectors are
//!   packed into `u64` bit-plane words (one plane per input-bit phase ×
//!   polarity, one per stored cell bit × polarity), and each column current
//!   becomes a popcount/shift accumulation over the packed lanes instead of
//!   a branchy per-lane scan. Currents are sums of small non-negative
//!   integers, so the popcount total equals the scalar `f64` sum exactly;
//!   the SAR-ADC transfer function sees identical inputs either way. The
//!   packed path engages whenever cell conductances are integral
//!   (`noise_sigma == 0`); conductance noise makes them real-valued, which
//!   falls back to the scalar lane scan (`scalar_lanes` forces the fallback
//!   for benchmarking).
//! * **Tile sharding.** The per-tile (row-segment × column-strip) MVM loop
//!   is sharded over `threads` scoped worker threads
//!   (`std::thread::scope`), each owning a contiguous output-channel range
//!   and a private accumulator. Per-(sample, channel) accumulation order is
//!   the same as the sequential loop and the conductance-noise stream is
//!   seeded per strip (not per evaluation order), so any worker count
//!   produces bit-identical results.

use std::sync::Mutex;

use crate::backend::nn::{self, ConvExec, ExactConv, NetSpec};
use crate::backend::{ExecBackend, FwdKind};
use crate::model::{ConvLayer, ModelInfo};
use crate::quant::{self, QuantizedModel};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::xbar::XbarConfig;
use crate::Result;

/// Crossbar fidelity knobs for the simulator.
#[derive(Clone, Copy, Debug)]
pub struct SimXbarConfig {
    /// Word lines per array: strips deeper than this split into row
    /// segments, each converted (and ADC-quantized) separately.
    pub rows: usize,
    /// Bits stored per ReRAM cell.
    pub cell_bits: u8,
    /// DAC resolution for the bit-serial activation stream.
    pub input_bits: u8,
    /// SAR ADC resolution applied to every column current; 0 = ideal
    /// (lossless) conversion.
    pub adc_bits: u8,
    /// Zero-mean Gaussian conductance noise per programmed cell, in units
    /// of one cell level; 0 = noise-free.
    pub noise_sigma: f64,
    /// Seed for the conductance-noise draw (deterministic per seed; the
    /// stream is derived per (seed, layer, strip) so programmed array state
    /// does not depend on evaluation order or thread sharding).
    pub seed: u64,
    /// Testing knob: run the explicit phase/slice loop even when ideal
    /// converters would permit the algebraically equal integer fast path.
    pub force_phase_loop: bool,
    /// Worker threads sharding the per-tile (row-segment × column-strip)
    /// MVM loop; 0 = one per available core, 1 = sequential. Results are
    /// bit-identical for every value (see the module docs).
    pub threads: usize,
    /// Testing/bench knob: disable the packed u64 bit-plane popcount path
    /// inside the phase loop and use the scalar per-lane scan instead
    /// (numerically identical; this only trades speed).
    pub scalar_lanes: bool,
}

impl Default for SimXbarConfig {
    fn default() -> Self {
        Self {
            rows: 128,
            cell_bits: 2,
            input_bits: 8,
            adc_bits: 0,
            noise_sigma: 0.0,
            seed: 0x51b,
            force_phase_loop: false,
            threads: 0,
            scalar_lanes: false,
        }
    }
}

impl SimXbarConfig {
    /// Inherit the array geometry from the hardware cost-model config
    /// (ideal converters; opt into ADC/noise with the builder helpers).
    pub fn from_xbar(x: &XbarConfig) -> Self {
        Self {
            rows: x.rows,
            cell_bits: x.cell_bits,
            input_bits: x.input_bits,
            ..Self::default()
        }
    }

    /// Near-lossless DAC for reference comparisons: 20-bit input codes keep
    /// the activation-quantization error below ~1e-5 relative.
    pub fn high_fidelity() -> Self {
        Self { input_bits: 20, ..Self::default() }
    }

    pub fn with_adc(mut self, bits: u8) -> Self {
        self.adc_bits = bits;
        self
    }

    pub fn with_noise(mut self, sigma: f64, seed: u64) -> Self {
        self.noise_sigma = sigma;
        self.seed = seed;
        self
    }

    /// Pin the tile-sharding worker count (0 = auto, 1 = sequential).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }
}

/// Per-strip weight precision feeding the simulator (bit widths + scales,
/// exactly the quantization stage's artifact).
#[derive(Clone, Debug)]
pub struct StripPrecision {
    /// Bits per strip, `ModelInfo::strips()` order; 0 = pruned.
    pub bits: Vec<u8>,
    /// Per-strip quantization scale (LSB).
    pub scales: Vec<f32>,
}

impl StripPrecision {
    pub fn from_quantized(qm: &QuantizedModel) -> Self {
        Self { bits: qm.bits.clone(), scales: qm.scales.clone() }
    }
}

/// u64 words covering a `len`-lane row segment.
fn words_of(len: usize) -> usize {
    len.div_ceil(64)
}

/// Row-segment partition of `d` word lines into ranges of at most `rows`
/// lanes: (lane start, lane count, u64-word offset) per segment, plus the
/// total packed word count. Each segment packs into its own words so
/// popcounts never cross a conversion boundary.
fn segments(d: usize, rows: usize) -> (Vec<(usize, usize, usize)>, usize) {
    let mut segs = Vec::new();
    let mut start = 0usize;
    let mut woff = 0usize;
    while start < d {
        let len = rows.min(d - start);
        segs.push((start, len, woff));
        woff += words_of(len);
        start += len;
    }
    (segs, woff)
}

/// Immutable per-call state of one bit-serial conv, shared by every channel
/// shard (everything here is read-only during the sharded MVM loop).
struct ConvCtx<'a> {
    layer: &'a ConvLayer,
    theta: &'a [f32],
    /// DAC codes, `[t, k²·d]`.
    codes_a: &'a [i32],
    /// Per-conversion-window activation scales, `[t]`.
    sa: &'a [f32],
    t: usize,
    sp: &'a StripPrecision,
    /// Strip-index base of this layer in `ModelInfo::strips()` order.
    base: usize,
    /// Row-segment partition of the layer depth `d`.
    segs: Vec<(usize, usize, usize)>,
    /// Packed u64 words per (phase/cell-bit × polarity) plane.
    total_words: usize,
    /// Ideal converters: take the integer-dot-product fast path.
    exact: bool,
    /// Run the phase loop on packed bit-planes (decided once here so the
    /// plane builder below and the shard readers can never disagree).
    use_packed: bool,
    /// Input-bit phases (`input_bits - 1`).
    phases: usize,
    /// Packed activation bit-planes per kernel tap (empty unless
    /// `use_packed`). Built once per conv call and shared read-only by
    /// every channel shard — the planes are channel-independent.
    a_planes: Vec<Vec<u64>>,
}

/// The simulator backend. Without strip metadata every conv runs in exact
/// f32 (fp32 reference deployments); with it, conv layers execute on the
/// simulated crossbars at their assigned per-strip precision.
pub struct SimXbar {
    pub cfg: SimXbarConfig,
    strips: Option<StripPrecision>,
    /// Parsed network graph of the last model seen, so the eval loop and the
    /// serving hot path don't re-parse the manifest layout on every batch.
    spec: Mutex<Option<(String, usize, NetSpec)>>,
}

impl SimXbar {
    pub fn new(cfg: SimXbarConfig) -> Self {
        Self { cfg, strips: None, spec: Mutex::new(None) }
    }

    /// Graph for `model`, parsed once per (name, param-count) and cached.
    fn spec_for(&self, model: &ModelInfo) -> Result<NetSpec> {
        let mut guard = self.spec.lock().unwrap();
        if let Some((name, params, spec)) = guard.as_ref() {
            if name == model.name() && *params == model.entry.num_params {
                return Ok(spec.clone());
            }
        }
        let spec = NetSpec::parse(model)?;
        *guard = Some((model.name().to_string(), model.entry.num_params, spec.clone()));
        Ok(spec)
    }

    pub fn with_strips(mut self, strips: StripPrecision) -> Self {
        self.strips = Some(strips);
        self
    }

    pub fn from_quantized(cfg: SimXbarConfig, qm: &QuantizedModel) -> Self {
        Self::new(cfg).with_strips(StripPrecision::from_quantized(qm))
    }

    /// Effective shard count for a layer with `n` output channels.
    fn effective_threads(&self, n: usize) -> usize {
        let req = if self.cfg.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.cfg.threads
        };
        req.min(n).max(1)
    }

    /// Bit-serial conv of one layer over im2col patches (the crossbar hot
    /// path). Exposed for the property tests.
    pub fn conv_bitserial(
        &self,
        model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
        sp: &StripPrecision,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        anyhow::ensure!(cfg.rows >= 1, "sim rows must be >= 1");
        anyhow::ensure!(
            (1..=8).contains(&cfg.cell_bits),
            "sim cell_bits {} out of range 1..=8",
            cfg.cell_bits
        );
        anyhow::ensure!(
            (2..=24).contains(&cfg.input_bits),
            "sim input_bits {} out of range 2..=24",
            cfg.input_bits
        );
        anyhow::ensure!(cfg.adc_bits <= 16, "sim adc_bits {} out of range 0..=16", cfg.adc_bits);
        anyhow::ensure!(
            sp.bits.len() == model.num_strips() && sp.scales.len() == sp.bits.len(),
            "strip precision covers {} strips, model has {}",
            sp.bits.len(),
            model.num_strips()
        );
        let d = layer.d;
        let n = layer.n;
        let kk = layer.k * layer.k;
        let cols = kk * d;
        let base: usize = model.conv_layers()[..layer.index]
            .iter()
            .map(ConvLayer::num_strips)
            .sum();

        // ---- DAC: symmetric input codes, scaled per conversion window ----
        let q_in = ((1i64 << (cfg.input_bits - 1)) - 1) as f32;
        let mut codes_a = vec![0i32; t * cols];
        let mut sa = vec![1.0f32; t];
        for ti in 0..t {
            let row = &patches[ti * cols..(ti + 1) * cols];
            let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if amax > 0.0 {
                let s = amax / q_in;
                sa[ti] = s;
                for (c, &v) in codes_a[ti * cols..(ti + 1) * cols].iter_mut().zip(row) {
                    *c = (v / s).round().clamp(-q_in, q_in) as i32;
                }
            }
        }

        let (segs, total_words) = segments(d, cfg.rows);
        let exact = cfg.adc_bits == 0 && cfg.noise_sigma == 0.0 && !cfg.force_phase_loop;
        let mut ctx = ConvCtx {
            layer,
            theta,
            codes_a: &codes_a,
            sa: &sa,
            t,
            sp,
            base,
            segs,
            total_words,
            exact,
            use_packed: !exact && cfg.noise_sigma == 0.0 && !cfg.scalar_lanes,
            phases: (cfg.input_bits - 1) as usize,
            a_planes: Vec::new(),
        };
        if ctx.use_packed {
            let planes: Vec<Vec<u64>> =
                (0..kk).map(|g| pack_activation_planes(&ctx, g)).collect();
            ctx.a_planes = planes;
        }

        let mut out = vec![0.0f32; t * n];
        let threads = self.effective_threads(n);
        if threads <= 1 {
            self.conv_channel_range(&ctx, 0, n, &mut out)?;
        } else {
            // Shard the column-strip loop: each worker owns a contiguous
            // channel range and a private [t, width] accumulator, so the
            // per-(sample, channel) accumulation order is exactly the
            // sequential loop's and the merged result is bit-identical for
            // every worker count.
            let chunk = n.div_ceil(threads);
            let ranges: Vec<(usize, usize)> = (0..threads)
                .map(|w| (w * chunk, ((w + 1) * chunk).min(n)))
                .filter(|(c0, c1)| c1 > c0)
                .collect();
            let parts: Vec<Result<Vec<f32>>> = std::thread::scope(|scope| {
                let ctx = &ctx;
                let handles: Vec<_> = ranges
                    .iter()
                    .map(|&(c0, c1)| {
                        scope.spawn(move || {
                            let mut part = vec![0.0f32; t * (c1 - c0)];
                            self.conv_channel_range(ctx, c0, c1, &mut part)?;
                            Ok(part)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("sim shard thread panicked"))
                    .collect()
            });
            for (&(c0, c1), part) in ranges.iter().zip(parts) {
                let part = part?;
                let w = c1 - c0;
                for ti in 0..t {
                    out[ti * n + c0..ti * n + c1].copy_from_slice(&part[ti * w..(ti + 1) * w]);
                }
            }
        }
        Ok(out)
    }

    /// Execute every strip whose output channel lies in `[c0, c1)` over all
    /// conversion windows, accumulating into `out` of shape `[t, c1 - c0]`.
    fn conv_channel_range(
        &self,
        ctx: &ConvCtx<'_>,
        c0: usize,
        c1: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let cfg = &self.cfg;
        let layer = ctx.layer;
        let d = layer.d;
        let n = layer.n;
        let kk = layer.k * layer.k;
        let cols = kk * d;
        let cw = c1 - c0;
        let t = ctx.t;
        let (exact, use_packed, phases) = (ctx.exact, ctx.use_packed, ctx.phases);
        let mask = (1i32 << cfg.cell_bits) - 1;
        let total_words = ctx.total_words;
        let segs = &ctx.segs;

        // SAR ADC transfer function over one row segment's column current.
        let adc = |i_raw: f64, seg_rows: usize| -> f64 {
            if cfg.adc_bits == 0 {
                return i_raw;
            }
            let fs = seg_rows as f64 * mask as f64;
            if fs <= 0.0 {
                return i_raw;
            }
            let levels = (1u64 << cfg.adc_bits) as f64 - 1.0;
            let step = (fs / levels).max(1.0);
            (i_raw / step).round().clamp(0.0, levels) * step
        };

        let mut codes_w = vec![0i32; d];
        // Packed weight planes of the current strip, layout
        // [cell slice × cell bit][polarity][segment words].
        let mut w_planes: Vec<u64> = Vec::new();

        for g in 0..kk {
            // Activation planes for this kernel tap, layout
            // [ti][phase][polarity][segment words] — packed once per conv
            // call in `ctx`, shared read-only across channel shards.
            let a_planes: &[u64] = if use_packed { &ctx.a_planes[g] } else { &[] };
            for ch in c0..c1 {
                let idx = ctx.base + g * n + ch;
                let bits = ctx.sp.bits[idx];
                if bits == 0 {
                    continue; // pruned strip: no cells programmed
                }
                anyhow::ensure!(
                    (1..=16).contains(&bits),
                    "strip {idx} has unsupported bit width {bits}"
                );
                let sw = ctx.sp.scales[idx];
                if sw <= 0.0 {
                    continue;
                }
                let q_w = quant::qmax(bits);
                for (dd, cwv) in codes_w.iter_mut().enumerate() {
                    let wv = ctx.theta[layer.theta_index(g, dd, ch)];
                    *cwv = (wv / sw).round().clamp(-q_w, q_w) as i32;
                }

                if exact {
                    // Ideal converters: the phase/slice decomposition
                    // telescopes to the plain integer dot product.
                    for ti in 0..t {
                        let arow = &ctx.codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                        let mut acc = 0i64;
                        for (&a, &cwv) in arow.iter().zip(codes_w.iter()) {
                            acc += a as i64 * cwv as i64;
                        }
                        out[ti * cw + (ch - c0)] +=
                            (acc as f64 * ctx.sa[ti] as f64 * sw as f64) as f32;
                    }
                    continue;
                }

                let ncells = bits.div_ceil(cfg.cell_bits) as usize;

                if use_packed {
                    // ---- packed bit-plane phase loop (integral cells) ----
                    pack_weight_planes(&mut w_planes, &codes_w, cfg.cell_bits, ncells, ctx);
                    let cell_bits = cfg.cell_bits as usize;
                    let stride_ti = phases * 2 * total_words;
                    for ti in 0..t {
                        let tb = ti * stride_ti;
                        let mut total = 0.0f64;
                        for &(_, len, woff) in segs {
                            let nw = words_of(len);
                            for p in 0..phases {
                                let app = &a_planes[tb + (p * 2) * total_words + woff..][..nw];
                                let apn = &a_planes[tb + (p * 2 + 1) * total_words + woff..][..nw];
                                for j in 0..ncells {
                                    // four currents: input polarity × column
                                    let (mut ipp, mut ipn) = (0u64, 0u64);
                                    let (mut inp, mut inn) = (0u64, 0u64);
                                    for b in 0..cell_bits {
                                        let row = (j * cell_bits + b) * 2;
                                        let gp = &w_planes[row * total_words + woff..][..nw];
                                        let gm = &w_planes[(row + 1) * total_words + woff..][..nw];
                                        let (mut cpp, mut cpn) = (0u32, 0u32);
                                        let (mut cnp, mut cnn) = (0u32, 0u32);
                                        for w in 0..nw {
                                            cpp += (app[w] & gp[w]).count_ones();
                                            cpn += (app[w] & gm[w]).count_ones();
                                            cnp += (apn[w] & gp[w]).count_ones();
                                            cnn += (apn[w] & gm[w]).count_ones();
                                        }
                                        ipp += (cpp as u64) << b;
                                        ipn += (cpn as u64) << b;
                                        inp += (cnp as u64) << b;
                                        inn += (cnn as u64) << b;
                                    }
                                    let w2 =
                                        2.0f64.powi(p as i32 + (j as i32) * cfg.cell_bits as i32);
                                    total += w2
                                        * ((adc(ipp as f64, len) + adc(inn as f64, len))
                                            - (adc(ipn as f64, len) + adc(inp as f64, len)));
                                }
                            }
                        }
                        out[ti * cw + (ch - c0)] += (total * ctx.sa[ti] as f64 * sw as f64) as f32;
                    }
                    continue;
                }

                // ---- scalar lane scan (noisy cells, or packing disabled) --
                // program the differential, bit-sliced cell columns
                let mut gpos = vec![0.0f64; ncells * d];
                let mut gneg = vec![0.0f64; ncells * d];
                for (dd, &cwv) in codes_w.iter().enumerate() {
                    let (p, q) = (cwv.max(0), (-cwv).max(0));
                    for j in 0..ncells {
                        let sh = (j as u32) * cfg.cell_bits as u32;
                        gpos[j * d + dd] = ((p >> sh) & mask) as f64;
                        gneg[j * d + dd] = ((q >> sh) & mask) as f64;
                    }
                }
                if cfg.noise_sigma > 0.0 {
                    // Per-strip stream: a given (seed, layer, strip) always
                    // programs the same array state, independent of which
                    // shard evaluates it or in what order.
                    let mut rng = Rng::seed_from_u64(
                        cfg.seed
                            ^ (layer.index as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                            ^ (idx as u64 + 1).wrapping_mul(0xbf58_476d_1ce4_e5b9),
                    );
                    for v in gpos.iter_mut().chain(gneg.iter_mut()) {
                        *v += rng.normal() as f64 * cfg.noise_sigma;
                    }
                }

                // ---- input-bit phases × cell slices × row segments ----
                for ti in 0..t {
                    let arow = &ctx.codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
                    let mut total = 0.0f64;
                    for &(seg_start, len, _) in segs {
                        let seg_end = seg_start + len;
                        for p in 0..phases as u32 {
                            let pbit = 1i32 << p;
                            for j in 0..ncells {
                                // four currents: input polarity × column
                                let (mut ipp, mut ipn) = (0.0f64, 0.0f64);
                                let (mut inp, mut inn) = (0.0f64, 0.0f64);
                                for dd in seg_start..seg_end {
                                    let a = arow[dd];
                                    if a == 0 || (a.abs() & pbit) == 0 {
                                        continue;
                                    }
                                    let gp = gpos[j * d + dd];
                                    let gm = gneg[j * d + dd];
                                    if a > 0 {
                                        ipp += gp;
                                        ipn += gm;
                                    } else {
                                        inp += gp;
                                        inn += gm;
                                    }
                                }
                                let w2 = 2.0f64.powi(p as i32 + (j as i32) * cfg.cell_bits as i32);
                                total += w2
                                    * ((adc(ipp, len) + adc(inn, len))
                                        - (adc(ipn, len) + adc(inp, len)));
                            }
                        }
                    }
                    out[ti * cw + (ch - c0)] += (total * ctx.sa[ti] as f64 * sw as f64) as f32;
                }
            }
        }
        Ok(())
    }
}

/// Pack kernel tap `g`'s DAC codes into u64 bit-plane words: one plane per
/// (input-bit phase × polarity), segmented like the row partition so a
/// popcount never crosses a conversion boundary. Layout per sample:
/// `[phase][polarity][segment words]`.
fn pack_activation_planes(ctx: &ConvCtx<'_>, g: usize) -> Vec<u64> {
    let d = ctx.layer.d;
    let cols = ctx.layer.k * ctx.layer.k * d;
    let total_words = ctx.total_words;
    let stride_ti = ctx.phases * 2 * total_words;
    let mut planes = vec![0u64; ctx.t * stride_ti];
    for ti in 0..ctx.t {
        let arow = &ctx.codes_a[ti * cols + g * d..ti * cols + (g + 1) * d];
        let tb = ti * stride_ti;
        for &(start, len, woff) in &ctx.segs {
            for l in 0..len {
                let a = arow[start + l];
                if a == 0 {
                    continue;
                }
                let pol = usize::from(a < 0);
                let bit = 1u64 << (l % 64);
                let w = woff + l / 64;
                let mut m = a.unsigned_abs();
                let mut p = 0usize;
                while m != 0 {
                    if m & 1 != 0 {
                        planes[tb + (p * 2 + pol) * total_words + w] |= bit;
                    }
                    m >>= 1;
                    p += 1;
                }
            }
        }
    }
    planes
}

/// Pack one strip's integer weight codes into u64 cell-bit planes: one
/// plane per (cell slice × cell bit × polarity), segmented like the row
/// partition. Layout: `[cell slice × cell bit][polarity][segment words]`.
fn pack_weight_planes(
    planes: &mut Vec<u64>,
    codes_w: &[i32],
    cell_bits: u8,
    ncells: usize,
    ctx: &ConvCtx<'_>,
) {
    let total_words = ctx.total_words;
    let cb = cell_bits as usize;
    let mask = (1i32 << cell_bits) - 1;
    planes.clear();
    planes.resize(ncells * cb * 2 * total_words, 0);
    for &(start, len, woff) in &ctx.segs {
        for l in 0..len {
            let cwv = codes_w[start + l];
            if cwv == 0 {
                continue;
            }
            let (p, q) = (cwv.max(0), (-cwv).max(0));
            let bit = 1u64 << (l % 64);
            let w = woff + l / 64;
            for j in 0..ncells {
                let sh = (j as u32) * cell_bits as u32;
                let pv = (p >> sh) & mask;
                let qv = (q >> sh) & mask;
                for b in 0..cb {
                    let cellbit = 1i32 << b;
                    let row = (j * cb + b) * 2;
                    if pv & cellbit != 0 {
                        planes[row * total_words + w] |= bit;
                    }
                    if qv & cellbit != 0 {
                        planes[(row + 1) * total_words + w] |= bit;
                    }
                }
            }
        }
    }
}

impl ConvExec for SimXbar {
    fn conv(
        &self,
        model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
    ) -> Result<Vec<f32>> {
        match &self.strips {
            None => ExactConv.conv(model, layer, theta, patches, t),
            Some(sp) => self.conv_bitserial(model, layer, theta, patches, t, sp),
        }
    }
}

impl ExecBackend for SimXbar {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn forward(
        &self,
        model: &ModelInfo,
        _kind: FwdKind,
        theta: &Tensor,
        x: &Tensor,
    ) -> Result<Tensor> {
        let spec = self.spec_for(model)?;
        nn::forward(model, &spec, theta.data(), x, self)
    }

    fn ready_check(&self, model: &ModelInfo, _theta: &Tensor) -> Result<()> {
        if let Some(sp) = &self.strips {
            anyhow::ensure!(
                sp.bits.len() == model.num_strips() && sp.scales.len() == sp.bits.len(),
                "strip precision covers {} strips, model has {}",
                sp.bits.len(),
                model.num_strips()
            );
        }
        self.spec_for(model)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry};
    use std::collections::HashMap;

    fn layer_model(k: usize, d: usize, n: usize) -> ModelInfo {
        ModelInfo::new(ModelEntry {
            name: "sim-layer".into(),
            num_params: k * k * d * n,
            num_conv_params: k * k * d * n,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![k * k * d * n], dtype: "f32".into() },
            layers: vec![LayerEntry {
                name: "stem.conv".into(),
                shape: vec![k, k, d, n],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            }],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        })
    }

    fn quantized_layer(m: &ModelInfo, seed: u64, bits: u8) -> (Vec<f32>, StripPrecision) {
        let mut rng = Rng::seed_from_u64(seed);
        let theta: Vec<f32> = (0..m.entry.num_params).map(|_| rng.normal() * 0.3).collect();
        let bm = crate::quant::BitMap::uniform(m.num_strips(), bits);
        let cfg = crate::config::QuantConfig {
            device_sigma: 0.0,
            ..crate::config::QuantConfig::default()
        };
        let qm = quant::apply(m, &theta, &bm, &cfg);
        (qm.theta, StripPrecision::from_quantized(&qm))
    }

    #[test]
    fn sim_phase_loop_equals_integer_fast_path() {
        let m = layer_model(1, 19, 3);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 7, 8);
        let mut rng = Rng::seed_from_u64(9);
        let t = 5;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        // rows=4 forces multi-segment conversion on the 19-row strips
        let base = SimXbarConfig { rows: 4, input_bits: 6, ..SimXbarConfig::default() };
        let fast = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let phased = SimXbar::new(SimXbarConfig { force_phase_loop: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        for (a, b) in fast.iter().zip(&phased) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn sim_pruned_and_zero_scale_strips_contribute_nothing() {
        let m = layer_model(1, 4, 2);
        let layer = m.layer(0).clone();
        let theta = vec![1.0f32; m.entry.num_params];
        let sp = StripPrecision { bits: vec![0, 8], scales: vec![0.0, 0.5] };
        let patches = vec![1.0f32; 4];
        let out = SimXbar::new(SimXbarConfig::default())
            .conv_bitserial(&m, &layer, &theta, &patches, 1, &sp)
            .unwrap();
        assert_eq!(out[0], 0.0, "pruned channel must stay silent");
        assert!(out[1] > 0.0);
    }

    #[test]
    fn sim_adc_and_noise_are_deterministic_per_seed() {
        let m = layer_model(3, 8, 4);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 21, 8);
        let mut rng = Rng::seed_from_u64(2);
        let t = 3;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let cfg = SimXbarConfig::default().with_adc(4).with_noise(0.05, 99);
        let run = || {
            SimXbar::new(cfg)
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap()
        };
        assert_eq!(run(), run(), "fixed seed must reproduce bit-identically");
        let other = SimXbar::new(cfg.with_noise(0.05, 100))
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_ne!(run(), other, "different seed must redraw the noise");
    }

    #[test]
    fn sim_packed_adc_phase_loop_matches_scalar_lanes_exactly() {
        // The packed popcount path and the scalar lane scan feed identical
        // currents to the ADC — outputs must match bit for bit.
        let m = layer_model(3, 10, 4);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 5, 8);
        let mut rng = Rng::seed_from_u64(55);
        let t = 3;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let base = SimXbarConfig { rows: 4, ..SimXbarConfig::default() }.with_adc(5);
        let packed = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let scalar = SimXbar::new(SimXbarConfig { scalar_lanes: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_eq!(packed, scalar);
    }

    #[test]
    fn sim_thread_sharding_is_bit_identical_even_with_noise() {
        // The noise stream is seeded per strip, so any shard count programs
        // the same array state and sums in the same per-channel order.
        let m = layer_model(3, 8, 6);
        let layer = m.layer(0).clone();
        let (theta, sp) = quantized_layer(&m, 8, 8);
        let mut rng = Rng::seed_from_u64(77);
        let t = 2;
        let patches: Vec<f32> =
            (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
        let noisy = SimXbarConfig { threads: 1, ..SimXbarConfig::default() }
            .with_adc(4)
            .with_noise(0.05, 11);
        let single = SimXbar::new(noisy)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        for threads in [2usize, 3, 8] {
            let got = SimXbar::new(SimXbarConfig { threads, ..noisy })
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap();
            assert_eq!(single, got, "{threads}-way shard must not change results");
        }
    }
}
