//! Always-on profiling counters for the programmed crossbar walk.
//!
//! Unlike [`crate::trace`] spans (default-off, per-request), these counters
//! are **always live**: they are accumulated arithmetically once per conv
//! call — a handful of relaxed `fetch_add`s derived from the programmed
//! layer's geometry — never inside the per-sample/per-word inner loops, so
//! they cost nothing measurable and cannot perturb the bit-identical walk.
//!
//! The simulator backend owns a [`WalkProfileAtomic`] twin; engine workers
//! snapshot it after every batch and push the delta into
//! [`crate::coordinator::Metrics`], where the aggregate surfaces in the
//! `serve` stats (text and `StatsJson`).

use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregated counters describing what the programmed walk actually did:
/// which strip stores ran, how many DAC phase steps and SIMD-kernel
/// dispatches they cost, how often the next-strip prefetch fired, and the
/// scratch-arena high-water mark.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WalkProfile {
    /// Programmed conv calls (one per conv layer per batch).
    pub conv_calls: u64,
    /// Programmed strips visited across all calls.
    pub strips_walked: u64,
    /// Strips served from the `Exact` (f32 codes) store.
    pub exact_strips: u64,
    /// Strips served from the `Packed` (u64 bit-plane) store.
    pub packed_strips: u64,
    /// Strips served from the `Analog` (noisy conductance) store.
    pub analog_strips: u64,
    /// DAC input-bit phase steps executed (per sample × segment × phase).
    pub phase_steps: u64,
    /// Packed-current evaluations dispatched to a vector kernel
    /// (AVX2/NEON).
    pub kernel_simd: u64,
    /// Packed-current evaluations dispatched to the scalar u64 kernel.
    pub kernel_scalar: u64,
    /// Next-strip prefetch stages issued by the blocked walk.
    pub prefetch_staged: u64,
    /// High-water mark of the per-worker scratch arena, in bytes.
    pub scratch_high_water_bytes: u64,
}

impl WalkProfile {
    /// Counter-wise difference `self - earlier` (saturating), with the
    /// high-water mark carried over as a maximum rather than subtracted.
    /// Workers use this to push per-batch deltas into shared metrics.
    pub fn delta(&self, earlier: &WalkProfile) -> WalkProfile {
        WalkProfile {
            conv_calls: self.conv_calls.saturating_sub(earlier.conv_calls),
            strips_walked: self.strips_walked.saturating_sub(earlier.strips_walked),
            exact_strips: self.exact_strips.saturating_sub(earlier.exact_strips),
            packed_strips: self.packed_strips.saturating_sub(earlier.packed_strips),
            analog_strips: self.analog_strips.saturating_sub(earlier.analog_strips),
            phase_steps: self.phase_steps.saturating_sub(earlier.phase_steps),
            kernel_simd: self.kernel_simd.saturating_sub(earlier.kernel_simd),
            kernel_scalar: self.kernel_scalar.saturating_sub(earlier.kernel_scalar),
            prefetch_staged: self.prefetch_staged.saturating_sub(earlier.prefetch_staged),
            scratch_high_water_bytes: self.scratch_high_water_bytes,
        }
    }

    /// Counter-wise sum (high-water mark merged as a maximum).
    pub fn absorb(&mut self, other: &WalkProfile) {
        self.conv_calls += other.conv_calls;
        self.strips_walked += other.strips_walked;
        self.exact_strips += other.exact_strips;
        self.packed_strips += other.packed_strips;
        self.analog_strips += other.analog_strips;
        self.phase_steps += other.phase_steps;
        self.kernel_simd += other.kernel_simd;
        self.kernel_scalar += other.kernel_scalar;
        self.prefetch_staged += other.prefetch_staged;
        self.scratch_high_water_bytes =
            self.scratch_high_water_bytes.max(other.scratch_high_water_bytes);
    }

    /// The profile as a JSON object (for `StatsJson` and `--json` outputs).
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        let n = |v: u64| Value::Num(v as f64);
        obj(vec![
            ("conv_calls", n(self.conv_calls)),
            ("strips_walked", n(self.strips_walked)),
            ("exact_strips", n(self.exact_strips)),
            ("packed_strips", n(self.packed_strips)),
            ("analog_strips", n(self.analog_strips)),
            ("phase_steps", n(self.phase_steps)),
            ("kernel_simd", n(self.kernel_simd)),
            ("kernel_scalar", n(self.kernel_scalar)),
            ("prefetch_staged", n(self.prefetch_staged)),
            ("scratch_high_water_bytes", n(self.scratch_high_water_bytes)),
        ])
    }
}

/// Shared-state twin of [`WalkProfile`]: relaxed atomics bumped once per
/// conv call by the backend, snapshot by whoever reports.
#[derive(Debug, Default)]
pub struct WalkProfileAtomic {
    conv_calls: AtomicU64,
    strips_walked: AtomicU64,
    exact_strips: AtomicU64,
    packed_strips: AtomicU64,
    analog_strips: AtomicU64,
    phase_steps: AtomicU64,
    kernel_simd: AtomicU64,
    kernel_scalar: AtomicU64,
    prefetch_staged: AtomicU64,
    scratch_high_water_bytes: AtomicU64,
}

impl WalkProfileAtomic {
    /// Add a per-call (or per-batch) delta. The high-water field is merged
    /// with `fetch_max`, everything else with `fetch_add`.
    pub fn add(&self, d: &WalkProfile) {
        let r = Ordering::Relaxed;
        self.conv_calls.fetch_add(d.conv_calls, r);
        self.strips_walked.fetch_add(d.strips_walked, r);
        self.exact_strips.fetch_add(d.exact_strips, r);
        self.packed_strips.fetch_add(d.packed_strips, r);
        self.analog_strips.fetch_add(d.analog_strips, r);
        self.phase_steps.fetch_add(d.phase_steps, r);
        self.kernel_simd.fetch_add(d.kernel_simd, r);
        self.kernel_scalar.fetch_add(d.kernel_scalar, r);
        self.prefetch_staged.fetch_add(d.prefetch_staged, r);
        self.scratch_high_water_bytes.fetch_max(d.scratch_high_water_bytes, r);
    }

    /// Record a new scratch-arena size observation.
    pub fn observe_scratch_bytes(&self, bytes: u64) {
        self.scratch_high_water_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    /// Copy the current counters out.
    pub fn snapshot(&self) -> WalkProfile {
        let r = Ordering::Relaxed;
        WalkProfile {
            conv_calls: self.conv_calls.load(r),
            strips_walked: self.strips_walked.load(r),
            exact_strips: self.exact_strips.load(r),
            packed_strips: self.packed_strips.load(r),
            analog_strips: self.analog_strips.load(r),
            phase_steps: self.phase_steps.load(r),
            kernel_simd: self.kernel_simd.load(r),
            kernel_scalar: self.kernel_scalar.load(r),
            prefetch_staged: self.prefetch_staged.load(r),
            scratch_high_water_bytes: self.scratch_high_water_bytes.load(r),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(base: u64) -> WalkProfile {
        WalkProfile {
            conv_calls: base,
            strips_walked: base * 2,
            exact_strips: base,
            packed_strips: base,
            analog_strips: 0,
            phase_steps: base * 8,
            kernel_simd: base * 4,
            kernel_scalar: base * 4,
            prefetch_staged: base,
            scratch_high_water_bytes: base * 100,
        }
    }

    #[test]
    fn delta_subtracts_counters_and_keeps_high_water() {
        let early = sample(2);
        let late = sample(5);
        let d = late.delta(&early);
        assert_eq!(d.conv_calls, 3);
        assert_eq!(d.strips_walked, 6);
        assert_eq!(d.phase_steps, 24);
        assert_eq!(d.scratch_high_water_bytes, 500);
    }

    #[test]
    fn atomic_twin_accumulates_and_maxes_high_water() {
        let a = WalkProfileAtomic::default();
        a.add(&sample(1));
        a.add(&sample(3));
        a.observe_scratch_bytes(50);
        let s = a.snapshot();
        assert_eq!(s.conv_calls, 4);
        assert_eq!(s.kernel_simd, 16);
        // max(100, 300, 50), not a sum
        assert_eq!(s.scratch_high_water_bytes, 300);
    }

    #[test]
    fn absorb_sums_counters_and_maxes_high_water() {
        let mut a = sample(1);
        a.absorb(&sample(2));
        assert_eq!(a.conv_calls, 3);
        assert_eq!(a.prefetch_staged, 3);
        assert_eq!(a.scratch_high_water_bytes, 200);
        let v = a.to_value();
        assert_eq!(v.get("conv_calls").unwrap().num().unwrap(), 3.0);
    }
}
