//! Pluggable execution backends for batched forward execution.
//!
//! Every consumer of a forward pass — the accuracy evaluator, the serving
//! engine, the parity tests — talks to an [`ExecBackend`] instead of the
//! PJRT runtime directly. Two implementations exist:
//!
//! * **pjrt** — [`crate::runtime::Runtime`]: executes the AOT-compiled
//!   `fwd_eval`/`fwd_serve` HLO artifacts. Bit-exact with the Python-side
//!   training graphs, but requires `make artifacts` to have run.
//! * **sim** — [`SimXbar`]: a native (pure-Rust) bit-serial crossbar
//!   simulator. Conv layers execute strip-by-strip at the bitmap's per-strip
//!   precision — weight codes sliced across multi-bit ReRAM cells on a
//!   differential column pair, activations streamed as input-bit phases,
//!   optional per-column ADC quantization and seeded conductance noise —
//!   while every non-conv op (GroupNorm, ReLU, residual adds, pooling, the
//!   dense head) runs in exact f32. Needs no artifacts at all, so the whole
//!   evaluate/deploy pipeline is testable on any machine.
//!
//! The simulator is the higher-fidelity model of what the paper's hardware
//! actually computes (the PJRT graphs fake-quantize weights but still do
//! ideal f32 MACs); the PJRT backend is the faster, training-parity path.
//!
//! ## Program-once crossbars
//!
//! Real ReRAM arrays are programmed once at deploy time and then only
//! driven. The simulator mirrors that lifecycle: all weight-side work —
//! per-strip quantization to integer codes, `u64` bit-plane packing, analog
//! conductance programming with the seeded per-strip noise draw — happens a
//! single time in a [`ProgrammedModel`] artifact (see
//! [`programmed`]), cached per `(model, theta, strips)` fingerprint on the
//! backend instance (the config is fixed per instance). The conv hot path
//! is then a **read-only walk** over programmed tiles through a compact
//! index that drops pruned and zero-scale strips entirely. Engine workers
//! program inside [`ExecBackend::ready_check`], so programming cost lands
//! before readiness is signalled — never on a request — and is observable
//! through [`ExecBackend::program_ns`] (surfaced per worker in the serving
//! stats). A per-worker [`scratch::Scratch`] arena supplies every reusable
//! buffer (im2col patches, DAC codes, packed activation planes, per-shard
//! accumulators), so the steady-state forward pass performs zero heap
//! allocation beyond the returned logits tensor.
//!
//! ## Bit-plane packing and the tile-sharding invariants
//!
//! The simulator's hot path is engineered for throughput without giving up
//! fidelity. The phase loop's word-line drive vectors are packed into `u64`
//! **bit-plane words** — one plane per (input-bit phase × polarity) on the
//! activation side, one per (cell slice × cell bit × polarity) on the
//! weight side — so each simulated column current is a popcount/shift
//! accumulation over 64 lanes at a time instead of a branchy per-lane scan.
//! Because a column current is a sum of small non-negative integers, the
//! popcount total equals the scalar sum *exactly*, and the SAR-ADC transfer
//! function sees identical inputs either way. The programmed walk widens
//! this further: weight planes are stored word-major/row-minor
//! ([`programmed::pack_weight_rows_into`]) so one `std::arch` vector load
//! (AVX2 on x86_64, runtime-detected; NEON on aarch64) covers 4 packed
//! rows per step, with the scalar u64 loop as the portable fallback
//! ([`SimXbarConfig::simd`]; the `RERAM_MPQ_SIMD=off` environment variable
//! kills vector dispatch). The walk is cache-blocked along the sample axis
//! and double-buffered — the next strip's planes are staged while the
//! current strip accumulates — and activation planes are packed once per
//! batch in a single fused pass. On top of that, the per-tile (row-segment
//! × column-strip) MVM loop shards across scoped worker threads
//! (`SimXbarConfig::threads`; 0 = one per core).
//!
//! Four invariants make this safe to enable everywhere:
//!
//! 1. **Order preservation** — each shard owns a contiguous output-channel
//!    range with a private accumulator, and per-(sample, channel) partial
//!    sums are added in the same kernel-tap order as the sequential loop,
//!    so floating-point accumulation is unchanged.
//! 2. **Shard-stable noise** — the conductance-noise stream is seeded per
//!    (seed, layer, strip), never from evaluation order, so a given strip
//!    programs the same array state under any shard count.
//! 3. **Program-time equals call-time** — the programmed artifact stores
//!    exactly the values the re-quantize-per-call reference path derives
//!    (same rounding, same packing, same noise stream), so the tile walk is
//!    bit-identical to it for every config corner.
//! 4. **Integer currents, one merge order** — every kernel (scalar, AVX2,
//!    NEON) produces exact integer column currents; the ADC transfer and
//!    the f64 shift-and-add merge run in one shared outer loop in a fixed
//!    order, so kernel width and sample-axis blocking can never change a
//!    result bit.
//!
//! Together they guarantee results are **bit-identical** for every
//! `threads` value, for the packed vs. scalar (`scalar_lanes`) path, for
//! every [`SimdMode`] under any runtime-detection outcome, and for the
//! programmed vs. re-pack-per-call path — property-tested in
//! `tests/properties.rs`.

pub mod nn;
pub mod profile;
pub mod programmed;
pub mod scratch;
pub mod simxbar;

pub use profile::{WalkProfile, WalkProfileAtomic};
pub use programmed::{ExecMode, ProgrammedLayer, ProgrammedModel, ProgrammedStrip, StripStore};
pub use scratch::{ConvScratch, NnScratch, Scratch};
pub use simxbar::{SimXbar, SimXbarConfig, SimdMode, StripPrecision};

use crate::model::ModelInfo;
use crate::tensor::Tensor;
use crate::Result;

/// Which forward graph a backend call serves. The PJRT backend dispatches to
/// the matching AOT executable; the simulator runs the same native graph for
/// both (the distinction only exists because the AOT artifacts are compiled
/// per batch shape).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwdKind {
    /// Offline accuracy evaluation (`fwd_eval` batch shape).
    Eval,
    /// Online serving (`fwd_serve` batch shape).
    Serve,
}

/// A batched forward-execution substrate.
pub trait ExecBackend {
    /// Short stable identifier ("pjrt" / "sim") used in cache keys, logs and
    /// startup errors.
    fn name(&self) -> &'static str;

    /// Run the forward pass: `theta` is the flat parameter vector, `x` the
    /// image batch `[B, 32, 32, 3]`. Returns logits `[B, num_classes]`.
    fn forward(&self, model: &ModelInfo, kind: FwdKind, theta: &Tensor, x: &Tensor)
        -> Result<Tensor>;

    /// Cheap validation run by the serving engine's readiness handshake
    /// before it starts accepting requests, so a misconfigured deployment
    /// fails loudly at startup instead of on the first batch. Backends with
    /// deploy-time state (the simulator's programmed crossbars) build it
    /// here, so the cost never lands on a request.
    fn ready_check(&self, _model: &ModelInfo, _theta: &Tensor) -> Result<()> {
        Ok(())
    }

    /// Nanoseconds spent on deploy-time programming (crossbar tile
    /// construction) by this backend instance; 0 when nothing was
    /// programmed. The engine records this per worker after the readiness
    /// check, so `serve` stats expose the deploy-time cost.
    fn program_ns(&self) -> u64 {
        0
    }

    /// Cumulative crossbar-walk profiling counters for this backend
    /// instance ([`WalkProfile`]), or `None` for backends without a
    /// programmed walk (pjrt). Engine workers snapshot this after every
    /// batch and fold the delta into the shared metrics.
    fn walk_profile(&self) -> Option<WalkProfile> {
        None
    }

    /// One self-healing monitor step at logical tick `tick` (the worker's
    /// served-batch count): probe canary strips, detect runtime fault
    /// evolution, and repair by re-programming + hot-swapping a standby
    /// artifact (see [`crate::health`]). Engine workers call this between
    /// batches every `probe_every` batches. The default — and the pjrt
    /// backend, whose artifacts cannot degrade — monitors nothing.
    fn health_step(
        &self,
        _model: &ModelInfo,
        _theta: &Tensor,
        _tick: u64,
    ) -> Option<crate::health::StepReport> {
        None
    }
}

impl ExecBackend for crate::runtime::Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn forward(
        &self,
        model: &ModelInfo,
        kind: FwdKind,
        theta: &Tensor,
        x: &Tensor,
    ) -> Result<Tensor> {
        let key = match kind {
            FwdKind::Eval => "fwd_eval",
            FwdKind::Serve => "fwd_serve",
        };
        let exe = model
            .entry
            .executables
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("model has no {key} executable"))?;
        let out = self.exec(exe, &[theta.clone(), x.clone()])?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("{key} returned no outputs"))
    }

    fn ready_check(&self, model: &ModelInfo, _theta: &Tensor) -> Result<()> {
        let exe = model
            .entry
            .executables
            .get("fwd_serve")
            .ok_or_else(|| anyhow::anyhow!("model has no fwd_serve executable"))?;
        let path = self.artifacts().join(exe);
        anyhow::ensure!(
            path.exists(),
            "serve artifact missing: {} (run `make artifacts`)",
            path.display()
        );
        Ok(())
    }
}
