//! Reusable per-worker buffers for the zero-alloc inference path.
//!
//! Every buffer the forward pass and the simulated-crossbar conv need per
//! call lives here, owned by the backend instance (one per engine worker)
//! and threaded through [`crate::backend::nn::forward`] /
//! [`crate::backend::nn::ConvExec::conv`] as `&mut`. Buffers are grown with
//! `clear()` + `resize()` (capacity is kept), so after the first forward
//! pass of a given shape the steady-state hot loop performs **zero heap
//! allocation** — the only allocation left per request is the returned
//! logits tensor.

/// Forward-pass-level buffers (activations, im2col patches, pooling).
#[derive(Default)]
pub struct NnScratch {
    /// The running activation map `[b, h, w, c]`.
    pub act: Vec<f32>,
    /// Normalized copy of `act` for identity-shortcut blocks (the one
    /// activation copy per block that is actually required — `act` must
    /// survive for the residual add).
    pub y: Vec<f32>,
    /// conv1 output of the current block.
    pub y1: Vec<f32>,
    /// conv2 output of the current block.
    pub y2: Vec<f32>,
    /// Projection-shortcut conv output (swapped into `act`).
    pub sh: Vec<f32>,
    /// im2col patch matrix `[t, K²·D]` of the current conv.
    pub patches: Vec<f32>,
    /// Per-sample mean-pool accumulator of the head (hoisted out of the
    /// per-sample loop).
    pub pooled: Vec<f64>,
}

/// Conv-backend-internal buffers (DAC codes, packed activation planes,
/// per-shard accumulators).
#[derive(Default)]
pub struct ConvScratch {
    /// DAC activation codes `[t, K²·D]`.
    pub codes_a: Vec<i32>,
    /// Per-conversion-window activation scales `[t]`.
    pub sa: Vec<f32>,
    /// Packed activation bit-planes, flattened
    /// `[tap][ti][phase][polarity][segment words]` — built **once per
    /// batch** by the fused single-pass packer (never per sample or per
    /// tap) and consumed read-only by every channel shard of the
    /// SIMD-widened blocked walk. Grown in place like every other arena
    /// buffer, so the steady state stays allocation-free.
    pub a_planes: Vec<u64>,
    /// Per-shard `[t, channel-range]` accumulators of the tile-sharded MVM
    /// loop (one per worker thread, reused across calls).
    pub parts: Vec<Vec<f32>>,
}

/// The full per-worker scratch arena: the forward-pass buffers plus the
/// conv-backend buffers, split so the two layers can borrow their halves
/// independently.
#[derive(Default)]
pub struct Scratch {
    pub nn: NnScratch,
    pub conv: ConvScratch,
}

impl Scratch {
    /// Total bytes currently reserved by the arena (capacities, not
    /// lengths). This is the walk profile's high-water observable, and —
    /// because capacities only grow — a steady value across repeated
    /// forward passes is exactly the zero-alloc invariant the tests pin.
    pub fn bytes(&self) -> u64 {
        let f32s = self.nn.act.capacity()
            + self.nn.y.capacity()
            + self.nn.y1.capacity()
            + self.nn.y2.capacity()
            + self.nn.sh.capacity()
            + self.nn.patches.capacity()
            + self.conv.sa.capacity()
            + self.conv.parts.iter().map(|p| p.capacity()).sum::<usize>();
        let bytes = f32s * std::mem::size_of::<f32>()
            + self.nn.pooled.capacity() * std::mem::size_of::<f64>()
            + self.conv.codes_a.capacity() * std::mem::size_of::<i32>()
            + self.conv.a_planes.capacity() * std::mem::size_of::<u64>();
        bytes as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_buffers_keep_capacity_across_reuse() {
        let mut s = Scratch::default();
        s.nn.act.resize(1024, 0.0);
        s.conv.codes_a.resize(2048, 0);
        let cap_act = s.nn.act.capacity();
        let cap_codes = s.conv.codes_a.capacity();
        // the reuse discipline: clear + resize never shrinks capacity
        s.nn.act.clear();
        s.nn.act.resize(512, 0.0);
        s.conv.codes_a.clear();
        s.conv.codes_a.resize(100, 0);
        assert!(s.nn.act.capacity() >= cap_act);
        assert!(s.conv.codes_a.capacity() >= cap_codes);
    }

    #[test]
    fn bytes_counts_capacity_and_never_shrinks_on_reuse() {
        let mut s = Scratch::default();
        assert_eq!(s.bytes(), 0);
        s.nn.act.resize(1024, 0.0);
        s.conv.a_planes.resize(64, 0);
        s.conv.parts.push(vec![0.0f32; 128]);
        let high = s.bytes();
        assert!(high >= (1024 * 4 + 64 * 8 + 128 * 4) as u64);
        // the reuse discipline keeps the arena at its high-water mark
        s.nn.act.clear();
        s.nn.act.resize(10, 0.0);
        assert_eq!(s.bytes(), high);
    }
}
