//! Native (pure-Rust) forward pass of the strip-conv ResNet family.
//!
//! The network structure is *parsed from the manifest's parameter layout*
//! (`ModelEntry::layers`), mirroring `python/compile/model.py`: a stem conv,
//! stages of pre-activation residual blocks named `s{stage}.b{block}.*`
//! (stride 2 on the first block of every non-zero stage), and a
//! GroupNorm → ReLU → mean-pool → dense head. Conv execution is pluggable
//! through [`ConvExec`] so the bit-serial crossbar simulator can take over
//! exactly the layers the paper quantizes while everything else stays in
//! exact f32.
//!
//! ## Zero-alloc steady state
//!
//! [`forward`] threads a per-worker [`Scratch`] arena through every layer:
//! im2col patches, conv outputs, the one activation copy an identity
//! shortcut requires, DAC codes and packed activation planes all live in
//! reusable buffers. After the first pass of a given shape the hot loop
//! performs no heap allocation; the returned logits tensor is the only
//! allocation left per request.

use std::collections::HashMap;

use crate::backend::scratch::{ConvScratch, Scratch};
use crate::model::{ConvLayer, LayerEntry, ModelInfo};
use crate::tensor::Tensor;
use crate::Result;

/// GroupNorm parameter reference: offsets of gamma/beta in the flat vector.
#[derive(Clone, Copy, Debug)]
pub struct GnRef {
    pub gamma: usize,
    pub beta: usize,
    pub c: usize,
}

/// One pre-activation residual block.
#[derive(Clone, Debug)]
pub struct BlockSpec {
    pub gn1: GnRef,
    /// Index into `ModelInfo::conv_layers`.
    pub conv1: usize,
    pub gn2: GnRef,
    pub conv2: usize,
    /// 1×1 projection when the channel count changes.
    pub shortcut: Option<usize>,
    pub stride: usize,
}

/// The parsed network graph.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub stem: usize,
    pub blocks: Vec<BlockSpec>,
    pub head_gn: GnRef,
    /// Theta offset of the dense weight `[C, classes]`.
    pub dense_w: usize,
    /// Theta offset of the dense bias `[classes]`.
    pub dense_b: usize,
    pub classes: usize,
}

fn gn_ref(entries: &HashMap<&str, &LayerEntry>, pfx: &str) -> Result<GnRef> {
    let g = entries
        .get(format!("{pfx}.gamma").as_str())
        .ok_or_else(|| anyhow::anyhow!("layer {pfx}.gamma missing from manifest"))?;
    let b = entries
        .get(format!("{pfx}.beta").as_str())
        .ok_or_else(|| anyhow::anyhow!("layer {pfx}.beta missing from manifest"))?;
    anyhow::ensure!(
        g.shape.len() == 1 && g.shape == b.shape,
        "groupnorm {pfx} has malformed shapes {:?}/{:?}",
        g.shape,
        b.shape
    );
    // The reference model reshapes to (groups, c/groups); a width whose
    // channel counts don't divide min(8, c) must fail here, loudly, not
    // leave trailing channels unnormalized.
    let c = g.shape[0];
    anyhow::ensure!(
        c % c.min(8) == 0,
        "groupnorm {pfx}: {c} channels not divisible by {} groups",
        c.min(8)
    );
    Ok(GnRef { gamma: g.theta_offset, beta: b.theta_offset, c })
}

impl NetSpec {
    /// Reconstruct the graph from the parameter layout. Fails loudly when
    /// the layer naming convention does not match the strip-conv ResNet
    /// family (the simulator cannot execute arbitrary manifests).
    pub fn parse(model: &ModelInfo) -> Result<NetSpec> {
        let conv_idx: HashMap<&str, usize> = model
            .conv_layers()
            .iter()
            .map(|l| (l.name.as_str(), l.index))
            .collect();
        let entries: HashMap<&str, &LayerEntry> = model
            .entry
            .layers
            .iter()
            .map(|l| (l.name.as_str(), l))
            .collect();

        let stem = *conv_idx
            .get("stem.conv")
            .ok_or_else(|| anyhow::anyhow!("model has no stem.conv layer"))?;

        let mut blocks = Vec::new();
        let mut s = 0usize;
        while conv_idx.contains_key(format!("s{s}.b0.conv1").as_str()) {
            let mut b = 0usize;
            while let Some(&conv1) = conv_idx.get(format!("s{s}.b{b}.conv1").as_str()) {
                let pfx = format!("s{s}.b{b}");
                let conv2 = *conv_idx
                    .get(format!("{pfx}.conv2").as_str())
                    .ok_or_else(|| anyhow::anyhow!("block {pfx} has conv1 but no conv2"))?;
                let shortcut = conv_idx.get(format!("{pfx}.shortcut").as_str()).copied();
                blocks.push(BlockSpec {
                    gn1: gn_ref(&entries, &format!("{pfx}.gn1"))?,
                    conv1,
                    gn2: gn_ref(&entries, &format!("{pfx}.gn2"))?,
                    conv2,
                    shortcut,
                    stride: if s > 0 && b == 0 { 2 } else { 1 },
                });
                b += 1;
            }
            s += 1;
        }
        anyhow::ensure!(!blocks.is_empty(), "no residual blocks parsed from layer names");

        let head_gn = gn_ref(&entries, "head.gn")?;
        let dw = entries
            .get("head.dense.w")
            .ok_or_else(|| anyhow::anyhow!("model has no head.dense.w layer"))?;
        let db = entries
            .get("head.dense.b")
            .ok_or_else(|| anyhow::anyhow!("model has no head.dense.b layer"))?;
        anyhow::ensure!(
            dw.shape.len() == 2 && dw.shape[0] == head_gn.c,
            "dense weight shape {:?} does not match head width {}",
            dw.shape,
            head_gn.c
        );
        Ok(NetSpec {
            stem,
            blocks,
            head_gn,
            dense_w: dw.theta_offset,
            dense_b: db.theta_offset,
            classes: dw.shape[1],
        })
    }
}

/// Pluggable conv execution over im2col patches.
pub trait ConvExec {
    /// `patches` is `[t, K²·D]` (column order `(kh·K + kw)·D + d`, matching
    /// the HWIO theta layout); writes `[t, N]` into `out` (cleared and
    /// resized by the implementation). `scratch` carries the backend's
    /// reusable internal buffers so the steady-state call allocates
    /// nothing.
    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
        scratch: &mut ConvScratch,
        out: &mut Vec<f32>,
    ) -> Result<()>;
}

/// Ideal f32 conv (the reference the simulator is property-tested against).
pub struct ExactConv;

impl ConvExec for ExactConv {
    fn conv(
        &self,
        _model: &ModelInfo,
        layer: &ConvLayer,
        theta: &[f32],
        patches: &[f32],
        t: usize,
        _scratch: &mut ConvScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let cols = layer.k * layer.k * layer.d;
        let n = layer.n;
        let w = &theta[layer.theta_offset..layer.theta_offset + cols * n];
        out.clear();
        out.resize(t * n, 0.0);
        for ti in 0..t {
            let row = &patches[ti * cols..(ti + 1) * cols];
            let o = &mut out[ti * n..(ti + 1) * n];
            for (ci, &a) in row.iter().enumerate() {
                if a == 0.0 {
                    continue; // padding zeros dominate the border patches
                }
                for (ov, &wv) in o.iter_mut().zip(&w[ci * n..(ci + 1) * n]) {
                    *ov += a * wv;
                }
            }
        }
        Ok(())
    }
}

/// im2col with SAME padding into a reusable buffer: `x` is `[b, h, w, c]`
/// row-major; fills `out` with `[b·oh·ow, k²·c]` (out-of-bounds taps stay
/// zero) and returns (oh, ow).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let oh = (h + stride - 1) / stride;
    let ow = (w + stride - 1) / stride;
    // XLA-style SAME: total = max((o-1)*stride + k - in, 0), low half first.
    let pt = ((oh - 1) * stride + k).saturating_sub(h) / 2;
    let pl = ((ow - 1) * stride + k).saturating_sub(w) / 2;
    let cols = k * k * c;
    out.clear();
    out.resize(b * oh * ow * cols, 0.0);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let base = ((bi * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                        let dst = base + (ky * k + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    (oh, ow)
}

/// Allocating [`im2col_into`] wrapper: returns (`patches`, oh, ow).
pub fn im2col(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
) -> (Vec<f32>, usize, usize) {
    let mut out = Vec::new();
    let (oh, ow) = im2col_into(x, b, h, w, c, k, stride, &mut out);
    (out, oh, ow)
}

/// GroupNorm (groups = min(8, C), eps 1e-5), matching `model.py`.
fn group_norm(x: &mut [f32], b: usize, hw: usize, c: usize, theta: &[f32], gn: &GnRef) {
    debug_assert_eq!(gn.c, c);
    let groups = c.min(8);
    let gs = c / groups;
    let gamma = &theta[gn.gamma..gn.gamma + c];
    let beta = &theta[gn.beta..gn.beta + c];
    for bi in 0..b {
        for g in 0..groups {
            let mut sum = 0.0f64;
            let mut sumsq = 0.0f64;
            for p in 0..hw {
                let base = (bi * hw + p) * c + g * gs;
                for &v in &x[base..base + gs] {
                    let v = v as f64;
                    sum += v;
                    sumsq += v * v;
                }
            }
            let n = (hw * gs) as f64;
            let mu = sum / n;
            let var = (sumsq / n - mu * mu).max(0.0);
            let inv = 1.0 / (var + 1e-5).sqrt();
            for p in 0..hw {
                let base = (bi * hw + p) * c + g * gs;
                for (j, v) in x[base..base + gs].iter_mut().enumerate() {
                    let ch = g * gs + j;
                    *v = ((*v as f64 - mu) * inv) as f32 * gamma[ch] + beta[ch];
                }
            }
        }
    }
}

fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// One conv layer over im2col patches, reusing `patches` and `out` and
/// handing `cs` to the backend. Returns the output spatial shape.
#[allow(clippy::too_many_arguments)]
fn conv_layer<C: ConvExec + ?Sized>(
    model: &ModelInfo,
    idx: usize,
    theta: &[f32],
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: usize,
    conv: &C,
    patches: &mut Vec<f32>,
    cs: &mut ConvScratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize)> {
    let layer = model.layer(idx);
    anyhow::ensure!(
        layer.d == c,
        "layer {} expects {} input channels, got {c}",
        layer.name,
        layer.d
    );
    let _span = crate::trace::span_with(|| format!("layer:{}", layer.name));
    let (oh, ow) = im2col_into(x, b, h, w, c, layer.k, stride, patches);
    conv.conv(model, layer, theta, patches, b * oh * ow, cs, out)?;
    Ok((oh, ow))
}

/// Full forward pass: images `[B, H, W, 3]` (or flat `[B, H·W·3]`) → logits
/// `[B, classes]`. Every conv goes through `conv`; everything else is f32.
///
/// All intermediate buffers come from `scratch`, so steady-state calls of a
/// fixed shape allocate nothing beyond the returned tensor. Residual blocks
/// copy the activation map at most once: identity blocks copy it to
/// normalize without losing the shortcut operand, projection blocks
/// normalize in place (the map is replaced by the projection anyway).
pub fn forward<C: ConvExec + ?Sized>(
    model: &ModelInfo,
    spec: &NetSpec,
    theta: &[f32],
    x: &Tensor,
    conv: &C,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    anyhow::ensure!(
        theta.len() == model.entry.num_params,
        "theta length {} does not match model ({} params)",
        theta.len(),
        model.entry.num_params
    );
    let shape = x.shape();
    let (b, mut h, mut w, mut c) = match shape.len() {
        4 => (shape[0], shape[1], shape[2], shape[3]),
        2 if shape[1] == 32 * 32 * 3 => (shape[0], 32, 32, 3),
        _ => anyhow::bail!("unsupported input shape {shape:?}"),
    };
    let Scratch { nn: ns, conv: cs } = scratch;

    // Stem.
    let (oh, ow) = conv_layer(
        model,
        spec.stem,
        theta,
        x.data(),
        b,
        h,
        w,
        c,
        1,
        conv,
        &mut ns.patches,
        cs,
        &mut ns.act,
    )?;
    h = oh;
    w = ow;
    c = model.layer(spec.stem).n;

    // Residual stages.
    for blk in &spec.blocks {
        let c_out = model.layer(blk.conv1).n;
        if let Some(sc) = blk.shortcut {
            // The projection replaces `act`, so normalize it in place — the
            // normalized map feeds conv1 *and* the shortcut conv, no copy.
            group_norm(&mut ns.act, b, h * w, c, theta, &blk.gn1);
            relu(&mut ns.act);
            let (oh, ow) = conv_layer(
                model,
                blk.conv1,
                theta,
                &ns.act,
                b,
                h,
                w,
                c,
                blk.stride,
                conv,
                &mut ns.patches,
                cs,
                &mut ns.y1,
            )?;
            group_norm(&mut ns.y1, b, oh * ow, c_out, theta, &blk.gn2);
            relu(&mut ns.y1);
            let (oh2, ow2) = conv_layer(
                model,
                blk.conv2,
                theta,
                &ns.y1,
                b,
                oh,
                ow,
                c_out,
                1,
                conv,
                &mut ns.patches,
                cs,
                &mut ns.y2,
            )?;
            debug_assert_eq!((oh, ow), (oh2, ow2));
            let _ = conv_layer(
                model,
                sc,
                theta,
                &ns.act,
                b,
                h,
                w,
                c,
                blk.stride,
                conv,
                &mut ns.patches,
                cs,
                &mut ns.sh,
            )?;
            std::mem::swap(&mut ns.act, &mut ns.sh);
            for (a, v) in ns.act.iter_mut().zip(&ns.y2) {
                *a += v;
            }
            h = oh;
            w = ow;
            c = c_out;
        } else {
            anyhow::ensure!(
                blk.stride == 1 && c == c_out,
                "identity shortcut requires matching dims"
            );
            // `act` must survive for the residual add: the one activation
            // copy this block needs.
            ns.y.clear();
            ns.y.extend_from_slice(&ns.act);
            group_norm(&mut ns.y, b, h * w, c, theta, &blk.gn1);
            relu(&mut ns.y);
            let (oh, ow) = conv_layer(
                model,
                blk.conv1,
                theta,
                &ns.y,
                b,
                h,
                w,
                c,
                blk.stride,
                conv,
                &mut ns.patches,
                cs,
                &mut ns.y1,
            )?;
            group_norm(&mut ns.y1, b, oh * ow, c_out, theta, &blk.gn2);
            relu(&mut ns.y1);
            let (oh2, ow2) = conv_layer(
                model,
                blk.conv2,
                theta,
                &ns.y1,
                b,
                oh,
                ow,
                c_out,
                1,
                conv,
                &mut ns.patches,
                cs,
                &mut ns.y2,
            )?;
            debug_assert_eq!((oh, ow), (oh2, ow2));
            for (a, v) in ns.act.iter_mut().zip(&ns.y2) {
                *a += v;
            }
            h = oh;
            w = ow;
        }
    }

    // Head: GN → ReLU → global mean pool → dense.
    group_norm(&mut ns.act, b, h * w, c, theta, &spec.head_gn);
    relu(&mut ns.act);
    let hw = h * w;
    let k = spec.classes;
    let dw = &theta[spec.dense_w..spec.dense_w + c * k];
    let db = &theta[spec.dense_b..spec.dense_b + k];
    let mut logits = vec![0.0f32; b * k];
    // The pool accumulator is hoisted out of the per-sample loop: one
    // buffer, re-zeroed per sample, never reallocated.
    ns.pooled.clear();
    ns.pooled.resize(c, 0.0);
    for bi in 0..b {
        for pc in ns.pooled.iter_mut() {
            *pc = 0.0;
        }
        for p in 0..hw {
            let base = (bi * hw + p) * c;
            for (pc, &v) in ns.pooled.iter_mut().zip(&ns.act[base..base + c]) {
                *pc += v as f64;
            }
        }
        for pc in ns.pooled.iter_mut() {
            *pc /= hw as f64;
        }
        let row = &mut logits[bi * k..(bi + 1) * k];
        row.copy_from_slice(db);
        for (ci, &p) in ns.pooled.iter().enumerate() {
            for (rv, &wv) in row.iter_mut().zip(&dw[ci * k..(ci + 1) * k]) {
                *rv += p as f32 * wv;
            }
        }
    }
    Ok(Tensor::new(vec![b, k], logits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixture;

    #[test]
    fn im2col_same_stride1_centers_patch() {
        // 1×3×3×1 input, K=3, stride 1: center patch sees the whole image.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let (p, oh, ow) = im2col(&x, 1, 3, 3, 1, 3, 1);
        assert_eq!((oh, ow), (3, 3));
        // center output pixel (1,1): full image in kernel order
        let center = &p[(1 * 3 + 1) * 9..(1 * 3 + 1) * 9 + 9];
        assert_eq!(center, &x[..]);
        // corner (0,0): top-left taps are padding zeros
        let corner = &p[..9];
        assert_eq!(corner, &[0., 0., 0., 0., 1., 2., 0., 4., 5.]);
    }

    #[test]
    fn im2col_same_stride2_shapes() {
        let x = vec![1.0f32; 1 * 32 * 32 * 2];
        let (p, oh, ow) = im2col(&x, 1, 32, 32, 2, 3, 2);
        assert_eq!((oh, ow), (16, 16));
        assert_eq!(p.len(), 16 * 16 * 9 * 2);
        // stride-2 SAME over 32 with K=3: pad low = 0 — output (0,0) reads
        // input rows 0..3 directly (no zero taps at the top-left).
        assert_eq!(p[0], 1.0);
        // 1×1 conv never pads
        let (p1, oh1, _) = im2col(&x, 1, 32, 32, 2, 1, 2);
        assert_eq!(oh1, 16);
        assert!(p1.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn im2col_into_reuses_a_dirty_buffer() {
        // Stale contents (from a previous, larger conv) must not leak into
        // the padding zeros of the next call.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut buf = vec![7.0f32; 4096];
        let (oh, ow) = im2col_into(&x, 1, 3, 3, 1, 3, 1, &mut buf);
        assert_eq!((oh, ow), (3, 3));
        let (fresh, _, _) = im2col(&x, 1, 3, 3, 1, 3, 1);
        assert_eq!(buf, fresh);
    }

    #[test]
    fn group_norm_normalizes_and_scales() {
        // 1 sample, 2 pixels, 2 channels, groups = min(8,2) = 2 (one channel
        // per group): each channel normalized independently over pixels.
        let mut x = vec![1.0f32, 10.0, 3.0, 30.0]; // [p0c0, p0c1, p1c0, p1c1]
        let theta = vec![2.0f32, 1.0, 0.5, 0.0]; // gamma=[2,1], beta=[0.5,0]
        let gn = GnRef { gamma: 0, beta: 2, c: 2 };
        group_norm(&mut x, 1, 2, 2, &theta, &gn);
        // channel 0: values {1,3} -> normalized {-1, 1} -> ×2 + 0.5
        assert!((x[0] - (-1.5)).abs() < 1e-3, "{:?}", x);
        assert!((x[2] - 2.5).abs() < 1e-3, "{:?}", x);
        // channel 1: {10,30} -> {-1,1} -> ×1 + 0
        assert!((x[1] + 1.0).abs() < 1e-3, "{:?}", x);
        assert!((x[3] - 1.0).abs() < 1e-3, "{:?}", x);
    }

    #[test]
    fn parse_recovers_fixture_structure() {
        let fx = fixture::tiny(3);
        let spec = NetSpec::parse(&fx.model).unwrap();
        assert_eq!(spec.blocks.len(), 3);
        // first block of stages 1 and 2 downsample; stage 0 does not
        assert_eq!(spec.blocks[0].stride, 1);
        assert_eq!(spec.blocks[1].stride, 2);
        assert_eq!(spec.blocks[2].stride, 2);
        assert!(spec.blocks[0].shortcut.is_none());
        assert!(spec.blocks[1].shortcut.is_some());
        assert!(spec.blocks[2].shortcut.is_some());
        assert_eq!(spec.classes, 10);
    }

    #[test]
    fn forward_produces_finite_logits_per_sample() {
        let fx = fixture::tiny(5);
        let spec = NetSpec::parse(&fx.model).unwrap();
        let xb = fx.test.x.slice_rows(0, 2);
        let mut scratch = Scratch::default();
        let logits = forward(&fx.model, &spec, &fx.theta, &xb, &ExactConv, &mut scratch).unwrap();
        assert_eq!(logits.shape(), &[2, 10]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
        // per-sample independence: row 0 of a batch equals a solo forward
        let solo = forward(
            &fx.model,
            &spec,
            &fx.theta,
            &fx.test.x.slice_rows(0, 1),
            &ExactConv,
            &mut scratch,
        )
        .unwrap();
        for (a, b) in solo.data().iter().zip(logits.data()) {
            assert_eq!(a, b, "batch composition must not change a sample's logits");
        }
    }

    #[test]
    fn forward_is_bit_identical_with_a_reused_scratch() {
        // The scratch arena is the zero-alloc mechanism; reusing it across
        // calls (dirty buffers, different batch sizes) must never change a
        // result bit.
        let fx = fixture::tiny(8);
        let spec = NetSpec::parse(&fx.model).unwrap();
        let mut scratch = Scratch::default();
        let xb2 = fx.test.x.slice_rows(0, 2);
        let first = forward(&fx.model, &spec, &fx.theta, &xb2, &ExactConv, &mut scratch).unwrap();
        // interleave a different shape to dirty every buffer
        let xb1 = fx.test.x.slice_rows(2, 3);
        let _ = forward(&fx.model, &spec, &fx.theta, &xb1, &ExactConv, &mut scratch).unwrap();
        let again = forward(&fx.model, &spec, &fx.theta, &xb2, &ExactConv, &mut scratch).unwrap();
        assert_eq!(first.data(), again.data());
    }
}
