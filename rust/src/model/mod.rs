//! The manifest contract between `python/compile/aot.py` and the Rust
//! coordinator: parameter layout, conv layers, and the paper's strip-weight
//! indexing (§4.1 — a strip is the `1×1×D` slice of an HWIO conv kernel at a
//! fixed (kh, kw, output-channel)).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::Result;

/// A binary tensor artifact reference.
#[derive(Clone, Debug)]
pub struct BinEntry {
    pub file: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One parameter tensor in the flat layout.
#[derive(Clone, Debug)]
pub struct LayerEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: String,
    pub theta_offset: usize,
    pub convflat_offset: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct BatchSizes {
    pub eval: usize,
    pub serve: usize,
    pub calib: usize,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub num_params: usize,
    pub num_conv_params: usize,
    pub fp32_test_acc: f64,
    pub params: BinEntry,
    pub layers: Vec<LayerEntry>,
    pub executables: HashMap<String, String>,
    pub batch: BatchSizes,
}

#[derive(Clone, Debug)]
pub struct KernelEntry {
    pub t: usize,
    pub d: usize,
    pub g: usize,
    pub n: usize,
    pub strip_mvm: String,
    pub mixed_strip_mvm: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: u32,
    pub dataset: HashMap<String, BinEntry>,
    pub models: HashMap<String, ModelEntry>,
    pub kernel: KernelEntry,
    pub num_classes: usize,
    pub dir: PathBuf,
}

fn bin_entry(v: &Value) -> Result<BinEntry> {
    Ok(BinEntry {
        file: v.get("file")?.str()?.to_string(),
        shape: v.get("shape")?.usize_vec()?,
        dtype: v.get("dtype")?.str()?.to_string(),
    })
}

fn layer_entry(v: &Value) -> Result<LayerEntry> {
    Ok(LayerEntry {
        name: v.get("name")?.str()?.to_string(),
        shape: v.get("shape")?.usize_vec()?,
        kind: v.get("kind")?.str()?.to_string(),
        theta_offset: v.get("theta_offset")?.usize()?,
        convflat_offset: match v.opt("convflat_offset") {
            Some(x) => Some(x.usize()?),
            None => None,
        },
    })
}

fn model_entry(v: &Value) -> Result<ModelEntry> {
    let batch = v.get("batch")?;
    Ok(ModelEntry {
        name: v.get("name")?.str()?.to_string(),
        num_params: v.get("num_params")?.usize()?,
        num_conv_params: v.get("num_conv_params")?.usize()?,
        fp32_test_acc: v.get("fp32_test_acc")?.num()?,
        params: bin_entry(v.get("params")?)?,
        layers: v
            .get("layers")?
            .arr()?
            .iter()
            .map(layer_entry)
            .collect::<Result<_>>()?,
        executables: v
            .get("executables")?
            .obj()?
            .iter()
            .map(|(k, val)| Ok((k.clone(), val.str()?.to_string())))
            .collect::<Result<_>>()?,
        batch: BatchSizes {
            eval: batch.get("eval")?.usize()?,
            serve: batch.get("serve")?.usize()?,
            calib: batch.get("calib")?.usize()?,
        },
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts` first)", path.display()))?;
        let v = Value::parse(&text)?;
        let kernel = v.get("kernel")?;
        Ok(Manifest {
            version: v.get("version")?.usize()? as u32,
            dataset: v
                .get("dataset")?
                .obj()?
                .iter()
                .map(|(k, val)| Ok((k.clone(), bin_entry(val)?)))
                .collect::<Result<_>>()?,
            models: v
                .get("models")?
                .obj()?
                .iter()
                .map(|(k, val)| Ok((k.clone(), model_entry(val)?)))
                .collect::<Result<_>>()?,
            kernel: KernelEntry {
                t: kernel.get("t")?.usize()?,
                d: kernel.get("d")?.usize()?,
                g: kernel.get("g")?.usize()?,
                n: kernel.get("n")?.usize()?,
                strip_mvm: kernel.get("strip_mvm")?.str()?.to_string(),
                mixed_strip_mvm: kernel.get("mixed_strip_mvm")?.str()?.to_string(),
            },
            num_classes: v.get("num_classes")?.usize()?,
            dir: dir.to_path_buf(),
        })
    }

    pub fn tensor(&self, entry: &BinEntry) -> Result<Tensor> {
        Tensor::load_bin(&self.dir.join(&entry.file), entry.shape.clone())
    }

    pub fn dataset_tensor(&self, key: &str) -> Result<Tensor> {
        let e = self
            .dataset
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("dataset key {key} missing from manifest"))?;
        self.tensor(e)
    }

    pub fn model(&self, name: &str) -> Result<ModelInfo> {
        let entry = self
            .models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))?;
        Ok(ModelInfo::new(entry.clone()))
    }
}

/// One quantizable conv layer, with strip geometry derived from its HWIO shape.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    /// Index into `ModelInfo::conv_layers`.
    pub index: usize,
    pub name: String,
    /// Kernel spatial size K (square kernels).
    pub k: usize,
    /// Input depth D — the strip length.
    pub d: usize,
    /// Output channels N.
    pub n: usize,
    pub theta_offset: usize,
    pub convflat_offset: usize,
}

impl ConvLayer {
    /// Number of strips in this layer: K²·N (paper §4.1).
    pub fn num_strips(&self) -> usize {
        self.k * self.k * self.n
    }

    pub fn num_params(&self) -> usize {
        self.k * self.k * self.d * self.n
    }

    /// Flat index (within theta) of element (g, d, n) where g = kh*K + kw.
    #[inline]
    pub fn theta_index(&self, g: usize, dd: usize, n: usize) -> usize {
        self.theta_offset + (g * self.d + dd) * self.n + n
    }

    /// Flat index within the conv-flat vector (HVP/GSQ output layout).
    #[inline]
    pub fn convflat_index(&self, g: usize, dd: usize, n: usize) -> usize {
        self.convflat_offset + (g * self.d + dd) * self.n + n
    }
}

/// Identifies one strip-weight: (conv layer, kernel position g = kh*K+kw,
/// output channel n).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StripId {
    pub layer: usize,
    pub g: usize,
    pub n: usize,
}

/// A model plus its derived strip table.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub entry: ModelEntry,
    conv_layers: Vec<ConvLayer>,
    strips: Vec<StripId>,
}

impl ModelInfo {
    pub fn new(entry: ModelEntry) -> Self {
        let mut conv_layers = Vec::new();
        for l in &entry.layers {
            if l.kind == "conv" {
                let (k, d, n) = (l.shape[0], l.shape[2], l.shape[3]);
                assert_eq!(l.shape[0], l.shape[1], "non-square kernel {:?}", l.shape);
                conv_layers.push(ConvLayer {
                    index: conv_layers.len(),
                    name: l.name.clone(),
                    k,
                    d,
                    n,
                    theta_offset: l.theta_offset,
                    convflat_offset: l.convflat_offset.expect("conv layer missing convflat_offset"),
                });
            }
        }
        let mut strips = Vec::new();
        for (li, l) in conv_layers.iter().enumerate() {
            for g in 0..l.k * l.k {
                for n in 0..l.n {
                    strips.push(StripId { layer: li, g, n });
                }
            }
        }
        Self { entry, conv_layers, strips }
    }

    pub fn name(&self) -> &str {
        &self.entry.name
    }

    pub fn conv_layers(&self) -> &[ConvLayer] {
        &self.conv_layers
    }

    pub fn layer(&self, idx: usize) -> &ConvLayer {
        &self.conv_layers[idx]
    }

    /// All strips, layer-major then g-major then n.
    pub fn strips(&self) -> &[StripId] {
        &self.strips
    }

    pub fn num_strips(&self) -> usize {
        self.strips.len()
    }

    /// Copy the D values of a strip out of the flat parameter vector.
    pub fn strip_values(&self, theta: &[f32], s: StripId) -> Vec<f32> {
        let l = &self.conv_layers[s.layer];
        (0..l.d).map(|dd| theta[l.theta_index(s.g, dd, s.n)]).collect()
    }

    /// Allocation-free variant: fill `buf` with the strip's values.
    pub fn strip_values_into(&self, theta: &[f32], s: StripId, buf: &mut Vec<f32>) {
        let l = &self.conv_layers[s.layer];
        buf.clear();
        buf.extend((0..l.d).map(|dd| theta[l.theta_index(s.g, dd, s.n)]));
    }

    /// Overwrite the D values of a strip in the flat parameter vector.
    pub fn set_strip_values(&self, theta: &mut [f32], s: StripId, vals: &[f32]) {
        let l = &self.conv_layers[s.layer];
        assert_eq!(vals.len(), l.d);
        for (dd, v) in vals.iter().enumerate() {
            theta[l.theta_index(s.g, dd, s.n)] = *v;
        }
    }

    /// ‖w_strip‖² over the flat parameter vector.
    pub fn strip_l2sq(&self, theta: &[f32], s: StripId) -> f64 {
        let l = &self.conv_layers[s.layer];
        (0..l.d)
            .map(|dd| {
                let v = theta[l.theta_index(s.g, dd, s.n)] as f64;
                v * v
            })
            .sum()
    }

    /// Sum a conv-flat-sized vector (e.g. a Hessian-diagonal estimate) over
    /// the elements of each strip → one value per strip, in `strips()` order.
    pub fn reduce_convflat_per_strip(&self, convflat: &[f32]) -> Vec<f64> {
        assert_eq!(convflat.len(), self.entry.num_conv_params);
        let mut out = Vec::with_capacity(self.strips.len());
        for s in &self.strips {
            let l = &self.conv_layers[s.layer];
            let mut acc = 0.0f64;
            for dd in 0..l.d {
                acc += convflat[l.convflat_index(s.g, dd, s.n)] as f64;
            }
            out.push(acc);
        }
        out
    }

    /// Load the fp32 checkpoint from the artifacts dir.
    pub fn load_params(&self, manifest: &Manifest) -> Result<Vec<f32>> {
        Ok(manifest.tensor(&self.entry.params)?.into_data())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_entry() -> ModelEntry {
        // one conv layer [2,2,3,4] at theta offset 5, convflat offset 0
        ModelEntry {
            name: "toy".into(),
            num_params: 5 + 2 * 2 * 3 * 4,
            num_conv_params: 2 * 2 * 3 * 4,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![53], dtype: "f32".into() },
            layers: vec![
                LayerEntry {
                    name: "gn".into(),
                    shape: vec![5],
                    kind: "gn".into(),
                    theta_offset: 0,
                    convflat_offset: None,
                },
                LayerEntry {
                    name: "c1".into(),
                    shape: vec![2, 2, 3, 4],
                    kind: "conv".into(),
                    theta_offset: 5,
                    convflat_offset: Some(0),
                },
            ],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        }
    }

    #[test]
    fn strip_table_geometry() {
        let m = ModelInfo::new(toy_entry());
        assert_eq!(m.conv_layers().len(), 1);
        let l = m.layer(0);
        assert_eq!((l.k, l.d, l.n), (2, 3, 4));
        assert_eq!(l.num_strips(), 16); // K²·N = 4·4
        assert_eq!(m.num_strips(), 16);
    }

    #[test]
    fn strip_values_roundtrip() {
        let m = ModelInfo::new(toy_entry());
        let mut theta = vec![0.0f32; m.entry.num_params];
        let s = StripId { layer: 0, g: 3, n: 2 };
        m.set_strip_values(&mut theta, s, &[1.0, 2.0, 3.0]);
        assert_eq!(m.strip_values(&theta, s), vec![1.0, 2.0, 3.0]);
        // elements land at stride N within the layer block
        let l = m.layer(0);
        assert_eq!(theta[l.theta_index(3, 0, 2)], 1.0);
        assert_eq!(theta[l.theta_index(3, 1, 2)], 2.0);
        // no bleed into other strips
        let other = StripId { layer: 0, g: 3, n: 1 };
        assert_eq!(m.strip_values(&theta, other), vec![0.0, 0.0, 0.0]);
        assert!((m.strip_l2sq(&theta, s) - 14.0).abs() < 1e-9);
    }

    #[test]
    fn reduce_convflat_sums_within_strip() {
        let m = ModelInfo::new(toy_entry());
        let mut flat = vec![0.0f32; m.entry.num_conv_params];
        let l = m.layer(0);
        // put 1.0 in every element of strip (g=1, n=0)
        for dd in 0..l.d {
            flat[l.convflat_index(1, dd, 0)] = 1.0;
        }
        let per = m.reduce_convflat_per_strip(&flat);
        let idx = m
            .strips()
            .iter()
            .position(|s| s.g == 1 && s.n == 0)
            .unwrap();
        assert_eq!(per[idx], 3.0);
        assert_eq!(per.iter().sum::<f64>(), 3.0);
    }
}
