//! Strip quantizers (paper §4.1, §4.3): symmetric uniform int-b codes with
//! per-strip or per-layer scales, the `expand()` alignment factor, and the
//! ReRAM device-variation model.

use crate::config::{Granularity, QuantConfig, Tier};
use crate::model::ModelInfo;
use crate::util::rng::Rng;

/// Largest positive code of a symmetric b-bit quantizer.
#[inline]
pub fn qmax(bits: u8) -> f32 {
    ((1i32 << (bits - 1)) - 1) as f32
}

/// Symmetric scale for a value range: `scale = max|w| / qmax`.
#[inline]
pub fn symmetric_scale(vals: &[f32], bits: u8) -> f32 {
    let amax = vals.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if amax > 0.0 {
        amax / qmax(bits)
    } else {
        1.0
    }
}

/// Quantize to integer codes on the given scale.
pub fn quantize_codes(vals: &[f32], bits: u8, scale: f32) -> Vec<i32> {
    let q = qmax(bits);
    vals.iter()
        .map(|v| (v / scale).round().clamp(-q, q) as i32)
        .collect()
}

/// Dequantize codes back to f32.
pub fn dequantize(codes: &[i32], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale).collect()
}

/// Fake-quantize in one step: `deq(quant(v))`.
pub fn fake_quantize(vals: &[f32], bits: u8, scale: f32) -> Vec<f32> {
    let q = qmax(bits);
    vals.iter()
        .map(|v| (v / scale).round().clamp(-q, q) * scale)
        .collect()
}

/// The paper's `expand()` factor aligning low-bit partial sums onto the
/// high-bit accumulation grid: the ratio of quantization steps.
#[inline]
pub fn expand_factor(scale_lo: f32, scale_hi: f32) -> f32 {
    scale_lo / scale_hi
}

/// Per-strip precision assignment produced by clustering.
#[derive(Clone, Debug)]
pub struct BitMap {
    /// bits per strip, in `ModelInfo::strips()` order; 0 = pruned.
    pub bits: Vec<u8>,
}

impl BitMap {
    pub fn uniform(n: usize, bits: u8) -> Self {
        Self { bits: vec![bits; n] }
    }

    /// Fraction of strips in the low tier (the paper's compression ratio;
    /// pruned strips count as compressed too).
    pub fn compression_ratio(&self, hi_bits: u8) -> f64 {
        let lo = self.bits.iter().filter(|&&b| b != hi_bits).count();
        lo as f64 / self.bits.len().max(1) as f64
    }

    pub fn count_bits(&self, bits: u8) -> usize {
        self.bits.iter().filter(|&&b| b == bits).count()
    }
}

/// Result of quantizing a model: the dequantized ("fake-quant") parameter
/// vector to feed the forward executable, plus the per-strip metadata the
/// crossbar mapper consumes.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub theta: Vec<f32>,
    /// Per-strip scale (LSB) actually used.
    pub scales: Vec<f32>,
    /// Per-strip bit width (copy of the bitmap).
    pub bits: Vec<u8>,
    /// Mean squared quantization error over conv weights.
    pub mse: f64,
}

impl QuantizedModel {
    /// Machine-readable stage-artifact summary (the parameter vector itself
    /// stays binary).
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj(vec![
            ("mse", Value::Num(self.mse)),
            ("strips", Value::Num(self.bits.len() as f64)),
            ("params", Value::Num(self.theta.len() as f64)),
        ])
    }
}

/// Per-layer shared scale for a tier (one conductance window per array bank).
fn layer_scale(model: &ModelInfo, theta: &[f32], layer: usize, bits: u8) -> f32 {
    let l = model.layer(layer);
    let lo = l.theta_offset;
    let hi = lo + l.num_params();
    symmetric_scale(&theta[lo..hi], bits)
}

/// Apply mixed-precision quantization to the conv weights of `theta`
/// according to `bitmap`, with the device-variation model of `cfg`.
///
/// Strips with bits == 0 are pruned (zeroed) — used by the HAP baseline.
pub fn apply(
    model: &ModelInfo,
    theta: &[f32],
    bitmap: &BitMap,
    cfg: &QuantConfig,
) -> QuantizedModel {
    assert_eq!(bitmap.bits.len(), model.num_strips());
    let mut out = theta.to_vec();
    let mut scales = vec![0.0f32; model.num_strips()];
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut sq_err = 0.0f64;
    let mut n_q = 0usize;

    // Cache per-(layer, bits) layer scales.
    let mut layer_scales: std::collections::HashMap<(usize, u8), f32> =
        std::collections::HashMap::new();

    // Hot loop: one pass per strip with reusable buffers (no per-strip
    // allocation — §Perf).
    let mut vals: Vec<f32> = Vec::new();
    let mut deq: Vec<f32> = Vec::new();
    for (i, s) in model.strips().iter().enumerate() {
        let bits = bitmap.bits[i];
        model.strip_values_into(&out, *s, &mut vals);
        if bits == 0 {
            deq.clear();
            deq.resize(vals.len(), 0.0);
            model.set_strip_values(&mut out, *s, &deq);
            for v in &vals {
                sq_err += (*v as f64) * (*v as f64);
            }
            n_q += vals.len();
            continue;
        }
        let tier = tier_for(cfg, bits);
        let scale = match tier.granularity {
            Granularity::PerStrip => symmetric_scale(&vals, bits),
            Granularity::PerLayer => *layer_scales
                .entry((s.layer, bits))
                .or_insert_with(|| layer_scale(model, theta, s.layer, bits)),
        };
        scales[i] = scale;
        let q = qmax(bits);
        deq.clear();
        deq.extend(vals.iter().map(|v| (v / scale).round().clamp(-q, q) * scale));
        if cfg.device_sigma > 0.0 {
            for v in deq.iter_mut() {
                *v += rng.normal() * cfg.device_sigma * scale;
            }
        }
        for (a, b) in vals.iter().zip(deq.iter()) {
            let e = (*a - *b) as f64;
            sq_err += e * e;
        }
        n_q += vals.len();
        model.set_strip_values(&mut out, *s, &deq);
    }

    QuantizedModel {
        theta: out,
        scales,
        bits: bitmap.bits.clone(),
        mse: sq_err / n_q.max(1) as f64,
    }
}

fn tier_for(cfg: &QuantConfig, bits: u8) -> Tier {
    if bits == cfg.hi.bits {
        cfg.hi
    } else if bits == cfg.lo.bits {
        cfg.lo
    } else {
        // Other widths (ablations): per-strip scaling.
        Tier { bits, granularity: Granularity::PerStrip }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(8), 127.0);
        assert_eq!(qmax(4), 7.0);
        assert_eq!(qmax(2), 1.0);
    }

    #[test]
    fn fake_quant_error_bounded_by_half_lsb() {
        let vals: Vec<f32> = (-50..50).map(|i| i as f32 * 0.037).collect();
        for bits in [4u8, 8] {
            let s = symmetric_scale(&vals, bits);
            let deq = fake_quantize(&vals, bits, s);
            for (a, b) in vals.iter().zip(deq.iter()) {
                assert!((a - b).abs() <= s * 0.5 + 1e-6, "bits={bits}");
            }
        }
    }

    #[test]
    fn codes_respect_range() {
        let vals = vec![-3.0f32, -1.0, 0.0, 0.5, 2.9];
        let s = symmetric_scale(&vals, 4);
        let codes = quantize_codes(&vals, 4, s);
        assert!(codes.iter().all(|&c| (-7..=7).contains(&c)));
        // extremes hit the rails
        assert_eq!(codes[0], -7);
    }

    #[test]
    fn zero_strip_gets_unit_scale() {
        assert_eq!(symmetric_scale(&[0.0, 0.0], 8), 1.0);
    }

    #[test]
    fn expand_is_scale_ratio() {
        assert_eq!(expand_factor(0.4, 0.1), 4.0);
    }

    #[test]
    fn bitmap_cr_counts_non_hi() {
        let bm = BitMap { bits: vec![8, 8, 4, 4, 4, 0] };
        assert!((bm.compression_ratio(8) - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(bm.count_bits(4), 3);
    }

    #[test]
    fn eight_bit_roundtrip_is_tighter_than_four_bit() {
        let vals: Vec<f32> = (0..64).map(|i| ((i * 37) % 13) as f32 * 0.11 - 0.7).collect();
        let e8: f32 = {
            let s = symmetric_scale(&vals, 8);
            fake_quantize(&vals, 8, s)
                .iter()
                .zip(&vals)
                .map(|(a, b)| (a - b).abs())
                .sum()
        };
        let e4: f32 = {
            let s = symmetric_scale(&vals, 4);
            fake_quantize(&vals, 4, s)
                .iter()
                .zip(&vals)
                .map(|(a, b)| (a - b).abs())
                .sum()
        };
        assert!(e8 < e4);
    }
}
