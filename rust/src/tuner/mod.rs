//! Parallel Pareto auto-tuner over the stage cache.
//!
//! The paper reports one hand-picked operating point (86.33% accuracy at
//! 70% compression); this module searches the whole (threshold, bits,
//! alignment) space the staged [`crate::coordinator::CompressionPlan`]
//! makes cheap. The expensive sensitivity prefix is memoized per worker,
//! so each additional candidate pays only the tail stages — the tuner
//! reports the observed prefix reuse via the plan's per-stage cache hit
//! counters ([`crate::coordinator::CacheStats`]).
//!
//! The moving parts, one per submodule:
//!
//! * [`space`] — [`Candidate`] operating points, the [`Axes`] cross
//!   product, and its deterministic (optionally seed-shuffled) schedule.
//! * [`frontier`] — the live 3-objective Pareto frontier (accuracy ↑,
//!   compression ↑, deployed storage bytes ↓) with dominated-point
//!   pruning; insertion-order independent.
//! * [`state`] — resumable JSON search state: explored points, seed,
//!   fingerprint, elapsed budget. An interrupted run continues where it
//!   left off and converges bit-identically to an uninterrupted one.
//! * [`driver`] — the worker fan-out ([`run`]) and the degenerate
//!   single-axis CR sweep ([`sweep_cr`]) that reproduces the paper's
//!   Table 3 (`experiments::table3` is a thin wrapper over it).
//!
//! The CLI front-end is `reram-mpq tune` (budget / axes / resume flags,
//! `--json` output); see `docs/ARCHITECTURE.md` for the data-flow of one
//! tuning run.

pub mod driver;
pub mod frontier;
pub mod space;
pub mod state;

pub use driver::{run, sweep_cr, TuneConfig, TuneOutcome, TuneShared};
pub use frontier::{Frontier, FrontierPoint, Objectives};
pub use space::{Axes, Candidate, DEFAULT_BITS, TABLE3_CRS};
pub use state::{ExploredPoint, SearchState, STATE_VERSION};
