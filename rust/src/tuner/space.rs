//! The tuner's search space: candidate operating points and their
//! deterministic schedule.
//!
//! A [`Candidate`] pins the three stage knobs the staged
//! [`crate::coordinator::CompressionPlan`] exposes cheaply once the
//! sensitivity prefix is cached: the fixed compression ratio fed to the
//! threshold stage, the (hi, lo) quantizer bit pair, and whether the
//! capacity-alignment stage runs. [`Axes`] is the cross product of per-knob
//! value lists; [`Axes::schedule`] linearizes it deterministically (CR-major,
//! optionally Fisher–Yates-shuffled by a seed) so a resumed search walks the
//! exact same candidate order as an uninterrupted one.

use crate::util::json::{obj, Value};
use crate::util::rng::Rng;
use crate::Result;

/// The paper's Table 3 compression-ratio sweep points — the single shared
/// definition consumed by `experiments::table3`, the `table3_cr_sweep`
/// bench and the tuner's degenerate single-axis case.
pub const TABLE3_CRS: &[f64] = &[0.0, 0.1, 0.5, 0.7, 0.9, 1.0];

/// Default (hi, lo) bit pairs for the `bits` axis: the paper's 8/4 point
/// plus cheaper tails the storage objective can trade against.
pub const DEFAULT_BITS: &[(u8, u8)] = &[(8, 4), (8, 2), (4, 2)];

/// One candidate operating point: the knobs of a single plan-tail
/// evaluation.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    /// Fixed compression ratio handed to the threshold stage
    /// (`ThresholdMode::FixedCr`).
    pub cr: f64,
    /// High-tier quantizer bits.
    pub hi_bits: u8,
    /// Low-tier quantizer bits.
    pub lo_bits: u8,
    /// Whether the crossbar capacity-alignment stage runs (paper §4.2).
    pub align: bool,
}

impl Candidate {
    /// Stable identity key — the explored-set index of the resumable search
    /// state. `f64` `Display` is shortest-roundtrip, so distinct ratios
    /// never collide.
    pub fn key(&self) -> String {
        format!(
            "cr{}:hi{}:lo{}:al{}",
            self.cr, self.hi_bits, self.lo_bits, self.align as u8
        )
    }

    /// JSON form (`cr` / `hi_bits` / `lo_bits` / `align`).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("cr", Value::Num(self.cr)),
            ("hi_bits", Value::Num(self.hi_bits as f64)),
            ("lo_bits", Value::Num(self.lo_bits as f64)),
            ("align", Value::Bool(self.align)),
        ])
    }

    /// Parse the [`Candidate::to_value`] form back.
    pub fn from_value(v: &Value) -> Result<Self> {
        let align = match v.get("align")? {
            Value::Bool(b) => *b,
            other => anyhow::bail!("candidate align is not a bool: {other:?}"),
        };
        Ok(Self {
            cr: v.get("cr")?.num()?,
            hi_bits: v.get("hi_bits")?.usize()? as u8,
            lo_bits: v.get("lo_bits")?.usize()? as u8,
            align,
        })
    }
}

/// The search space: per-knob value lists whose cross product is the
/// candidate set.
#[derive(Clone, Debug)]
pub struct Axes {
    /// Compression-ratio axis (always present).
    pub crs: Vec<f64>,
    /// (hi, lo) quantizer bit pairs.
    pub bits: Vec<(u8, u8)>,
    /// Capacity-alignment on/off.
    pub aligns: Vec<bool>,
}

impl Axes {
    /// The degenerate single-axis space: sweep `crs` with the bit pair and
    /// alignment pinned — exactly the paper's Table 3 shape.
    pub fn cr_axis(crs: &[f64], hi_bits: u8, lo_bits: u8) -> Result<Self> {
        Self::new(crs.to_vec(), vec![(hi_bits, lo_bits)], vec![true])
    }

    /// A validated space from explicit per-knob lists.
    pub fn new(crs: Vec<f64>, bits: Vec<(u8, u8)>, aligns: Vec<bool>) -> Result<Self> {
        anyhow::ensure!(!crs.is_empty(), "the cr axis must have at least one point");
        anyhow::ensure!(!bits.is_empty() && !aligns.is_empty(), "empty search axis");
        for &cr in &crs {
            anyhow::ensure!((0.0..=1.0).contains(&cr), "cr {cr} outside [0,1]");
        }
        for &(hi, lo) in &bits {
            anyhow::ensure!(
                (1..=8u8).contains(&lo) && (1..=8u8).contains(&hi) && hi >= lo,
                "bad bit pair {hi}/{lo} (need 1 <= lo <= hi <= 8)"
            );
        }
        Ok(Self { crs, bits, aligns })
    }

    /// Parse a CLI axes spec: a comma-separated subset of
    /// `cr`, `bits`, `align` (`cr` is mandatory — it is the spine every
    /// other axis multiplies). Omitted axes are pinned to `default_bits` /
    /// alignment-on.
    pub fn parse(spec: &str, crs: &[f64], default_bits: (u8, u8)) -> Result<Self> {
        let mut with_bits = false;
        let mut with_align = false;
        let mut saw_cr = false;
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            match tok {
                "cr" => saw_cr = true,
                "bits" => with_bits = true,
                "align" => with_align = true,
                other => anyhow::bail!("unknown axis '{other}' (expected cr|bits|align)"),
            }
        }
        anyhow::ensure!(saw_cr, "the axes spec must include 'cr'");
        let bits = if with_bits {
            DEFAULT_BITS.to_vec()
        } else {
            vec![default_bits]
        };
        let aligns = if with_align { vec![true, false] } else { vec![true] };
        Self::new(crs.to_vec(), bits, aligns)
    }

    /// Total number of candidates (cross-product size).
    pub fn len(&self) -> usize {
        self.crs.len() * self.bits.len() * self.aligns.len()
    }

    /// True when the space is empty (never, post-validation).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The deterministic candidate order: CR-major cross product, then an
    /// optional Fisher–Yates shuffle keyed by `seed` (`0` keeps sweep
    /// order, which is what the degenerate Table 3 case relies on). The
    /// same `(axes, seed)` always yields the same schedule — resumability
    /// depends on it.
    pub fn schedule(&self, seed: u64) -> Vec<Candidate> {
        let mut out = Vec::with_capacity(self.len());
        for &cr in &self.crs {
            for &(hi_bits, lo_bits) in &self.bits {
                for &align in &self.aligns {
                    out.push(Candidate { cr, hi_bits, lo_bits, align });
                }
            }
        }
        if seed != 0 {
            let mut rng = Rng::seed_from_u64(seed);
            for i in (1..out.len()).rev() {
                out.swap(i, rng.below(i + 1));
            }
        }
        out
    }

    /// FNV fingerprint of the `(schedule, seed)` pair. Stored in the search
    /// state so a resume against a different space or seed is rejected
    /// instead of silently mixing incompatible explored sets.
    pub fn fingerprint(&self, seed: u64) -> u64 {
        let mut text = format!("seed{seed}");
        for c in self.schedule(seed) {
            text.push('|');
            text.push_str(&c.key());
        }
        let mut h = 0xcbf29ce484222325u64;
        for b in text.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cr_axis_schedules_in_sweep_order() {
        let axes = Axes::cr_axis(TABLE3_CRS, 8, 4).unwrap();
        let sched = axes.schedule(0);
        assert_eq!(sched.len(), TABLE3_CRS.len());
        for (c, &cr) in sched.iter().zip(TABLE3_CRS) {
            assert_eq!(c.cr, cr);
            assert_eq!((c.hi_bits, c.lo_bits, c.align), (8, 4, true));
        }
    }

    #[test]
    fn shuffled_schedule_is_deterministic_and_a_permutation() {
        let axes = Axes::parse("cr,bits,align", TABLE3_CRS, (8, 4)).unwrap();
        assert_eq!(axes.len(), TABLE3_CRS.len() * DEFAULT_BITS.len() * 2);
        let a = axes.schedule(9);
        let b = axes.schedule(9);
        assert_eq!(a, b);
        assert_ne!(a, axes.schedule(0), "seeded schedule should differ from sweep order");
        let mut keys: Vec<String> = a.iter().map(Candidate::key).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), axes.len(), "shuffle must stay a permutation");
    }

    #[test]
    fn fingerprint_separates_spaces_and_seeds() {
        let a = Axes::cr_axis(TABLE3_CRS, 8, 4).unwrap();
        let b = Axes::cr_axis(TABLE3_CRS, 8, 2).unwrap();
        assert_ne!(a.fingerprint(0), b.fingerprint(0));
        assert_ne!(a.fingerprint(0), a.fingerprint(1));
        assert_eq!(a.fingerprint(0), a.fingerprint(0));
    }

    #[test]
    fn parse_rejects_unknown_axes_and_missing_cr() {
        assert!(Axes::parse("cr,perf", TABLE3_CRS, (8, 4)).is_err());
        assert!(Axes::parse("bits", TABLE3_CRS, (8, 4)).is_err());
        assert!(Axes::new(vec![1.5], vec![(8, 4)], vec![true]).is_err());
        assert!(Axes::new(vec![0.5], vec![(4, 8)], vec![true]).is_err());
    }

    #[test]
    fn candidate_roundtrips_json_and_keys_are_distinct() {
        let c = Candidate { cr: 0.7, hi_bits: 8, lo_bits: 4, align: true };
        let back = Candidate::from_value(&Value::parse(&c.to_value().to_json()).unwrap()).unwrap();
        assert_eq!(c, back);
        let d = Candidate { align: false, ..back };
        assert_ne!(c.key(), d.key());
    }
}
