//! The search driver: fan candidates across worker threads, evaluate each
//! through the stage-cached plan tail, and fold results into the frontier.
//!
//! ## Parallelism model
//!
//! [`CompressionPlan`] is deliberately single-threaded (`Rc`-shared state +
//! stage cache), so the driver mirrors the sharded engine's worker idiom
//! instead of sharing one plan: each worker thread clones the loaded model
//! state out of [`TuneShared`], roots its *own* plan (and thus its own
//! stage cache) on the simulator backend, and pulls candidates from a
//! shared atomic cursor. The expensive sensitivity prefix is computed once
//! per worker and memoized; every subsequent candidate on that worker hits
//! the cached prefix and only re-runs the cheap tail stages. Per-worker
//! [`CacheStats`] are summed into the outcome so prefix reuse is
//! observable, not assumed.
//!
//! ## Determinism and resume
//!
//! The candidate order is fixed by [`Axes::schedule`]; the atomic cursor
//! hands out schedule indices in order, and a claimed candidate is always
//! fully evaluated and recorded, so any interruption (eval budget,
//! wall-clock budget) leaves the explored set a *prefix* of the pending
//! schedule. Simulator evaluation is seeded and bit-deterministic, and the
//! frontier is insertion-order independent — together that makes
//! `interrupted run + resume` bit-identical to an uninterrupted run, which
//! `rust/tests/tuner_resume.rs` and the CI tune smoke assert.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::backend::{ProgrammedModel, SimXbarConfig, StripPrecision};
use crate::config::RunConfig;
use crate::coordinator::{
    CacheStats, CompressionPlan, EvalOpts, Executor, ModelState, PipelineReport, ThresholdMode,
};
use crate::dataset::{CalibSet, TestSet};
use crate::fixture::Fixture;
use crate::model::ModelInfo;
use crate::tuner::frontier::{Frontier, Objectives};
use crate::tuner::space::{Axes, Candidate};
use crate::tuner::state::{ExploredPoint, SearchState};
use crate::util::json::{obj, Value};
use crate::xbar::MappingStrategy;
use crate::Result;

/// Budgets and evaluation fidelity of one tune run.
#[derive(Clone, Copy, Debug)]
pub struct TuneConfig {
    /// Worker threads candidates fan out across (clamped to the pending
    /// candidate count; each worker roots its own plan + stage cache).
    pub workers: usize,
    /// Maximum *new* evaluations this run (resume picks up the rest).
    pub max_evals: usize,
    /// Wall-clock budget in milliseconds, counted across resumes via
    /// [`SearchState::elapsed_ms`]. `u64::MAX` = unbounded.
    pub budget_ms: u64,
    /// Accuracy-evaluation options (test batches per candidate).
    pub opts: EvalOpts,
    /// Simulator config candidates are evaluated on (accuracy fidelity) and
    /// that seeds the storage objective's programming pass.
    pub sim: SimXbarConfig,
}

impl Default for TuneConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            max_evals: usize::MAX,
            budget_ms: u64::MAX,
            opts: EvalOpts::default(),
            sim: SimXbarConfig::default(),
        }
    }
}

/// The loaded, thread-shareable model state a tune run fans out from.
/// Workers clone these owned buffers to root their per-thread plans —
/// unlike [`CompressionPlan`] itself, this struct is `Send + Sync`.
pub struct TuneShared {
    /// Model layout (conv layers + strip table).
    pub model: ModelInfo,
    /// fp32 checkpoint parameters.
    pub theta: Vec<f32>,
    /// Test split candidates are scored on.
    pub test: TestSet,
    /// Calibration split (sensitivity stage input).
    pub calib: CalibSet,
    /// Stage configuration the per-worker plans are rooted with (the
    /// candidate's bit pair overrides `cfg.quant` per evaluation).
    pub cfg: RunConfig,
}

impl TuneShared {
    /// Tune over the hermetic in-memory fixture workload.
    pub fn from_fixture(fx: Fixture, cfg: RunConfig) -> Self {
        Self { model: fx.model, theta: fx.theta, test: fx.test, calib: fx.calib, cfg }
    }
}

/// What one [`run`] call did: new evaluations, the frontier of the whole
/// explored set, and the summed per-worker cache counters.
pub struct TuneOutcome {
    /// Candidates newly evaluated by this run.
    pub evals: usize,
    /// Total explored points (including prior runs of a resumed state).
    pub explored: usize,
    /// Pareto frontier over the full explored set.
    pub frontier: Frontier,
    /// Stage-cache counters summed across this run's workers; the
    /// memoized-sensitivity contract shows up as `prefix_hits() > 0`
    /// whenever any worker evaluated more than one candidate.
    pub cache: CacheStats,
    /// Wall-clock milliseconds this run spent.
    pub elapsed_ms: u64,
}

impl TuneOutcome {
    /// JSON summary: counters, cache stats, the frontier, and every
    /// explored point (the CLI `--json` payload).
    pub fn to_value(&self, state: &SearchState) -> Value {
        obj(vec![
            ("evals", Value::Num(self.evals as f64)),
            ("explored", Value::Num(self.explored as f64)),
            ("elapsed_ms", Value::Num(self.elapsed_ms as f64)),
            ("total_elapsed_ms", Value::Num(state.elapsed_ms as f64)),
            ("cache", self.cache.to_value()),
            ("frontier", self.frontier.to_value()),
            (
                "points",
                Value::Arr(state.explored.values().map(ExploredPoint::to_value).collect()),
            ),
        ])
    }
}

enum Msg {
    Point(ExploredPoint),
    Done(CacheStats),
    Fail(anyhow::Error),
}

/// Build the candidate's plan tail on `plan`'s shared stage cache: fixed-CR
/// threshold → clustering (± capacity alignment) → the candidate's bit pair
/// → packed mapping. With the candidate pinned to the plan's own quant
/// config and `align = true` this is byte-for-byte the chain
/// `experiments::table3` always ran.
fn chain<'a>(plan: &CompressionPlan<'a>, cand: &Candidate) -> CompressionPlan<'a> {
    let mut q = plan.config().quant;
    q.hi.bits = cand.hi_bits;
    q.lo.bits = cand.lo_bits;
    let mut p = plan
        .clone()
        .threshold(ThresholdMode::FixedCr(cand.cr))
        .cluster()
        .quantize(q)
        .map(MappingStrategy::Packed);
    if cand.align {
        p = p.align_to_capacity();
    }
    p
}

/// The deployed-storage objective: program the candidate's quantized strips
/// once and count the packed weight bit-plane bytes. Always measured in the
/// deterministic `Packed` exec mode (noise off, ADC on) regardless of the
/// evaluation config's fidelity knobs — the `Exact` debug mode stores i32
/// codes whose byte count would not respond to the bit axis at all.
fn storage_bytes(plan: &CompressionPlan<'_>, sim: &SimXbarConfig) -> Result<u64> {
    let qm = plan.quantized()?;
    let sp = StripPrecision::from_quantized(&qm);
    let mut scfg = *sim;
    scfg.noise_sigma = 0.0;
    scfg.scalar_lanes = false;
    scfg.force_phase_loop = false;
    if scfg.adc_bits == 0 {
        scfg.adc_bits = 8;
    }
    let pm = ProgrammedModel::program(plan.model(), &qm.theta, &sp, &scfg)?;
    Ok(pm.planes_bytes as u64)
}

fn eval_candidate(
    plan: &CompressionPlan<'_>,
    cand: &Candidate,
    tcfg: &TuneConfig,
) -> Result<ExploredPoint> {
    let p = chain(plan, cand);
    let report = p.evaluate(tcfg.opts)?;
    let bytes = storage_bytes(&p, &tcfg.sim)?;
    Ok(ExploredPoint {
        candidate: cand.clone(),
        objectives: Objectives {
            top1: report.accuracy.top1,
            compression: report.compression_ratio,
            storage_bytes: bytes,
        },
    })
}

/// Run (or continue) a tune: evaluate every not-yet-explored candidate of
/// `axes` within the config's budgets, folding results into `state`. The
/// caller persists `state` (e.g. [`SearchState::save`]) to make the run
/// resumable; re-invoking with the same arguments continues where the
/// budget cut it off and converges to the same explored set and frontier
/// an uninterrupted run produces.
pub fn run(
    shared: &TuneShared,
    axes: &Axes,
    tcfg: &TuneConfig,
    state: &mut SearchState,
) -> Result<TuneOutcome> {
    anyhow::ensure!(
        state.fingerprint == axes.fingerprint(state.seed),
        "search state fingerprint does not match this space/seed \
         (it was produced by a different tune invocation)"
    );
    let t0 = Instant::now();
    let pending: Vec<Candidate> = axes
        .schedule(state.seed)
        .into_iter()
        .filter(|c| !state.explored.contains_key(&c.key()))
        .collect();
    let cap = pending.len().min(tcfg.max_evals);
    let todo = &pending[..cap];
    let remaining_ms = tcfg.budget_ms.saturating_sub(state.elapsed_ms);
    let workers = tcfg.workers.max(1).min(cap.max(1));

    let mut cache = CacheStats::default();
    let mut evals = 0usize;
    let mut first_err: Option<anyhow::Error> = None;

    if cap > 0 && remaining_ms > 0 {
        let next = AtomicUsize::new(0);
        let stop = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<Msg>();
        std::thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                let (next, stop) = (&next, &stop);
                s.spawn(move || {
                    let plan = CompressionPlan::from_state(
                        ModelState {
                            exec: Executor::Sim(tcfg.sim),
                            model: shared.model.clone(),
                            theta: shared.theta.clone(),
                            test: shared.test.clone(),
                            calib: shared.calib.clone(),
                        },
                        shared.cfg.clone(),
                    );
                    loop {
                        if stop.load(Ordering::Relaxed)
                            || t0.elapsed().as_millis() as u64 >= remaining_ms
                        {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= todo.len() {
                            break;
                        }
                        let cand = &todo[i];
                        // Span one candidate evaluation; the `cache` tag
                        // diffs the plan's Copy cache counters across the
                        // eval, so prefix reuse is visible per span.
                        let before = plan.cache_stats();
                        let mut span = crate::trace::span("tune.eval");
                        span.tag("cr", || format!("{:.3}", cand.cr));
                        span.tag("bits", || format!("{}/{}", cand.hi_bits, cand.lo_bits));
                        span.tag("align", || cand.align.to_string());
                        let result = eval_candidate(&plan, cand, tcfg);
                        span.tag("cache", || {
                            let after = plan.cache_stats();
                            let hit = after.prefix_hits() > before.prefix_hits();
                            (if hit { "hit" } else { "miss" }).to_string()
                        });
                        drop(span);
                        crate::trace::flush_thread();
                        match result {
                            Ok(point) => {
                                let _ = tx.send(Msg::Point(point));
                            }
                            Err(e) => {
                                stop.store(true, Ordering::Relaxed);
                                let _ = tx.send(Msg::Fail(e));
                                break;
                            }
                        }
                    }
                    let _ = tx.send(Msg::Done(plan.cache_stats()));
                });
            }
            drop(tx);
            for msg in rx {
                match msg {
                    Msg::Point(p) => {
                        state.explored.insert(p.candidate.key(), p);
                        evals += 1;
                    }
                    Msg::Done(stats) => cache.absorb(&stats),
                    Msg::Fail(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
        });
    }
    if let Some(e) = first_err {
        return Err(e);
    }

    let elapsed_ms = t0.elapsed().as_millis() as u64;
    state.elapsed_ms += elapsed_ms;
    Ok(TuneOutcome {
        evals,
        explored: state.explored.len(),
        frontier: state.frontier(),
        cache,
        elapsed_ms,
    })
}

/// The degenerate single-axis case of the driver: sweep `crs` serially on
/// an *existing* plan (keeping its stage cache and root backend), pinning
/// the bit pair to the plan's quant config and alignment on — exactly the
/// paper's Table 3 / Figure 8 sweeps. `experiments::table3` and
/// `experiments::fig8` are thin wrappers over this.
pub fn sweep_cr(
    plan: &CompressionPlan<'_>,
    crs: &[f64],
    opts: EvalOpts,
) -> Result<Vec<PipelineReport>> {
    let q = plan.config().quant;
    crs.iter()
        .map(|&cr| {
            let cand = Candidate { cr, hi_bits: q.hi.bits, lo_bits: q.lo.bits, align: true };
            chain(plan, &cand).evaluate(opts)
        })
        .collect()
}
