//! Resumable search state: everything an interrupted tune run needs to
//! continue exactly where it stopped.
//!
//! The state is deliberately minimal — the RNG seed, the space fingerprint,
//! the accumulated wall-clock budget, and the explored map (candidate key →
//! candidate + measured objectives). The frontier is *derived*, never
//! trusted from disk: [`SearchState::frontier`] rebuilds it from the
//! explored set on every call, so a resumed run's frontier is the frontier
//! of its explored points by construction (see the order-independence
//! property on [`Frontier`]).
//!
//! Serialization uses the crate's own JSON substrate. Keys are sorted
//! (`BTreeMap`) and `f64` values print shortest-roundtrip, so the same
//! explored set always serializes to the same bytes —
//! [`SearchState::canonical_value`] (which drops the elapsed-budget field)
//! is the bit-stability contract CI asserts under resume.

use std::collections::BTreeMap;
use std::path::Path;

use crate::tuner::frontier::{Frontier, Objectives};
use crate::tuner::space::Candidate;
use crate::util::json::{obj, Value};
use crate::Result;

/// Schema version of the on-disk state file.
pub const STATE_VERSION: usize = 1;

/// One evaluated candidate: the knobs plus the measured objectives.
#[derive(Clone, Debug, PartialEq)]
pub struct ExploredPoint {
    /// The operating point that was evaluated.
    pub candidate: Candidate,
    /// Its measured accuracy / compression / storage objectives.
    pub objectives: Objectives,
}

impl ExploredPoint {
    /// JSON form (`key` / `candidate` / `objectives`).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("key", Value::Str(self.candidate.key())),
            ("candidate", self.candidate.to_value()),
            ("objectives", self.objectives.to_value()),
        ])
    }

    /// Parse the [`ExploredPoint::to_value`] form back.
    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(Self {
            candidate: Candidate::from_value(v.get("candidate")?)?,
            objectives: Objectives::from_value(v.get("objectives")?)?,
        })
    }
}

/// The resumable search state of one tuning run.
#[derive(Clone, Debug, Default)]
pub struct SearchState {
    /// Schedule-shuffle seed the run was started with.
    pub seed: u64,
    /// [`crate::tuner::Axes::fingerprint`] of the space + seed; a resume
    /// against a different space is rejected.
    pub fingerprint: u64,
    /// Wall-clock milliseconds spent across all runs so far (counted
    /// against `TuneConfig::budget_ms`).
    pub elapsed_ms: u64,
    /// Every evaluated candidate, keyed by [`Candidate::key`].
    pub explored: BTreeMap<String, ExploredPoint>,
}

impl SearchState {
    /// Fresh state for a `(seed, fingerprint)` pair.
    pub fn new(seed: u64, fingerprint: u64) -> Self {
        Self { seed, fingerprint, elapsed_ms: 0, explored: BTreeMap::new() }
    }

    /// The Pareto frontier of the explored set, rebuilt from scratch
    /// (deterministic: the explored map iterates in key order and the
    /// frontier is insertion-order independent anyway).
    pub fn frontier(&self) -> Frontier {
        let mut f = Frontier::default();
        for (key, p) in &self.explored {
            f.insert(key, p.objectives);
        }
        f
    }

    /// Full JSON form, including the derived frontier (for inspection —
    /// [`SearchState::from_value`] ignores it and re-derives).
    pub fn to_value(&self) -> Value {
        let mut fields = vec![("version", Value::Num(STATE_VERSION as f64))];
        fields.extend(self.identity_fields());
        fields.push(("elapsed_ms", Value::Num(self.elapsed_ms as f64)));
        fields.push(("frontier", self.frontier().to_value()));
        obj(fields)
    }

    /// JSON form *without* the elapsed-budget counter — the part of the
    /// state that must be bit-identical between an interrupted-and-resumed
    /// run and an uninterrupted one.
    pub fn canonical_value(&self) -> Value {
        let mut fields = self.identity_fields();
        fields.push(("frontier", self.frontier().to_value()));
        obj(fields)
    }

    fn identity_fields(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("seed", Value::Num(self.seed as f64)),
            ("fingerprint", Value::Str(format!("{:016x}", self.fingerprint))),
            (
                "explored",
                Value::Arr(self.explored.values().map(ExploredPoint::to_value).collect()),
            ),
        ]
    }

    /// Parse a state file's JSON back (frontier and version fields are
    /// informational; the explored set is authoritative).
    pub fn from_value(v: &Value) -> Result<Self> {
        let version = v.get("version")?.usize()?;
        anyhow::ensure!(
            version == STATE_VERSION,
            "unsupported tuner state version {version} (expected {STATE_VERSION})"
        );
        let fingerprint = u64::from_str_radix(v.get("fingerprint")?.str()?, 16)?;
        let mut explored = BTreeMap::new();
        for pv in v.get("explored")?.arr()? {
            let p = ExploredPoint::from_value(pv)?;
            explored.insert(p.candidate.key(), p);
        }
        Ok(Self {
            seed: v.get("seed")?.usize()? as u64,
            fingerprint,
            elapsed_ms: v.get("elapsed_ms")?.usize()? as u64,
            explored,
        })
    }

    /// Write the state to `path` (atomic enough for a single writer: the
    /// file is replaced wholesale).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_value().to_json())?;
        Ok(())
    }

    /// Load a state file written by [`SearchState::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading tuner state {}: {e}", path.display()))?;
        Self::from_value(&Value::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(cr: f64, top1: f64) -> ExploredPoint {
        ExploredPoint {
            candidate: Candidate { cr, hi_bits: 8, lo_bits: 4, align: true },
            objectives: Objectives { top1, compression: cr, storage_bytes: 1000 - (cr * 100.0) as u64 },
        }
    }

    fn sample() -> SearchState {
        let mut st = SearchState::new(3, 0xdeadbeefcafef00d);
        st.elapsed_ms = 17;
        for p in [point(0.0, 0.5), point(0.5, 0.4), point(1.0, 0.4)] {
            st.explored.insert(p.candidate.key(), p);
        }
        st
    }

    #[test]
    fn state_roundtrips_byte_identically() {
        let st = sample();
        let text = st.to_value().to_json();
        let back = SearchState::from_value(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_value().to_json(), text);
        assert_eq!(back.seed, 3);
        assert_eq!(back.fingerprint, 0xdeadbeefcafef00d);
        assert_eq!(back.elapsed_ms, 17);
        assert_eq!(back.explored.len(), 3);
    }

    #[test]
    fn canonical_value_drops_elapsed_only() {
        let mut a = sample();
        let mut b = sample();
        a.elapsed_ms = 1;
        b.elapsed_ms = 99_999;
        assert_eq!(a.canonical_value().to_json(), b.canonical_value().to_json());
        b.explored.remove(&point(0.5, 0.4).candidate.key());
        assert_ne!(a.canonical_value().to_json(), b.canonical_value().to_json());
    }

    #[test]
    fn frontier_is_derived_from_explored() {
        let st = sample();
        let f = st.frontier();
        // cr=1.0 dominates cr=0.5 (same accuracy, more compression, fewer
        // bytes); cr=0.0 survives on accuracy.
        assert_eq!(f.len(), 2);
        assert!(f.contains(&point(0.0, 0.5).candidate.key()));
        assert!(f.contains(&point(1.0, 0.4).candidate.key()));
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let st = sample();
        let path = std::env::temp_dir().join(format!("tuner-state-{}.json", std::process::id()));
        st.save(&path).unwrap();
        let back = SearchState::load(&path).unwrap();
        assert_eq!(back.canonical_value().to_json(), st.canonical_value().to_json());
        let _ = std::fs::remove_file(&path);
    }
}
