//! 3-objective Pareto frontier with dominated-point pruning.
//!
//! The tuner scores every candidate operating point on three axes at once —
//! top-1 accuracy (maximize), compression ratio (maximize) and deployed
//! storage bytes (minimize; the packed weight bit-planes a device would
//! actually hold, per [`crate::backend::ProgrammedModel::planes_bytes`]).
//! A point *dominates* another when it is at least as good on every axis
//! and strictly better on one; the frontier is the set of non-dominated
//! points.
//!
//! [`Frontier::insert`] is order-independent: domination is a strict
//! partial order, so incremental insertion with pruning converges to the
//! unique maximal set of whatever points were offered, regardless of the
//! order worker threads report them in. That property is what makes an
//! interrupted-and-resumed search bit-identical to an uninterrupted one
//! (see [`crate::tuner::state`]), and it is property-tested below.

use crate::util::json::{obj, Value};
use crate::Result;

/// The three tuning objectives of one evaluated operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    /// Top-1 accuracy on the evaluated test batches (maximize).
    pub top1: f64,
    /// Achieved compression ratio — fraction of strips in the low tier
    /// (maximize).
    pub compression: f64,
    /// Deployed storage: packed weight bit-plane bytes of the programmed
    /// artifact (minimize).
    pub storage_bytes: u64,
}

impl Objectives {
    /// Strict Pareto domination: at least as good on all three axes and
    /// strictly better on at least one. Irreflexive and transitive, so the
    /// non-dominated set of a point collection is unique.
    pub fn dominates(&self, other: &Objectives) -> bool {
        let ge = self.top1 >= other.top1
            && self.compression >= other.compression
            && self.storage_bytes <= other.storage_bytes;
        let gt = self.top1 > other.top1
            || self.compression > other.compression
            || self.storage_bytes < other.storage_bytes;
        ge && gt
    }

    /// JSON form (`top1` / `compression` / `storage_bytes`).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("top1", Value::Num(self.top1)),
            ("compression", Value::Num(self.compression)),
            ("storage_bytes", Value::Num(self.storage_bytes as f64)),
        ])
    }

    /// Parse the [`Objectives::to_value`] form back.
    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(Self {
            top1: v.get("top1")?.num()?,
            compression: v.get("compression")?.num()?,
            storage_bytes: v.get("storage_bytes")?.usize()? as u64,
        })
    }
}

/// One frontier entry: the candidate's stable key plus its objectives.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontierPoint {
    /// The candidate key ([`crate::tuner::Candidate::key`]) this point was
    /// evaluated from.
    pub key: String,
    /// Its measured objectives.
    pub objectives: Objectives,
}

/// A live Pareto frontier. Points are kept sorted by key so serialization
/// and comparison are deterministic regardless of insertion order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Frontier {
    points: Vec<FrontierPoint>,
}

impl Frontier {
    /// Offer a point. Returns `true` when it joined the frontier (pruning
    /// every point it dominates), `false` when an existing point dominates
    /// it. Points with identical objectives coexist: neither dominates.
    pub fn insert(&mut self, key: &str, o: Objectives) -> bool {
        if self.points.iter().any(|p| p.objectives.dominates(&o)) {
            return false;
        }
        self.points.retain(|p| !o.dominates(&p.objectives));
        let at = self.points.partition_point(|p| p.key.as_str() < key);
        self.points
            .insert(at, FrontierPoint { key: key.to_string(), objectives: o });
        true
    }

    /// The current non-dominated set, sorted by key.
    pub fn points(&self) -> &[FrontierPoint] {
        &self.points
    }

    /// Number of points on the frontier.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when nothing was inserted yet (or everything was pruned —
    /// impossible: the last survivor of any insert sequence stays).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Whether `key` is currently on the frontier.
    pub fn contains(&self, key: &str) -> bool {
        self.points.iter().any(|p| p.key == key)
    }

    /// JSON array of frontier points (key + objectives), in key order.
    pub fn to_value(&self) -> Value {
        Value::Arr(
            self.points
                .iter()
                .map(|p| {
                    obj(vec![
                        ("key", Value::Str(p.key.clone())),
                        ("objectives", p.objectives.to_value()),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn o(top1: f64, cr: f64, bytes: u64) -> Objectives {
        Objectives { top1, compression: cr, storage_bytes: bytes }
    }

    #[test]
    fn domination_is_strict_and_directional() {
        let better = o(0.9, 0.7, 100);
        let worse = o(0.8, 0.7, 120);
        assert!(better.dominates(&worse));
        assert!(!worse.dominates(&better));
        // equal objectives: neither dominates
        assert!(!better.dominates(&better));
        // trade-off (higher accuracy, more bytes): incomparable
        let tradeoff = o(0.95, 0.7, 200);
        assert!(!better.dominates(&tradeoff));
        assert!(!tradeoff.dominates(&better));
    }

    #[test]
    fn insert_prunes_dominated_and_rejects_dominated() {
        let mut f = Frontier::default();
        assert!(f.insert("a", o(0.8, 0.5, 100)));
        assert!(f.insert("b", o(0.9, 0.5, 100))); // dominates a -> a pruned
        assert_eq!(f.len(), 1);
        assert!(f.contains("b"));
        assert!(!f.insert("c", o(0.85, 0.5, 100))); // dominated by b
        assert!(f.insert("d", o(0.7, 0.9, 50))); // incomparable trade-off
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn equal_objective_points_coexist() {
        let mut f = Frontier::default();
        assert!(f.insert("a", o(0.8, 0.5, 100)));
        assert!(f.insert("b", o(0.8, 0.5, 100)));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn frontier_is_insertion_order_independent() {
        // Seeded pseudo-random point cloud inserted in several different
        // orders must converge to the identical frontier (the resume
        // bit-stability guarantee rests on this).
        let mut rng = Rng::seed_from_u64(7);
        let points: Vec<(String, Objectives)> = (0..64)
            .map(|i| {
                (
                    format!("p{i}"),
                    o(
                        (rng.below(10) as f64) / 10.0,
                        (rng.below(10) as f64) / 10.0,
                        rng.below(1000) as u64,
                    ),
                )
            })
            .collect();
        let build = |order: &[usize]| {
            let mut f = Frontier::default();
            for &i in order {
                let (k, ov) = &points[i];
                f.insert(k, *ov);
            }
            f
        };
        let forward: Vec<usize> = (0..points.len()).collect();
        let reverse: Vec<usize> = (0..points.len()).rev().collect();
        let mut shuffled = forward.clone();
        for i in (1..shuffled.len()).rev() {
            shuffled.swap(i, rng.below(i + 1));
        }
        let a = build(&forward);
        assert_eq!(a, build(&reverse));
        assert_eq!(a, build(&shuffled));
        // and nothing on the frontier is dominated by any offered point
        for p in a.points() {
            for (_, ov) in &points {
                assert!(!ov.dominates(&p.objectives));
            }
        }
    }

    #[test]
    fn objectives_roundtrip_json() {
        let ov = o(0.8125, 0.7, 12345);
        let back = Objectives::from_value(&Value::parse(&ov.to_value().to_json()).unwrap()).unwrap();
        assert_eq!(ov, back);
    }
}
