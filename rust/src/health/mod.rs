//! Serving-side self-healing: canary-probe damage detection, spare-slot
//! quarantine, background repair, and hot artifact swap.
//!
//! Real ReRAM keeps degrading *after* the crossbars are programmed:
//! conductance drift and stuck-at faults accumulate while the device
//! serves. The `faults` engine models that with
//! [`crate::faults::EvolutionSpec`] — a logical-clock time axis where one
//! tick is one served batch — and this module closes the loop from
//! detection to repair:
//!
//! 1. **Detect** — the artifact reserves known-answer *canary* strips per
//!    layer ([`crate::backend::programmed::CanaryStrip`]). A probe replays
//!    each canary's fault-free expected codes through the spec evolved to
//!    the current tick ([`probe_canaries`]) and compares against the codes
//!    the device was actually programmed with: the fault streams are
//!    deterministic per (seed, layer, slot, site), so at the programmed
//!    tick the replay matches bit for bit, and any mismatch is exactly the
//!    runtime degradation since programming.
//! 2. **Quarantine + repair** — on detection, a standby artifact is
//!    re-programmed in the background at the *current* tick. Programming
//!    re-ranks every candidate slot (natural + reserved spares) by
//!    [`crate::faults::slot_damage`] under the evolved spec and re-runs
//!    sensitivity-aware placement ([`crate::faults::assign_slots_spares`]),
//!    so high-sensitivity strips migrate off damaged slots onto spares and
//!    the most damaged slots are left unused. [`repair_diff`] reports the
//!    migration as typed counters: strips that moved (`repairs`) and slots
//!    vacated (`quarantined`).
//! 3. **Swap** — the engine worker installs the standby artifact at a
//!    batch boundary (`ExecBackend::health_step`), so the steady-state
//!    forward walk stays read-only and zero-alloc between swaps.
//!
//! The monitor runs *between* batches on the worker thread (probing is
//! O(canaries × depth), far from the request path) and the re-programming
//! pass runs on a spawned background thread, so serving never blocks on
//! repair. Health counters flow into `Metrics`, the serve stats frames,
//! and `trace` spans (`health.probe`, `health.reprogram`).

use std::collections::HashSet;

use crate::backend::programmed::ProgrammedModel;
use crate::faults::{self, ScenarioSpec};

/// Outcome of one health-monitor step, folded into
/// [`crate::coordinator::Metrics`] by the engine worker.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepReport {
    /// Logical tick (served-batch count) the step ran at.
    pub tick: u64,
    /// Canary strips probed (0 when the artifact reserves none).
    pub probes: u64,
    /// Canary code lanes whose evolved replay disagrees with the
    /// programmed state — the damage signal.
    pub canary_mismatches: u64,
    /// Physical slots vacated by a completed repair (only on swap).
    pub quarantined: u64,
    /// Strips whose physical slot changed in a completed repair (only on
    /// swap).
    pub repairs: u64,
    /// A standby artifact finished programming and was hot-swapped in.
    pub swapped: bool,
    /// A standby re-programming pass was started in the background.
    pub reprogram_started: bool,
}

/// Replay every canary strip of `prog` through `spec` (the fault spec
/// evolved to the probe tick) and compare against the programmed state.
/// Returns `(probes, mismatched lanes)`. Zero mismatches at the artifact's
/// own programmed tick is a determinism invariant: the per-site fault
/// streams are pure functions of (seed, layer, slot, site).
pub fn probe_canaries(prog: &ProgrammedModel, spec: &ScenarioSpec) -> (u64, u64) {
    let mut probes = 0u64;
    let mut mismatches = 0u64;
    for pl in &prog.layers {
        for c in &pl.canaries {
            probes += 1;
            let mut codes = c.expected.clone();
            let mut sw = 1.0f32;
            faults::apply_to_strip(
                spec,
                pl.index,
                c.slot as usize,
                pl.nslots_ext,
                prog.cell_bits,
                c.ncells,
                &mut codes,
                &mut sw,
            );
            mismatches +=
                codes.iter().zip(&c.programmed).filter(|(a, b)| a != b).count() as u64;
        }
    }
    (probes, mismatches)
}

/// Diff the strip→slot assignment of two artifacts programmed from the
/// same `(model, theta, strips)` tuple: `repairs` strips moved to a new
/// physical slot, and `quarantined` slots used by `old` are vacated in
/// `new`. Strip order is deterministic (channel-major, kernel-tap
/// ascending) and independent of placement, so the positional diff is
/// exact.
pub fn repair_diff(old: &ProgrammedModel, new: &ProgrammedModel) -> (u64, u64) {
    let mut repairs = 0u64;
    let mut quarantined = 0u64;
    for (ol, nl) in old.layers.iter().zip(&new.layers) {
        for (os, ns) in ol.strips.iter().zip(&nl.strips) {
            if os.slot != ns.slot {
                repairs += 1;
            }
        }
        let mut vacated: HashSet<u32> = ol.strips.iter().map(|s| s.slot).collect();
        for ns in &nl.strips {
            vacated.remove(&ns.slot);
        }
        quarantined += vacated.len() as u64;
    }
    (repairs, quarantined)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::programmed::{CanaryStrip, ProgrammedLayer};
    use crate::backend::{ExecMode, ProgrammedModel, ProgrammedStrip, StripStore};

    fn strip(slot: u32) -> ProgrammedStrip {
        ProgrammedStrip { g: 0, sw: 1.0, slot, store: StripStore::Exact { codes: vec![1] } }
    }

    fn model_with(slots: &[u32], canaries: Vec<CanaryStrip>) -> ProgrammedModel {
        ProgrammedModel {
            mode: ExecMode::Exact,
            layers: vec![ProgrammedLayer {
                index: 0,
                d: 4,
                n: slots.len(),
                kk: 1,
                strips: slots.iter().map(|&s| strip(s)).collect(),
                chan: slots.iter().enumerate().map(|(i, _)| (i as u32, 1)).collect(),
                segs: vec![(0, 4, 0)],
                total_words: 1,
                nslots_ext: slots.len() + 2 + canaries.len(),
                canaries,
            }],
            live_strips: slots.len(),
            dropped_strips: 0,
            planes_bytes: 0,
            program_ns: 1,
            scenario: None,
            cell_bits: 2,
            tick: 0,
            health: faults::HealthSpec { canaries: 0, spares: 2 },
        }
    }

    #[test]
    fn repair_diff_counts_moves_and_vacated_slots() {
        let old = model_with(&[0, 1, 2], vec![]);
        let same = model_with(&[0, 1, 2], vec![]);
        assert_eq!(repair_diff(&old, &same), (0, 0));
        // Strip 1 moved to spare slot 4; slot 1 is vacated (quarantined).
        let new = model_with(&[0, 4, 2], vec![]);
        assert_eq!(repair_diff(&old, &new), (1, 1));
        // Two strips swap slots: two repairs, nothing vacated.
        let swapped = model_with(&[1, 0, 2], vec![]);
        assert_eq!(repair_diff(&old, &swapped), (2, 0));
    }

    #[test]
    fn probe_matches_at_programmed_tick_and_detects_evolution() {
        let spec = ScenarioSpec::default().with_stuck(0.4, 9).with_evolution(0.0, 0.01);
        let t0 = spec.at_tick(0);
        let expected: Vec<i32> = (0..4).map(|i| i * 3 - 5).collect();
        let mut programmed = expected.clone();
        let mut sw = 1.0f32;
        faults::apply_to_strip(&t0, 0, 5, 6, 2, 2, &mut programmed, &mut sw);
        let canary =
            CanaryStrip { slot: 5, ncells: 2, expected: expected.clone(), programmed, sw };
        let prog = model_with(&[0, 1, 2], vec![canary]);
        // Replay at the programmed tick: bit-identical, zero mismatches.
        assert_eq!(probe_canaries(&prog, &t0), (1, 0));
        // Far enough in the future the stuck-at rate saturates and the
        // canary pattern cannot survive unchanged.
        let late = spec.at_tick(1_000_000);
        let (probes, mism) = probe_canaries(&prog, &late);
        assert_eq!(probes, 1);
        assert!(mism > 0, "saturated stuck-at must perturb the canary");
    }
}
