//! # reram-mpq
//!
//! Sensitivity-aware mixed-precision quantization framework for ReRAM-based
//! computing-in-memory — a reproduction of Chen et al. (CS.AR 2025) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! ## The staged `CompressionPlan` builder
//!
//! The paper's Figure-4 dataflow — sensitivity → FIM threshold → clustering
//! + crossbar alignment → quantization → mapping → cost/accuracy — is
//! exposed as a typed, staged builder. Stages are composable (swap one,
//! keep the rest), and their artifacts are memoized in a cache shared by
//! every plan cloned from the same root, so exploring many operating points
//! recomputes only what changed:
//!
//! ```no_run
//! use reram_mpq::coordinator::{CompressionPlan, EvalOpts, ThresholdMode};
//! use reram_mpq::xbar::MappingStrategy;
//!
//! # fn main() -> reram_mpq::Result<()> {
//! let dir = reram_mpq::artifacts_dir();
//! let manifest = reram_mpq::Manifest::load(&dir)?;
//! let runtime = reram_mpq::Runtime::new(dir)?;
//!
//! let plan = CompressionPlan::for_model(&runtime, &manifest, "resnet20")?
//!     .threshold(ThresholdMode::FixedCr(0.7))   // or Alg1 / Sweep
//!     .cluster()
//!     .align_to_capacity()                      // paper §4.2 alignment
//!     .map(MappingStrategy::Packed);
//!
//! // Offline terminal: accuracy + hardware cost (tables/figures).
//! let report = plan.evaluate(EvalOpts::batches(4))?;
//! println!("top-1 {:.2}%", report.accuracy.top1 * 100.0);
//!
//! // Online terminal: the same stages feed the serving engine.
//! let handle = plan.deploy(Default::default())?;
//! let prediction = handle.classify(vec![0.0; 32 * 32 * 3])?;
//! # let _ = prediction;
//!
//! // A clone shares the stage cache: only the changed suffix recomputes.
//! let sweep = plan.clone().threshold(ThresholdMode::Sweep);
//! let _ = sweep.evaluate(EvalOpts::batches(4))?;
//! # Ok(()) }
//! ```
//!
//! Baselines are just another bit-allocation stage: inject an explicit
//! bitmap with `bitmap_from` (e.g. `baselines::hap_bitmap`) and reuse the
//! same quantize/map/evaluate/deploy tail.
//!
//! ## Execution backends
//!
//! Every forward pass — the accuracy evaluator, the serving engine, the
//! parity tests — runs on an [`backend::ExecBackend`]. Two implementations
//! ship, selected per plan root (`CompressionPlan::for_model_on`), per
//! terminal (`evaluate_on`/`deploy_on`), or on the CLI via `--backend`:
//!
//! | backend | substrate | fidelity | requires |
//! |---------|-----------|----------|----------|
//! | `pjrt`  | AOT-compiled HLO through PJRT | training-parity f32 MACs on fake-quantized weights | `make artifacts` (manifest + HLO + XLA) |
//! | `sim`   | [`backend::SimXbar`] native bit-serial crossbar simulator | per-strip cell slicing, input-bit phases, optional ADC quantization + seeded conductance noise; exact f32 for non-conv ops | nothing — runs anywhere |
//!
//! The simulator consumes the same quantization artifacts the mapper does
//! (per-strip bits + scales), so the evaluate/deploy pipeline is exercised
//! end to end on machines with no artifacts at all; [`fixture`] provides
//! fully in-memory models/datasets for exactly that. With ideal converters
//! the bit-serial decomposition is algebraically exact (property-tested
//! against a reference f32 conv); with `adc_bits`/`noise_sigma` set it
//! models the converter rounding and device variation the paper's §1 cites.
//!
//! ## Layers
//!
//! The Rust layer (this crate) is the paper's framework itself plus every
//! substrate it depends on:
//!
//! * [`backend`] — pluggable execution backends: the `ExecBackend` trait,
//!   the native bit-serial crossbar simulator (`SimXbar`) and the native
//!   ResNet graph it runs on.
//! * [`runtime`] — PJRT client wrapper: loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them on the
//!   request path (Python never runs at inference time).
//! * [`fixture`] — synthetic in-memory models/datasets for the hermetic
//!   (artifact-free) test suite and simulator demos.
//! * [`tensor`] — minimal dense tensor + binary artifact IO.
//! * [`model`] — manifest contract: parameter layout, conv layers, strips.
//! * [`dataset`] — CIFAR-Syn test/calibration data loading + batching.
//! * [`quant`] — symmetric int4/int8 strip quantizers, device-variation
//!   model, packing (paper §4.1/§4.3).
//! * [`sensitivity`] — Hutchinson Hessian-diagonal driver → per-strip
//!   sensitivity scores (paper §4.1).
//! * [`faults`] — device-variability scenario engine: composable drift /
//!   stuck-at / IR-drop / read-noise fault injection on programmed
//!   crossbars, runtime fault evolution on a logical serving clock, plus
//!   sensitivity-aware strip placement over natural + spare slots.
//! * [`health`] — serving-side self-healing: canary-probe damage
//!   detection, spare-slot quarantine, background repair programming, and
//!   hot artifact swap at batch boundaries.
//! * [`fim`] — empirical Fisher diagonal + Algorithm 1 threshold search
//!   (paper §4.2).
//! * [`clustering`] — sensitivity clustering and the dynamic crossbar-
//!   capacity alignment (paper §4.2).
//! * [`xbar`] — NeuroSim-lite ReRAM crossbar simulator: arrays, ADC/DAC
//!   energy, latency, mapping, utilization (substrate for §5).
//! * [`coordinator`] — the execution engine: the staged `CompressionPlan`
//!   builder and its stage cache, request batching, accuracy evaluation,
//!   stepwise mixed-precision accumulation (paper §4.3).
//! * [`serve`] — the network serving front-end: length-prefixed binary
//!   wire protocol, TCP server with per-connection threads, dynamic
//!   micro-batching with bounded-queue admission control, plain-text and
//!   machine-readable JSON stats frames, and the load-generating client
//!   behind `bench-client`.
//! * [`tuner`] — parallel Pareto auto-tuner over the stage cache: fans
//!   candidate operating points across worker threads, maintains a
//!   3-objective accuracy/compression/storage frontier, and writes
//!   resumable JSON search state (`reram-mpq tune`).
//! * [`trace`] — request-lifecycle tracing: a std-only, default-off span
//!   recorder (thread-local buffers + mpsc drain, one shared monotonic
//!   epoch) exporting Chrome-trace JSON (Perfetto-loadable) and a per-span
//!   summary table (`--trace-out`, `RERAM_MPQ_TRACE`).
//! * [`baselines`] — HAP structured pruning and uniform-precision
//!   comparators used by the paper's tables.
//! * [`report`] — emitters that regenerate the paper's tables/figures.
//!
//! A narrative layer map — staged plan → backends/programmed artifacts →
//! sharded engine → serve front-end → faults → tuner, with the data-flow
//! of one request and one tuning run — lives in `docs/ARCHITECTURE.md`.

pub mod backend;
pub mod baselines;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod experiments;
pub mod faults;
pub mod fim;
pub mod fixture;
pub mod health;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sensitivity;
pub mod serve;
pub mod tensor;
pub mod trace;
pub mod tuner;
pub mod util;
pub mod xbar;

pub use backend::{ExecBackend, SimXbar, SimXbarConfig, SimdMode, WalkProfile};
pub use config::RunConfig;
pub use coordinator::{CompressionPlan, EvalOpts, Executor, PipelineReport, ThresholdMode};
pub use model::{Manifest, ModelInfo};
pub use runtime::Runtime;
pub use tensor::Tensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Resolve the artifacts directory: `$RERAM_MPQ_ARTIFACTS` or ./artifacts,
/// walking up from the current dir so examples/benches work from anywhere.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("RERAM_MPQ_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(DEFAULT_ARTIFACTS);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return DEFAULT_ARTIFACTS.into();
        }
    }
}
