//! Staged `CompressionPlan` builder — the paper's Figure-4 dataflow as a
//! composable, cacheable pipeline (sensitivity → FIM threshold → clustering /
//! alignment → quantization → crossbar mapping → evaluate / deploy).
//!
//! Each stage produces an owned, inspectable artifact that is memoized in a
//! [`StageCache`] shared by every plan cloned from the same
//! [`CompressionPlan::for_model`] root: two plans that share a stage prefix
//! share the computed prefix (the Hutchinson analyzer runs once, however
//! many operating points are explored). Swapping *one* stage — a different
//! bit-allocation policy, mapper, or threshold rule — is a one-line change
//! that invalidates exactly the downstream stages and nothing else.
//!
//! ```no_run
//! # use reram_mpq::coordinator::{CompressionPlan, EvalOpts, ThresholdMode};
//! # use reram_mpq::xbar::MappingStrategy;
//! # fn main() -> reram_mpq::Result<()> {
//! # let dir = reram_mpq::artifacts_dir();
//! # let manifest = reram_mpq::Manifest::load(&dir)?;
//! # let runtime = reram_mpq::Runtime::new(dir)?;
//! let plan = CompressionPlan::for_model(&runtime, &manifest, "resnet20")?
//!     .threshold(ThresholdMode::FixedCr(0.7))
//!     .cluster()
//!     .align_to_capacity()
//!     .map(MappingStrategy::Packed);
//! let report = plan.evaluate(EvalOpts::batches(4))?;   // offline: tables/figures
//! let handle = plan.deploy(Default::default())?;       // online: serving engine
//! # Ok(()) }
//! ```
//!
//! Baselines are just another bit-allocation stage: an explicit [`BitMap`]
//! (e.g. HAP pruning) enters the plan through [`CompressionPlan::bitmap_from`]
//! and flows through the same quantize/map/evaluate/deploy tail.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use crate::backend::{SimXbar, SimXbarConfig, StripPrecision};
use crate::clustering::{self, Clustering};
use crate::config::{QuantConfig, RunConfig, SensitivityConfig};
use crate::coordinator::engine::{BackendSpec, Engine, EngineConfig, EngineHandle};
use crate::coordinator::eval;
use crate::coordinator::pipeline::{PipelineReport, ThresholdMode};
use crate::dataset::{CalibSet, TestSet};
use crate::faults::{HealthSpec, Placement, Scenario, ScenarioSpec};
use crate::fim::ThresholdSearch;
use crate::model::{Manifest, ModelInfo};
use crate::quant::{self, BitMap, QuantizedModel};
use crate::runtime::Runtime;
use crate::sensitivity::{self, Analyzer, Sensitivity};
use crate::util::json::{obj, Value};
use crate::xbar::{self, MappingStrategy, ModelMapping};
use crate::Result;

/// Which execution substrate a plan's forward passes run on.
///
/// * `Pjrt` — the AOT-compiled HLO artifacts through the PJRT runtime
///   (training-parity numerics; requires `make artifacts`). Sensitivity and
///   FIM search also need this backend (they drive the `hvp`/`gsq` graphs).
/// * `Sim` — the native bit-serial crossbar simulator
///   ([`crate::backend::SimXbar`]): no artifacts, no XLA. Sensitivity falls
///   back to the magnitude proxy and the FIM search modes are unavailable,
///   but the whole quantize → map → evaluate → deploy tail runs anywhere.
#[derive(Clone, Copy)]
pub enum Executor<'a> {
    Pjrt(&'a Runtime),
    Sim(SimXbarConfig),
}

impl Executor<'_> {
    /// Stable tag used in logs and error messages.
    pub fn name(&self) -> &'static str {
        match self {
            Executor::Pjrt(_) => "pjrt",
            Executor::Sim(_) => "sim",
        }
    }

    /// Full cache-key tag: two sim evaluations with different fidelity knobs
    /// (ADC resolution, noise sigma/seed, geometry) are different artifacts
    /// and must never alias in the stage cache. The execution-strategy knobs
    /// (`threads`, `scalar_lanes`) are deliberately excluded: they are
    /// bit-identical by construction, so they *should* alias.
    fn cache_tag(&self) -> String {
        match self {
            Executor::Pjrt(_) => "pjrt".into(),
            Executor::Sim(c) => format!(
                "sim:r{}c{}i{}a{}n{}s{}p{}",
                c.rows,
                c.cell_bits,
                c.input_bits,
                c.adc_bits,
                c.noise_sigma,
                c.seed,
                c.force_phase_loop as u8
            ),
        }
    }
}

/// Candidate quantiles swept by [`ThresholdMode::Sweep`] (paper §5).
pub const SWEEP_CANDIDATES: &[f64] = &[0.0, 0.1, 0.3, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0];
/// FIM/energy trade-off weight of the sweep's joint objective.
pub const SWEEP_LAMBDA: f64 = 0.5;

/// Per-strip Hutchinson sensitivity artifact, shared without cloning the
/// score vectors.
pub type SensitivityScores = Arc<Sensitivity>;

/// The threshold-stage artifact: which operating point was chosen and what
/// it cost to find it.
#[derive(Clone, Debug)]
pub struct ChosenThreshold {
    pub mode: ThresholdMode,
    /// Fraction of strips assigned to the low tier (quantile of the score
    /// distribution).
    pub quantile: f64,
    /// Score-space threshold of the winning candidate (NaN when the mode
    /// fixes the quantile directly and no search ran).
    pub threshold: f64,
    /// FIM evaluations spent by the search (0 for `FixedCr`).
    pub fim_evals: usize,
}

impl ChosenThreshold {
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("mode", self.mode.to_value()),
            ("quantile", Value::Num(self.quantile)),
            ("threshold", Value::num_or_null(self.threshold)),
            ("fim_evals", Value::Num(self.fim_evals as f64)),
        ])
    }
}

/// Per-stage cache counters — the memoization contract is observable, not
/// just an implementation detail. `*_runs` counts stage computations that
/// actually ran (cache misses); `*_hits` counts lookups served from the
/// shared cache. The auto-tuner ([`crate::tuner`]) sums these across its
/// workers to report how much of the expensive sensitivity prefix was
/// reused rather than recomputed.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Sensitivity-stage computations (Hutchinson / magnitude proxy).
    pub sensitivity_runs: usize,
    /// Threshold-stage computations (FIM search or fixed-CR constant).
    pub threshold_runs: usize,
    /// Clustering-stage computations (± capacity alignment).
    pub clustering_runs: usize,
    /// Quantization-stage computations.
    pub quantize_runs: usize,
    /// Mapping-stage computations.
    pub mapping_runs: usize,
    /// Evaluation-terminal computations.
    pub eval_runs: usize,
    /// Sensitivity-stage cache hits.
    pub sensitivity_hits: usize,
    /// Threshold-stage cache hits.
    pub threshold_hits: usize,
    /// Clustering-stage cache hits.
    pub clustering_hits: usize,
    /// Quantization-stage cache hits.
    pub quantize_hits: usize,
    /// Mapping-stage cache hits.
    pub mapping_hits: usize,
    /// Evaluation-terminal cache hits.
    pub eval_hits: usize,
}

impl CacheStats {
    /// Hits on the expensive shared prefix (sensitivity + threshold +
    /// clustering) — the stages the staged-plan design exists to amortize
    /// across operating points.
    pub fn prefix_hits(&self) -> usize {
        self.sensitivity_hits + self.threshold_hits + self.clustering_hits
    }

    /// Total cache hits across every stage.
    pub fn total_hits(&self) -> usize {
        self.prefix_hits() + self.quantize_hits + self.mapping_hits + self.eval_hits
    }

    /// Total stage computations (cache misses) across every stage.
    pub fn total_runs(&self) -> usize {
        self.sensitivity_runs
            + self.threshold_runs
            + self.clustering_runs
            + self.quantize_runs
            + self.mapping_runs
            + self.eval_runs
    }

    /// Fold another counter set into this one (the tuner aggregates the
    /// per-worker plan caches this way).
    pub fn absorb(&mut self, other: &CacheStats) {
        self.sensitivity_runs += other.sensitivity_runs;
        self.threshold_runs += other.threshold_runs;
        self.clustering_runs += other.clustering_runs;
        self.quantize_runs += other.quantize_runs;
        self.mapping_runs += other.mapping_runs;
        self.eval_runs += other.eval_runs;
        self.sensitivity_hits += other.sensitivity_hits;
        self.threshold_hits += other.threshold_hits;
        self.clustering_hits += other.clustering_hits;
        self.quantize_hits += other.quantize_hits;
        self.mapping_hits += other.mapping_hits;
        self.eval_hits += other.eval_hits;
    }

    /// JSON summary (`runs` / `hits` totals plus `prefix_hits` and the
    /// per-stage sensitivity counters the tune smoke asserts on).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("runs", Value::Num(self.total_runs() as f64)),
            ("hits", Value::Num(self.total_hits() as f64)),
            ("prefix_hits", Value::Num(self.prefix_hits() as f64)),
            ("sensitivity_runs", Value::Num(self.sensitivity_runs as f64)),
            ("sensitivity_hits", Value::Num(self.sensitivity_hits as f64)),
        ])
    }
}

/// Memoized stage artifacts, keyed by the exact stage configuration that
/// produced them. Shared (via `Rc`) across all plans cloned from one root.
#[derive(Default)]
pub struct StageCache {
    sensitivity: RefCell<HashMap<String, Arc<Sensitivity>>>,
    thresholds: RefCell<HashMap<String, Arc<ChosenThreshold>>>,
    clusterings: RefCell<HashMap<String, Arc<Clustering>>>,
    quantized: RefCell<HashMap<String, Arc<QuantizedModel>>>,
    mappings: RefCell<HashMap<String, Arc<ModelMapping>>>,
    reports: RefCell<HashMap<String, Arc<PipelineReport>>>,
    stats: Cell<CacheStats>,
}

impl StageCache {
    pub fn stats(&self) -> CacheStats {
        self.stats.get()
    }

    fn bump(&self, f: impl FnOnce(&mut CacheStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }
}

/// Look up `key`, computing and inserting on a miss. Returns the artifact
/// and whether it was freshly computed. The map borrow is released before
/// `compute` runs, so stages may recursively resolve their inputs.
fn memo<T>(
    map: &RefCell<HashMap<String, Arc<T>>>,
    key: &str,
    compute: impl FnOnce() -> Result<T>,
) -> Result<(Arc<T>, bool)> {
    if let Some(v) = map.borrow().get(key) {
        return Ok((v.clone(), false));
    }
    let v = Arc::new(compute()?);
    map.borrow_mut().insert(key.to_string(), v.clone());
    Ok((v, true))
}

fn fnv64(bytes: impl Iterator<Item = u8>) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Loaded per-model state shared by every plan cloned from one root: the
/// fp32 checkpoint, the test/calibration splits and the execution backend.
pub struct ModelState<'a> {
    pub exec: Executor<'a>,
    pub model: ModelInfo,
    pub theta: Vec<f32>,
    pub test: TestSet,
    pub calib: CalibSet,
}

#[derive(Clone)]
struct ExplicitBitmap {
    bitmap: Arc<BitMap>,
    key: String,
}

/// Evaluation options for the [`CompressionPlan::evaluate`] terminal.
#[derive(Clone, Copy, Debug)]
pub struct EvalOpts {
    /// Number of test batches (full test set by default; sweeps and benches
    /// shrink this for iteration speed).
    pub eval_batches: usize,
}

impl Default for EvalOpts {
    fn default() -> Self {
        Self { eval_batches: usize::MAX }
    }
}

impl EvalOpts {
    /// Evaluate the full test set.
    pub fn full() -> Self {
        Self::default()
    }

    /// Evaluate at most `n` test batches.
    pub fn batches(n: usize) -> Self {
        Self { eval_batches: n }
    }
}

/// A staged compression plan over one loaded model. Cheap to clone; clones
/// share the loaded state and the stage cache, so exploring many operating
/// points recomputes only the stages that differ.
#[derive(Clone)]
pub struct CompressionPlan<'a> {
    state: Rc<ModelState<'a>>,
    cache: Rc<StageCache>,
    cfg: RunConfig,
    threshold_mode: ThresholdMode,
    align: bool,
    strategy: MappingStrategy,
    explicit: Option<ExplicitBitmap>,
    nominal: Option<ThresholdMode>,
    scenario: Option<(ScenarioSpec, Placement)>,
    health: HealthSpec,
}

impl<'a> CompressionPlan<'a> {
    /// Load `model_name` with the default [`RunConfig`] and return the plan
    /// root. Clone the result to fork plans that share the stage cache.
    pub fn for_model(
        runtime: &'a Runtime,
        manifest: &'a Manifest,
        model_name: &str,
    ) -> Result<Self> {
        Self::for_model_with(runtime, manifest, model_name, RunConfig::default())
    }

    /// Load `model_name` with an explicit configuration.
    pub fn for_model_with(
        runtime: &'a Runtime,
        manifest: &Manifest,
        model_name: &str,
        cfg: RunConfig,
    ) -> Result<Self> {
        Self::for_model_on(Executor::Pjrt(runtime), manifest, model_name, cfg)
    }

    /// Load `model_name` onto an explicit execution backend. The simulator
    /// backend needs only the manifest's data artifacts (parameters +
    /// dataset bins), never the compiled HLO.
    pub fn for_model_on(
        exec: Executor<'a>,
        manifest: &Manifest,
        model_name: &str,
        cfg: RunConfig,
    ) -> Result<Self> {
        let model = manifest.model(model_name)?;
        let theta = model.load_params(manifest)?;
        let test = TestSet::load(manifest)?;
        let calib = CalibSet::load(manifest, model.entry.batch.calib)?;
        Ok(Self::from_state(
            ModelState { exec, model, theta, test, calib },
            cfg,
        ))
    }

    /// Root a plan on already-loaded state — the hermetic entrypoint used by
    /// in-memory fixtures ([`crate::fixture`]), where no manifest exists on
    /// disk at all.
    pub fn from_state(state: ModelState<'a>, cfg: RunConfig) -> Self {
        Self {
            state: Rc::new(state),
            cache: Rc::new(StageCache::default()),
            cfg,
            threshold_mode: ThresholdMode::Sweep,
            align: false,
            strategy: MappingStrategy::Packed,
            explicit: None,
            nominal: None,
            scenario: None,
            health: HealthSpec::default(),
        }
    }

    /// The PJRT runtime behind this plan, for stages that can only run on
    /// the AOT artifacts (Hutchinson HVP, FIM search).
    fn pjrt_runtime(&self) -> Result<&'a Runtime> {
        match self.state.exec {
            Executor::Pjrt(rt) => Ok(rt),
            Executor::Sim(_) => anyhow::bail!(
                "this stage drives the AOT hvp/gsq executables and requires the pjrt backend \
                 (the sim backend supports FixedCr thresholds with proxy sensitivity)"
            ),
        }
    }

    // ---- stage builders ---------------------------------------------------

    /// Replace the whole run configuration (keeps the loaded state + cache).
    pub fn with_config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Configure the Hutchinson sensitivity stage.
    pub fn sensitivity(mut self, cfg: SensitivityConfig) -> Self {
        self.cfg.sensitivity = cfg;
        self
    }

    /// Choose how the operating threshold is picked (default: `Sweep`).
    pub fn threshold(mut self, mode: ThresholdMode) -> Self {
        self.threshold_mode = mode;
        self
    }

    /// Fluent marker for the clustering stage (clustering is implied by the
    /// threshold stage; this names it in the chain for readability).
    pub fn cluster(self) -> Self {
        self
    }

    /// Enable the paper's dynamic crossbar-capacity alignment (§4.2):
    /// per layer, demote the lowest-score high-bit strips until the hi count
    /// is a multiple of the array capacity.
    pub fn align_to_capacity(mut self) -> Self {
        self.align = true;
        self
    }

    /// Configure the mixed-precision quantization stage.
    pub fn quantize(mut self, cfg: QuantConfig) -> Self {
        self.cfg.quant = cfg;
        self
    }

    /// Choose the strip-to-crossbar mapping strategy (default: `Packed`).
    pub fn map(mut self, strategy: MappingStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Bypass sensitivity/threshold/clustering with an explicit per-strip
    /// bit allocation — baselines (HAP pruning, uniform precision) become
    /// just another bit-allocation stage feeding the same tail.
    pub fn bitmap_from(mut self, bitmap: BitMap) -> Self {
        let key = format!(
            "bm:{:016x}:{}",
            fnv64(bitmap.bits.iter().copied()),
            bitmap.bits.len()
        );
        self.explicit = Some(ExplicitBitmap { bitmap: Arc::new(bitmap), key });
        self
    }

    /// Label the report with a nominal operating point (e.g. the requested
    /// compression ratio of an explicit baseline bitmap).
    pub fn nominal(mut self, mode: ThresholdMode) -> Self {
        self.nominal = Some(mode);
        self
    }

    /// Attach a device-variability fault scenario (and its strip-placement
    /// policy) to the simulator terminals. Inactive (all-zero) specs are
    /// dropped. Faults apply when a worker programs its crossbars, so only
    /// `Executor::Sim` evaluations/deployments see them; the PJRT backend
    /// has no programmed device to fault and ignores the scenario.
    pub fn with_scenario(mut self, spec: ScenarioSpec, placement: Placement) -> Self {
        self.scenario = if spec.is_active() { Some((spec, placement)) } else { None };
        self
    }

    /// Reserve per-layer health machinery — known-answer canary strips and
    /// spare column slots — when the simulator programs its crossbars (see
    /// [`crate::health`]). Works with or without an attached fault
    /// scenario: canaries on a healthy device simply read back clean, and
    /// with zero reservations this is a no-op.
    pub fn with_health(mut self, health: HealthSpec) -> Self {
        self.health = health;
        self
    }

    // ---- loaded-state accessors -------------------------------------------

    pub fn model(&self) -> &ModelInfo {
        &self.state.model
    }

    pub fn theta(&self) -> &[f32] {
        &self.state.theta
    }

    pub fn test(&self) -> &TestSet {
        &self.state.test
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Per-stage run (miss) and hit counters for the shared stage cache
    /// (memoization is part of the API contract — see the builder tests;
    /// the tuner reports [`CacheStats::prefix_hits`] across its workers).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    // ---- stage cache keys ---------------------------------------------------

    fn sens_key(&self) -> String {
        let s = self.cfg.sensitivity;
        // The backend is part of the key: the sim backend's magnitude proxy
        // and the pjrt Hutchinson estimate are different artifacts.
        format!("sens:{}:{}:{}:{}", self.state.exec.name(), s.probes, s.calib_batches, s.seed)
    }

    fn quant_part(&self) -> String {
        let q = self.cfg.quant;
        format!(
            "q:{}{:?}/{}{:?}:sg{}:sd{}",
            q.hi.bits, q.hi.granularity, q.lo.bits, q.lo.granularity, q.device_sigma, q.seed
        )
    }

    fn threshold_key(&self) -> String {
        let t = self.cfg.threshold;
        let mode = match self.threshold_mode {
            ThresholdMode::Alg1 => "alg1".to_string(),
            ThresholdMode::Sweep => "sweep".to_string(),
            ThresholdMode::FixedCr(c) => format!("cr{c}"),
        };
        format!(
            "{}|thr:{}:{}:{}:{}:{}:{}:{}|{}",
            self.sens_key(),
            mode,
            t.t0_quantile,
            t.learning_rate,
            t.tolerance,
            t.max_iters,
            t.fd_step,
            t.calib_batches,
            self.quant_part()
        )
    }

    fn cluster_key(&self) -> String {
        // The crossbar geometry only shapes the clustering when alignment is
        // on; unaligned clusterings are geometry-independent and shared
        // across geometry sweeps (crossbar_explorer, table4's ORIGIN rows).
        if self.align {
            let x = self.cfg.xbar;
            format!(
                "{}|cl:align:r{}c{}cb{}",
                self.threshold_key(),
                x.rows,
                x.cols,
                x.cell_bits
            )
        } else {
            format!("{}|cl:raw", self.threshold_key())
        }
    }

    fn bitmap_key(&self) -> String {
        match &self.explicit {
            Some(e) => e.key.clone(),
            None => self.cluster_key(),
        }
    }

    fn quant_key(&self) -> String {
        format!("{}|{}", self.bitmap_key(), self.quant_part())
    }

    fn map_key(&self) -> String {
        let x = self.cfg.xbar;
        format!(
            "{}|map:{:?}:r{}c{}cb{}",
            self.bitmap_key(),
            self.strategy,
            x.rows,
            x.cols,
            x.cell_bits
        )
    }

    // ---- stage artifacts ----------------------------------------------------

    /// Per-strip sensitivity scores (paper §4.1). Computed once per
    /// configuration across every plan sharing this cache. On the PJRT
    /// backend this is the Hutchinson Hessian estimate through the `hvp`
    /// executable; on the simulator backend it falls back to the
    /// artifact-free magnitude proxy.
    pub fn sensitivity_scores(&self) -> Result<SensitivityScores> {
        let key = self.sens_key();
        let (v, fresh) = memo(&self.cache.sensitivity, &key, || {
            let st = &self.state;
            match st.exec {
                Executor::Pjrt(runtime) => {
                    crate::info!(
                        "hutchinson sensitivity: model={} probes={}",
                        st.model.name(),
                        self.cfg.sensitivity.probes
                    );
                    let analyzer = Analyzer {
                        runtime,
                        model: &st.model,
                        calib: &st.calib,
                        cfg: self.cfg.sensitivity,
                    };
                    analyzer.run(&st.theta)
                }
                Executor::Sim(_) => {
                    crate::info!(
                        "magnitude-proxy sensitivity (sim backend): model={}",
                        st.model.name()
                    );
                    Ok(sensitivity::magnitude_proxy(&st.model, &st.theta))
                }
            }
        })?;
        if fresh {
            self.cache.bump(|s| s.sensitivity_runs += 1);
        } else {
            self.cache.bump(|s| s.sensitivity_hits += 1);
        }
        Ok(v)
    }

    /// The threshold-stage decision (paper §4.2, Algorithm 1 / §5 sweep).
    pub fn chosen_threshold(&self) -> Result<Arc<ChosenThreshold>> {
        anyhow::ensure!(
            self.explicit.is_none(),
            "plan uses an explicit bitmap; it has no threshold stage"
        );
        let key = self.threshold_key();
        let (v, fresh) = memo(&self.cache.thresholds, &key, || {
            match self.threshold_mode {
                ThresholdMode::FixedCr(cr) => Ok(ChosenThreshold {
                    mode: self.threshold_mode,
                    quantile: cr,
                    threshold: f64::NAN,
                    fim_evals: 0,
                }),
                ThresholdMode::Alg1 | ThresholdMode::Sweep => {
                    let runtime = self.pjrt_runtime()?;
                    let sens = self.sensitivity_scores()?;
                    let st = &self.state;
                    let search = ThresholdSearch {
                        runtime,
                        model: &st.model,
                        calib: &st.calib,
                        sens: sens.as_ref(),
                        quant_cfg: self.cfg.quant,
                        cfg: self.cfg.threshold,
                    };
                    let res = if self.threshold_mode == ThresholdMode::Alg1 {
                        search.gradient_descent(&st.theta)?
                    } else {
                        search.sweep(&st.theta, SWEEP_CANDIDATES, SWEEP_LAMBDA)?
                    };
                    crate::info!(
                        "threshold chosen: q={:.3} fim={:.4e}",
                        res.best.quantile,
                        res.best.fim_dist
                    );
                    Ok(ChosenThreshold {
                        mode: self.threshold_mode,
                        quantile: res.best.quantile,
                        threshold: res.best.threshold,
                        fim_evals: res.evals,
                    })
                }
            }
        })?;
        if fresh {
            self.cache.bump(|s| s.threshold_runs += 1);
        } else {
            self.cache.bump(|s| s.threshold_hits += 1);
        }
        Ok(v)
    }

    /// The clustering-stage artifact (after optional capacity alignment).
    pub fn clustering(&self) -> Result<Arc<Clustering>> {
        anyhow::ensure!(
            self.explicit.is_none(),
            "plan uses an explicit bitmap; it has no clustering stage"
        );
        let key = self.cluster_key();
        let (v, fresh) = memo(&self.cache.clusterings, &key, || {
            let sens = self.sensitivity_scores()?;
            let thr = self.chosen_threshold()?;
            let q = self.cfg.quant;
            let mut c = clustering::cluster_at_cr(&sens.scores, thr.quantile, q.hi.bits, q.lo.bits);
            if self.align {
                let st = &self.state;
                let xcfg = self.cfg.xbar;
                let caps: Vec<usize> = st
                    .model
                    .conv_layers()
                    .iter()
                    .map(|l| xcfg.capacity_strips(l.d, q.hi.bits))
                    .collect();
                c = clustering::align_to_capacity(
                    &st.model,
                    &sens.scores,
                    &c,
                    q.hi.bits,
                    q.lo.bits,
                    |li| caps[li],
                );
            }
            Ok(c)
        })?;
        if fresh {
            self.cache.bump(|s| s.clustering_runs += 1);
        } else {
            self.cache.bump(|s| s.clustering_hits += 1);
        }
        Ok(v)
    }

    /// The per-strip bit allocation this plan quantizes and maps with:
    /// the explicit bitmap if one was injected, else the clustering's.
    pub fn bitmap(&self) -> Result<Arc<BitMap>> {
        match &self.explicit {
            Some(e) => Ok(e.bitmap.clone()),
            None => Ok(Arc::new(self.clustering()?.bitmap.clone())),
        }
    }

    /// The quantization-stage artifact: fake-quantized parameters + per-strip
    /// scales + quantization MSE (paper §4.1/§4.3).
    pub fn quantized(&self) -> Result<Arc<QuantizedModel>> {
        let key = self.quant_key();
        let (v, fresh) = memo(&self.cache.quantized, &key, || {
            let st = &self.state;
            let clustering;
            let bm: &BitMap = match &self.explicit {
                Some(e) => e.bitmap.as_ref(),
                None => {
                    clustering = self.clustering()?;
                    &clustering.bitmap
                }
            };
            Ok(quant::apply(&st.model, &st.theta, bm, &self.cfg.quant))
        })?;
        if fresh {
            self.cache.bump(|s| s.quantize_runs += 1);
        } else {
            self.cache.bump(|s| s.quantize_hits += 1);
        }
        Ok(v)
    }

    /// The mapping-stage artifact: strips placed onto crossbar arrays.
    pub fn mapping(&self) -> Result<Arc<ModelMapping>> {
        let key = self.map_key();
        let (v, fresh) = memo(&self.cache.mappings, &key, || {
            let st = &self.state;
            let clustering;
            let bm: &BitMap = match &self.explicit {
                Some(e) => e.bitmap.as_ref(),
                None => {
                    clustering = self.clustering()?;
                    &clustering.bitmap
                }
            };
            Ok(xbar::map_model(&st.model, bm, &self.cfg.xbar, self.strategy))
        })?;
        if fresh {
            self.cache.bump(|s| s.mapping_runs += 1);
        } else {
            self.cache.bump(|s| s.mapping_hits += 1);
        }
        Ok(v)
    }

    /// Resolve the plan's fault scenario into the form the simulator
    /// consumes: sensitivity-aware placement needs the per-strip scores, so
    /// the sensitivity stage (cached) is pulled in exactly when the policy
    /// asks for it. A health reservation with no fault scenario still
    /// yields a scenario (zero-fault spec, natural placement) — canaries
    /// and spares must be programmed for probes to have something to read.
    fn fault_scenario(&self) -> Result<Option<Scenario>> {
        let (spec, placement) = match self.scenario {
            Some((spec, placement)) => (spec, placement),
            None if self.health.is_active() => (ScenarioSpec::default(), Placement::Naive),
            None => return Ok(None),
        };
        let mut sc = Scenario::new(spec).with_placement(placement).with_health(self.health);
        if placement == Placement::SensitivityAware {
            let sens = self.sensitivity_scores()?;
            sc = sc.with_scores(Arc::new(sens.scores.clone()));
        }
        Ok(Some(sc))
    }

    /// Cache-key fragment for the active scenario ("none" when absent).
    fn scenario_part(&self) -> String {
        let h = self.health;
        let health_part =
            if h.is_active() { format!(":hc{}s{}", h.canaries, h.spares) } else { String::new() };
        match self.scenario {
            None => format!("scn:none{health_part}"),
            Some((spec, placement)) => {
                format!("scn:{:016x}:{}{health_part}", spec.fingerprint(), placement.name())
            }
        }
    }

    // ---- terminal operations ------------------------------------------------

    /// Offline terminal: quantize, map, cost and evaluate accuracy — the
    /// report every table/figure of the paper consumes. Runs on the plan's
    /// root backend; use [`CompressionPlan::evaluate_on`] to pick another.
    pub fn evaluate(&self, opts: EvalOpts) -> Result<PipelineReport> {
        self.evaluate_on(self.state.exec, opts)
    }

    /// Evaluate on an explicit backend. On `Executor::Sim` the accuracy pass
    /// executes the quantized strips bit-serially on the simulated crossbars
    /// (the per-strip bits/scales feed the cell programming); on
    /// `Executor::Pjrt` the fake-quantized parameters run through the AOT
    /// `fwd_eval` graph.
    pub fn evaluate_on(&self, exec: Executor<'_>, opts: EvalOpts) -> Result<PipelineReport> {
        let key = format!(
            "{}|{}|eval{}:{}|nom{:?}|{}|x{:016x}",
            self.quant_key(),
            self.map_key(),
            exec.cache_tag(),
            opts.eval_batches,
            self.nominal,
            self.scenario_part(),
            fnv64(self.cfg.xbar.to_value().to_json().bytes())
        );
        let (r, fresh) = memo(&self.cache.reports, &key, || {
            let st = &self.state;
            let q = self.cfg.quant;
            let qm = self.quantized()?;
            let mapping = self.mapping()?;
            let cost = xbar::cost(&mapping, &self.cfg.xbar);
            let accuracy = match exec {
                Executor::Pjrt(rt) => eval::evaluate_batches(
                    rt,
                    &st.model,
                    &qm.theta,
                    &st.test,
                    opts.eval_batches,
                )?,
                Executor::Sim(scfg) => {
                    let mut sim = SimXbar::from_quantized(scfg, &qm);
                    if let Some(sc) = self.fault_scenario()? {
                        sim = sim.with_scenario(sc);
                    }
                    eval::evaluate_batches(
                        &sim,
                        &st.model,
                        &qm.theta,
                        &st.test,
                        opts.eval_batches,
                    )?
                }
            };
            let clustering;
            let bm: &BitMap = match &self.explicit {
                Some(e) => e.bitmap.as_ref(),
                None => {
                    clustering = self.clustering()?;
                    &clustering.bitmap
                }
            };
            let (mode, threshold, fim_evals) = match &self.explicit {
                Some(e) => (
                    self.nominal
                        .unwrap_or(ThresholdMode::FixedCr(e.bitmap.compression_ratio(q.hi.bits))),
                    f64::NAN,
                    0,
                ),
                None => {
                    let thr = self.chosen_threshold()?;
                    let c = self.clustering()?;
                    (self.nominal.unwrap_or(self.threshold_mode), c.threshold, thr.fim_evals)
                }
            };
            Ok(PipelineReport {
                model: st.model.name().to_string(),
                mode,
                compression_ratio: bm.compression_ratio(q.hi.bits),
                q_hi: bm.count_bits(q.hi.bits),
                total_strips: bm.bits.len(),
                accuracy,
                fp32_accuracy: st.model.entry.fp32_test_acc,
                cost,
                utilization_hi: mapping.utilization(q.hi.bits),
                utilization_all: mapping.utilization_all(),
                quant_mse: qm.mse,
                threshold,
                fim_evals,
            })
        })?;
        if fresh {
            self.cache.bump(|s| s.eval_runs += 1);
        } else {
            self.cache.bump(|s| s.eval_hits += 1);
        }
        Ok((*r).clone())
    }

    /// Online terminal: quantize through the plan's stages and start the
    /// dynamic-batching serving engine on the result. Runs on the plan's
    /// root backend; use [`CompressionPlan::deploy_on`] to pick another.
    pub fn deploy(&self, cfg: EngineConfig) -> Result<EngineHandle> {
        self.deploy_on(self.state.exec, cfg)
    }

    /// Deploy on an explicit backend. Sim deployments carry the quantized
    /// per-strip precision into every engine worker so serving executes on
    /// the simulated crossbars; each worker **programs its crossbar tiles
    /// once at startup** (quantized weight codes, packed bit-planes, analog
    /// conductances — the program-once artifact of
    /// [`crate::backend::programmed`]) inside the readiness handshake, so
    /// requests only ever pay the read-only tile walk. `cfg.workers` shards
    /// the engine across N backend workers (responses stay bit-identical —
    /// both backends are per-sample deterministic), and startup failures
    /// surface as a typed [`crate::coordinator::StartupError`] through the
    /// per-worker readiness handshake; per-worker programming cost is
    /// observable via the handle's metrics (`program_ns_mean`/`_max`).
    pub fn deploy_on(&self, exec: Executor<'_>, cfg: EngineConfig) -> Result<EngineHandle> {
        let qm = self.quantized()?;
        let st = &self.state;
        let spec = match exec {
            // The engine worker rebuilds its own PJRT client, from the same
            // artifacts the passed runtime loads (not the plan root's —
            // a sim-rooted plan can deploy_on a pjrt runtime).
            Executor::Pjrt(rt) => BackendSpec::Pjrt { artifacts: rt.artifacts().to_path_buf() },
            Executor::Sim(scfg) => BackendSpec::Sim {
                cfg: scfg,
                strips: Some(StripPrecision::from_quantized(&qm)),
                scenario: self.fault_scenario()?,
            },
        };
        let engine = Engine::new(spec, &st.model, qm.theta.clone(), cfg)?;
        Ok(engine.start()?)
    }

    /// Serve the unquantized fp32 checkpoint (reference deployments). On the
    /// simulator backend this runs every layer in exact f32.
    pub fn deploy_fp32(&self, cfg: EngineConfig) -> Result<EngineHandle> {
        let st = &self.state;
        let spec = match st.exec {
            Executor::Pjrt(rt) => BackendSpec::Pjrt { artifacts: rt.artifacts().to_path_buf() },
            Executor::Sim(scfg) => BackendSpec::Sim { cfg: scfg, strips: None, scenario: None },
        };
        let engine = Engine::new(spec, &st.model, st.theta.clone(), cfg)?;
        Ok(engine.start()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memo_computes_once_per_key() {
        let map: RefCell<HashMap<String, Arc<usize>>> = RefCell::new(HashMap::new());
        let mut calls = 0usize;
        for _ in 0..3 {
            let (v, _) = memo(&map, "k", || {
                calls += 1;
                Ok(42)
            })
            .unwrap();
            assert_eq!(*v, 42);
        }
        assert_eq!(calls, 1);
        let (_, fresh) = memo(&map, "k2", || Ok(7)).unwrap();
        assert!(fresh);
    }

    #[test]
    fn memo_error_is_not_cached() {
        let map: RefCell<HashMap<String, Arc<usize>>> = RefCell::new(HashMap::new());
        assert!(memo(&map, "k", || anyhow::bail!("boom")).is_err());
        let (v, fresh) = memo(&map, "k", || Ok(1)).unwrap();
        assert!(fresh);
        assert_eq!(*v, 1);
    }

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        let a = fnv64([1u8, 2, 3].into_iter());
        let b = fnv64([1u8, 2, 3].into_iter());
        let c = fnv64([3u8, 2, 1].into_iter());
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn cache_stats_bump() {
        let cache = StageCache::default();
        cache.bump(|s| s.sensitivity_runs += 1);
        cache.bump(|s| s.sensitivity_runs += 1);
        cache.bump(|s| s.eval_runs += 1);
        let s = cache.stats();
        assert_eq!(s.sensitivity_runs, 2);
        assert_eq!(s.eval_runs, 1);
        assert_eq!(s.mapping_runs, 0);
    }

    #[test]
    fn cache_stats_hits_totals_and_absorb() {
        let mut a = CacheStats {
            sensitivity_runs: 1,
            sensitivity_hits: 3,
            threshold_hits: 2,
            clustering_hits: 1,
            quantize_hits: 5,
            eval_runs: 4,
            ..Default::default()
        };
        assert_eq!(a.prefix_hits(), 6);
        assert_eq!(a.total_hits(), 11);
        assert_eq!(a.total_runs(), 5);
        let b = CacheStats { sensitivity_hits: 1, mapping_runs: 2, ..Default::default() };
        a.absorb(&b);
        assert_eq!(a.sensitivity_hits, 4);
        assert_eq!(a.mapping_runs, 2);
        assert_eq!(a.prefix_hits(), 7);
        let v = a.to_value();
        assert_eq!(v.get("prefix_hits").unwrap().num().unwrap(), 7.0);
        assert_eq!(v.get("runs").unwrap().num().unwrap(), 7.0);
    }

    #[test]
    fn chosen_threshold_json_handles_nan() {
        let t = ChosenThreshold {
            mode: ThresholdMode::FixedCr(0.7),
            quantile: 0.7,
            threshold: f64::NAN,
            fim_evals: 0,
        };
        let v = t.to_value();
        assert_eq!(v.get("threshold").unwrap(), &Value::Null);
        // serializes to valid JSON
        let text = v.to_json();
        assert!(Value::parse(&text).is_ok(), "{text}");
    }
}
