//! Accuracy evaluation over any [`ExecBackend`] (PJRT `fwd_eval` artifact
//! or the native crossbar simulator).

use crate::backend::{ExecBackend, FwdKind};
use crate::dataset::TestSet;
use crate::model::ModelInfo;
use crate::tensor::Tensor;
use crate::Result;

/// Top-1 / top-5 accuracy.
#[derive(Clone, Copy, Debug, Default)]
pub struct Accuracy {
    pub top1: f64,
    pub top5: f64,
    pub samples: usize,
}

impl Accuracy {
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj(vec![
            ("top1", Value::Num(self.top1)),
            ("top5", Value::Num(self.top5)),
            ("samples", Value::Num(self.samples as f64)),
        ])
    }
}

/// Evaluate `theta` on the test set through the backend's eval forward.
pub fn evaluate<B: ExecBackend + ?Sized>(
    backend: &B,
    model: &ModelInfo,
    theta: &[f32],
    test: &TestSet,
) -> Result<Accuracy> {
    evaluate_batches(backend, model, theta, test, usize::MAX)
}

/// Evaluate on at most `max_batches` eval batches (for quick sweeps).
pub fn evaluate_batches<B: ExecBackend + ?Sized>(
    backend: &B,
    model: &ModelInfo,
    theta: &[f32],
    test: &TestSet,
    max_batches: usize,
) -> Result<Accuracy> {
    let b = model.entry.batch.eval;
    let theta_t = Tensor::from_vec(theta.to_vec());
    let nb = test.num_batches(b).min(max_batches);
    anyhow::ensure!(nb > 0, "test set smaller than one eval batch");

    let (mut c1, mut c5, mut n) = (0usize, 0usize, 0usize);
    for i in 0..nb {
        let (x, y) = test.batch(i, b);
        let logits = backend.forward(model, FwdKind::Eval, &theta_t, &x)?;
        let k = logits.shape()[1];
        for (row, &label) in logits.data().chunks_exact(k).zip(y.iter()) {
            let mut idx: Vec<usize> = (0..k).collect();
            idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
            if idx[0] == label {
                c1 += 1;
            }
            if idx.iter().take(5).any(|&i| i == label) {
                c5 += 1;
            }
            n += 1;
        }
    }
    Ok(Accuracy {
        top1: c1 as f64 / n as f64,
        top5: c5 as f64 / n as f64,
        samples: n,
    })
}
