//! Serving engine: the L3 request hot path. Requests are dynamically
//! batched (size- or deadline-triggered), padded to the static serve batch
//! shape, executed on a pluggable [`ExecBackend`] (PJRT artifacts or the
//! native crossbar simulator), and answered through per-request channels.
//! Python is never involved.
//!
//! Built on std threads + channels (this environment has no tokio; the
//! batching discipline is the same as a vLLM-style router's). The backend
//! is constructed *inside* the worker thread — PJRT handles are not `Send` —
//! and [`Engine::start`] blocks on a readiness handshake so a backend that
//! cannot come up surfaces a typed [`StartupError`] to the caller instead
//! of a log line and a silently dead queue.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::{ExecBackend, FwdKind, SimXbar, SimXbarConfig, StripPrecision};
use crate::model::ModelInfo;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::Result;

use super::metrics::Metrics;

/// How the engine worker constructs its execution backend (inside the
/// worker thread — PJRT handles are not `Send`, the simulator is).
#[derive(Clone)]
pub enum BackendSpec {
    /// PJRT over the AOT artifacts directory.
    Pjrt { artifacts: PathBuf },
    /// Native bit-serial crossbar simulator; `strips` carries the deployed
    /// quantization (None = exact-f32 fp32 deployment).
    Sim { cfg: SimXbarConfig, strips: Option<StripPrecision> },
}

impl BackendSpec {
    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt { .. } => "pjrt",
            BackendSpec::Sim { .. } => "sim",
        }
    }
}

/// Why the engine failed to come up. Returned by [`Engine::start`]'s
/// readiness handshake so callers see *why* serving is down (missing
/// artifacts, PJRT client failure, malformed deployment) instead of a
/// swallowed log line.
#[derive(Clone, Debug)]
pub struct StartupError {
    /// Which backend failed ("pjrt" / "sim").
    pub backend: &'static str,
    pub reason: String,
}

impl std::fmt::Display for StartupError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine {} backend failed to start: {}", self.backend, self.reason)
    }
}

impl std::error::Error for StartupError {}

/// One classification request: a 32×32×3 image.
struct Request {
    t0: Instant,
    image: Vec<f32>,
    reply: SyncSender<BatchResult>,
}

/// Prediction for one image.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub class: usize,
    /// Time spent inside the engine (queue + execute), microseconds.
    pub latency_us: u64,
}

/// Why a request's batch failed inside the engine. Every pending request of
/// a failed batch receives this explicitly (no silently dropped channels).
#[derive(Clone, Debug)]
pub struct BatchError(pub String);

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for BatchError {}

type BatchResult = std::result::Result<Response, BatchError>;

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Flush a partial batch after this long.
    pub max_wait: Duration,
    /// Bounded queue length (backpressure).
    pub queue: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { max_wait: Duration::from_millis(2), queue: 1024 }
    }
}

/// Handle for submitting requests (cloneable across threads).
#[derive(Clone)]
pub struct EngineHandle {
    tx: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
}

/// A pending reply the caller can wait on.
pub struct Pending {
    rx: Receiver<BatchResult>,
}

impl Pending {
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow::anyhow!("engine batch failed: {e}")),
            Err(_) => Err(anyhow::anyhow!("engine dropped request")),
        }
    }
}

impl EngineHandle {
    /// Submit one image; returns a handle to wait on.
    pub fn submit(&self, image: Vec<f32>) -> Result<Pending> {
        let (reply, rx) = sync_channel(1);
        self.metrics.observe_request();
        self.tx
            .send(Request { t0: Instant::now(), image, reply })
            .map_err(|_| anyhow::anyhow!("engine stopped"))?;
        Ok(Pending { rx })
    }

    /// Submit and wait (convenience).
    pub fn classify(&self, image: Vec<f32>) -> Result<Response> {
        self.submit(image)?.wait()
    }
}

/// The engine: owns its backend spec (the backend itself lives entirely
/// inside the batching thread), the deployed weights and the batching loop.
pub struct Engine {
    spec: BackendSpec,
    model: ModelInfo,
    theta: Tensor,
    batch: usize,
    image_elems: usize,
    cfg: EngineConfig,
}

/// Worker-side state (constructed inside the engine thread).
struct Worker {
    backend: Box<dyn ExecBackend>,
    model: ModelInfo,
    theta: Tensor,
    batch: usize,
    image_elems: usize,
}

impl Engine {
    pub fn new(
        spec: BackendSpec,
        model: &ModelInfo,
        theta: Vec<f32>,
        cfg: EngineConfig,
    ) -> Result<Self> {
        if matches!(spec, BackendSpec::Pjrt { .. }) {
            model
                .entry
                .executables
                .get("fwd_serve")
                .ok_or_else(|| anyhow::anyhow!("model has no fwd_serve executable"))?;
        }
        Ok(Self {
            spec,
            model: model.clone(),
            theta: Tensor::from_vec(theta),
            batch: model.entry.batch.serve,
            image_elems: 32 * 32 * 3,
            cfg,
        })
    }

    /// PJRT engine over an artifacts directory (the pre-backend API shape).
    pub fn pjrt(
        artifacts: PathBuf,
        model: &ModelInfo,
        theta: Vec<f32>,
        cfg: EngineConfig,
    ) -> Result<Self> {
        Self::new(BackendSpec::Pjrt { artifacts }, model, theta, cfg)
    }

    fn build_worker(self) -> Result<Worker> {
        // Backend-independent deployment validation; each backend's
        // ready_check adds only its own substrate checks on top.
        anyhow::ensure!(
            self.theta.len() == self.model.entry.num_params,
            "theta length {} does not match model ({} params)",
            self.theta.len(),
            self.model.entry.num_params
        );
        let backend: Box<dyn ExecBackend> = match &self.spec {
            BackendSpec::Pjrt { artifacts } => Box::new(Runtime::new(artifacts.clone())?),
            BackendSpec::Sim { cfg, strips } => {
                let mut sim = SimXbar::new(*cfg);
                if let Some(sp) = strips {
                    sim = sim.with_strips(sp.clone());
                }
                Box::new(sim)
            }
        };
        backend.ready_check(&self.model, &self.theta)?;
        Ok(Worker {
            backend,
            model: self.model,
            theta: self.theta,
            batch: self.batch,
            image_elems: self.image_elems,
        })
    }

    /// Spawn the batching loop. Blocks until the worker thread has built its
    /// backend and passed the readiness check, then returns the submission
    /// handle; a backend that cannot come up yields a typed [`StartupError`]
    /// instead of a dead queue. The loop exits when every handle is dropped.
    pub fn start(self) -> std::result::Result<EngineHandle, StartupError> {
        let (tx, rx) = sync_channel::<Request>(self.cfg.queue);
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(), StartupError>>(1);
        let metrics = Arc::new(Metrics::default());
        let handle = EngineHandle { tx, metrics: metrics.clone() };

        let cfg = self.cfg;
        let backend_name = self.spec.name();
        std::thread::spawn(move || {
            // The backend is created inside this thread (PJRT is !Send).
            let worker = match self.build_worker() {
                Ok(w) => {
                    let _ = ready_tx.send(Ok(()));
                    w
                }
                Err(e) => {
                    crate::error!("engine {backend_name} backend failed to start: {e:#}");
                    let _ = ready_tx.send(Err(StartupError {
                        backend: backend_name,
                        reason: format!("{e:#}"),
                    }));
                    return;
                }
            };
            let mut pending: Vec<Request> = Vec::with_capacity(worker.batch);
            loop {
                // Wait for the first request of a batch.
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break, // all senders gone
                }
                let deadline = Instant::now() + cfg.max_wait;
                // Fill until size- or deadline-triggered.
                while pending.len() < worker.batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                if let Err(e) = worker.run_batch(&mut pending, &metrics) {
                    crate::error!("batch failed: {e}");
                    // Answer every pending request with a typed error (no
                    // silently dropped reply channels) and count the failure.
                    metrics.observe_batch_failure(pending.len());
                    let err = BatchError(e.to_string());
                    for req in pending.drain(..) {
                        let _ = req.reply.send(Err(err.clone()));
                    }
                }
            }
        });

        match ready_rx.recv() {
            Ok(Ok(())) => Ok(handle),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(StartupError {
                backend: backend_name,
                reason: "engine worker exited before the readiness handshake".into(),
            }),
        }
    }
}

impl Worker {
    fn run_batch(&self, pending: &mut Vec<Request>, metrics: &Metrics) -> Result<()> {
        let n = pending.len();
        // Pad to the static batch shape.
        let mut x = vec![0.0f32; self.batch * self.image_elems];
        for (i, req) in pending.iter().enumerate() {
            anyhow::ensure!(
                req.image.len() == self.image_elems,
                "bad image size {}",
                req.image.len()
            );
            x[i * self.image_elems..(i + 1) * self.image_elems].copy_from_slice(&req.image);
        }
        let xt = Tensor::new(vec![self.batch, 32, 32, 3], x);
        let logits = self.backend.forward(&self.model, FwdKind::Serve, &self.theta, &xt)?;
        let k = logits.shape()[1];

        let now = Instant::now();
        // Record metrics *before* replying: callers may snapshot as soon as
        // their reply lands.
        let batch_lat = pending
            .iter()
            .map(|r| now.duration_since(r.t0).as_micros() as u64)
            .max()
            .unwrap_or(0);
        metrics.observe_batch(n, batch_lat);
        let mut max_lat = 0u64;
        for (i, req) in pending.drain(..).enumerate() {
            let row = logits.data()[i * k..(i + 1) * k].to_vec();
            let class = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap_or(0);
            let latency_us = now.duration_since(req.t0).as_micros() as u64;
            max_lat = max_lat.max(latency_us);
            let _ = req.reply.send(Ok(Response { logits: row, class, latency_us }));
        }
        debug_assert!(max_lat <= batch_lat);
        Ok(())
    }
}
