//! Serving engine: the L3 request hot path. Requests are dynamically
//! batched (size- or deadline-triggered), padded to the static serve batch
//! shape, executed on a pluggable [`ExecBackend`] (PJRT artifacts or the
//! native crossbar simulator), and answered through per-request channels.
//! Python is never involved.
//!
//! Built on std threads + channels (this environment has no tokio; the
//! batching discipline is the same as a vLLM-style router's). The
//! [`ShardedEngine`] runs one dispatcher thread (the batching loop) in
//! front of `EngineConfig::workers` backend worker threads. Each worker
//! constructs its own backend *inside* its thread — PJRT handles are not
//! `Send` — and [`ShardedEngine::start`] blocks on a per-worker readiness
//! handshake, aggregating failures into a typed [`StartupError`] so a
//! backend that cannot come up surfaces to the caller instead of a log
//! line and a silently dead queue. Sim workers **program their crossbars**
//! (the program-once tile artifact) inside that handshake, so deploy-time
//! programming cost never lands on a request; each worker's cost is
//! recorded in [`Metrics`] before it reports ready. Formed batches are handed to the first
//! worker with a free queue slot (falling back to a blocking round-robin
//! send when all are busy), and shutdown drains every accepted request —
//! replies are always delivered, as a [`Response`] or a typed
//! [`BatchFail`], never a dropped channel.
//!
//! ## Worker supervision and self-healing
//!
//! Each worker's batch execution runs under `catch_unwind`: a panic
//! mid-batch answers the in-flight requests with a typed degraded reply
//! ([`BatchFail::Degraded`] → [`WaitError::Degraded`]), then the worker
//! **respawns in place** — rebuilding its backend and re-programming its
//! crossbars from the original seed — before taking the next batch.
//! Only a failed respawn takes the worker down ([`Metrics`] counts both).
//! Between batches, every `EngineConfig::probe_every` served batches the
//! worker runs one [`ExecBackend::health_step`] at its served-batch tick:
//! canary probing, runtime fault-evolution detection, and background
//! repair programming with a hot artifact swap (see [`crate::health`]).
//! The test-only `EngineConfig::chaos_panic_after` injects one deliberate
//! panic on the Nth batch across the pool, so CI can prove the
//! degrade-respawn-recover path end to end.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SendError, SyncSender, TrySendError,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::{ExecBackend, FwdKind, SimXbar, SimXbarConfig, StripPrecision};
use crate::faults::Scenario;
use crate::model::ModelInfo;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::Result;

use super::metrics::Metrics;

/// How each engine worker constructs its execution backend (inside the
/// worker thread — PJRT handles are not `Send`, the simulator is).
#[derive(Clone)]
pub enum BackendSpec {
    /// PJRT over the AOT artifacts directory.
    Pjrt { artifacts: PathBuf },
    /// Native bit-serial crossbar simulator; `strips` carries the deployed
    /// quantization (None = exact-f32 fp32 deployment) and `scenario` an
    /// optional device-variability fault scenario applied when the worker
    /// programs its crossbars (None = healthy device).
    Sim { cfg: SimXbarConfig, strips: Option<StripPrecision>, scenario: Option<Scenario> },
}

impl BackendSpec {
    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt { .. } => "pjrt",
            BackendSpec::Sim { .. } => "sim",
        }
    }
}

/// Why the engine failed to come up. Returned by [`ShardedEngine::start`]'s
/// readiness handshake so callers see *why* serving is down (missing
/// artifacts, PJRT client failure, malformed deployment) instead of a
/// swallowed log line. With several workers, the first failure wins and
/// `worker` names the shard that reported it.
#[derive(Clone, Debug)]
pub struct StartupError {
    /// Which backend failed ("pjrt" / "sim").
    pub backend: &'static str,
    /// Index of the worker whose backend failed to build.
    pub worker: usize,
    pub reason: String,
}

impl std::fmt::Display for StartupError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "engine {} backend failed to start (worker {}): {}",
            self.backend, self.worker, self.reason
        )
    }
}

impl std::error::Error for StartupError {}

/// One classification request: a 32×32×3 image.
struct Request {
    t0: Instant,
    image: Vec<f32>,
    reply: SyncSender<BatchResult>,
}

/// Prediction for one image.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub class: usize,
    /// Time spent inside the engine (queue + execute), microseconds.
    pub latency_us: u64,
}

/// Why a request's batch failed inside the engine. Every pending request of
/// a failed batch receives this explicitly (no silently dropped channels).
#[derive(Clone, Debug)]
pub struct BatchError(pub String);

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for BatchError {}

/// How a request's batch failed to produce a [`Response`]. `Error` is a
/// hard engine failure; `Degraded` is the supervised path — the worker
/// panicked mid-batch and is respawning, so the caller should retry
/// shortly rather than treat the service as broken.
#[derive(Clone, Debug)]
pub enum BatchFail {
    /// Hard failure with its typed error.
    Error(BatchError),
    /// Answered degraded during worker repair/respawn; retryable.
    Degraded(String),
}

type BatchResult = std::result::Result<Response, BatchFail>;

/// Why a [`Pending::wait_timeout`] produced no [`Response`]. `Timeout` is
/// the load-bearing variant: it is what keeps a dead or wedged worker from
/// hanging a serving connection thread forever (the `serve` front-end
/// converts it into a typed degraded frame carrying the missed deadline).
#[derive(Clone, Debug)]
pub enum WaitError {
    /// No reply within the deadline (slow, overloaded, or dead worker).
    Timeout,
    /// The engine dropped the request's reply channel (shutdown before
    /// dispatch — the drain paths normally answer everything).
    Dropped,
    /// The request's batch failed inside the engine, with its typed error.
    Failed(BatchError),
    /// The request was answered degraded — its worker panicked mid-batch
    /// and is respawning. Retryable; the `serve` front-end converts it
    /// into a typed `Degraded` frame with a retry hint.
    Degraded {
        reason: String,
    },
}

impl std::fmt::Display for WaitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WaitError::Timeout => write!(f, "engine reply timed out"),
            WaitError::Dropped => write!(f, "engine dropped request"),
            WaitError::Failed(e) => write!(f, "engine batch failed: {e}"),
            WaitError::Degraded { reason } => write!(f, "engine degraded: {reason}"),
        }
    }
}

impl std::error::Error for WaitError {}

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Flush a partial batch after this long.
    pub max_wait: Duration,
    /// Bounded queue length (backpressure).
    pub queue: usize,
    /// Backend worker threads behind the batching loop. Each builds its own
    /// backend instance in-thread; 1 is the classic single-worker engine.
    /// Responses are bit-identical for every worker count (both backends
    /// are per-sample deterministic).
    pub workers: usize,
    /// Run one health-monitor step ([`ExecBackend::health_step`]) every
    /// this many served batches per worker; 0 (the default) disables the
    /// monitor entirely — no probe work, no behavior change.
    pub probe_every: u64,
    /// Test-only chaos injection: panic deliberately on the Nth batch
    /// executed across the worker pool (0 = never). Proves the
    /// degrade-respawn-recover path under real traffic.
    pub chaos_panic_after: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            queue: 1024,
            workers: 1,
            probe_every: 0,
            chaos_panic_after: 0,
        }
    }
}

impl EngineConfig {
    /// `workers` sharded backend workers, defaults otherwise.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Health-probe cadence in served batches per worker (0 = off).
    pub fn with_probe_every(mut self, probe_every: u64) -> Self {
        self.probe_every = probe_every;
        self
    }

    /// Inject one deliberate worker panic on the Nth batch (test-only).
    pub fn with_chaos_panic_after(mut self, n: u64) -> Self {
        self.chaos_panic_after = n;
        self
    }
}

/// Handle for submitting requests (cloneable across threads).
#[derive(Clone)]
pub struct EngineHandle {
    tx: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
}

/// A pending reply the caller can wait on.
pub struct Pending {
    rx: Receiver<BatchResult>,
}

impl Pending {
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(BatchFail::Error(e))) => Err(anyhow::anyhow!("engine batch failed: {e}")),
            Ok(Err(BatchFail::Degraded(reason))) => {
                Err(anyhow::anyhow!("engine degraded: {reason}"))
            }
            Err(_) => Err(anyhow::anyhow!("engine dropped request")),
        }
    }

    /// [`Pending::wait`] with an upper bound: a worker that died or wedged
    /// mid-batch can never park the caller forever. Takes `&self` so a
    /// caller may keep waiting after a timeout if it wants to.
    pub fn wait_timeout(&self, timeout: Duration) -> std::result::Result<Response, WaitError> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(BatchFail::Error(e))) => Err(WaitError::Failed(e)),
            Ok(Err(BatchFail::Degraded(reason))) => Err(WaitError::Degraded { reason }),
            Err(RecvTimeoutError::Timeout) => Err(WaitError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(WaitError::Dropped),
        }
    }
}

impl EngineHandle {
    /// Submit one image; returns a handle to wait on.
    pub fn submit(&self, image: Vec<f32>) -> Result<Pending> {
        let (reply, rx) = sync_channel(1);
        self.metrics.observe_request();
        self.tx
            .send(Request { t0: Instant::now(), image, reply })
            .map_err(|_| anyhow::anyhow!("engine stopped"))?;
        Ok(Pending { rx })
    }

    /// Submit and wait (convenience).
    pub fn classify(&self, image: Vec<f32>) -> Result<Response> {
        self.submit(image)?.wait()
    }

    /// Submit a pre-formed group of images as one unit: the requests enter
    /// the engine queue back-to-back, so the dispatcher coalesces them into
    /// execution batches instead of re-discovering them one batching
    /// deadline at a time. This is the hand-off path of the `serve`
    /// front-end's micro-batcher. Returns one [`Pending`] per image, in
    /// submission order. On error (engine stopped) the already-enqueued
    /// prefix is answered through the engine's normal drain paths; the
    /// caller only ever sees the `Err`.
    pub fn submit_batch(&self, images: Vec<Vec<f32>>) -> Result<Vec<Pending>> {
        let t0 = Instant::now();
        let mut pendings = Vec::with_capacity(images.len());
        for image in images {
            let (reply, rx) = sync_channel(1);
            self.metrics.observe_request();
            self.tx
                .send(Request { t0, image, reply })
                .map_err(|_| anyhow::anyhow!("engine stopped"))?;
            pendings.push(Pending { rx });
        }
        Ok(pendings)
    }
}

/// The engine: owns its backend spec (backends live entirely inside the
/// worker threads), the deployed weights, and the batching/dispatch loops.
pub struct ShardedEngine {
    spec: BackendSpec,
    model: ModelInfo,
    theta: Tensor,
    batch: usize,
    image_elems: usize,
    cfg: EngineConfig,
}

/// The pre-sharding name, kept as an alias: a `ShardedEngine` with
/// `workers == 1` *is* the classic single-worker engine.
pub type Engine = ShardedEngine;

/// Everything a worker thread needs to build its in-thread backend. Kept
/// cloneable so a supervised worker can rebuild itself after a panic.
#[derive(Clone)]
struct WorkerSeed {
    spec: BackendSpec,
    model: ModelInfo,
    theta: Tensor,
    batch: usize,
    image_elems: usize,
}

/// Worker-side state (constructed inside a worker thread).
struct Worker {
    backend: Box<dyn ExecBackend>,
    model: ModelInfo,
    theta: Tensor,
    batch: usize,
    image_elems: usize,
}

impl WorkerSeed {
    fn build(self) -> Result<Worker> {
        // Backend-independent deployment validation; each backend's
        // ready_check adds only its own substrate checks on top.
        anyhow::ensure!(
            self.theta.len() == self.model.entry.num_params,
            "theta length {} does not match model ({} params)",
            self.theta.len(),
            self.model.entry.num_params
        );
        let backend: Box<dyn ExecBackend> = match &self.spec {
            BackendSpec::Pjrt { artifacts } => Box::new(Runtime::new(artifacts.clone())?),
            BackendSpec::Sim { cfg, strips, scenario } => {
                let mut sim = SimXbar::new(*cfg);
                if let Some(sp) = strips {
                    sim = sim.with_strips(sp.clone());
                }
                if let Some(sc) = scenario {
                    sim = sim.with_scenario(sc.clone());
                }
                Box::new(sim)
            }
        };
        backend.ready_check(&self.model, &self.theta)?;
        Ok(Worker {
            backend,
            model: self.model,
            theta: self.theta,
            batch: self.batch,
            image_elems: self.image_elems,
        })
    }
}

impl ShardedEngine {
    pub fn new(
        spec: BackendSpec,
        model: &ModelInfo,
        theta: Vec<f32>,
        cfg: EngineConfig,
    ) -> Result<Self> {
        if matches!(spec, BackendSpec::Pjrt { .. }) {
            model
                .entry
                .executables
                .get("fwd_serve")
                .ok_or_else(|| anyhow::anyhow!("model has no fwd_serve executable"))?;
        }
        Ok(Self {
            spec,
            model: model.clone(),
            theta: Tensor::from_vec(theta),
            batch: model.entry.batch.serve,
            image_elems: 32 * 32 * 3,
            cfg,
        })
    }

    /// PJRT engine over an artifacts directory (the pre-backend API shape).
    pub fn pjrt(
        artifacts: PathBuf,
        model: &ModelInfo,
        theta: Vec<f32>,
        cfg: EngineConfig,
    ) -> Result<Self> {
        Self::new(BackendSpec::Pjrt { artifacts }, model, theta, cfg)
    }

    /// Spawn the worker pool and the batching/dispatch loop. Blocks until
    /// every worker thread has built its backend and passed the readiness
    /// check, then returns the submission handle; any worker that cannot
    /// come up yields a typed [`StartupError`] (first failure wins) instead
    /// of a dead queue. The loops exit when every handle is dropped, after
    /// draining and answering everything already accepted.
    pub fn start(self) -> std::result::Result<EngineHandle, StartupError> {
        let workers = self.cfg.workers.max(1);
        let (tx, rx) = sync_channel::<Request>(self.cfg.queue);
        let metrics = Arc::new(Metrics::default());
        let handle = EngineHandle { tx, metrics: metrics.clone() };
        let backend_name = self.spec.name();
        let cfg = self.cfg;
        let batch_size = self.batch;

        // Record the active fault scenario (or "none") before readiness so
        // the `scenario:` stats line is meaningful from the first snapshot.
        if let BackendSpec::Sim { scenario, .. } = &self.spec {
            metrics.set_scenario(
                scenario.as_ref().map_or_else(|| "none".into(), |sc| sc.describe()),
            );
        }

        // With several workers, split the machine between them: an
        // auto-threaded simulator (threads == 0) would otherwise spawn one
        // tile shard per core *per worker*, oversubscribing the host by
        // `workers ×` and inverting the engine-level scaling. Results are
        // bit-identical for any thread count, so this is purely a
        // scheduling choice.
        let mut spec = self.spec;
        if workers > 1 {
            if let BackendSpec::Sim { cfg: scfg, .. } = &mut spec {
                if scfg.threads == 0 {
                    let cores =
                        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
                    scfg.threads = (cores / workers).max(1);
                }
            }
        }

        // Per-worker readiness handshake: every worker reports exactly once
        // (tagged with its index) and then *drops its sender*, so a worker
        // that panics inside backend construction — reporting nothing —
        // closes the channel instead of deadlocking the aggregation below.
        type Readiness = (usize, std::result::Result<(), StartupError>);
        let (ready_tx, ready_rx) = sync_channel::<Readiness>(workers);
        // Shared batch counter for chaos injection: exactly one worker
        // panics, on the Nth batch executed across the pool.
        let chaos = Arc::new(AtomicU64::new(0));
        // Per-worker batch queues, capacity 1: at most one batch waits
        // behind the one a worker is executing, so dispatch can spill to a
        // free worker instead of piling onto a busy one.
        let mut batch_txs: Vec<SyncSender<Vec<Request>>> = Vec::with_capacity(workers);
        for w in 0..workers {
            let (btx, brx) = sync_channel::<Vec<Request>>(1);
            batch_txs.push(btx);
            let seed = WorkerSeed {
                spec: spec.clone(),
                model: self.model.clone(),
                theta: self.theta.clone(),
                batch: self.batch,
                image_elems: self.image_elems,
            };
            let ready = ready_tx.clone();
            let metrics = metrics.clone();
            let chaos = chaos.clone();
            std::thread::spawn(move || {
                // The backend is created inside this thread (PJRT is !Send).
                let mut worker = match seed.clone().build() {
                    Ok(wk) => {
                        // Deploy-time crossbar programming happened inside
                        // the readiness check; record its cost *before*
                        // signalling ready, so `start()` returning implies
                        // every worker's programming is finished and
                        // observable — no request ever pays it.
                        metrics.observe_program(wk.backend.program_ns());
                        let _ = ready.send((w, Ok(())));
                        drop(ready);
                        wk
                    }
                    Err(e) => {
                        crate::error!("engine {backend_name} worker {w} failed to start: {e:#}");
                        let _ = ready.send((
                            w,
                            Err(StartupError {
                                backend: backend_name,
                                worker: w,
                                reason: format!("{e:#}"),
                            }),
                        ));
                        return;
                    }
                };
                // Batches arrive until the dispatcher drops this queue; each
                // is answered in full — successes per request, failures with
                // typed BatchFail replies (no silently dropped channels).
                // Execution is supervised: a panic answers the in-flight
                // batch degraded and respawns the worker in place.
                let mut last_walk = crate::backend::WalkProfile::default();
                let mut served_batches = 0u64;
                while let Ok(mut batch) = brx.recv() {
                    let mut span = crate::trace::span("worker.batch");
                    span.tag("worker", || w.to_string());
                    span.tag("size", || batch.len().to_string());
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if cfg.chaos_panic_after > 0
                            && chaos.fetch_add(1, Ordering::Relaxed) + 1 == cfg.chaos_panic_after
                        {
                            panic!("chaos: injected worker panic");
                        }
                        worker.run_batch(&mut batch, &metrics)
                    }));
                    drop(span);
                    match run {
                        Ok(run) => {
                            if let Err(e) = run {
                                crate::error!("batch failed on worker {w}: {e}");
                                metrics.observe_batch_failure(batch.len());
                                let err = BatchFail::Error(BatchError(e.to_string()));
                                for req in batch.drain(..) {
                                    let _ = req.reply.send(Err(err.clone()));
                                }
                            }
                            served_batches += 1;
                            // Fold this batch's crossbar-walk counters into
                            // the shared metrics (the backend keeps a
                            // cumulative profile; the worker pushes deltas).
                            if let Some(now) = worker.backend.walk_profile() {
                                metrics.add_walk(&now.delta(&last_walk));
                                last_walk = now;
                            }
                            // Health monitor at the batch boundary: probe
                            // canaries, detect runtime evolution, repair.
                            if cfg.probe_every > 0 && served_batches % cfg.probe_every == 0 {
                                if let Some(rep) = worker.backend.health_step(
                                    &worker.model,
                                    &worker.theta,
                                    served_batches,
                                ) {
                                    metrics.observe_health(&rep);
                                }
                            }
                        }
                        Err(_) => {
                            // The worker panicked mid-batch: answer every
                            // in-flight request with a typed degraded reply
                            // (retryable), then rebuild backend + crossbars
                            // from the seed before the next batch.
                            crate::error!("engine worker {w} panicked mid-batch; respawning");
                            metrics.observe_batch_failure(batch.len());
                            let err = BatchFail::Degraded(
                                "worker panicked mid-batch; respawning".into(),
                            );
                            for req in batch.drain(..) {
                                metrics.observe_degraded();
                                let _ = req.reply.send(Err(err.clone()));
                            }
                            let mut span = crate::trace::span("worker.respawn");
                            span.tag("worker", || w.to_string());
                            match seed.clone().build() {
                                Ok(fresh) => {
                                    worker = fresh;
                                    metrics.observe_program(worker.backend.program_ns());
                                    metrics.observe_respawn();
                                    last_walk = crate::backend::WalkProfile::default();
                                }
                                Err(e) => {
                                    // Typed WorkerDown: the pool sheds this
                                    // shard; the dispatcher routes around a
                                    // disconnected queue.
                                    crate::error!(
                                        "engine worker {w} failed to respawn: {e:#}; worker down"
                                    );
                                    metrics.observe_worker_down();
                                    drop(span);
                                    crate::trace::flush_thread();
                                    return;
                                }
                            }
                            drop(span);
                        }
                    }
                    crate::trace::flush_thread();
                }
            });
        }
        drop(ready_tx);

        let mut failure: Option<StartupError> = None;
        let mut reported = vec![false; workers];
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok((w, Ok(()))) => reported[w] = true,
                Ok((w, Err(e))) => {
                    reported[w] = true;
                    failure.get_or_insert(e);
                }
                Err(_) => {
                    // Every live worker has reported and dropped its sender,
                    // yet reports are missing: a worker thread panicked
                    // during backend construction. Still a typed failure,
                    // attributed to the first silent worker.
                    let w = reported.iter().position(|&r| !r).unwrap_or(0);
                    failure.get_or_insert(StartupError {
                        backend: backend_name,
                        worker: w,
                        reason: "engine worker exited before the readiness handshake".into(),
                    });
                    break;
                }
            }
        }
        if let Some(e) = failure {
            // Dropping batch_txs here lets any healthy workers exit cleanly.
            return Err(e);
        }

        // Dispatcher: the batching loop (size- or deadline-triggered), then
        // hand-off to the worker pool.
        std::thread::spawn(move || {
            let mut rr = 0usize;
            let mut pending: Vec<Request> = Vec::with_capacity(batch_size);
            loop {
                // Wait for the first request of a batch.
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break, // all handles gone and the queue drained
                }
                let deadline = Instant::now() + cfg.max_wait;
                // Fill until size- or deadline-triggered.
                while pending.len() < batch_size {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                let batch = std::mem::replace(&mut pending, Vec::with_capacity(batch_size));
                {
                    let mut span = crate::trace::span("engine.dispatch");
                    span.tag("size", || batch.len().to_string());
                    dispatch(&batch_txs, &mut rr, batch, &metrics);
                }
                crate::trace::flush_thread();
            }
            // Dropping the worker queues ends the worker loops once they
            // finish what was dispatched; every accepted request has been
            // handed off, so every reply channel gets an answer.
        });

        Ok(handle)
    }
}

/// Hand a formed batch to the worker pool: first worker with a free queue
/// slot starting at the round-robin cursor (cheap least-loaded — a busy
/// worker is skipped, uniform load still spreads evenly). When every live
/// queue is full, block on the first known-alive (Full) worker seen —
/// never on a disconnected one — and only when *no* worker is left alive
/// answer the batch with typed errors.
fn dispatch(
    batch_txs: &[SyncSender<Vec<Request>>],
    rr: &mut usize,
    mut batch: Vec<Request>,
    metrics: &Metrics,
) {
    if batch.is_empty() {
        return;
    }
    let target = *rr % batch_txs.len();
    *rr = rr.wrapping_add(1);
    let mut alive: Option<usize> = None;
    for i in 0..batch_txs.len() {
        let k = (target + i) % batch_txs.len();
        match batch_txs[k].try_send(batch) {
            Ok(()) => return,
            Err(TrySendError::Full(b)) => {
                alive.get_or_insert(k);
                batch = b;
            }
            Err(TrySendError::Disconnected(b)) => batch = b,
        }
    }
    let Some(k) = alive else {
        // Every worker is gone (they can only have panicked mid-run):
        // answer the requests with typed errors, not dropped channels.
        fail_batch(batch, metrics);
        return;
    };
    if let Err(SendError(b)) = batch_txs[k].send(batch) {
        fail_batch(b, metrics);
    }
}

/// Answer every request of an undeliverable batch with a typed error.
fn fail_batch(batch: Vec<Request>, metrics: &Metrics) {
    metrics.observe_batch_failure(batch.len());
    let err = BatchFail::Error(BatchError("engine worker unavailable".into()));
    for req in batch {
        let _ = req.reply.send(Err(err.clone()));
    }
}

impl Worker {
    fn run_batch(&self, pending: &mut Vec<Request>, metrics: &Metrics) -> Result<()> {
        let n = pending.len();
        // Pad to the static batch shape.
        let mut x = vec![0.0f32; self.batch * self.image_elems];
        for (i, req) in pending.iter().enumerate() {
            anyhow::ensure!(
                req.image.len() == self.image_elems,
                "bad image size {}",
                req.image.len()
            );
            x[i * self.image_elems..(i + 1) * self.image_elems].copy_from_slice(&req.image);
        }
        let xt = Tensor::new(vec![self.batch, 32, 32, 3], x);
        let logits = {
            let _span = crate::trace::span("backend.forward");
            self.backend.forward(&self.model, FwdKind::Serve, &self.theta, &xt)?
        };
        let k = logits.shape()[1];

        let now = Instant::now();
        // Record metrics *before* replying: callers may snapshot as soon as
        // their reply lands.
        let batch_lat = pending
            .iter()
            .map(|r| now.duration_since(r.t0).as_micros() as u64)
            .max()
            .unwrap_or(0);
        metrics.observe_batch(n, batch_lat);
        let mut max_lat = 0u64;
        for (i, req) in pending.drain(..).enumerate() {
            let row = logits.data()[i * k..(i + 1) * k].to_vec();
            let class = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap_or(0);
            let latency_us = now.duration_since(req.t0).as_micros() as u64;
            max_lat = max_lat.max(latency_us);
            // Per-request latency into the log2 histogram (percentiles),
            // before replying — callers may snapshot on reply arrival.
            metrics.observe_latency(latency_us);
            let _ = req.reply.send(Ok(Response { logits: row, class, latency_us }));
        }
        debug_assert!(max_lat <= batch_lat);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_engine_programs_crossbars_before_accepting_requests() {
        use crate::fixture;
        use crate::quant::{self, BitMap};

        let fx = fixture::tiny(9);
        let bits: Vec<u8> = (0..fx.model.num_strips())
            .map(|i| if i % 2 == 0 { 8 } else { 4 })
            .collect();
        let qcfg = crate::config::QuantConfig {
            device_sigma: 0.0,
            ..crate::config::QuantConfig::default()
        };
        let qm = quant::apply(&fx.model, &fx.theta, &BitMap { bits }, &qcfg);
        let spec = BackendSpec::Sim {
            cfg: SimXbarConfig::default().with_threads(1),
            strips: Some(StripPrecision::from_quantized(&qm)),
            scenario: None,
        };
        let engine = ShardedEngine::new(
            spec,
            &fx.model,
            qm.theta.clone(),
            EngineConfig::default().with_workers(2),
        )
        .unwrap();
        let handle = engine.start().unwrap();
        // The readiness handshake records each worker's programming cost
        // before the worker reports ready, so by the time start() returns —
        // i.e. before the first request can be accepted — every worker has
        // programmed its crossbars and the cost is observable.
        let snap = handle.metrics.snapshot();
        assert_eq!(snap.programmed_workers, 2, "both workers programmed before readiness");
        assert!(snap.program_ns_max > 0, "quantized deployment must program tiles");
        assert!(snap.program_ns_mean > 0.0);
        // And the programmed engine still answers requests.
        let r = handle.classify(vec![0.1; 32 * 32 * 3]).unwrap();
        assert_eq!(r.logits.len(), 10);
        // The worker folds its crossbar walk profile into the metrics
        // right after the batch (replies land first — poll briefly).
        let deadline = Instant::now() + Duration::from_secs(5);
        let walk = loop {
            let walk = handle.metrics.snapshot().walk;
            if walk.conv_calls > 0 || Instant::now() >= deadline {
                break walk;
            }
            std::thread::sleep(Duration::from_millis(5));
        };
        assert!(walk.conv_calls > 0, "programmed conv calls profiled");
        assert!(walk.strips_walked > 0);
        assert!(walk.packed_strips > 0, "quantized deployment walks packed strips");
        assert!(walk.kernel_simd + walk.kernel_scalar > 0);
        assert!(walk.scratch_high_water_bytes > 0);
    }

    #[test]
    fn sim_engine_records_fault_scenario_in_metrics() {
        use crate::faults::{Placement, Scenario, ScenarioSpec};
        use crate::fixture;

        let fx = fixture::tiny(11);
        let scenario = Scenario::new(ScenarioSpec::default().with_stuck(0.02, 3))
            .with_placement(Placement::SensitivityAware);
        let spec = BackendSpec::Sim {
            cfg: SimXbarConfig::default().with_threads(1),
            strips: None,
            scenario: Some(scenario.clone()),
        };
        let ecfg = EngineConfig::default();
        let engine = ShardedEngine::new(spec, &fx.model, fx.theta.clone(), ecfg).unwrap();
        let handle = engine.start().unwrap();
        assert_eq!(handle.metrics.scenario_desc(), scenario.describe());
        assert!(handle.metrics.scenario_desc().contains("stuck"));
        // A scenario-carrying fp32 deployment still serves (faults only
        // apply to quantized programming, so this is the healthy path).
        let r = handle.classify(vec![0.1; 32 * 32 * 3]).unwrap();
        assert_eq!(r.logits.len(), 10);
    }

    #[test]
    fn pending_wait_timeout_distinguishes_timeout_drop_and_failure() {
        // Timeout: a reply channel nobody answers must bound the wait.
        let (tx, rx) = sync_channel::<BatchResult>(1);
        let p = Pending { rx };
        match p.wait_timeout(Duration::from_millis(10)) {
            Err(WaitError::Timeout) => {}
            other => panic!("expected Timeout, got {other:?}"),
        }
        // Dropped: the engine went away without replying.
        drop(tx);
        match p.wait_timeout(Duration::from_millis(10)) {
            Err(WaitError::Dropped) => {}
            other => panic!("expected Dropped, got {other:?}"),
        }
        // Failed: a typed batch error passes through intact.
        let (tx, rx) = sync_channel::<BatchResult>(1);
        tx.send(Err(BatchFail::Error(BatchError("boom".into())))).unwrap();
        let p = Pending { rx };
        match p.wait_timeout(Duration::from_millis(10)) {
            Err(WaitError::Failed(e)) => assert_eq!(e.0, "boom"),
            other => panic!("expected Failed, got {other:?}"),
        }
        // Degraded: a respawning worker's typed reply carries its reason.
        let (tx, rx) = sync_channel::<BatchResult>(1);
        tx.send(Err(BatchFail::Degraded("respawning".into()))).unwrap();
        let p = Pending { rx };
        match p.wait_timeout(Duration::from_millis(10)) {
            Err(WaitError::Degraded { reason }) => assert_eq!(reason, "respawning"),
            other => panic!("expected Degraded, got {other:?}"),
        }
        // And a real response still comes through.
        let (tx, rx) = sync_channel::<BatchResult>(1);
        tx.send(Ok(Response { logits: vec![0.5], class: 0, latency_us: 7 }))
            .unwrap();
        let p = Pending { rx };
        let r = p.wait_timeout(Duration::from_millis(10)).unwrap();
        assert_eq!(r.class, 0);
        assert_eq!(r.latency_us, 7);
    }

    #[test]
    fn worker_panic_answers_degraded_then_respawns_and_recovers() {
        use crate::fixture;

        let fx = fixture::tiny(13);
        let spec = BackendSpec::Sim {
            cfg: SimXbarConfig::default().with_threads(1),
            strips: None,
            scenario: None,
        };
        // First batch across the pool panics deliberately.
        let ecfg = EngineConfig::default().with_chaos_panic_after(1);
        let engine = ShardedEngine::new(spec, &fx.model, fx.theta.clone(), ecfg).unwrap();
        let handle = engine.start().unwrap();

        // The request riding the panicked batch gets a typed Degraded
        // reply, not an error and not a dropped channel.
        let image = vec![0.1f32; 32 * 32 * 3];
        let p = handle.submit(image.clone()).unwrap();
        match p.wait_timeout(Duration::from_secs(30)) {
            Err(WaitError::Degraded { reason }) => {
                assert!(reason.contains("panicked"), "{reason}")
            }
            other => panic!("expected Degraded, got {other:?}"),
        }

        // The worker respawned in place: the next request is answered
        // normally and the supervision counters recorded the event.
        let r = handle.classify(image).unwrap();
        assert_eq!(r.logits.len(), 10);
        let snap = handle.metrics.snapshot();
        assert!(snap.respawns >= 1, "respawn must be counted");
        assert!(snap.degraded >= 1, "degraded reply must be counted");
        assert_eq!(snap.workers_down, 0);
        // Respawn re-programs the backend; both generations are recorded.
        assert!(snap.programmed_workers >= 2);
    }
}
