//! Serving engine: the L3 request hot path. Requests are dynamically
//! batched (size- or deadline-triggered), padded to the static `fwd_serve`
//! batch shape, executed on PJRT, and answered through per-request channels.
//! Python is never involved.
//!
//! Built on std threads + channels (this environment has no tokio; the
//! batching discipline is the same as a vLLM-style router's).

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::model::ModelInfo;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::Result;

use super::metrics::Metrics;

/// One classification request: a 32×32×3 image.
struct Request {
    t0: Instant,
    image: Vec<f32>,
    reply: SyncSender<BatchResult>,
}

/// Prediction for one image.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub class: usize,
    /// Time spent inside the engine (queue + execute), microseconds.
    pub latency_us: u64,
}

/// Why a request's batch failed inside the engine. Every pending request of
/// a failed batch receives this explicitly (no silently dropped channels).
#[derive(Clone, Debug)]
pub struct BatchError(pub String);

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for BatchError {}

type BatchResult = std::result::Result<Response, BatchError>;

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Flush a partial batch after this long.
    pub max_wait: Duration,
    /// Bounded queue length (backpressure).
    pub queue: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { max_wait: Duration::from_millis(2), queue: 1024 }
    }
}

/// Handle for submitting requests (cloneable across threads).
#[derive(Clone)]
pub struct EngineHandle {
    tx: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
}

/// A pending reply the caller can wait on.
pub struct Pending {
    rx: Receiver<BatchResult>,
}

impl Pending {
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow::anyhow!("engine batch failed: {e}")),
            Err(_) => Err(anyhow::anyhow!("engine dropped request")),
        }
    }
}

impl EngineHandle {
    /// Submit one image; returns a handle to wait on.
    pub fn submit(&self, image: Vec<f32>) -> Result<Pending> {
        let (reply, rx) = sync_channel(1);
        self.metrics.observe_request();
        self.tx
            .send(Request { t0: Instant::now(), image, reply })
            .map_err(|_| anyhow::anyhow!("engine stopped"))?;
        Ok(Pending { rx })
    }

    /// Submit and wait (convenience).
    pub fn classify(&self, image: Vec<f32>) -> Result<Response> {
        self.submit(image)?.wait()
    }
}

/// The engine: owns its *own* PJRT runtime (xla handles are not `Send`, so
/// the client lives entirely inside the batching thread), the quantized
/// weights and the batching loop.
pub struct Engine {
    artifacts: PathBuf,
    exe: String,
    theta: Tensor,
    batch: usize,
    image_elems: usize,
    cfg: EngineConfig,
}

/// Worker-side state (constructed inside the engine thread).
struct Worker {
    runtime: Runtime,
    exe: String,
    theta: Tensor,
    batch: usize,
    image_elems: usize,
}

impl Engine {
    pub fn new(
        artifacts: PathBuf,
        model: &ModelInfo,
        theta: Vec<f32>,
        cfg: EngineConfig,
    ) -> Result<Self> {
        let exe = model
            .entry
            .executables
            .get("fwd_serve")
            .ok_or_else(|| anyhow::anyhow!("model has no fwd_serve executable"))?
            .clone();
        Ok(Self {
            artifacts,
            exe,
            theta: Tensor::from_vec(theta),
            batch: model.entry.batch.serve,
            image_elems: 32 * 32 * 3,
            cfg,
        })
    }

    /// Spawn the batching loop; returns the submission handle. The loop
    /// exits when every handle is dropped.
    pub fn start(self) -> EngineHandle {
        let (tx, rx) = sync_channel::<Request>(self.cfg.queue);
        let metrics = Arc::new(Metrics::default());
        let handle = EngineHandle { tx, metrics: metrics.clone() };

        let cfg = self.cfg;
        std::thread::spawn(move || {
            // The PJRT client is created inside this thread (xla is !Send).
            let worker = match Runtime::new(self.artifacts.clone()) {
                Ok(runtime) => Worker {
                    runtime,
                    exe: self.exe,
                    theta: self.theta,
                    batch: self.batch,
                    image_elems: self.image_elems,
                },
                Err(e) => {
                    crate::error!("engine runtime failed to start: {e}");
                    return;
                }
            };
            let mut pending: Vec<Request> = Vec::with_capacity(worker.batch);
            loop {
                // Wait for the first request of a batch.
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break, // all senders gone
                }
                let deadline = Instant::now() + cfg.max_wait;
                // Fill until size- or deadline-triggered.
                while pending.len() < worker.batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                if let Err(e) = worker.run_batch(&mut pending, &metrics) {
                    crate::error!("batch failed: {e}");
                    // Answer every pending request with a typed error (no
                    // silently dropped reply channels) and count the failure.
                    metrics.observe_batch_failure(pending.len());
                    let err = BatchError(e.to_string());
                    for req in pending.drain(..) {
                        let _ = req.reply.send(Err(err.clone()));
                    }
                }
            }
        });
        handle
    }
}

impl Worker {
    fn run_batch(&self, pending: &mut Vec<Request>, metrics: &Metrics) -> Result<()> {
        let n = pending.len();
        // Pad to the static batch shape.
        let mut x = vec![0.0f32; self.batch * self.image_elems];
        for (i, req) in pending.iter().enumerate() {
            anyhow::ensure!(
                req.image.len() == self.image_elems,
                "bad image size {}",
                req.image.len()
            );
            x[i * self.image_elems..(i + 1) * self.image_elems].copy_from_slice(&req.image);
        }
        let xt = Tensor::new(vec![self.batch, 32, 32, 3], x);
        let out = self.runtime.exec(&self.exe, &[self.theta.clone(), xt])?;
        let logits = &out[0];
        let k = logits.shape()[1];

        let now = Instant::now();
        // Record metrics *before* replying: callers may snapshot as soon as
        // their reply lands.
        let batch_lat = pending
            .iter()
            .map(|r| now.duration_since(r.t0).as_micros() as u64)
            .max()
            .unwrap_or(0);
        metrics.observe_batch(n, batch_lat);
        let mut max_lat = 0u64;
        for (i, req) in pending.drain(..).enumerate() {
            let row = logits.data()[i * k..(i + 1) * k].to_vec();
            let class = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap_or(0);
            let latency_us = now.duration_since(req.t0).as_micros() as u64;
            max_lat = max_lat.max(latency_us);
            let _ = req.reply.send(Ok(Response { logits: row, class, latency_us }));
        }
        debug_assert!(max_lat <= batch_lat);
        Ok(())
    }
}
