//! Pipeline report types shared by the offline (tables/figures) and online
//! (serving) terminals of the staged [`CompressionPlan`] builder
//! (paper Figure 4). The builder itself lives in [`super::plan`].
//!
//! [`CompressionPlan`]: super::plan::CompressionPlan

use crate::coordinator::eval::Accuracy;
use crate::util::json::{obj, Value};
use crate::xbar::CostReport;

/// How the operating threshold is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ThresholdMode {
    /// Paper Algorithm 1 (gradient descent on the FIM difference).
    Alg1,
    /// Paper §5 deployment variant: FIM+energy Pareto sweep.
    Sweep,
    /// Fixed compression ratio (for CR-sweep experiments).
    FixedCr(f64),
}

impl ThresholdMode {
    pub fn to_value(&self) -> Value {
        match self {
            ThresholdMode::Alg1 => obj(vec![("kind", Value::Str("alg1".into()))]),
            ThresholdMode::Sweep => obj(vec![("kind", Value::Str("sweep".into()))]),
            ThresholdMode::FixedCr(cr) => obj(vec![
                ("kind", Value::Str("fixed_cr".into())),
                ("cr", Value::Num(*cr)),
            ]),
        }
    }
}

/// Everything one evaluated plan produces.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub model: String,
    pub mode: ThresholdMode,
    pub compression_ratio: f64,
    pub q_hi: usize,
    pub total_strips: usize,
    pub accuracy: Accuracy,
    pub fp32_accuracy: f64,
    pub cost: CostReport,
    pub utilization_hi: f64,
    pub utilization_all: f64,
    pub quant_mse: f64,
    pub threshold: f64,
    pub fim_evals: usize,
}

impl PipelineReport {
    /// Machine-readable form (the CLI's `--json` output).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("model", Value::Str(self.model.clone())),
            ("mode", self.mode.to_value()),
            ("compression_ratio", Value::Num(self.compression_ratio)),
            ("q_hi", Value::Num(self.q_hi as f64)),
            ("total_strips", Value::Num(self.total_strips as f64)),
            ("accuracy", self.accuracy.to_value()),
            ("fp32_accuracy", Value::Num(self.fp32_accuracy)),
            ("cost", self.cost.to_value()),
            ("utilization_hi", Value::Num(self.utilization_hi)),
            ("utilization_all", Value::Num(self.utilization_all)),
            ("quant_mse", Value::Num(self.quant_mse)),
            ("threshold", Value::num_or_null(self.threshold)),
            ("fim_evals", Value::Num(self.fim_evals as f64)),
        ])
    }
}
