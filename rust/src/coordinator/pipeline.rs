//! The end-to-end compression pipeline (paper Figure 4): pre-trained model →
//! strip sensitivity (Hessian) → threshold (FIM) → clustering + crossbar
//! alignment → mixed-precision quantization → crossbar mapping → cost +
//! accuracy report.


use crate::clustering::{self, Clustering};
use crate::config::RunConfig;
use crate::coordinator::eval::{self, Accuracy};
use crate::dataset::{CalibSet, TestSet};
use crate::fim::ThresholdSearch;
use crate::model::{Manifest, ModelInfo};
use crate::quant::{self, BitMap};
use crate::runtime::Runtime;
use crate::sensitivity::{Analyzer, Sensitivity};
use crate::xbar::{self, CostReport, MappingStrategy};
use crate::Result;

/// How the operating threshold is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ThresholdMode {
    /// Paper Algorithm 1 (gradient descent on the FIM difference).
    Alg1,
    /// Paper §5 deployment variant: FIM+energy Pareto sweep.
    Sweep,
    /// Fixed compression ratio (for CR-sweep experiments).
    FixedCr(f64),
}

/// Everything one pipeline run produces.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub model: String,
    pub mode: ThresholdMode,
    pub compression_ratio: f64,
    pub q_hi: usize,
    pub total_strips: usize,
    pub accuracy: Accuracy,
    pub fp32_accuracy: f64,
    pub cost: CostReport,
    pub utilization_hi: f64,
    pub utilization_all: f64,
    pub quant_mse: f64,
    pub threshold: f64,
    pub fim_evals: usize,
}

/// Owns the loaded state for one model and runs pipeline variants on it.
pub struct Pipeline<'a> {
    pub runtime: &'a Runtime,
    pub manifest: &'a Manifest,
    pub model: ModelInfo,
    pub theta: Vec<f32>,
    pub test: TestSet,
    pub calib: CalibSet,
    pub cfg: RunConfig,
    sensitivity: Option<Sensitivity>,
}

impl<'a> Pipeline<'a> {
    pub fn new(
        runtime: &'a Runtime,
        manifest: &'a Manifest,
        model_name: &str,
        cfg: RunConfig,
    ) -> Result<Self> {
        let model = manifest.model(model_name)?;
        let theta = model.load_params(manifest)?;
        let test = TestSet::load(manifest)?;
        let calib = CalibSet::load(manifest, model.entry.batch.calib)?;
        Ok(Self { runtime, manifest, model, theta, test, calib, cfg, sensitivity: None })
    }

    /// Hutchinson sensitivity scores (cached across runs on this pipeline).
    pub fn sensitivity(&mut self) -> Result<&Sensitivity> {
        if self.sensitivity.is_none() {
            let analyzer = Analyzer {
                runtime: self.runtime,
                model: &self.model,
                calib: &self.calib,
                cfg: self.cfg.sensitivity,
            };
            crate::info!("hutchinson sensitivity: model={} probes={}", self.model.name(), self.cfg.sensitivity.probes);
            self.sensitivity = Some(analyzer.run(&self.theta)?);
        }
        Ok(self.sensitivity.as_ref().unwrap())
    }

    /// Choose a clustering according to `mode`.
    pub fn choose_clustering(&mut self, mode: ThresholdMode) -> Result<(Clustering, usize)> {
        let quant_cfg = self.cfg.quant;
        let thr_cfg = self.cfg.threshold;
        self.sensitivity()?;
        let sens = self.sensitivity.clone().unwrap();
        let (clustering, evals) = match mode {
            ThresholdMode::FixedCr(cr) => (
                clustering::cluster_at_cr(&sens.scores, cr, quant_cfg.hi.bits, quant_cfg.lo.bits),
                0,
            ),
            ThresholdMode::Alg1 | ThresholdMode::Sweep => {
                let search = ThresholdSearch {
                    runtime: self.runtime,
                    model: &self.model,
                    calib: &self.calib,
                    sens: &sens,
                    quant_cfg,
                    cfg: thr_cfg,
                };
                let res = if mode == ThresholdMode::Alg1 {
                    search.gradient_descent(&self.theta)?
                } else {
                    search.sweep(
                        &self.theta,
                        &[0.0, 0.1, 0.3, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0],
                        0.5,
                    )?
                };
                crate::info!("threshold chosen: q={:.3} fim={:.4e}", res.best.quantile, res.best.fim_dist);
                (
                    clustering::cluster_at_cr(
                        &sens.scores,
                        res.best.quantile,
                        quant_cfg.hi.bits,
                        quant_cfg.lo.bits,
                    ),
                    res.evals,
                )
            }
        };
        Ok((clustering, evals))
    }

    /// Run the full pipeline. `align` enables the paper's dynamic crossbar
    /// alignment; `strategy` picks the mapper.
    pub fn run(
        &mut self,
        mode: ThresholdMode,
        align: bool,
        strategy: MappingStrategy,
        eval_batches: usize,
    ) -> Result<PipelineReport> {
        let (mut clustering, fim_evals) = self.choose_clustering(mode)?;
        let quant_cfg = self.cfg.quant;
        let xcfg = self.cfg.xbar;

        if align {
            let sens = self.sensitivity.clone().unwrap();
            let model = &self.model;
            let caps: Vec<usize> = model
                .conv_layers()
                .iter()
                .map(|l| xcfg.capacity_strips(l.d, quant_cfg.hi.bits))
                .collect();
            clustering = clustering::align_to_capacity(
                model,
                &sens.scores,
                &clustering,
                quant_cfg.hi.bits,
                quant_cfg.lo.bits,
                |li| caps[li],
            );
        }

        self.report_for_bitmap(&clustering.bitmap, mode, clustering.threshold, fim_evals, strategy, eval_batches)
    }

    /// Quantize + map + evaluate an explicit bitmap (shared by baselines).
    pub fn report_for_bitmap(
        &mut self,
        bitmap: &BitMap,
        mode: ThresholdMode,
        threshold: f64,
        fim_evals: usize,
        strategy: MappingStrategy,
        eval_batches: usize,
    ) -> Result<PipelineReport> {
        let quant_cfg = self.cfg.quant;
        let xcfg = self.cfg.xbar;
        let qm = quant::apply(&self.model, &self.theta, bitmap, &quant_cfg);
        let mapping = xbar::map_model(&self.model, bitmap, &xcfg, strategy);
        let cost = xbar::cost(&mapping, &xcfg);
        let accuracy =
            eval::evaluate_batches(self.runtime, &self.model, &qm.theta, &self.test, eval_batches)?;
        let q_hi = bitmap.count_bits(quant_cfg.hi.bits);
        Ok(PipelineReport {
            model: self.model.name().to_string(),
            mode,
            compression_ratio: bitmap.compression_ratio(quant_cfg.hi.bits),
            q_hi,
            total_strips: bitmap.bits.len(),
            accuracy,
            fp32_accuracy: self.model.entry.fp32_test_acc,
            cost,
            utilization_hi: mapping.utilization(quant_cfg.hi.bits),
            utilization_all: mapping.utilization_all(),
            quant_mse: qm.mse,
            threshold,
            fim_evals,
        })
    }
}
