//! Lightweight serving metrics: lock-free counters, latency aggregation,
//! and a fixed-bucket log2 latency histogram.
//!
//! The histogram records **per-request** latencies (the engine feeds it one
//! observation per answered request) into 64 power-of-two buckets — bucket
//! `k` covers `[2^k, 2^(k+1))` microseconds, bucket 0 additionally holds 0.
//! Percentile queries return the *upper edge* of the bucket holding the
//! requested rank, so they over- rather than under-report tail latency and
//! never interpolate between observations that were not taken.
//!
//! Two edges need care. The top bucket (63) has no finite power-of-two
//! upper edge; a percentile landing there is **clamped** to
//! [`LATENCY_SATURATION_US`] (2⁶³) instead of reporting `u64::MAX` µs as
//! if it were a measurement, and [`Snapshot::latency_saturated`] flags the
//! clamp so the stats line can label the value `>=` rather than present a
//! five-century latency as observed. At the bottom, bucket 0 conflates 0
//! and 1 µs — sub-µs observations surface as 1 µs, which
//! [`fmt_latency_us`] renders as `<=1` (an upper bound, like every other
//! bucket edge, not a claim the request took a full microsecond).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::backend::{WalkProfile, WalkProfileAtomic};
use crate::util::json::{obj, Value};

/// Number of log2 latency buckets. 64 covers the entire `u64` microsecond
/// range (bucket 63 is `[2^63, u64::MAX]`), so every observation is
/// recorded — but a percentile landing in bucket 63 has no finite bucket
/// edge to report and is clamped to [`LATENCY_SATURATION_US`].
pub const HIST_BUCKETS: usize = 64;

/// Clamp value reported for percentiles that land in the open-ended top
/// bucket (`[2^63, u64::MAX]` µs). A reported latency equal to this value
/// means "at least 2⁶³ µs" — a saturated measurement, not an observation;
/// [`Snapshot::latency_saturated`] is set whenever the histogram holds any
/// such sample, and [`fmt_latency_us`] labels the value `>=2^63`.
pub const LATENCY_SATURATION_US: u64 = 1u64 << 63;

/// Bucket index of a latency: `floor(log2(us))`, with 0 mapping onto
/// bucket 0 alongside 1.
fn bucket(latency_us: u64) -> usize {
    if latency_us == 0 {
        0
    } else {
        63 - latency_us.leading_zeros() as usize
    }
}

/// The value a percentile query reports for a bucket: its largest member,
/// except the open-ended top bucket, which clamps to
/// [`LATENCY_SATURATION_US`] so a saturated tail reads "at least 2⁶³" and
/// never `u64::MAX` µs masquerading as a measurement.
fn bucket_upper_edge(k: usize) -> u64 {
    if k >= 63 {
        LATENCY_SATURATION_US
    } else {
        (1u64 << (k + 1)) - 1
    }
}

/// Render a histogram-derived latency for the stats line. Bucket edges are
/// upper bounds, and two of them need labels to read honestly: bucket 0's
/// edge conflates sub-µs requests with 1 µs ones (`<=1`), and the top
/// bucket's clamped edge is a floor, not a measurement (`>=2^63`).
pub fn fmt_latency_us(us: u64) -> String {
    if us >= LATENCY_SATURATION_US {
        ">=2^63".to_string()
    } else if us == 1 {
        // Bucket 0's upper edge: the request took at most 1 µs, possibly 0.
        "<=1".to_string()
    } else {
        // 0 only appears when nothing was observed; report it bare.
        us.to_string()
    }
}

/// Smallest rank (1-based) covered by quantile `q` over `total` samples,
/// then the upper edge of the bucket where the cumulative count reaches it.
fn quantile_from(counts: &[u64; HIST_BUCKETS], q: f64, total: u64) -> u64 {
    if total == 0 {
        return 0;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (k, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return bucket_upper_edge(k);
        }
    }
    bucket_upper_edge(HIST_BUCKETS - 1)
}

pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Batches whose execution failed (every member request got an error
    /// reply — see `engine::BatchError`).
    pub failed_batches: AtomicU64,
    /// Requests answered with an error because their batch failed.
    pub failed_requests: AtomicU64,
    latency_us_sum: AtomicU64,
    latency_us_max: AtomicU64,
    /// Per-request latency histogram (log2 buckets, microseconds).
    hist: [AtomicU64; HIST_BUCKETS],
    /// Sum of per-worker deploy-time crossbar-programming nanoseconds.
    program_ns_total: AtomicU64,
    /// Slowest worker's programming time (the startup critical path).
    program_ns_max: AtomicU64,
    /// Workers that completed their deploy-time programming phase (the
    /// engine records one observation per worker, before readiness).
    programmed_workers: AtomicU64,
    /// Requests refused because the admission queue was full.
    rejected_queue_full: AtomicU64,
    /// Requests refused because the frame failed to decode (protocol
    /// error, wrong image size).
    rejected_decode: AtomicU64,
    /// Requests refused because the batcher was already shut down.
    rejected_shutdown: AtomicU64,
    /// Requests answered degraded because their reply deadline expired
    /// (`wait_timeout` in the serve front-end).
    rejected_deadline: AtomicU64,
    /// Health-monitor steps that probed canary strips.
    health_probes: AtomicU64,
    /// Canary code lanes found mismatched against the programmed state.
    health_canary_mismatches: AtomicU64,
    /// Physical slots quarantined (vacated) by completed repairs.
    health_quarantined: AtomicU64,
    /// Strips migrated to a new physical slot by completed repairs.
    health_repairs: AtomicU64,
    /// Standby artifacts hot-swapped in at a batch boundary.
    health_swaps: AtomicU64,
    /// Background standby re-programming passes started.
    health_reprograms: AtomicU64,
    /// Workers respawned in place after a mid-batch panic.
    worker_respawns: AtomicU64,
    /// Workers that went down for good (respawn failed).
    workers_down: AtomicU64,
    /// Requests answered with a typed degraded reply (worker panic or
    /// missed deadline).
    degraded_replies: AtomicU64,
    /// Aggregated crossbar walk-profile counters (engine workers push
    /// per-batch deltas from their backend's [`WalkProfile`]).
    walk: WalkProfileAtomic,
    /// Description of the deployed fault scenario + placement mode (set
    /// once by the engine at startup; `None` = fault-free). Kept out of
    /// [`Snapshot`] so the snapshot stays `Copy`.
    scenario: Mutex<Option<String>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            failed_batches: AtomicU64::new(0),
            failed_requests: AtomicU64::new(0),
            latency_us_sum: AtomicU64::new(0),
            latency_us_max: AtomicU64::new(0),
            hist: std::array::from_fn(|_| AtomicU64::new(0)),
            program_ns_total: AtomicU64::new(0),
            program_ns_max: AtomicU64::new(0),
            programmed_workers: AtomicU64::new(0),
            rejected_queue_full: AtomicU64::new(0),
            rejected_decode: AtomicU64::new(0),
            rejected_shutdown: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
            health_probes: AtomicU64::new(0),
            health_canary_mismatches: AtomicU64::new(0),
            health_quarantined: AtomicU64::new(0),
            health_repairs: AtomicU64::new(0),
            health_swaps: AtomicU64::new(0),
            health_reprograms: AtomicU64::new(0),
            worker_respawns: AtomicU64::new(0),
            workers_down: AtomicU64::new(0),
            degraded_replies: AtomicU64::new(0),
            walk: WalkProfileAtomic::default(),
            scenario: Mutex::new(None),
        }
    }
}

/// Point-in-time snapshot of the serving metrics. The percentiles come from
/// the per-request log2 histogram: each is the upper edge of its bucket
/// (conservative — never below the true percentile by more than the bucket
/// resolution, never above a real observation's bucket).
#[derive(Clone, Copy, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub failed_batches: u64,
    pub failed_requests: u64,
    pub mean_batch_fill: f64,
    pub mean_latency_us: f64,
    pub max_latency_us: u64,
    /// Per-request latencies observed by the histogram (answered requests).
    pub observed_requests: u64,
    pub p50_latency_us: u64,
    pub p95_latency_us: u64,
    pub p99_latency_us: u64,
    /// True when the histogram holds at least one observation in the
    /// open-ended top bucket (`>= 2^63` µs). Any percentile equal to
    /// [`LATENCY_SATURATION_US`] is then a clamped floor, not a
    /// measurement.
    pub latency_saturated: bool,
    /// Workers whose deploy-time programming phase completed (recorded
    /// before the engine's readiness handshake concludes). Counts every
    /// worker, including backends with nothing to program — those report
    /// 0 ns, so `program_ns_max > 0` is the "tiles were actually
    /// programmed" signal.
    pub programmed_workers: u64,
    /// Mean per-worker programming nanoseconds (0 when nothing programmed).
    pub program_ns_mean: f64,
    /// Slowest worker's programming nanoseconds.
    pub program_ns_max: u64,
    /// Requests refused because the admission queue was full.
    pub rejected_queue_full: u64,
    /// Requests refused because the frame failed to decode.
    pub rejected_decode: u64,
    /// Requests refused because the batcher was already shut down.
    pub rejected_shutdown: u64,
    /// Requests answered degraded because their reply deadline expired.
    pub rejected_deadline: u64,
    /// Health-monitor steps that probed canary strips.
    pub probes: u64,
    /// Canary code lanes found mismatched against the programmed state.
    pub canary_mismatches: u64,
    /// Physical slots quarantined (vacated) by completed repairs.
    pub quarantined: u64,
    /// Strips migrated to a new physical slot by completed repairs.
    pub repairs: u64,
    /// Standby artifacts hot-swapped in at a batch boundary.
    pub swaps: u64,
    /// Background standby re-programming passes started.
    pub reprograms: u64,
    /// Workers respawned in place after a mid-batch panic.
    pub respawns: u64,
    /// Workers that went down for good (respawn failed).
    pub workers_down: u64,
    /// Requests answered with a typed degraded reply.
    pub degraded: u64,
    /// Aggregated crossbar walk-profile counters.
    pub walk: WalkProfile,
}

impl Snapshot {
    /// All rejections, whatever the reason (the pre-split single counter).
    /// Deadline misses count here too: the request was admitted but never
    /// answered with logits, which is what a caller retrying on "rejected"
    /// cares about.
    pub fn rejected_total(&self) -> u64 {
        self.rejected_queue_full
            + self.rejected_decode
            + self.rejected_shutdown
            + self.rejected_deadline
    }
}

impl Metrics {
    pub fn observe_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_batch(&self, items: usize, latency_us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
        self.latency_us_sum.fetch_add(latency_us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(latency_us, Ordering::Relaxed);
    }

    /// One answered request's end-to-end engine latency into the histogram.
    pub fn observe_latency(&self, latency_us: u64) {
        self.hist[bucket(latency_us)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_batch_failure(&self, items: usize) {
        self.failed_batches.fetch_add(1, Ordering::Relaxed);
        self.failed_requests.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// One worker's deploy-time crossbar-programming cost. The engine calls
    /// this once per worker, after its backend's readiness check and before
    /// the worker reports ready — so by the time `start()` returns, every
    /// worker's programming is both finished and recorded here.
    pub fn observe_program(&self, ns: u64) {
        self.program_ns_total.fetch_add(ns, Ordering::Relaxed);
        self.program_ns_max.fetch_max(ns, Ordering::Relaxed);
        self.programmed_workers.fetch_add(1, Ordering::Relaxed);
    }

    /// A request refused because the admission queue was full.
    pub fn observe_rejected_queue_full(&self) {
        self.rejected_queue_full.fetch_add(1, Ordering::Relaxed);
    }

    /// A request refused because its frame failed to decode (protocol
    /// error or wrong image size).
    pub fn observe_rejected_decode(&self) {
        self.rejected_decode.fetch_add(1, Ordering::Relaxed);
    }

    /// A request refused because the batcher was already shut down.
    pub fn observe_rejected_shutdown(&self) {
        self.rejected_shutdown.fetch_add(1, Ordering::Relaxed);
    }

    /// An admitted request whose reply deadline expired before its batch
    /// finished (answered with a typed degraded frame, not an error).
    pub fn observe_rejected_deadline(&self) {
        self.rejected_deadline.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one self-healing monitor step in (engine workers call this
    /// after every [`crate::health::StepReport`] their backend returns).
    pub fn observe_health(&self, rep: &crate::health::StepReport) {
        let r = Ordering::Relaxed;
        self.health_probes.fetch_add(rep.probes, r);
        self.health_canary_mismatches.fetch_add(rep.canary_mismatches, r);
        self.health_quarantined.fetch_add(rep.quarantined, r);
        self.health_repairs.fetch_add(rep.repairs, r);
        if rep.swapped {
            self.health_swaps.fetch_add(1, r);
        }
        if rep.reprogram_started {
            self.health_reprograms.fetch_add(1, r);
        }
    }

    /// A worker respawned in place after a mid-batch panic.
    pub fn observe_respawn(&self) {
        self.worker_respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker went down for good (its respawn failed).
    pub fn observe_worker_down(&self) {
        self.workers_down.fetch_add(1, Ordering::Relaxed);
    }

    /// A request answered with a typed degraded reply (worker panic or
    /// missed deadline) instead of logits.
    pub fn observe_degraded(&self) {
        self.degraded_replies.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold a crossbar walk-profile delta in (engine workers call this
    /// once per batch with the change since their last snapshot).
    pub fn add_walk(&self, delta: &WalkProfile) {
        self.walk.add(delta);
    }

    /// Record the deployed fault scenario description (the engine sets it
    /// once at startup, before readiness).
    pub fn set_scenario(&self, desc: String) {
        *self.scenario.lock().unwrap() = Some(desc);
    }

    /// The deployed fault scenario + placement mode; "none" when the
    /// deployment is fault-free (or nothing was recorded).
    pub fn scenario_desc(&self) -> String {
        self.scenario.lock().unwrap().clone().unwrap_or_else(|| "none".to_string())
    }

    pub fn snapshot(&self) -> Snapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        let workers = self.programmed_workers.load(Ordering::Relaxed);
        let mut counts = [0u64; HIST_BUCKETS];
        let mut observed = 0u64;
        for (dst, src) in counts.iter_mut().zip(self.hist.iter()) {
            *dst = src.load(Ordering::Relaxed);
            observed += *dst;
        }
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            failed_batches: self.failed_batches.load(Ordering::Relaxed),
            failed_requests: self.failed_requests.load(Ordering::Relaxed),
            mean_batch_fill: if batches == 0 {
                0.0
            } else {
                self.batched_items.load(Ordering::Relaxed) as f64 / batches as f64
            },
            mean_latency_us: if batches == 0 {
                0.0
            } else {
                self.latency_us_sum.load(Ordering::Relaxed) as f64 / batches as f64
            },
            max_latency_us: self.latency_us_max.load(Ordering::Relaxed),
            observed_requests: observed,
            p50_latency_us: quantile_from(&counts, 0.50, observed),
            p95_latency_us: quantile_from(&counts, 0.95, observed),
            p99_latency_us: quantile_from(&counts, 0.99, observed),
            latency_saturated: counts[HIST_BUCKETS - 1] > 0,
            programmed_workers: workers,
            program_ns_mean: if workers == 0 {
                0.0
            } else {
                self.program_ns_total.load(Ordering::Relaxed) as f64 / workers as f64
            },
            program_ns_max: self.program_ns_max.load(Ordering::Relaxed),
            rejected_queue_full: self.rejected_queue_full.load(Ordering::Relaxed),
            rejected_decode: self.rejected_decode.load(Ordering::Relaxed),
            rejected_shutdown: self.rejected_shutdown.load(Ordering::Relaxed),
            rejected_deadline: self.rejected_deadline.load(Ordering::Relaxed),
            probes: self.health_probes.load(Ordering::Relaxed),
            canary_mismatches: self.health_canary_mismatches.load(Ordering::Relaxed),
            quarantined: self.health_quarantined.load(Ordering::Relaxed),
            repairs: self.health_repairs.load(Ordering::Relaxed),
            swaps: self.health_swaps.load(Ordering::Relaxed),
            reprograms: self.health_reprograms.load(Ordering::Relaxed),
            respawns: self.worker_respawns.load(Ordering::Relaxed),
            workers_down: self.workers_down.load(Ordering::Relaxed),
            degraded: self.degraded_replies.load(Ordering::Relaxed),
            walk: self.walk.snapshot(),
        }
    }

    /// The raw log2 latency histogram counts (bucket `k` covers
    /// `[2^k, 2^(k+1))` µs; see the module docs for the edge buckets).
    pub fn hist_counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|k| self.hist[k].load(Ordering::Relaxed))
    }

    /// Merge another metrics instance into this one: counters and the
    /// histogram add bucket-wise, maxima merge as maxima, and the walk
    /// profile absorbs. The scenario description is kept unless unset here.
    /// This is how per-shard or per-process serve stats fold into one view,
    /// and the merge the histogram tests pin down: merged percentiles stay
    /// monotone and every bucket is the exact sum of its inputs.
    pub fn absorb(&self, other: &Metrics) {
        let r = Ordering::Relaxed;
        self.requests.fetch_add(other.requests.load(r), r);
        self.batches.fetch_add(other.batches.load(r), r);
        self.batched_items.fetch_add(other.batched_items.load(r), r);
        self.failed_batches.fetch_add(other.failed_batches.load(r), r);
        self.failed_requests.fetch_add(other.failed_requests.load(r), r);
        self.latency_us_sum.fetch_add(other.latency_us_sum.load(r), r);
        self.latency_us_max.fetch_max(other.latency_us_max.load(r), r);
        for (dst, src) in self.hist.iter().zip(other.hist.iter()) {
            dst.fetch_add(src.load(r), r);
        }
        self.program_ns_total.fetch_add(other.program_ns_total.load(r), r);
        self.program_ns_max.fetch_max(other.program_ns_max.load(r), r);
        self.programmed_workers.fetch_add(other.programmed_workers.load(r), r);
        self.rejected_queue_full.fetch_add(other.rejected_queue_full.load(r), r);
        self.rejected_decode.fetch_add(other.rejected_decode.load(r), r);
        self.rejected_shutdown.fetch_add(other.rejected_shutdown.load(r), r);
        self.rejected_deadline.fetch_add(other.rejected_deadline.load(r), r);
        self.health_probes.fetch_add(other.health_probes.load(r), r);
        self.health_canary_mismatches.fetch_add(other.health_canary_mismatches.load(r), r);
        self.health_quarantined.fetch_add(other.health_quarantined.load(r), r);
        self.health_repairs.fetch_add(other.health_repairs.load(r), r);
        self.health_swaps.fetch_add(other.health_swaps.load(r), r);
        self.health_reprograms.fetch_add(other.health_reprograms.load(r), r);
        self.worker_respawns.fetch_add(other.worker_respawns.load(r), r);
        self.workers_down.fetch_add(other.workers_down.load(r), r);
        self.degraded_replies.fetch_add(other.degraded_replies.load(r), r);
        self.walk.add(&other.walk.snapshot());
        let mut mine = self.scenario.lock().unwrap();
        if mine.is_none() {
            mine.clone_from(&other.scenario.lock().unwrap());
        }
    }

    /// The complete machine-readable snapshot as a JSON value: engine
    /// counters, latency percentiles, raw histogram buckets, program cost,
    /// rejected-by-reason breakdown, scenario, and the walk profile. The
    /// server wraps this with its connection/batcher objects to answer
    /// `StatsJsonReq`.
    pub fn stats_value(&self) -> Value {
        let s = self.snapshot();
        let n = |v: u64| Value::Num(v as f64);
        obj(vec![
            (
                "engine",
                obj(vec![
                    ("requests", n(s.requests)),
                    ("batches", n(s.batches)),
                    ("failed_batches", n(s.failed_batches)),
                    ("failed_requests", n(s.failed_requests)),
                    ("mean_batch_fill", Value::Num(s.mean_batch_fill)),
                    (
                        "latency",
                        obj(vec![
                            ("mean_batch_us", Value::Num(s.mean_latency_us)),
                            ("max_us", n(s.max_latency_us)),
                            ("observed_requests", n(s.observed_requests)),
                            ("p50_us", n(s.p50_latency_us)),
                            ("p95_us", n(s.p95_latency_us)),
                            ("p99_us", n(s.p99_latency_us)),
                            ("saturated", Value::Bool(s.latency_saturated)),
                        ]),
                    ),
                ]),
            ),
            (
                "rejected",
                obj(vec![
                    ("queue_full", n(s.rejected_queue_full)),
                    ("decode", n(s.rejected_decode)),
                    ("shutdown", n(s.rejected_shutdown)),
                    ("deadline", n(s.rejected_deadline)),
                    ("total", n(s.rejected_total())),
                ]),
            ),
            (
                "health",
                obj(vec![
                    ("probes", n(s.probes)),
                    ("canary_mismatches", n(s.canary_mismatches)),
                    ("quarantined", n(s.quarantined)),
                    ("repairs", n(s.repairs)),
                    ("swaps", n(s.swaps)),
                    ("reprograms", n(s.reprograms)),
                    ("respawns", n(s.respawns)),
                    ("workers_down", n(s.workers_down)),
                    ("degraded", n(s.degraded)),
                ]),
            ),
            (
                "program",
                obj(vec![
                    ("workers", n(s.programmed_workers)),
                    ("ns_mean", Value::Num(s.program_ns_mean)),
                    ("ns_max", n(s.program_ns_max)),
                ]),
            ),
            ("scenario", Value::Str(self.scenario_desc())),
            ("walk_profile", s.walk.to_value()),
            (
                "hist",
                Value::Arr(self.hist_counts().iter().map(|&c| Value::Num(c as f64)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.observe_request();
        m.observe_request();
        m.observe_batch(2, 100);
        m.observe_batch(1, 300);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 1.5).abs() < 1e-12);
        assert!((s.mean_latency_us - 200.0).abs() < 1e-12);
        assert_eq!(s.max_latency_us, 300);
        assert_eq!(s.failed_batches, 0);
        assert_eq!(s.failed_requests, 0);
    }

    #[test]
    fn batch_failures_are_counted_separately() {
        let m = Metrics::default();
        for _ in 0..3 {
            m.observe_request();
        }
        m.observe_batch(1, 50);
        m.observe_batch_failure(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 1, "failed batches do not pollute the success count");
        assert_eq!(s.failed_batches, 1);
        assert_eq!(s.failed_requests, 2);
        assert!((s.mean_batch_fill - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // bucket k covers [2^k, 2^(k+1)); 0 shares bucket 0 with 1.
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 1);
        assert_eq!(bucket(4), 2);
        assert_eq!(bucket(7), 2);
        assert_eq!(bucket(8), 3);
        assert_eq!(bucket(1023), 9);
        assert_eq!(bucket(1024), 10);
        // the 62/63 boundary: bucket 62 covers [2^62, 2^63), 63 the rest
        assert_eq!(bucket(1u64 << 62), 62);
        assert_eq!(bucket((1u64 << 63) - 1), 62);
        assert_eq!(bucket(1u64 << 63), 63);
        assert_eq!(bucket(u64::MAX), 63);
        // upper edges are the largest member of each bucket — except the
        // open-ended top bucket, which clamps to the saturation floor
        // instead of reporting u64::MAX as if it were observed.
        assert_eq!(bucket_upper_edge(0), 1);
        assert_eq!(bucket_upper_edge(1), 3);
        assert_eq!(bucket_upper_edge(9), 1023);
        assert_eq!(bucket_upper_edge(62), (1u64 << 63) - 1);
        assert_eq!(bucket_upper_edge(63), LATENCY_SATURATION_US);
        // every bucket's upper edge maps back into that bucket, so a
        // reported percentile always lands in the bucket it came from
        for k in 0..HIST_BUCKETS {
            assert_eq!(bucket(bucket_upper_edge(k)), k, "edge of bucket {k}");
        }
    }

    #[test]
    fn histogram_percentiles_report_bucket_upper_edges() {
        let m = Metrics::default();
        // Four fast requests (bucket 0) and one slow outlier at 100 us
        // (bucket 6: [64, 128), upper edge 127).
        for _ in 0..4 {
            m.observe_latency(1);
        }
        m.observe_latency(100);
        let s = m.snapshot();
        assert_eq!(s.observed_requests, 5);
        // p50 rank = ceil(0.5 * 5) = 3 -> bucket 0 -> edge 1.
        assert_eq!(s.p50_latency_us, 1);
        // p95 rank = ceil(4.75) = 5 -> the outlier's bucket edge.
        assert_eq!(s.p95_latency_us, 127);
        assert_eq!(s.p99_latency_us, 127);
    }

    #[test]
    fn programming_cost_aggregates_per_worker() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.programmed_workers, 0);
        assert_eq!(s.program_ns_mean, 0.0);
        assert_eq!(s.program_ns_max, 0);
        m.observe_program(100);
        m.observe_program(300);
        let s = m.snapshot();
        assert_eq!(s.programmed_workers, 2);
        assert!((s.program_ns_mean - 200.0).abs() < 1e-12);
        assert_eq!(s.program_ns_max, 300);
    }

    #[test]
    fn scenario_description_defaults_to_none_and_records_once_set() {
        let m = Metrics::default();
        assert_eq!(m.scenario_desc(), "none");
        m.set_scenario("stuck(rate=0.05) placement=sensitivity".to_string());
        assert_eq!(m.scenario_desc(), "stuck(rate=0.05) placement=sensitivity");
    }

    #[test]
    fn histogram_empty_and_saturated() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.observed_requests, 0);
        assert_eq!(s.p50_latency_us, 0);
        assert_eq!(s.p99_latency_us, 0);
        assert!(!s.latency_saturated);
        // The top bucket accepts the largest representable latency, but the
        // reported percentile clamps to the saturation floor (and flags it)
        // rather than claiming a u64::MAX-µs request was measured.
        m.observe_latency(u64::MAX);
        let s = m.snapshot();
        assert_eq!(s.observed_requests, 1);
        assert_eq!(s.p50_latency_us, LATENCY_SATURATION_US);
        assert!(s.latency_saturated);
        // A single sub-µs request: bucket 0's edge, never a bare 0.
        let m = Metrics::default();
        m.observe_latency(0);
        let s = m.snapshot();
        assert_eq!(s.p50_latency_us, 1);
        assert!(!s.latency_saturated);
    }

    #[test]
    fn absorb_merges_histograms_bucket_wise() {
        let a = Metrics::default();
        let b = Metrics::default();
        // a: fast requests (buckets 0 and 3); b: slow ones (buckets 6, 10)
        for _ in 0..4 {
            a.observe_latency(1);
        }
        a.observe_latency(9);
        b.observe_latency(100);
        b.observe_latency(100);
        b.observe_latency(1500);
        let ha = a.hist_counts();
        let hb = b.hist_counts();
        a.absorb(&b);
        let merged = a.hist_counts();
        // every bucket is the exact element-wise sum of its inputs
        for k in 0..HIST_BUCKETS {
            assert_eq!(merged[k], ha[k] + hb[k], "bucket {k}");
        }
        let s = a.snapshot();
        assert_eq!(s.observed_requests, 8);
        // merged percentiles are bucket edges of the combined population
        assert_eq!(s.p50_latency_us, 1); // rank 4 of 8 -> bucket 0 edge
        assert_eq!(s.p95_latency_us, 2047); // rank 8 -> bucket 10 edge
        assert_eq!(s.p99_latency_us, 2047);
    }

    #[test]
    fn absorb_keeps_percentiles_monotone_and_sums_counters() {
        let a = Metrics::default();
        let b = Metrics::default();
        let mut seed = 0x9e3779b97f4a7c15u64;
        for i in 0..200u64 {
            // cheap xorshift spread over ~5 orders of magnitude
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            let us = seed % 100_000;
            if i % 2 == 0 {
                a.observe_latency(us);
            } else {
                b.observe_latency(us);
            }
        }
        a.observe_request();
        b.observe_request();
        a.observe_batch(3, 120);
        b.observe_batch(5, 80);
        a.observe_rejected_queue_full();
        b.observe_rejected_decode();
        b.observe_rejected_shutdown();
        a.observe_rejected_deadline();
        a.observe_respawn();
        b.observe_degraded();
        b.observe_health(&crate::health::StepReport {
            tick: 8,
            probes: 2,
            canary_mismatches: 1,
            quarantined: 3,
            repairs: 4,
            swapped: true,
            reprogram_started: true,
        });
        let (sa, sb) = (a.snapshot(), b.snapshot());
        a.absorb(&b);
        let s = a.snapshot();
        // percentile monotonicity on the merged histogram
        assert!(s.p50_latency_us <= s.p95_latency_us);
        assert!(s.p95_latency_us <= s.p99_latency_us);
        // merged percentiles are bracketed by the per-instance ones
        assert!(s.p50_latency_us >= sa.p50_latency_us.min(sb.p50_latency_us));
        assert!(s.p50_latency_us <= sa.p50_latency_us.max(sb.p50_latency_us));
        assert!(s.p99_latency_us >= sa.p99_latency_us.min(sb.p99_latency_us));
        assert!(s.p99_latency_us <= sa.p99_latency_us.max(sb.p99_latency_us));
        // counters sum, maxima max, rejected reasons merge per reason
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.observed_requests, 200);
        assert_eq!(s.max_latency_us, sa.max_latency_us.max(sb.max_latency_us));
        assert_eq!(s.rejected_queue_full, 1);
        assert_eq!(s.rejected_decode, 1);
        assert_eq!(s.rejected_shutdown, 1);
        assert_eq!(s.rejected_deadline, 1);
        assert_eq!(s.rejected_total(), 4);
        // health counters sum too
        assert_eq!(s.probes, 2);
        assert_eq!(s.canary_mismatches, 1);
        assert_eq!(s.quarantined, 3);
        assert_eq!(s.repairs, 4);
        assert_eq!(s.swaps, 1);
        assert_eq!(s.reprograms, 1);
        assert_eq!(s.respawns, 1);
        assert_eq!(s.degraded, 1);
    }

    #[test]
    fn health_counters_accumulate_per_step() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(
            (s.probes, s.canary_mismatches, s.quarantined, s.repairs, s.swaps),
            (0, 0, 0, 0, 0)
        );
        // An idle probe step: canaries read back clean, nothing swapped.
        m.observe_health(&crate::health::StepReport {
            tick: 16,
            probes: 3,
            ..Default::default()
        });
        // A later step that detected evolution and completed a repair.
        m.observe_health(&crate::health::StepReport {
            tick: 32,
            probes: 3,
            canary_mismatches: 5,
            quarantined: 2,
            repairs: 2,
            swapped: true,
            reprogram_started: true,
        });
        m.observe_respawn();
        m.observe_worker_down();
        m.observe_degraded();
        m.observe_degraded();
        m.observe_rejected_deadline();
        let s = m.snapshot();
        assert_eq!(s.probes, 6);
        assert_eq!(s.canary_mismatches, 5);
        assert_eq!(s.quarantined, 2);
        assert_eq!(s.repairs, 2);
        assert_eq!(s.swaps, 1, "only the swapped step counts a swap");
        assert_eq!(s.reprograms, 1);
        assert_eq!(s.respawns, 1);
        assert_eq!(s.workers_down, 1);
        assert_eq!(s.degraded, 2);
        assert_eq!(s.rejected_deadline, 1);
        assert_eq!(s.rejected_total(), 1, "deadline misses count as rejections");
    }

    #[test]
    fn stats_value_exposes_the_full_snapshot_as_json() {
        let m = Metrics::default();
        m.observe_request();
        m.observe_batch(2, 100);
        m.observe_latency(100);
        m.observe_rejected_queue_full();
        m.add_walk(&crate::backend::WalkProfile { conv_calls: 7, ..Default::default() });
        m.observe_health(&crate::health::StepReport {
            tick: 4,
            probes: 1,
            repairs: 1,
            swapped: true,
            ..Default::default()
        });
        let text = m.stats_value().to_json();
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.get("engine").unwrap().get("requests").unwrap().num().unwrap(), 1.0);
        assert_eq!(v.get("rejected").unwrap().get("queue_full").unwrap().num().unwrap(), 1.0);
        assert_eq!(v.get("rejected").unwrap().get("deadline").unwrap().num().unwrap(), 0.0);
        assert_eq!(v.get("rejected").unwrap().get("total").unwrap().num().unwrap(), 1.0);
        assert_eq!(v.get("health").unwrap().get("repairs").unwrap().num().unwrap(), 1.0);
        assert_eq!(v.get("health").unwrap().get("swaps").unwrap().num().unwrap(), 1.0);
        assert_eq!(v.get("health").unwrap().get("respawns").unwrap().num().unwrap(), 0.0);
        assert_eq!(
            v.get("walk_profile").unwrap().get("conv_calls").unwrap().num().unwrap(),
            7.0
        );
        let hist = v.get("hist").unwrap().arr().unwrap();
        assert_eq!(hist.len(), HIST_BUCKETS);
        assert_eq!(hist.iter().map(|b| b.num().unwrap() as u64).sum::<u64>(), 1);
        assert_eq!(v.get("scenario").unwrap().str().unwrap(), "none");
    }

    #[test]
    fn latency_formatting_labels_the_clamped_edges() {
        assert_eq!(fmt_latency_us(0), "0");
        assert_eq!(fmt_latency_us(1), "<=1");
        assert_eq!(fmt_latency_us(2), "2");
        assert_eq!(fmt_latency_us(127), "127");
        assert_eq!(fmt_latency_us((1u64 << 63) - 1), &((1u64 << 63) - 1).to_string());
        assert_eq!(fmt_latency_us(LATENCY_SATURATION_US), ">=2^63");
        assert_eq!(fmt_latency_us(u64::MAX), ">=2^63");
    }
}
