//! Lightweight serving metrics (lock-free counters + latency aggregation).

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Batches whose execution failed (every member request got an error
    /// reply — see `engine::BatchError`).
    pub failed_batches: AtomicU64,
    /// Requests answered with an error because their batch failed.
    pub failed_requests: AtomicU64,
    latency_us_sum: AtomicU64,
    latency_us_max: AtomicU64,
}

/// Point-in-time snapshot of the serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub failed_batches: u64,
    pub failed_requests: u64,
    pub mean_batch_fill: f64,
    pub mean_latency_us: f64,
    pub max_latency_us: u64,
}

impl Metrics {
    pub fn observe_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_batch(&self, items: usize, latency_us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
        self.latency_us_sum.fetch_add(latency_us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(latency_us, Ordering::Relaxed);
    }

    pub fn observe_batch_failure(&self, items: usize) {
        self.failed_batches.fetch_add(1, Ordering::Relaxed);
        self.failed_requests.fetch_add(items as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches,
            failed_batches: self.failed_batches.load(Ordering::Relaxed),
            failed_requests: self.failed_requests.load(Ordering::Relaxed),
            mean_batch_fill: if batches == 0 {
                0.0
            } else {
                self.batched_items.load(Ordering::Relaxed) as f64 / batches as f64
            },
            mean_latency_us: if batches == 0 {
                0.0
            } else {
                self.latency_us_sum.load(Ordering::Relaxed) as f64 / batches as f64
            },
            max_latency_us: self.latency_us_max.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.observe_request();
        m.observe_request();
        m.observe_batch(2, 100);
        m.observe_batch(1, 300);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 1.5).abs() < 1e-12);
        assert!((s.mean_latency_us - 200.0).abs() < 1e-12);
        assert_eq!(s.max_latency_us, 300);
        assert_eq!(s.failed_batches, 0);
        assert_eq!(s.failed_requests, 0);
    }

    #[test]
    fn batch_failures_are_counted_separately() {
        let m = Metrics::default();
        for _ in 0..3 {
            m.observe_request();
        }
        m.observe_batch(1, 50);
        m.observe_batch_failure(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 1, "failed batches do not pollute the success count");
        assert_eq!(s.failed_batches, 1);
        assert_eq!(s.failed_requests, 2);
        assert!((s.mean_batch_fill - 1.0).abs() < 1e-12);
    }
}
