//! L3 coordinator: the staged compression-plan builder, the accuracy
//! evaluator (generic over execution backends), the sharded serving engine
//! (dynamic batching dispatched over N backend workers, PJRT or the native
//! crossbar simulator) and its metrics.

pub mod engine;
pub mod eval;
pub mod metrics;
pub mod pipeline;
pub mod plan;

pub use engine::{
    BackendSpec, BatchError, Engine, EngineConfig, EngineHandle, Pending, Response,
    ShardedEngine, StartupError, WaitError,
};
pub use eval::{evaluate, evaluate_batches, Accuracy};
pub use metrics::{fmt_latency_us, Metrics, Snapshot, LATENCY_SATURATION_US};
pub use pipeline::{PipelineReport, ThresholdMode};
pub use plan::{
    CacheStats, ChosenThreshold, CompressionPlan, EvalOpts, Executor, ModelState,
    SensitivityScores, StageCache,
};
