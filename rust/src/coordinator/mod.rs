//! L3 coordinator: the staged compression-plan builder, the accuracy
//! evaluator, the serving engine (dynamic batching over PJRT) and its
//! metrics.

pub mod engine;
pub mod eval;
pub mod metrics;
pub mod pipeline;
pub mod plan;

pub use engine::{BatchError, Engine, EngineConfig, EngineHandle, Response};
pub use eval::{evaluate, evaluate_batches, Accuracy};
pub use metrics::{Metrics, Snapshot};
pub use pipeline::{PipelineReport, ThresholdMode};
pub use plan::{
    CacheStats, ChosenThreshold, CompressionPlan, EvalOpts, SensitivityScores, StageCache,
};
