//! L3 coordinator: the compression pipeline, the accuracy evaluator, the
//! serving engine (dynamic batching over PJRT) and its metrics.

pub mod engine;
pub mod eval;
pub mod metrics;
pub mod pipeline;

pub use engine::{Engine, EngineConfig, EngineHandle, Response};
pub use eval::{evaluate, evaluate_batches, Accuracy};
pub use metrics::{Metrics, Snapshot};
pub use pipeline::{Pipeline, PipelineReport, ThresholdMode};
