//! Run configuration: quantization scheme, sensitivity estimation,
//! threshold-search and hardware knobs. JSON-serializable so experiment
//! configs can be checked in / passed via `--config`.


use crate::xbar::XbarConfig;

/// Scale granularity of a quantizer (paper: strips map to crossbar columns,
/// so per-strip scaling is the structured choice; per-layer models a shared
/// conductance range across a whole low-bit array bank).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerStrip,
    PerLayer,
}

/// One precision tier.
#[derive(Clone, Copy, Debug)]
pub struct Tier {
    pub bits: u8,
    pub granularity: Granularity,
}

/// Quantization scheme for the mixed-precision pipeline.
#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    /// High-sensitivity tier (paper: 8-bit, per-strip).
    pub hi: Tier,
    /// Low-sensitivity tier (paper: 4-bit; per-layer scale models one
    /// shared conductance window per low-bit array bank).
    pub lo: Tier,
    /// ReRAM device (conductance) variation, as a fraction of the
    /// quantization step injected as zero-mean Gaussian noise on the
    /// dequantized weight — the analog non-ideality the paper's §1 cites.
    pub device_sigma: f32,
    /// RNG seed for device variation (deterministic experiments).
    pub seed: u64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        Self {
            hi: Tier { bits: 8, granularity: Granularity::PerStrip },
            lo: Tier { bits: 4, granularity: Granularity::PerLayer },
            device_sigma: 0.8,
            seed: 0x5eed,
        }
    }
}

/// Hutchinson estimator settings (paper §2.3/§4.1).
#[derive(Clone, Copy, Debug)]
pub struct SensitivityConfig {
    /// Number of Rademacher probes m.
    pub probes: usize,
    /// Number of calibration batches averaged per probe.
    pub calib_batches: usize,
    pub seed: u64,
}

impl Default for SensitivityConfig {
    fn default() -> Self {
        Self { probes: 8, calib_batches: 2, seed: 0xbeef }
    }
}

/// Algorithm 1 (FIM-difference threshold search) settings.
#[derive(Clone, Copy, Debug)]
pub struct ThresholdConfig {
    /// Initial threshold as a *quantile* of the strip-sensitivity
    /// distribution. T0 = 1.0 reproduces the paper's "maximum compression"
    /// starting point (all strips low-bit).
    pub t0_quantile: f64,
    pub learning_rate: f64,
    pub tolerance: f64,
    pub max_iters: usize,
    /// Finite-difference half-step (in quantile space) for dF/dT.
    pub fd_step: f64,
    /// Calibration batches used per FIM evaluation.
    pub calib_batches: usize,
}

impl Default for ThresholdConfig {
    fn default() -> Self {
        Self {
            t0_quantile: 1.0,
            learning_rate: 0.25,
            tolerance: 1e-4,
            max_iters: 12,
            fd_step: 0.05,
            calib_batches: 1,
        }
    }
}

/// Top-level run configuration.
#[derive(Clone, Debug, Default)]
pub struct RunConfig {
    pub quant: QuantConfig,
    pub sensitivity: SensitivityConfig,
    pub threshold: ThresholdConfig,
    pub xbar: XbarConfig,
}

impl RunConfig {
    /// Parse a (possibly partial) JSON config; unspecified fields keep
    /// their defaults.
    pub fn from_json(text: &str) -> crate::Result<Self> {
        use crate::util::json::Value;
        let v = Value::parse(text)?;
        let mut c = RunConfig::default();
        if let Some(q) = v.opt("quant") {
            if let Some(t) = q.opt("hi") {
                c.quant.hi = Tier::from_value(t, c.quant.hi)?;
            }
            if let Some(t) = q.opt("lo") {
                c.quant.lo = Tier::from_value(t, c.quant.lo)?;
            }
            if let Some(s) = q.opt("device_sigma") {
                c.quant.device_sigma = s.num()? as f32;
            }
            if let Some(s) = q.opt("seed") {
                c.quant.seed = s.num()? as u64;
            }
        }
        if let Some(s) = v.opt("sensitivity") {
            if let Some(p) = s.opt("probes") {
                c.sensitivity.probes = p.usize()?;
            }
            if let Some(p) = s.opt("calib_batches") {
                c.sensitivity.calib_batches = p.usize()?;
            }
            if let Some(p) = s.opt("seed") {
                c.sensitivity.seed = p.num()? as u64;
            }
        }
        if let Some(t) = v.opt("threshold") {
            if let Some(p) = t.opt("t0_quantile") {
                c.threshold.t0_quantile = p.num()?;
            }
            if let Some(p) = t.opt("learning_rate") {
                c.threshold.learning_rate = p.num()?;
            }
            if let Some(p) = t.opt("tolerance") {
                c.threshold.tolerance = p.num()?;
            }
            if let Some(p) = t.opt("max_iters") {
                c.threshold.max_iters = p.usize()?;
            }
            if let Some(p) = t.opt("fd_step") {
                c.threshold.fd_step = p.num()?;
            }
            if let Some(p) = t.opt("calib_batches") {
                c.threshold.calib_batches = p.usize()?;
            }
        }
        if let Some(x) = v.opt("xbar") {
            c.xbar = XbarConfig::from_value(x, c.xbar)?;
        }
        Ok(c)
    }

    pub fn to_json(&self) -> String {
        use crate::util::json::{obj, Value};
        obj(vec![
            (
                "quant",
                obj(vec![
                    ("hi", self.quant.hi.to_value()),
                    ("lo", self.quant.lo.to_value()),
                    ("device_sigma", Value::Num(self.quant.device_sigma as f64)),
                    ("seed", Value::Num(self.quant.seed as f64)),
                ]),
            ),
            (
                "sensitivity",
                obj(vec![
                    ("probes", Value::Num(self.sensitivity.probes as f64)),
                    ("calib_batches", Value::Num(self.sensitivity.calib_batches as f64)),
                    ("seed", Value::Num(self.sensitivity.seed as f64)),
                ]),
            ),
            (
                "threshold",
                obj(vec![
                    ("t0_quantile", Value::Num(self.threshold.t0_quantile)),
                    ("learning_rate", Value::Num(self.threshold.learning_rate)),
                    ("tolerance", Value::Num(self.threshold.tolerance)),
                    ("max_iters", Value::Num(self.threshold.max_iters as f64)),
                    ("fd_step", Value::Num(self.threshold.fd_step)),
                    ("calib_batches", Value::Num(self.threshold.calib_batches as f64)),
                ]),
            ),
            ("xbar", self.xbar.to_value()),
        ])
        .to_json()
    }
}

impl Tier {
    fn from_value(v: &crate::util::json::Value, default: Tier) -> crate::Result<Tier> {
        let mut t = default;
        if let Some(b) = v.opt("bits") {
            t.bits = b.usize()? as u8;
        }
        if let Some(g) = v.opt("granularity") {
            t.granularity = match g.str()? {
                "per_strip" => Granularity::PerStrip,
                "per_layer" => Granularity::PerLayer,
                other => anyhow::bail!("unknown granularity '{other}'"),
            };
        }
        Ok(t)
    }

    fn to_value(&self) -> crate::util::json::Value {
        crate::util::json::obj(vec![
            ("bits", crate::util::json::Value::Num(self.bits as f64)),
            (
                "granularity",
                crate::util::json::Value::Str(
                    match self.granularity {
                        Granularity::PerStrip => "per_strip",
                        Granularity::PerLayer => "per_layer",
                    }
                    .to_string(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_tiers() {
        let c = RunConfig::default();
        assert_eq!(c.quant.hi.bits, 8);
        assert_eq!(c.quant.lo.bits, 4);
        assert_eq!(c.threshold.t0_quantile, 1.0);
    }

    #[test]
    fn json_roundtrip() {
        let c = RunConfig::default();
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.quant.hi.bits, c.quant.hi.bits);
        assert_eq!(c2.xbar.rows, c.xbar.rows);
    }
}
