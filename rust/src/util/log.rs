//! Tiny leveled stderr logger (offline build — no `tracing`).
//!
//! Level from `RERAM_MPQ_LOG` (error|warn|info|debug), default info.

use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: OnceLock<Level> = OnceLock::new();

pub fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("RERAM_MPQ_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    })
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) };
}
