//! In-repo substrates for the offline build: JSON, PRNG, CLI parsing,
//! logging, bench harness (the usual crates.io dependencies are not
//! available in this environment — see DESIGN.md §3).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
