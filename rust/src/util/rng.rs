//! Deterministic PRNG substrate (offline build — no `rand` crate).
//!
//! xoshiro256++ seeded via SplitMix64 — the standard high-quality small
//! generator — plus the samplers the framework needs: Rademacher probes
//! (Hutchinson) and Gaussian noise (ReRAM device variation, Box–Muller).

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller transform.
    spare_normal: Option<f32>,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion (Vigna's recommended seeding).
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Rademacher ±1.
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.bool() {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal (Box–Muller, pair-cached: one ln/sqrt per two
    /// samples — this sampler sits on the quantizer's device-noise hot
    /// path, see EXPERIMENTS.md §Perf).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (sin, cos) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some((r * sin) as f32);
        (r * cos) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn rademacher_is_balanced() {
        let mut r = Rng::seed_from_u64(7);
        let sum: f32 = (0..100_000).map(|_| r.rademacher()).sum();
        assert!(sum.abs() < 2_000.0, "sum={sum}"); // ~6 sigma
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(9);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
