//! Minimal JSON parser + writer (offline substrate — no serde available).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the RunConfig files: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Not streaming; inputs are small.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// A number, or `null` when it is not finite (JSON has no NaN/Inf — the
    /// stage artifacts use NaN for "no threshold applies").
    pub fn num_or_null(v: f64) -> Value {
        if v.is_finite() {
            Value::Num(v)
        } else {
            Value::Null
        }
    }

    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        let n = self.num()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    /// Serialize (compact).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Convenience: build an object from pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "version": 1,
            "models": {"resnet8": {"acc": 0.9061, "layers": [{"shape": [3,3,3,16]}]}},
            "name": "a\"b\\c\nd",
            "flag": true, "none": null, "neg": -1.5e-3
        }"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().usize().unwrap(), 1);
        let m = v.get("models").unwrap().get("resnet8").unwrap();
        assert!((m.get("acc").unwrap().num().unwrap() - 0.9061).abs() < 1e-12);
        assert_eq!(
            m.get("layers").unwrap().arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .usize_vec()
                .unwrap(),
            vec![3, 3, 3, 16]
        );
        assert_eq!(v.get("name").unwrap().str().unwrap(), "a\"b\\c\nd");
        assert_eq!(v.get("flag").unwrap(), &Value::Bool(true));
        assert_eq!(v.get("none").unwrap(), &Value::Null);
        assert!((v.get("neg").unwrap().num().unwrap() + 0.0015).abs() < 1e-12);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,"x"],"b":{"c":false}}"#;
        let v = Value::parse(text).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{}extra").is_err());
        assert!(Value::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes_and_raw() {
        let v = Value::parse(r#""éé""#).unwrap();
        assert_eq!(v.str().unwrap(), "éé");
    }

    #[test]
    fn missing_key_reports_name() {
        let v = Value::parse(r#"{"a":1}"#).unwrap();
        let err = v.get("b").unwrap_err().to_string();
        assert!(err.contains("'b'"));
    }
}
