//! Minimal benchmark harness (offline build — no `criterion`).
//!
//! Warmup + timed iterations, reporting mean / stddev / min. Used by the
//! `benches/*.rs` targets (declared `harness = false`).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "bench {:<42} {:>10.3} ms/iter  (±{:>7.3} ms, min {:>9.3} ms, n={})",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.stddev.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bench {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 1,
            min_iters: 3,
            max_iters: 30,
            budget: Duration::from_secs(10),
        }
    }
}

impl Bench {
    /// Fast profile for CI-style runs (override with BENCH_BUDGET_SECS).
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if let Ok(s) = std::env::var("BENCH_BUDGET_SECS") {
            if let Ok(secs) = s.parse::<u64>() {
                b.budget = Duration::from_secs(secs);
            }
        }
        b
    }

    /// Run `f` repeatedly, returning the measurement (and printing it).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let t_start = Instant::now();
        let mut times = Vec::new();
        while times.len() < self.min_iters
            || (times.len() < self.max_iters && t_start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let n = times.len();
        let mean_s = times.iter().map(|d| d.as_secs_f64()).sum::<f64>() / n as f64;
        let var = times
            .iter()
            .map(|d| (d.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>()
            / n as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: n,
            mean: Duration::from_secs_f64(mean_s),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: *times.iter().min().unwrap(),
        };
        println!("{}", m.report());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_at_least_min_iters() {
        let b = Bench { warmup: 0, min_iters: 4, max_iters: 8, budget: Duration::ZERO };
        let mut count = 0;
        let m = b.run("noop", || count += 1);
        assert_eq!(m.iters, 4);
        assert_eq!(count, 4);
    }
}
