//! Minimal benchmark harness (offline build — no `criterion`).
//!
//! Warmup + timed iterations, reporting mean / stddev / min — and, so
//! benches stop being write-only, machine-readable JSON: every measurement
//! a runner records can be emitted to `BENCH_<name>.json` (schema per
//! record: `name` / `iters` / `mean_ns` / `stddev_ns` / `min_ns` /
//! `git_sha`, plus any [`Bench::annotate`] extras such as the serving
//! bench's `req_per_s` / `p99_ns`), which CI's `bench-smoke` job uploads
//! and gates against `benches/baseline.json`. Used by the `benches/*.rs`
//! targets (declared `harness = false`).
//!
//! Environment knobs (see [`Bench::from_env`]): `BENCH_QUICK=1` switches to
//! the CI smoke profile, and `BENCH_WARMUP` / `BENCH_MIN_ITERS` /
//! `BENCH_MAX_ITERS` / `BENCH_BUDGET_SECS` override fields individually.
//! `BENCH_JSON_DIR` redirects where the JSON lands (default: cwd).

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::{obj, Value};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark name (the JSON record key the perf gate matches on).
    pub name: String,
    /// Timed iterations taken (after warmup).
    pub iters: usize,
    /// Mean wall-clock time per iteration.
    pub mean: Duration,
    /// Sample standard deviation across iterations (0 for a single one).
    pub stddev: Duration,
    /// Fastest observed iteration.
    pub min: Duration,
    /// Extra named scalars attached after the run via [`Bench::annotate`]
    /// (e.g. `req_per_s` / `p99_ns` for the serving benches). Emitted as
    /// additional fields of the JSON record, next to mean/stddev.
    pub extras: Vec<(String, f64)>,
}

impl Measurement {
    /// Human-readable one-line summary (what [`Bench::run`] prints).
    pub fn report(&self) -> String {
        format!(
            "bench {:<42} {:>10.3} ms/iter  (±{:>7.3} ms, min {:>9.3} ms, n={})",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.stddev.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters
        )
    }

    /// JSON record for the perf pipeline (nanosecond units). Any attached
    /// extras ride along as additional numeric fields.
    pub fn to_value(&self, git_sha: &str) -> Value {
        let mut fields = vec![
            ("name", Value::Str(self.name.clone())),
            ("iters", Value::Num(self.iters as f64)),
            ("mean_ns", Value::Num(self.mean.as_nanos() as f64)),
            ("stddev_ns", Value::Num(self.stddev.as_nanos() as f64)),
            ("min_ns", Value::Num(self.min.as_nanos() as f64)),
            ("git_sha", Value::Str(git_sha.to_string())),
        ];
        for (k, v) in &self.extras {
            fields.push((k.as_str(), Value::Num(*v)));
        }
        obj(fields)
    }
}

/// Git SHA stamped into the bench JSON: the short working-tree hash, the
/// `GITHUB_SHA` env (detached CI checkouts), or "unknown".
pub fn git_sha() -> String {
    if let Ok(out) = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
    {
        if out.status.success() {
            let s = String::from_utf8_lossy(&out.stdout).trim().to_string();
            if !s.is_empty() {
                return s;
            }
        }
    }
    if let Ok(s) = std::env::var("GITHUB_SHA") {
        if !s.is_empty() {
            return s.chars().take(12).collect();
        }
    }
    "unknown".into()
}

/// Benchmark runner with a time budget per benchmark. Records every
/// measurement it takes so the run can be emitted as JSON afterwards.
pub struct Bench {
    /// Untimed warmup iterations before measurement starts.
    pub warmup: usize,
    /// Minimum timed iterations, taken even past the budget.
    pub min_iters: usize,
    /// Hard cap on timed iterations.
    pub max_iters: usize,
    /// Wall-clock budget per benchmark once `min_iters` is satisfied.
    pub budget: Duration,
    results: RefCell<Vec<Measurement>>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 1,
            min_iters: 3,
            max_iters: 30,
            budget: Duration::from_secs(10),
            results: RefCell::new(Vec::new()),
        }
    }
}

impl Bench {
    /// The default profile with the environment overrides applied:
    /// `BENCH_QUICK=1` first (CI smoke mode: no warmup, 1–3 iterations,
    /// 1 s budget), then any individual `BENCH_WARMUP` / `BENCH_MIN_ITERS`
    /// / `BENCH_MAX_ITERS` / `BENCH_BUDGET_SECS` on top.
    pub fn from_env() -> Self {
        let mut b = Self::default();
        b.apply_env(&|k| std::env::var(k).ok());
        b
    }

    /// Apply the env-style overrides through `get` (injected for tests).
    pub fn apply_env(&mut self, get: &dyn Fn(&str) -> Option<String>) {
        if get("BENCH_QUICK").as_deref() == Some("1") {
            self.warmup = 0;
            self.min_iters = 1;
            self.max_iters = 3;
            self.budget = Duration::from_secs(1);
        }
        if let Some(v) = get("BENCH_WARMUP").and_then(|s| s.parse::<usize>().ok()) {
            self.warmup = v;
        }
        if let Some(v) = get("BENCH_MIN_ITERS").and_then(|s| s.parse::<usize>().ok()) {
            self.min_iters = v;
        }
        if let Some(v) = get("BENCH_MAX_ITERS").and_then(|s| s.parse::<usize>().ok()) {
            self.max_iters = v;
        }
        if let Some(secs) = get("BENCH_BUDGET_SECS").and_then(|s| s.parse::<u64>().ok()) {
            self.budget = Duration::from_secs(secs);
        }
        // At least one measured iteration, and a coherent min/max pair —
        // the quick profile must never divide by zero or emit NaN.
        self.min_iters = self.min_iters.max(1);
        self.max_iters = self.max_iters.max(self.min_iters);
    }

    /// Run `f` repeatedly, returning the measurement (and printing and
    /// recording it).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let t_start = Instant::now();
        let mut times = Vec::new();
        while times.len() < self.min_iters.max(1)
            || (times.len() < self.max_iters && t_start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let n = times.len();
        let mean_s = times.iter().map(|d| d.as_secs_f64()).sum::<f64>() / n as f64;
        // Sample stddev (n−1 divisor), guarded so a single-iteration quick
        // run reports 0 instead of leaking a division by zero / NaN into
        // the JSON output.
        let var = if n < 2 {
            0.0
        } else {
            times
                .iter()
                .map(|d| (d.as_secs_f64() - mean_s).powi(2))
                .sum::<f64>()
                / (n - 1) as f64
        };
        let m = Measurement {
            name: name.to_string(),
            iters: n,
            mean: Duration::from_secs_f64(mean_s),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: *times.iter().min().unwrap(),
            extras: Vec::new(),
        };
        println!("{}", m.report());
        self.results.borrow_mut().push(m.clone());
        m
    }

    /// Everything recorded by [`Bench::run`] so far.
    pub fn measurements(&self) -> Vec<Measurement> {
        self.results.borrow().clone()
    }

    /// Attach extra named scalars to the most recent recorded measurement
    /// called `name`; they are emitted alongside mean/stddev in its JSON
    /// record (e.g. req/s and p99 latency for the serving benches). A name
    /// never recorded is a no-op.
    pub fn annotate(&self, name: &str, extras: &[(&str, f64)]) {
        let mut results = self.results.borrow_mut();
        if let Some(m) = results.iter_mut().rev().find(|m| m.name == name) {
            m.extras.extend(extras.iter().map(|(k, v)| (k.to_string(), *v)));
        }
    }

    /// Write every recorded measurement to `BENCH_<name>.json` under `dir`.
    pub fn emit_json_to(&self, dir: &Path, name: &str) -> crate::Result<PathBuf> {
        let sha = git_sha();
        let results = self.results.borrow();
        let v = obj(vec![
            ("bench", Value::Str(name.to_string())),
            ("git_sha", Value::Str(sha.clone())),
            (
                "results",
                Value::Arr(results.iter().map(|m| m.to_value(&sha)).collect()),
            ),
        ]);
        let path = dir.join(format!("BENCH_{name}.json"));
        std::fs::write(&path, v.to_json())?;
        println!("bench json: {}", path.display());
        Ok(path)
    }

    /// [`Bench::emit_json_to`] rooted at `$BENCH_JSON_DIR` (default: the
    /// current directory — CI uploads `BENCH_*.json` from the workspace).
    pub fn emit_json(&self, name: &str) -> crate::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
        self.emit_json_to(Path::new(&dir), name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_at_least_min_iters() {
        let b = Bench {
            warmup: 0,
            min_iters: 4,
            max_iters: 8,
            budget: Duration::ZERO,
            ..Bench::default()
        };
        let mut count = 0;
        let m = b.run("noop", || count += 1);
        assert_eq!(m.iters, 4);
        assert_eq!(count, 4);
    }

    #[test]
    fn single_iteration_has_zero_stddev_not_nan() {
        let b = Bench {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            budget: Duration::ZERO,
            ..Bench::default()
        };
        let m = b.run("one", || std::hint::black_box(1 + 1));
        assert_eq!(m.iters, 1);
        assert_eq!(m.stddev, Duration::ZERO);
        let v = m.to_value("abc");
        assert_eq!(v.get("stddev_ns").unwrap(), &Value::Num(0.0));
        assert!(Value::parse(&v.to_json()).is_ok());
    }

    #[test]
    fn quick_profile_and_overrides_from_env() {
        let mut b = Bench::default();
        b.apply_env(&|k| match k {
            "BENCH_QUICK" => Some("1".into()),
            "BENCH_MAX_ITERS" => Some("2".into()),
            _ => None,
        });
        assert_eq!(b.warmup, 0);
        assert_eq!(b.min_iters, 1);
        assert_eq!(b.max_iters, 2);
        assert_eq!(b.budget, Duration::from_secs(1));

        // degenerate overrides are clamped to a coherent profile
        let mut b = Bench::default();
        b.apply_env(&|k| match k {
            "BENCH_MIN_ITERS" => Some("0".into()),
            "BENCH_MAX_ITERS" => Some("0".into()),
            _ => None,
        });
        assert_eq!(b.min_iters, 1);
        assert_eq!(b.max_iters, 1);
    }

    #[test]
    fn annotate_attaches_extras_to_the_json_record() {
        let b = Bench {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            budget: Duration::ZERO,
            ..Bench::default()
        };
        b.run("serve", || std::hint::black_box(1 + 1));
        b.annotate("serve", &[("req_per_s", 1234.5), ("p99_ns", 6.7e6)]);
        b.annotate("never-recorded", &[("ignored", 1.0)]); // no-op, no panic
        let m = &b.measurements()[0];
        assert_eq!(m.extras.len(), 2);
        let v = m.to_value("sha");
        assert_eq!(v.get("req_per_s").unwrap().num().unwrap(), 1234.5);
        assert_eq!(v.get("p99_ns").unwrap().num().unwrap(), 6.7e6);
        // base schema fields stay intact next to the extras
        assert_eq!(v.get("iters").unwrap().num().unwrap(), 1.0);
        assert!(Value::parse(&v.to_json()).is_ok());
    }

    #[test]
    fn emit_json_roundtrips_schema() {
        let b = Bench {
            warmup: 0,
            min_iters: 2,
            max_iters: 2,
            budget: Duration::ZERO,
            ..Bench::default()
        };
        b.run("alpha", || std::hint::black_box(3 * 7));
        b.run("beta", || std::hint::black_box(2 + 2));
        let dir = std::env::temp_dir();
        let name = format!("selftest-{}", std::process::id());
        let path = b.emit_json_to(&dir, &name).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.get("bench").unwrap().str().unwrap(), name);
        assert!(!v.get("git_sha").unwrap().str().unwrap().is_empty());
        let results = match v.get("results").unwrap() {
            Value::Arr(a) => a,
            other => panic!("results not an array: {other:?}"),
        };
        assert_eq!(results.len(), 2);
        for (r, want) in results.iter().zip(["alpha", "beta"]) {
            assert_eq!(r.get("name").unwrap().str().unwrap(), want);
            assert_eq!(r.get("iters").unwrap().num().unwrap(), 2.0);
            assert!(r.get("mean_ns").unwrap().num().unwrap() >= 0.0);
            assert!(r.get("min_ns").unwrap().num().unwrap() >= 0.0);
            assert!(r.get("stddev_ns").unwrap().num().unwrap() >= 0.0);
        }
        let _ = std::fs::remove_file(path);
    }
}
