//! Minimal CLI argument parser (offline build — no `clap`).
//!
//! Shape: `prog [--global val]... <subcommand> [--flag] [--opt val]...`

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed arguments: a subcommand plus `--key value` / `--switch` options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: HashMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse, treating names in `switch_names` as valueless flags.
    pub fn parse(argv: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if switch_names.contains(&name) {
                    if inline.is_some() {
                        bail!("--{name} takes no value");
                    }
                    out.switches.push(name.to_string());
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    out.options.insert(name.to_string(), val);
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// [`Args::get`], but required: a missing flag is an error naming it.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("--{key} is required"))
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse()?)),
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse()?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_switches() {
        let a = Args::parse(
            &v(&["table2", "--eval-batches", "3", "--origin", "--cr=0.74"]),
            &["origin"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("table2"));
        assert_eq!(a.get("eval-batches"), Some("3"));
        assert!(a.has("origin"));
        assert_eq!(a.get_f64("cr").unwrap(), Some(0.74));
    }

    #[test]
    fn require_names_the_missing_flag() {
        let a = Args::parse(&v(&["bench-client", "--conns", "4"]), &[]).unwrap();
        assert_eq!(a.require("conns").unwrap(), "4");
        let err = a.require("addr").unwrap_err().to_string();
        assert!(err.contains("--addr"), "{err}");
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&v(&["x", "--cr"]), &[]).is_err());
    }

    #[test]
    fn switch_with_value_errors() {
        assert!(Args::parse(&v(&["x", "--origin=1"]), &["origin"]).is_err());
    }
}
