//! CIFAR-Syn dataset access on the Rust side (test + calibration splits
//! exported by `aot.py`; the training split never leaves Python).

use crate::model::Manifest;
use crate::tensor::Tensor;
use crate::Result;

/// Test split: images `[N,32,32,3]` + integer labels. `Clone` so the
/// tuner's worker threads can each root a plan on their own copy.
#[derive(Clone)]
pub struct TestSet {
    pub x: Tensor,
    pub y: Vec<usize>,
}

impl TestSet {
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let x = manifest.dataset_tensor("test_x")?;
        let y = manifest
            .dataset_tensor("test_y")?
            .into_data()
            .into_iter()
            .map(|v| v as usize)
            .collect();
        Ok(Self { x, y })
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Batch `i` of size `b` (images, labels).
    pub fn batch(&self, i: usize, b: usize) -> (Tensor, &[usize]) {
        let lo = i * b;
        let hi = (lo + b).min(self.len());
        (self.x.slice_rows(lo, hi), &self.y[lo..hi])
    }

    pub fn num_batches(&self, b: usize) -> usize {
        self.len() / b // full batches only (graph shapes are static)
    }
}

/// Calibration split: images + one-hot labels, sliced into fixed-size
/// batches matching the HVP/GSQ graph batch dimension. `Clone` so the
/// tuner's worker threads can each root a plan on their own copy.
#[derive(Clone)]
pub struct CalibSet {
    pub x: Tensor,
    pub y1h: Tensor,
    pub batch: usize,
}

impl CalibSet {
    pub fn load(manifest: &Manifest, batch: usize) -> Result<Self> {
        let x = manifest.dataset_tensor("calib_x")?;
        let y1h = manifest.dataset_tensor("calib_y1h")?;
        anyhow::ensure!(x.shape()[0] == y1h.shape()[0], "calib x/y length mismatch");
        Ok(Self { x, y1h, batch })
    }

    pub fn num_batches(&self) -> usize {
        self.x.shape()[0] / self.batch
    }

    pub fn get(&self, i: usize) -> (Tensor, Tensor) {
        let lo = i * self.batch;
        let hi = lo + self.batch;
        (self.x.slice_rows(lo, hi), self.y1h.slice_rows(lo, hi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testset_batching_is_contiguous() {
        let x = Tensor::new(vec![5, 2], (0..10).map(|v| v as f32).collect());
        let ts = TestSet { x, y: vec![0, 1, 2, 3, 4] };
        assert_eq!(ts.num_batches(2), 2);
        let (xb, yb) = ts.batch(1, 2);
        assert_eq!(xb.data(), &[4., 5., 6., 7.]);
        assert_eq!(yb, &[2, 3]);
    }
}
