//! Sensitivity clustering + dynamic crossbar alignment (paper §4.2).
//!
//! Strips with score `s_i > T` form the high-precision cluster; the rest the
//! low-precision cluster. Before mapping, `T` is nudged *per layer* so the
//! high-bit strip count `q` becomes a multiple of the layer's crossbar
//! column capacity `C` — high-bit arrays are packed full, the remainder is
//! demoted to the cheap low-bit tier.

use crate::model::ModelInfo;
use crate::quant::BitMap;

/// A sensitivity-threshold clustering of all strips.
#[derive(Clone, Debug)]
pub struct Clustering {
    pub bitmap: BitMap,
    /// The threshold actually applied (after any alignment demotions this is
    /// the effective per-layer boundary's global starting point).
    pub threshold: f64,
    /// Number of high-precision strips.
    pub q_hi: usize,
}

impl Clustering {
    pub fn compression_ratio(&self, hi_bits: u8) -> f64 {
        self.bitmap.compression_ratio(hi_bits)
    }

    /// Machine-readable stage-artifact summary.
    pub fn to_value(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj(vec![
            ("threshold", Value::num_or_null(self.threshold)),
            ("q_hi", Value::Num(self.q_hi as f64)),
            ("total_strips", Value::Num(self.bitmap.bits.len() as f64)),
        ])
    }
}

/// Basic threshold clustering: `s_i > t` → hi bits, else lo bits.
pub fn cluster(scores: &[f64], t: f64, hi_bits: u8, lo_bits: u8) -> Clustering {
    let bits: Vec<u8> = scores
        .iter()
        .map(|&s| if s > t { hi_bits } else { lo_bits })
        .collect();
    let q_hi = bits.iter().filter(|&&b| b == hi_bits).count();
    Clustering { bitmap: BitMap { bits }, threshold: t, q_hi }
}

/// Cluster to an exact target compression ratio (used by the CR-sweep
/// experiments): the `ceil(cr · n)` lowest-score strips get `lo_bits`.
pub fn cluster_at_cr(scores: &[f64], cr: f64, hi_bits: u8, lo_bits: u8) -> Clustering {
    let n = scores.len();
    let n_lo = ((cr * n as f64).round() as usize).min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    let mut bits = vec![hi_bits; n];
    for &i in idx.iter().take(n_lo) {
        bits[i] = lo_bits;
    }
    let q_hi = n - n_lo;
    let threshold = if n_lo == 0 {
        f64::NEG_INFINITY
    } else if n_lo == n {
        f64::INFINITY
    } else {
        scores[idx[n_lo - 1]]
    };
    Clustering { bitmap: BitMap { bits }, threshold, q_hi }
}

/// Dynamic alignment (paper §4.2): per layer, demote the lowest-score
/// high-bit strips until the layer's hi count is a multiple of that layer's
/// crossbar capacity `C` (strip-columns per high-bit array).
///
/// `capacity(layer_idx)` returns C for the layer; demotions move strips to
/// `lo_bits`.
pub fn align_to_capacity(
    model: &ModelInfo,
    scores: &[f64],
    clustering: &Clustering,
    hi_bits: u8,
    lo_bits: u8,
    capacity: impl Fn(usize) -> usize,
) -> Clustering {
    let mut bits = clustering.bitmap.bits.clone();
    for (li, _layer) in model.conv_layers().iter().enumerate() {
        let cap = capacity(li).max(1);
        // Indices of hi strips in this layer, sorted by ascending score.
        let mut hi_idx: Vec<usize> = model
            .strips()
            .iter()
            .enumerate()
            .filter(|(i, s)| s.layer == li && bits[*i] == hi_bits)
            .map(|(i, _)| i)
            .collect();
        // Paper: "incrementally adjust T to reduce q and make it a multiple
        // of C". When a layer's hi cluster is smaller than one array (q < C)
        // the only multiple below is 0 — wiping the cluster would change the
        // model without freeing any resource granularity, so the partial
        // array is kept instead.
        if hi_idx.len() < cap {
            continue;
        }
        let rem = hi_idx.len() % cap;
        if rem == 0 {
            continue;
        }
        hi_idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        for &i in hi_idx.iter().take(rem) {
            bits[i] = lo_bits;
        }
    }
    let q_hi = bits.iter().filter(|&&b| b == hi_bits).count();
    Clustering {
        bitmap: BitMap { bits },
        threshold: clustering.threshold,
        q_hi,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry};
    use std::collections::HashMap;

    fn toy(n_out: usize) -> ModelInfo {
        ModelInfo::new(ModelEntry {
            name: "toy".into(),
            num_params: 2 * n_out,
            num_conv_params: 2 * n_out,
            fp32_test_acc: 1.0,
            params: BinEntry { file: "x".into(), shape: vec![2 * n_out], dtype: "f32".into() },
            layers: vec![LayerEntry {
                name: "c".into(),
                shape: vec![1, 1, 2, n_out],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            }],
            executables: HashMap::new(),
            batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
        })
    }

    #[test]
    fn cluster_thresholds_strictly_above() {
        let c = cluster(&[1.0, 2.0, 3.0], 2.0, 8, 4);
        assert_eq!(c.bitmap.bits, vec![4, 4, 8]);
        assert_eq!(c.q_hi, 1);
    }

    #[test]
    fn cluster_at_cr_exact_counts() {
        let scores = vec![0.5, 0.1, 0.9, 0.3, 0.7];
        let c = cluster_at_cr(&scores, 0.6, 8, 4);
        assert_eq!(c.q_hi, 2);
        // lowest three (0.1, 0.3, 0.5) demoted
        assert_eq!(c.bitmap.bits, vec![4, 4, 8, 4, 8]);
        assert!((c.compression_ratio(8) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn cr_endpoints() {
        let scores = vec![1.0, 2.0];
        assert_eq!(cluster_at_cr(&scores, 0.0, 8, 4).q_hi, 2);
        assert_eq!(cluster_at_cr(&scores, 1.0, 8, 4).q_hi, 0);
    }

    #[test]
    fn align_demotes_remainder_lowest_first() {
        let m = toy(10); // 10 strips in one layer
        let scores: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let c = cluster(&scores, 2.5, 8, 4); // hi = strips 3..9 -> q=7
        assert_eq!(c.q_hi, 7);
        let aligned = align_to_capacity(&m, &scores, &c, 8, 4, |_| 4);
        // 7 % 4 = 3 demotions -> q = 4; lowest hi scores (3,4,5) demoted
        assert_eq!(aligned.q_hi, 4);
        assert_eq!(aligned.bitmap.bits[3], 4);
        assert_eq!(aligned.bitmap.bits[5], 4);
        assert_eq!(aligned.bitmap.bits[6], 8);
    }

    #[test]
    fn align_noop_when_divisible() {
        let m = toy(8);
        let scores: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let c = cluster(&scores, 3.5, 8, 4); // q = 4
        let aligned = align_to_capacity(&m, &scores, &c, 8, 4, |_| 4);
        assert_eq!(aligned.q_hi, 4);
        assert_eq!(aligned.bitmap.bits, c.bitmap.bits);
    }
}
