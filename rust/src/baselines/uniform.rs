//! Uniform-precision baselines: every strip at the same bit width — the
//! paper's Table 3 endpoints (0% compression = all 8-bit, 100% = all 4-bit).

use crate::quant::BitMap;

/// All strips at `bits`.
pub fn uniform_bitmap(n_strips: usize, bits: u8) -> BitMap {
    BitMap::uniform(n_strips, bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cr_endpoints() {
        let b8 = uniform_bitmap(10, 8);
        assert_eq!(b8.compression_ratio(8), 0.0);
        let b4 = uniform_bitmap(10, 4);
        assert_eq!(b4.compression_ratio(8), 1.0);
    }
}
