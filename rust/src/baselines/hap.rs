//! Hessian-Aware Pruning (HAP, Yu et al. WACV'22) baseline — the paper's
//! Table 2 comparator.
//!
//! HAP scores parameter groups by the same second-order loss perturbation
//! `ΔL ≈ w_p^T (Trace(H)/p) w_p / 2` and *prunes* (removes) the lowest-
//! scoring groups at a target compression ratio; survivors stay 8-bit.
//! Crucially, HAP's sparsity is not crossbar-structured: pruned weights
//! leave holes in the arrays, so it is mapped with the ORIGIN strategy —
//! reproducing the paper's observation that unstructured compression
//! cannot skip crossbar rows/columns (§2.2).

use crate::quant::BitMap;
use crate::sensitivity::Sensitivity;

/// Build a HAP bitmap: `cr` fraction of strips pruned (bits = 0), the rest
/// kept at `keep_bits`.
pub fn hap_bitmap(sens: &Sensitivity, cr: f64, keep_bits: u8) -> BitMap {
    let n = sens.scores.len();
    let n_prune = ((cr * n as f64).round() as usize).min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| sens.scores[a].total_cmp(&sens.scores[b]));
    let mut bits = vec![keep_bits; n];
    for &i in idx.iter().take(n_prune) {
        bits[i] = 0;
    }
    BitMap { bits }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sens(scores: Vec<f64>) -> Sensitivity {
        Sensitivity { scores, traces: vec![], probes: 1 }
    }

    #[test]
    fn prunes_lowest_scores() {
        let s = sens(vec![0.9, 0.1, 0.5, 0.3]);
        let bm = hap_bitmap(&s, 0.5, 8);
        assert_eq!(bm.bits, vec![8, 0, 8, 0]);
    }

    #[test]
    fn cr_zero_keeps_everything() {
        let s = sens(vec![1.0, 2.0]);
        assert_eq!(hap_bitmap(&s, 0.0, 8).bits, vec![8, 8]);
    }

    #[test]
    fn cr_one_prunes_everything() {
        let s = sens(vec![1.0, 2.0]);
        assert_eq!(hap_bitmap(&s, 1.0, 8).bits, vec![0, 0]);
    }
}
