//! Comparator methods used by the paper's evaluation: HAP structured
//! pruning (Table 2) and uniform-precision endpoints (Table 3).

pub mod hap;
pub mod uniform;

pub use hap::hap_bitmap;
pub use uniform::uniform_bitmap;
