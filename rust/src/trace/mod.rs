//! Request-lifecycle tracing — a std-only, low-overhead span recorder with
//! Chrome-trace export.
//!
//! The subsystem is **compiled in but default-off**: every instrumentation
//! point costs one relaxed atomic load (`enabled()`) until tracing is
//! switched on via [`enable`] / [`TraceConfig::from_env`] (the
//! `RERAM_MPQ_TRACE=1` environment knob) or a `--trace-out` CLI flag. With
//! tracing off, [`span`] returns an inert guard without recording,
//! allocating, or reading the clock — the zero-alloc steady-state invariant
//! of the programmed forward path holds exactly as before (property-tested
//! in `tests/trace_zero_alloc.rs`).
//!
//! ## Recording model
//!
//! Span begin/end events land in a **thread-local buffer** (no lock, no
//! shared cache line on the hot path) and are drained over an `mpsc`
//! channel: a buffer flushes to the channel when it fills, when the thread
//! exits (the thread-local's `Drop`), or when the instrumented layer calls
//! [`flush_thread`] at a request/batch boundary. [`drain`] collects every
//! flushed event, sorted by the shared monotonic clock (one `Instant`
//! epoch for the whole process, so cross-thread timestamps compare).
//!
//! ## Span taxonomy
//!
//! | span | where |
//! |------|-------|
//! | `server.handle` | one inbound frame, decode → reply (`serve::Server`) |
//! | `batcher.submit` | admission into the bounded queue |
//! | `ticket.wait` | connection thread parked on the reply |
//! | `server.reply` | reply frame write |
//! | `batch.coalesce` | batcher fill loop, first request → engine submit |
//! | `engine.dispatch` | dispatcher hands a batch to a worker |
//! | `worker.batch` | worker thread runs one batch end to end |
//! | `backend.forward` | one `ExecBackend::forward` call |
//! | `layer:<name>` | one conv layer inside the forward |
//! | `xbar.conv` | one programmed-tile walk (`SimXbar::conv_programmed`) |
//! | `tune.eval` | one tuner candidate evaluation (tags: cr/bits/align/cache) |
//!
//! ## Export
//!
//! [`chrome_trace_json`] renders drained events as Chrome trace-event JSON
//! (`B`/`E` duration events) loadable in Perfetto or `chrome://tracing`;
//! [`summary_table`] renders a compact per-span count/total/mean/max table.
//! `tools/check_trace.py` validates emitted traces in CI (well-formed,
//! balanced, required spans present).

use std::borrow::Cow;
use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{obj, Value};
use crate::Result;

/// One recorded span edge (begin or end).
#[derive(Clone, Debug)]
pub struct Event {
    /// Span name (static for hot-path spans, owned for per-layer names).
    pub name: Cow<'static, str>,
    /// `true` for a span begin (`ph: "B"`), `false` for an end (`"E"`).
    pub begin: bool,
    /// Nanoseconds since the process-wide trace epoch.
    pub ts_ns: u64,
    /// Recorder-assigned thread id (stable per OS thread for the process
    /// lifetime; also the Chrome-trace `tid`).
    pub tid: u64,
    /// Key/value tags attached via [`Span::tag`] (emitted on the end edge).
    pub args: Vec<(&'static str, String)>,
}

struct Global {
    enabled: AtomicBool,
    epoch: Instant,
    tx: Mutex<Sender<Vec<Event>>>,
    rx: Mutex<Receiver<Vec<Event>>>,
    next_tid: AtomicU64,
}

static GLOBAL: OnceLock<Global> = OnceLock::new();

fn global() -> &'static Global {
    GLOBAL.get_or_init(|| {
        let (tx, rx) = std::sync::mpsc::channel();
        Global {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            tx: Mutex::new(tx),
            rx: Mutex::new(rx),
            next_tid: AtomicU64::new(1),
        }
    })
}

struct Local {
    tid: u64,
    buf: Vec<Event>,
    tx: Sender<Vec<Event>>,
}

impl Local {
    fn flush(&mut self) {
        if !self.buf.is_empty() {
            // A send can only fail if the receiver is gone, i.e. never
            // (the receiver lives in the process-wide Global).
            let _ = self.tx.send(std::mem::take(&mut self.buf));
        }
    }
}

impl Drop for Local {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<Option<Local>> = const { RefCell::new(None) };
}

/// Thread-local buffer auto-flush threshold (events).
const FLUSH_AT: usize = 4096;

/// Is tracing live? One relaxed atomic load — this is the entire hot-path
/// cost of every instrumentation point while tracing is off (and before
/// the first [`enable`], not even that: the global is uninitialized).
#[inline]
pub fn enabled() -> bool {
    GLOBAL.get().is_some_and(|g| g.enabled.load(Ordering::Relaxed))
}

fn record(name: Cow<'static, str>, begin: bool, args: Vec<(&'static str, String)>) {
    let g = global();
    let ts_ns = g.epoch.elapsed().as_nanos() as u64;
    LOCAL.with(|cell| {
        let mut slot = cell.borrow_mut();
        let local = slot.get_or_insert_with(|| Local {
            tid: g.next_tid.fetch_add(1, Ordering::Relaxed),
            buf: Vec::with_capacity(1024),
            tx: g.tx.lock().unwrap().clone(),
        });
        local.buf.push(Event { name, begin, ts_ns, tid: local.tid, args });
        if local.buf.len() >= FLUSH_AT {
            local.flush();
        }
    });
}

/// RAII span guard: records a begin event on creation (when tracing is on)
/// and the matching end event on drop. An inert guard (tracing off) does
/// nothing at all.
pub struct Span {
    name: Option<Cow<'static, str>>,
    args: Vec<(&'static str, String)>,
}

impl Span {
    /// Attach a tag to this span, emitted with the end event. The value
    /// closure only runs when the span is live, so a disabled trace never
    /// pays for the formatting.
    #[inline]
    pub fn tag(&mut self, key: &'static str, value: impl FnOnce() -> String) -> &mut Self {
        if self.name.is_some() {
            self.args.push((key, value()));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(name) = self.name.take() {
            record(name, false, std::mem::take(&mut self.args));
        }
    }
}

/// Open a span with a static name. With tracing off this returns an inert
/// guard: no clock read, no allocation, no recording.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { name: None, args: Vec::new() };
    }
    record(Cow::Borrowed(name), true, Vec::new());
    Span { name: Some(Cow::Borrowed(name)), args: Vec::new() }
}

/// Open a span whose name is computed lazily (e.g. `layer:<name>`): the
/// closure only runs when tracing is on, so the disabled path never
/// allocates the name string.
#[inline]
pub fn span_with(name: impl FnOnce() -> String) -> Span {
    if !enabled() {
        return Span { name: None, args: Vec::new() };
    }
    let name: Cow<'static, str> = Cow::Owned(name());
    record(name.clone(), true, Vec::new());
    Span { name: Some(name), args: Vec::new() }
}

/// Tracing configuration resolved from the environment.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceConfig {
    /// Record spans from process start (`RERAM_MPQ_TRACE=1|on|true`).
    pub enabled: bool,
}

impl TraceConfig {
    /// Read the `RERAM_MPQ_TRACE` knob (off unless `1`, `on`, or `true`).
    pub fn from_env() -> Self {
        let enabled = std::env::var("RERAM_MPQ_TRACE")
            .map(|v| {
                let v = v.to_ascii_lowercase();
                v == "1" || v == "on" || v == "true"
            })
            .unwrap_or(false);
        Self { enabled }
    }
}

/// Apply a [`TraceConfig`] (turns the recorder on when asked; never off).
pub fn init(cfg: TraceConfig) {
    if cfg.enabled {
        enable();
    }
}

/// Switch span recording on, process-wide.
pub fn enable() {
    global().enabled.store(true, Ordering::SeqCst);
}

/// Switch span recording off (already-buffered events stay drainable).
pub fn disable() {
    if let Some(g) = GLOBAL.get() {
        g.enabled.store(false, Ordering::SeqCst);
    }
}

/// Flush the calling thread's buffered events to the drain channel. The
/// instrumented layers call this at request/batch/eval boundaries so a
/// [`drain`] from another thread (the `--trace-out` dumper, a test) sees
/// complete spans without waiting for buffers to fill or threads to exit.
pub fn flush_thread() {
    if GLOBAL.get().is_none() {
        return;
    }
    LOCAL.with(|cell| {
        if let Some(local) = cell.borrow_mut().as_mut() {
            local.flush();
        }
    });
}

/// Collect every event flushed so far (including the calling thread's
/// buffer), ordered by timestamp. Events are consumed: a second drain
/// returns only what was recorded in between.
pub fn drain() -> Vec<Event> {
    let Some(g) = GLOBAL.get() else {
        return Vec::new();
    };
    flush_thread();
    let rx = g.rx.lock().unwrap();
    let mut out = Vec::new();
    while let Ok(mut batch) = rx.try_recv() {
        out.append(&mut batch);
    }
    // Stable by timestamp: per-thread order is preserved (timestamps are
    // monotonic per thread and buffers flush in order), so B/E nesting
    // survives the merge.
    out.sort_by_key(|e| e.ts_ns);
    out
}

/// Render events as Chrome trace-event JSON (the `traceEvents` array of
/// `B`/`E` duration events), loadable in Perfetto / `chrome://tracing`.
/// Timestamps are microseconds from the trace epoch.
pub fn chrome_trace_json(events: &[Event]) -> String {
    let rows: Vec<Value> = events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", Value::Str(e.name.to_string())),
                ("ph", Value::Str((if e.begin { "B" } else { "E" }).to_string())),
                ("ts", Value::Num(e.ts_ns as f64 / 1e3)),
                ("pid", Value::Num(1.0)),
                ("tid", Value::Num(e.tid as f64)),
            ];
            if !e.args.is_empty() {
                fields.push((
                    "args",
                    Value::Obj(
                        e.args
                            .iter()
                            .map(|(k, v)| (k.to_string(), Value::Str(v.clone())))
                            .collect(),
                    ),
                ));
            }
            obj(fields)
        })
        .collect();
    obj(vec![
        ("traceEvents", Value::Arr(rows)),
        ("displayTimeUnit", Value::Str("ms".to_string())),
    ])
    .to_json()
}

/// Write `events` as Chrome trace JSON to `path`, atomically (tmp +
/// rename), so a reader — or a CI checker racing the serve dumper — never
/// sees a torn file.
pub fn write_chrome_trace(path: &Path, events: &[Event]) -> Result<()> {
    let tmp = path.with_extension("trace.tmp");
    std::fs::write(&tmp, chrome_trace_json(events))?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Compact per-span summary: count, total/mean/max duration in µs, one row
/// per span name, alphabetical. Unmatched begin events (spans still open
/// when drained) are not counted.
pub fn summary_table(events: &[Event]) -> String {
    use std::collections::{BTreeMap, HashMap};
    #[derive(Default)]
    struct Row {
        count: u64,
        total_ns: u64,
        max_ns: u64,
    }
    let mut stacks: HashMap<u64, Vec<(&str, u64)>> = HashMap::new();
    let mut rows: BTreeMap<&str, Row> = BTreeMap::new();
    for e in events {
        let stack = stacks.entry(e.tid).or_default();
        if e.begin {
            stack.push((e.name.as_ref(), e.ts_ns));
        } else if let Some((name, t0)) = stack.pop() {
            let dur = e.ts_ns.saturating_sub(t0);
            let row = rows.entry(name).or_default();
            row.count += 1;
            row.total_ns += dur;
            row.max_ns = row.max_ns.max(dur);
        }
    }
    let mut out =
        String::from("span                           count     total_us      mean_us       max_us\n");
    for (name, r) in rows {
        out.push_str(&format!(
            "{:<30} {:>5} {:>12.1} {:>12.1} {:>12.1}\n",
            name,
            r.count,
            r.total_ns as f64 / 1e3,
            r.total_ns as f64 / 1e3 / r.count as f64,
            r.max_ns as f64 / 1e3
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global; serialize the tests that toggle it
    // so parallel test threads can't interleave their event streams.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn drain_named(prefix: &str) -> Vec<Event> {
        drain().into_iter().filter(|e| e.name.starts_with(prefix)).collect()
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _guard = TEST_LOCK.lock().unwrap();
        disable();
        let _ = drain();
        {
            let mut s = span("t1.quiet");
            s.tag("never", || unreachable!("tag closures must not run when off"));
            let _ = span_with(|| unreachable!("name closures must not run when off"));
        }
        assert!(!enabled());
        assert!(drain_named("t1.").is_empty());
    }

    #[test]
    fn spans_emit_balanced_nested_events_and_chrome_json_parses() {
        let _guard = TEST_LOCK.lock().unwrap();
        enable();
        let _ = drain();
        {
            let mut outer = span("t2.outer");
            outer.tag("k", || "v".to_string());
            let _inner = span_with(|| "t2.layer:stem".to_string());
        }
        disable();
        let evs = drain_named("t2.");
        assert_eq!(evs.len(), 4, "{evs:?}");
        // per-thread LIFO: outer B, inner B, inner E, outer E
        assert_eq!(
            evs.iter().map(|e| (e.name.as_ref(), e.begin)).collect::<Vec<_>>(),
            vec![
                ("t2.outer", true),
                ("t2.layer:stem", true),
                ("t2.layer:stem", false),
                ("t2.outer", false),
            ]
        );
        assert!(evs.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
        assert_eq!(evs[3].args, vec![("k", "v".to_string())]);

        let json = chrome_trace_json(&evs);
        let v = Value::parse(&json).unwrap();
        let rows = v.get("traceEvents").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].get("ph").unwrap().str().unwrap(), "B");
        assert_eq!(rows[3].get("ph").unwrap().str().unwrap(), "E");
        assert_eq!(rows[3].get("args").unwrap().get("k").unwrap().str().unwrap(), "v");
        // a second drain sees nothing new
        assert!(drain_named("t2.").is_empty());
    }

    #[test]
    fn summary_table_counts_and_averages_per_name() {
        let _guard = TEST_LOCK.lock().unwrap();
        enable();
        let _ = drain();
        for _ in 0..3 {
            let _s = span("t3.step");
        }
        disable();
        let evs = drain_named("t3.");
        assert_eq!(evs.len(), 6);
        let table = summary_table(&evs);
        let line = table.lines().find(|l| l.starts_with("t3.step")).unwrap();
        assert!(line.split_whitespace().any(|f| f == "3"), "count 3 in {line:?}");
    }

    #[test]
    fn write_chrome_trace_is_atomic_and_loadable() {
        let _guard = TEST_LOCK.lock().unwrap();
        enable();
        let _ = drain();
        {
            let _s = span("t4.io");
        }
        disable();
        let evs = drain_named("t4.");
        let path = std::env::temp_dir().join(format!("trace-selftest-{}.json", std::process::id()));
        write_chrome_trace(&path, &evs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.get("traceEvents").unwrap().arr().unwrap().len(), 2);
        let _ = std::fs::remove_file(&path);
    }
}
