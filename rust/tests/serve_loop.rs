//! Loopback integration tests for the `serve` front-end, fully hermetic:
//! in-memory fixture models on the native `SimXbar` backend, a real TCP
//! server on an ephemeral loopback port, and the real protocol client.
//!
//! Everything here carries the `sim_` prefix so CI's hermetic gate counts
//! it: these tests must *run* (never skip) on a machine with no AOT
//! artifacts.

use std::net::TcpListener;
use std::time::Duration;

use reram_mpq::backend::SimXbarConfig;
use reram_mpq::coordinator::{
    CompressionPlan, EngineConfig, Executor, ModelState, ThresholdMode,
};
use reram_mpq::fixture::{self, Fixture};
use reram_mpq::serve::{
    bench_client, BatchPolicy, ClientReply, ServeClient, ServeConfig, Server,
};
use reram_mpq::RunConfig;

const ELEMS: usize = 32 * 32 * 3;

fn sim_plan(fx: Fixture, scfg: SimXbarConfig, cfg: RunConfig) -> CompressionPlan<'static> {
    CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(scfg),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        cfg,
    )
}

fn test_images(plan: &CompressionPlan<'_>, n: usize) -> Vec<Vec<f32>> {
    let test = plan.test();
    (0..n)
        .map(|j| test.x.data()[j * ELEMS..(j + 1) * ELEMS].to_vec())
        .collect()
}

fn start_server(
    handle: &reram_mpq::coordinator::EngineHandle,
    cfg: ServeConfig,
) -> (Server, String) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server = Server::start(listener, handle.clone(), cfg).unwrap();
    let addr = server.local_addr().to_string();
    (server, addr)
}

#[test]
fn sim_serve_loopback_is_bit_identical_to_direct_classify() {
    // N concurrent client connections must observe argmax AND logits
    // bit-identical to direct EngineHandle::classify: the simulator is
    // per-sample deterministic and the protocol ships raw f32 bits.
    let plan = sim_plan(fixture::tiny(61), SimXbarConfig::default(), RunConfig::default())
        .threshold(ThresholdMode::FixedCr(0.5));
    let handle = plan.deploy(EngineConfig::default()).unwrap();
    let n = 8usize;
    let images = test_images(&plan, n);
    let want: Vec<(usize, Vec<f32>)> = images
        .iter()
        .map(|img| {
            let r = handle.classify(img.clone()).unwrap();
            (r.class, r.logits)
        })
        .collect();

    let (_server, addr) = start_server(&handle, ServeConfig::default());
    let conns = 4usize;
    std::thread::scope(|s| {
        for c in 0..conns {
            let addr = &addr;
            let images = &images;
            let want = &want;
            s.spawn(move || {
                let mut client = ServeClient::connect(addr).unwrap();
                for j in (c..n).step_by(conns) {
                    match client.classify(images[j].clone()).unwrap() {
                        ClientReply::Ok { class, logits, .. } => {
                            assert_eq!(class, want[j].0, "sample {j}: argmax over the wire");
                            assert_eq!(logits, want[j].1, "sample {j}: logits not bit-exact");
                        }
                        other => panic!("sample {j}: unexpected reply {other:?}"),
                    }
                }
            });
        }
    });
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.failed_requests, 0);
    assert_eq!(snap.requests, 2 * n as u64, "direct + served requests");
}

#[test]
fn sim_serve_micro_batching_coalesces_concurrent_requests() {
    // Concurrent connections within one flush window must coalesce into
    // shared engine batches: mean batch fill strictly above 1.0. The long
    // flush window makes this deterministic — the first request of a group
    // waits 50ms, by which time every other connection has submitted.
    let plan = sim_plan(fixture::tiny(63), SimXbarConfig::default(), RunConfig::default());
    let handle = plan.deploy_fp32(EngineConfig::default()).unwrap();
    let cfg = ServeConfig {
        policy: BatchPolicy {
            max_batch: 8,
            flush_after: Duration::from_millis(50),
            queue: 64,
        },
        ..ServeConfig::default()
    };
    let (_server, addr) = start_server(&handle, cfg);
    let conns = 8usize;
    let per = 2usize;
    let images = test_images(&plan, conns);
    std::thread::scope(|s| {
        for c in 0..conns {
            let addr = &addr;
            let images = &images;
            s.spawn(move || {
                let mut client = ServeClient::connect(addr).unwrap();
                for _ in 0..per {
                    match client.classify(images[c].clone()).unwrap() {
                        ClientReply::Ok { .. } => {}
                        other => panic!("unexpected reply {other:?}"),
                    }
                }
            });
        }
    });
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.requests, (conns * per) as u64);
    assert_eq!(snap.failed_requests, 0);
    assert!(
        snap.mean_batch_fill > 1.0,
        "micro-batching never coalesced: {} batches for {} requests (fill {:.2})",
        snap.batches,
        snap.requests,
        snap.mean_batch_fill
    );
}

#[test]
fn sim_serve_overload_returns_rejected_not_deadlock() {
    // Queue capacity 1 at the admission door AND in the engine, serial
    // batches of 1, and the (slow in debug) simulator behind them: a
    // concurrent burst must shed load with typed Rejected frames while the
    // accepted requests still complete. No reply may be dropped and no
    // connection may hang — this is the acceptance test for admission
    // control.
    let plan = sim_plan(fixture::tiny(67), SimXbarConfig::default(), RunConfig::default());
    let handle = plan
        .deploy_fp32(EngineConfig {
            max_wait: Duration::from_millis(1),
            queue: 1,
            workers: 1,
            ..EngineConfig::default()
        })
        .unwrap();
    let cfg = ServeConfig {
        policy: BatchPolicy { max_batch: 1, flush_after: Duration::ZERO, queue: 1 },
        wait_timeout: Duration::from_secs(120),
    };
    let (_server, addr) = start_server(&handle, cfg);
    let conns = 8usize;
    let per = 2usize;
    let images = test_images(&plan, conns);
    let (ok, rejected) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                let addr = &addr;
                let images = &images;
                s.spawn(move || {
                    let mut client = ServeClient::connect(addr).unwrap();
                    let (mut ok, mut rejected) = (0usize, 0usize);
                    for _ in 0..per {
                        match client.classify(images[c].clone()).unwrap() {
                            ClientReply::Ok { .. } => ok += 1,
                            ClientReply::Rejected { .. } => rejected += 1,
                            ClientReply::Degraded { reason, .. } => {
                                panic!("unexpected degraded frame: {reason}")
                            }
                            ClientReply::Error { message, .. } => {
                                panic!("unexpected error frame: {message}")
                            }
                        }
                    }
                    (ok, rejected)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |acc, r| (acc.0 + r.0, acc.1 + r.1))
    });
    assert_eq!(ok + rejected, conns * per, "every request got a typed answer");
    assert!(ok >= 1, "nothing was served at all");
    assert!(
        rejected >= 1,
        "an overloaded capacity-1 pipeline never rejected (ok={ok})"
    );
    // The engine never saw the shed requests; nothing failed inside it.
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.failed_requests, 0);
}

#[test]
fn sim_serve_stats_frame_and_bench_client_account_for_every_frame() {
    let plan = sim_plan(fixture::tiny(71), SimXbarConfig::default(), RunConfig::default());
    let handle = plan.deploy_fp32(EngineConfig::default()).unwrap();
    let (_server, addr) = start_server(&handle, ServeConfig::default());
    let images = test_images(&plan, 4);
    let requests = 12usize;
    // 0 retries: every shed reply is terminal, so the accounting identity
    // below holds exactly.
    let report = bench_client(&addr, 3, requests, &images, 0).unwrap();
    assert_eq!(report.requests, requests);
    assert_eq!(
        report.ok + report.rejected + report.degraded + report.failed,
        requests,
        "every request accounted for: {report:?}"
    );
    assert_eq!(report.failed, 0, "{report:?}");
    assert_eq!(report.retries, 0, "{report:?}");
    // Default queue (256) cannot overflow on 12 requests.
    assert_eq!(report.rejected, 0, "{report:?}");
    assert!(report.p99_us >= report.p50_us, "{report:?}");
    assert!(report.req_per_s() > 0.0);

    // The plain-text stats frame reflects the traffic just driven and the
    // engine's histogram percentiles.
    let mut client = ServeClient::connect(&addr).unwrap();
    let text = client.stats().unwrap();
    assert!(text.contains("ok=12"), "stats:\n{text}");
    assert!(text.contains("rejected=0"), "stats:\n{text}");
    assert!(text.contains("p99="), "stats:\n{text}");
    assert!(text.contains("mean_fill="), "stats:\n{text}");
    // Deploy-time programming cost is part of the stats contract (fp32
    // deployments program nothing, but the per-worker field is present).
    assert!(text.contains("program_ns_mean="), "stats:\n{text}");
    assert!(text.contains("program_ns_max="), "stats:\n{text}");
    // The active fault scenario is part of the stats contract; a healthy
    // (scenario-free) deployment reports "none".
    assert!(text.contains("scenario: none"), "stats:\n{text}");
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.observed_requests, requests as u64);
    assert!(snap.p99_latency_us >= snap.p50_latency_us);
}

#[test]
fn sim_serve_stats_json_roundtrips_machine_readable_snapshot() {
    // The StatsJsonReq frame must answer one valid JSON document exposing
    // the complete snapshot: engine counters, the rejected breakdown, the
    // raw 64-bucket latency histogram, the crossbar walk profile, and the
    // server + batcher sections.
    use reram_mpq::util::json::Value;
    let plan = sim_plan(fixture::tiny(79), SimXbarConfig::default(), RunConfig::default())
        .threshold(ThresholdMode::FixedCr(0.5));
    let handle = plan.deploy(EngineConfig::default()).unwrap();
    let (_server, addr) = start_server(&handle, ServeConfig::default());
    let images = test_images(&plan, 4);
    let mut client = ServeClient::connect(&addr).unwrap();
    for img in &images {
        match client.classify(img.clone()).unwrap() {
            ClientReply::Ok { .. } => {}
            other => panic!("unexpected reply {other:?}"),
        }
    }

    let json = client.stats_json().unwrap();
    let v = Value::parse(&json).expect("stats_json is valid JSON");
    let engine = v.get("engine").unwrap();
    assert_eq!(engine.get("requests").unwrap().usize().unwrap(), 4, "{json}");
    let lat = engine.get("latency").unwrap();
    assert_eq!(lat.get("observed_requests").unwrap().usize().unwrap(), 4, "{json}");
    let rej = v.get("rejected").unwrap();
    for key in ["queue_full", "decode", "shutdown", "total"] {
        assert_eq!(rej.get(key).unwrap().usize().unwrap(), 0, "rejected.{key} in {json}");
    }
    let hist = v.get("hist").unwrap().arr().unwrap();
    assert_eq!(hist.len(), 64, "{json}");
    let total: usize = hist.iter().map(|b| b.usize().unwrap()).sum();
    assert_eq!(total, 4, "histogram counts the served requests: {json}");
    assert_eq!(v.get("scenario").unwrap().str().unwrap(), "none", "{json}");
    assert!(v.get("program").unwrap().get("workers").unwrap().usize().unwrap() >= 1, "{json}");
    assert_eq!(v.get("server").unwrap().get("ok").unwrap().usize().unwrap(), 4, "{json}");
    assert_eq!(v.get("batcher").unwrap().get("accepted").unwrap().usize().unwrap(), 4, "{json}");

    // Walk-profile counters fold in *after* replies are sent (the worker
    // pushes the delta once the batch completes), so poll briefly.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let v = Value::parse(&client.stats_json().unwrap()).unwrap();
        let walk = v.get("walk_profile").unwrap();
        let calls = walk.get("conv_calls").unwrap().usize().unwrap();
        if calls >= 1 {
            assert!(walk.get("strips_walked").unwrap().usize().unwrap() >= 1);
            assert!(walk.get("phase_steps").unwrap().usize().unwrap() >= 1);
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "walk profile never surfaced in stats JSON"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn sim_serve_chaos_panic_and_runtime_evolution_recover_bit_exact() {
    // End-to-end self-healing under live traffic: an injected worker panic
    // must answer the in-flight request with a typed Degraded frame and
    // respawn the worker, while a runtime stuck-at ramp must trip the
    // canary probes and drive a background repair with a hot artifact swap.
    //
    // The stuck rate evolves from a clean base (0.0) and saturates at 1.0
    // after one served batch, which makes the test deterministic twice
    // over: the first probe is guaranteed to see saturated damage, and the
    // effective spec is identical at every tick >= 1, so once the repaired
    // artifact is swapped in the monitor goes quiet and replies are
    // bit-stable again.
    use reram_mpq::faults::{HealthSpec, Placement, ScenarioSpec};
    let spec = ScenarioSpec::default().with_stuck(0.0, 41).with_evolution(0.0, 1.0);
    let plan = sim_plan(fixture::tiny(83), SimXbarConfig::default(), RunConfig::default())
        .threshold(ThresholdMode::FixedCr(0.5))
        .with_scenario(spec, Placement::SensitivityAware)
        .with_health(HealthSpec { canaries: 2, spares: 2 });
    let ecfg = EngineConfig {
        workers: 1,
        probe_every: 1,
        chaos_panic_after: 2,
        ..EngineConfig::default()
    };
    let handle = plan.deploy(ecfg).unwrap();
    let (_server, addr) = start_server(&handle, ServeConfig::default());
    let images = test_images(&plan, 2);
    let mut client = ServeClient::connect(&addr).unwrap();

    // Batch 1 serves normally; batch 2 rides the injected panic and must
    // come back as a typed Degraded frame on a connection that survives.
    match client.classify(images[0].clone()).unwrap() {
        ClientReply::Ok { .. } => {}
        other => panic!("first request: unexpected reply {other:?}"),
    }
    match client.classify(images[0].clone()).unwrap() {
        ClientReply::Degraded { reason, retry_after_ms, .. } => {
            assert!(reason.contains("panic"), "degraded reason: {reason}");
            assert!(retry_after_ms >= 1, "degraded frames carry a retry hint");
        }
        other => panic!("chaos batch: unexpected reply {other:?}"),
    }

    // Keep driving traffic (each served batch is one health tick) until the
    // repair cycle completes: probes fired, canaries mismatched, a standby
    // artifact was programmed in the background and hot-swapped in, and
    // sensitivity-aware re-placement moved strips off damaged slots.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        match client.classify(images[0].clone()).unwrap() {
            ClientReply::Ok { .. } => {}
            other => panic!("post-respawn request: unexpected reply {other:?}"),
        }
        let snap = handle.metrics.snapshot();
        if snap.swaps >= 1 && snap.repairs >= 1 {
            assert!(snap.probes >= 1, "{snap:?}");
            assert!(snap.canary_mismatches >= 1, "{snap:?}");
            assert!(snap.reprograms >= 1, "{snap:?}");
            assert_eq!(snap.respawns, 1, "{snap:?}");
            assert_eq!(snap.workers_down, 0, "{snap:?}");
            assert!(snap.degraded >= 1, "{snap:?}");
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "repair cycle never completed: {snap:?}"
        );
    }

    // Post-recovery the effective spec no longer moves (saturated), so the
    // swapped artifact is final: replies must be bit-identical between
    // consecutive direct classifies AND across the wire.
    let a = handle.classify(images[1].clone()).unwrap();
    let b = handle.classify(images[1].clone()).unwrap();
    assert_eq!(a.class, b.class);
    assert_eq!(a.logits, b.logits, "post-recovery replies must be bit-stable");
    match client.classify(images[1].clone()).unwrap() {
        ClientReply::Ok { class, logits, .. } => {
            assert_eq!(class, a.class, "wire argmax vs direct classify");
            assert_eq!(logits, a.logits, "wire logits not bit-exact after recovery");
        }
        other => panic!("post-recovery request: unexpected reply {other:?}"),
    }
}

#[test]
fn sim_serve_bad_image_size_answers_error_frame_and_connection_survives() {
    // An undersized image must be refused at the door with a typed Error
    // frame — never enter a batch (where it would fail the whole batch) —
    // and the connection must stay usable for the next request.
    let plan = sim_plan(fixture::tiny(73), SimXbarConfig::default(), RunConfig::default());
    let handle = plan.deploy_fp32(EngineConfig::default()).unwrap();
    let (_server, addr) = start_server(&handle, ServeConfig::default());
    let mut client = ServeClient::connect(&addr).unwrap();
    match client.classify(vec![0.0; 7]).unwrap() {
        ClientReply::Error { message, .. } => {
            assert!(message.contains("bad image size"), "{message}")
        }
        other => panic!("unexpected reply {other:?}"),
    }
    let images = test_images(&plan, 1);
    match client.classify(images[0].clone()).unwrap() {
        ClientReply::Ok { logits, .. } => assert_eq!(logits.len(), fixture::NUM_CLASSES),
        other => panic!("unexpected reply {other:?}"),
    }
    // The malformed request never reached the engine.
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.failed_requests, 0);
    assert_eq!(snap.requests, 1);
}
