//! Tuner integration tests on the hermetic fixture (sim backend): resume
//! bit-stability, stage-cache prefix reuse, frontier soundness, and the
//! degenerate Table 3 sweep equivalence. All `sim_`-prefixed — they run on
//! a bare machine with no artifacts and are counted by the CI hermetic
//! test gate.

use std::collections::BTreeSet;

use reram_mpq::coordinator::{
    CompressionPlan, EvalOpts, Executor, ModelState, ThresholdMode,
};
use reram_mpq::tuner::{
    self, Axes, SearchState, TuneConfig, TuneShared, TABLE3_CRS,
};
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{fixture, RunConfig};

const CRS: &[f64] = &[0.0, 0.5, 1.0];

fn shared(seed: u64) -> TuneShared {
    TuneShared::from_fixture(fixture::tiny(seed), RunConfig::default())
}

fn tcfg(workers: usize) -> TuneConfig {
    TuneConfig {
        workers,
        opts: EvalOpts::batches(2),
        ..TuneConfig::default()
    }
}

#[test]
fn sim_tuner_resume_matches_uninterrupted() {
    let sh = shared(11);
    let axes = Axes::cr_axis(TABLE3_CRS, 8, 4).unwrap();

    // Uninterrupted reference, two workers.
    let mut full = SearchState::new(0, axes.fingerprint(0));
    let out_full = tuner::run(&sh, &axes, &tcfg(2), &mut full).unwrap();
    assert_eq!(out_full.evals, TABLE3_CRS.len());
    assert!(!out_full.frontier.is_empty());

    // Kill after 3 evals, then resume with a different worker count.
    let mut part = SearchState::new(0, axes.fingerprint(0));
    let cut = TuneConfig { max_evals: 3, ..tcfg(1) };
    let out_cut = tuner::run(&sh, &axes, &cut, &mut part).unwrap();
    assert_eq!(out_cut.evals, 3);
    let out_resumed = tuner::run(&sh, &axes, &tcfg(2), &mut part).unwrap();
    assert_eq!(out_resumed.evals, TABLE3_CRS.len() - 3);

    // Point-for-point bit-identical (canonical form excludes elapsed_ms).
    assert_eq!(
        part.canonical_value().to_json(),
        full.canonical_value().to_json()
    );
}

#[test]
fn sim_tuner_resume_from_disk_roundtrip() {
    let sh = shared(12);
    let axes = Axes::cr_axis(CRS, 8, 4).unwrap();

    let mut full = SearchState::new(0, axes.fingerprint(0));
    tuner::run(&sh, &axes, &tcfg(1), &mut full).unwrap();

    // Interrupt, persist, reload from disk, resume.
    let mut part = SearchState::new(0, axes.fingerprint(0));
    let cut = TuneConfig { max_evals: 1, ..tcfg(1) };
    tuner::run(&sh, &axes, &cut, &mut part).unwrap();
    let path = std::env::temp_dir().join(format!("tuner-resume-{}.json", std::process::id()));
    part.save(&path).unwrap();
    let mut reloaded = SearchState::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    tuner::run(&sh, &axes, &tcfg(1), &mut reloaded).unwrap();

    assert_eq!(
        reloaded.canonical_value().to_json(),
        full.canonical_value().to_json()
    );
}

#[test]
fn sim_tuner_rejects_mismatched_state() {
    let sh = shared(13);
    let axes = Axes::cr_axis(CRS, 8, 4).unwrap();
    // State fingerprinted for a different space must be refused.
    let other = Axes::cr_axis(CRS, 8, 2).unwrap();
    let mut st = SearchState::new(0, other.fingerprint(0));
    assert!(tuner::run(&sh, &axes, &tcfg(1), &mut st).is_err());
}

#[test]
fn sim_tuner_reports_prefix_cache_hits() {
    let sh = shared(14);
    // Two knob axes over one worker: every candidate after the first reuses
    // the worker's memoized sensitivity prefix.
    let axes = Axes::parse("cr,bits", CRS, (8, 4)).unwrap();
    let mut st = SearchState::new(0, axes.fingerprint(0));
    let out = tuner::run(&sh, &axes, &tcfg(1), &mut st).unwrap();
    assert_eq!(out.evals, axes.len());
    assert!(
        out.cache.sensitivity_hits > 0,
        "expected memoized sensitivity reuse, got {:?} hits",
        out.cache.sensitivity_hits
    );
    assert!(out.cache.prefix_hits() > 0);
    // One worker computed the sensitivity scores exactly once.
    assert_eq!(out.cache.sensitivity_runs, 1);
}

#[test]
fn sim_tuner_frontier_is_sound_over_explored_set() {
    let sh = shared(15);
    let axes = Axes::parse("cr,bits", CRS, (8, 4)).unwrap();
    let mut st = SearchState::new(1, axes.fingerprint(1)); // shuffled schedule
    let out = tuner::run(&sh, &axes, &tcfg(2), &mut st).unwrap();
    assert!(!out.frontier.is_empty());

    let keys: BTreeSet<&str> = st.explored.keys().map(String::as_str).collect();
    for p in out.frontier.points() {
        // Frontier points come from the explored set...
        assert!(keys.contains(p.key.as_str()));
        // ...and none is dominated by anything explored.
        for e in st.explored.values() {
            assert!(
                !e.objectives.dominates(&p.objectives),
                "{} dominates frontier point {}",
                e.candidate.key(),
                p.key
            );
        }
    }
}

#[test]
fn sim_tuner_zero_budget_noop_then_resume_completes() {
    let sh = shared(16);
    let axes = Axes::cr_axis(CRS, 8, 4).unwrap();
    let mut st = SearchState::new(0, axes.fingerprint(0));
    let spent = TuneConfig { budget_ms: 0, ..tcfg(1) };
    let out = tuner::run(&sh, &axes, &spent, &mut st).unwrap();
    assert_eq!(out.evals, 0);
    assert!(out.frontier.is_empty());
    let out = tuner::run(&sh, &axes, &tcfg(1), &mut st).unwrap();
    assert_eq!(out.evals, CRS.len());
    assert_eq!(out.explored, CRS.len());
}

#[test]
fn sim_tuner_degenerate_cr_sweep_matches_plan_chain() {
    // sweep_cr on an existing plan must be byte-for-byte the chain the
    // Table 3 experiment always ran.
    let fx = fixture::tiny(17);
    let cfg = RunConfig::default();
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(Default::default()),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        cfg,
    );
    let opts = EvalOpts::batches(2);
    let swept = tuner::sweep_cr(&plan, CRS, opts).unwrap();
    for (&cr, got) in CRS.iter().zip(&swept) {
        let want = plan
            .clone()
            .threshold(ThresholdMode::FixedCr(cr))
            .cluster()
            .align_to_capacity()
            .map(MappingStrategy::Packed)
            .evaluate(opts)
            .unwrap();
        assert_eq!(got.accuracy.top1, want.accuracy.top1);
        assert_eq!(got.compression_ratio, want.compression_ratio);
        assert_eq!(got.q_hi, want.q_hi);
    }
}
