//! Integration tests over the full evaluate/deploy pipeline.
//!
//! Two halves:
//!
//! * **PJRT** — over the real AOT artifacts; require `make artifacts` to
//!   have run (they skip themselves otherwise so the tier-1 gate stays
//!   green on artifact-less runners). Each test builds its own runtime
//!   because PJRT clients are not Send/Sync.
//! * **SimXbar (`sim_*`)** — hermetic: in-memory fixtures on the native
//!   bit-serial crossbar simulator, no artifacts and no XLA state needed.
//!   These must never self-skip — the `hermetic` CI job runs
//!   `cargo test sim_` with no artifacts present and fails on any skip.

use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

use reram_mpq::backend::{ExecBackend, FwdKind, SimXbar, SimXbarConfig};
use reram_mpq::clustering;
use reram_mpq::config::SensitivityConfig;
use reram_mpq::coordinator::{
    evaluate_batches, BackendSpec, CompressionPlan, Engine, EngineConfig, EvalOpts, Executor,
    ModelState, ThresholdMode,
};
use reram_mpq::dataset::TestSet;
use reram_mpq::fixture::{self, Fixture};
use reram_mpq::model::ModelInfo;
use reram_mpq::quant;
use reram_mpq::tensor::Tensor;
use reram_mpq::util::rng::Rng;
use reram_mpq::xbar::{self, MappingStrategy};
use reram_mpq::{artifacts_dir, Manifest, RunConfig, Runtime};

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect("run `make artifacts` first"))
}

/// Skip (pass trivially) when the AOT artifacts have not been generated —
/// e.g. on a CI runner that only builds the Rust crate.
macro_rules! require_artifacts {
    () => {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

// PJRT clients are not Send/Sync, so every test builds its own runtime
// (cargo test runs tests on separate threads).
fn runtime() -> Runtime {
    Runtime::new(artifacts_dir()).expect("pjrt cpu client")
}

/// Fast sensitivity settings shared by the plan tests.
fn quick_cfg() -> RunConfig {
    RunConfig {
        sensitivity: SensitivityConfig { probes: 2, calib_batches: 1, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn manifest_contract_holds() {
    require_artifacts!();
    let m = manifest();
    assert!(m.models.contains_key("resnet8"));
    assert!(m.models.contains_key("resnet14"));
    assert!(m.models.contains_key("resnet20"));
    for entry in m.models.values() {
        let info = reram_mpq::model::ModelInfo::new(entry.clone());
        // strips cover exactly the conv params
        let strip_params: usize = info
            .strips()
            .iter()
            .map(|s| info.layer(s.layer).d)
            .sum();
        assert_eq!(strip_params, entry.num_conv_params);
        // params tensor length matches
        assert_eq!(entry.params.shape.iter().product::<usize>(), entry.num_params);
    }
}

#[test]
fn fp32_eval_reproduces_training_accuracy() {
    require_artifacts!();
    let m = manifest();
    let rt = runtime();
    let info = m.model("resnet8").unwrap();
    let theta = info.load_params(m).unwrap();
    let test = TestSet::load(m).unwrap();
    let acc = evaluate_batches(&rt, &info, &theta, &test, 4).unwrap();
    // python-side accuracy was measured on the same split; allow slack for
    // the 4-batch subset.
    assert!(
        (acc.top1 - info.entry.fp32_test_acc).abs() < 0.08,
        "rust eval {:.4} vs python {:.4}",
        acc.top1,
        info.entry.fp32_test_acc
    );
    assert!(acc.top5 >= acc.top1);
}

#[test]
fn pallas_fwd_matches_plain_fwd() {
    require_artifacts!();
    // The L1-in-L2 composition artifact must agree with the lax-conv graph.
    let m = manifest();
    let rt = runtime();
    let info = m.model("resnet8").unwrap();
    let theta = Tensor::from_vec(info.load_params(m).unwrap());
    let test = TestSet::load(m).unwrap();
    let b = info.entry.batch.serve;
    let (x, _) = test.batch(0, b);

    let plain = rt
        .exec(&info.entry.executables["fwd_serve"], &[theta.clone(), x.clone()])
        .unwrap();
    let pallas = rt
        .exec(&info.entry.executables["fwd_pallas"], &[theta, x])
        .unwrap();
    let max_err = plain[0]
        .data()
        .iter()
        .zip(pallas[0].data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-2, "pallas fwd deviates: {max_err}");
}

#[test]
fn strip_mvm_kernel_matches_rust_oracle() {
    require_artifacts!();
    let m = manifest();
    let rt = runtime();
    let k = &m.kernel;
    let (t, d, g, n) = (k.t, k.d, k.g, k.n);
    let mut rng = Rng::seed_from_u64(5);
    let a: Vec<f32> = (0..t * g * d).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..g * d * n).map(|_| (rng.below(255) as f32) - 127.0).collect();
    let s: Vec<f32> = (0..g * n).map(|_| rng.range(0.001, 0.01) as f32).collect();
    let out = rt
        .exec(
            &k.strip_mvm,
            &[
                Tensor::new(vec![t, g * d], a.clone()),
                Tensor::new(vec![g * d, n], w.clone()),
                Tensor::new(vec![g, n], s.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out[0].shape(), &[t, n]);
    let mut want = vec![0.0f64; t * n];
    for ti in 0..t {
        for gi in 0..g {
            for ni in 0..n {
                let mut acc = 0.0f64;
                for di in 0..d {
                    acc += a[ti * g * d + gi * d + di] as f64 * w[(gi * d + di) * n + ni] as f64;
                }
                want[ti * n + ni] += acc * s[gi * n + ni] as f64;
            }
        }
    }
    for (got, want) in out[0].data().iter().zip(&want) {
        assert!((*got as f64 - want).abs() < 1e-2, "{got} vs {want}");
    }
}

#[test]
fn mixed_kernel_equals_sum_of_clusters() {
    require_artifacts!();
    // Z = Z_q + expand(Z_p): the mixed executable must equal two separate
    // strip_mvm calls added in Rust (stepwise accumulation, paper §4.3).
    let m = manifest();
    let rt = runtime();
    let k = &m.kernel;
    let (t, d, g, n) = (k.t, k.d, k.g, k.n);
    let mut rng = Rng::seed_from_u64(6);
    let a = Tensor::new(vec![t, g * d], (0..t * g * d).map(|_| rng.normal()).collect());
    // complementary random hi/lo masks at strip granularity
    let mask: Vec<bool> = (0..g * n).map(|_| rng.bool()).collect();
    let mut wq = vec![0.0f32; g * d * n];
    let mut wp = vec![0.0f32; g * d * n];
    for gi in 0..g {
        for di in 0..d {
            for ni in 0..n {
                let v = (rng.below(15) as f32) - 7.0;
                if mask[gi * n + ni] {
                    wq[(gi * d + di) * n + ni] = v;
                } else {
                    wp[(gi * d + di) * n + ni] = v;
                }
            }
        }
    }
    let sq: Vec<f32> = (0..g * n).map(|i| if mask[i] { 0.01 } else { 0.0 }).collect();
    let sp: Vec<f32> = (0..g * n).map(|i| if mask[i] { 0.0 } else { 0.16 }).collect();
    let wq = Tensor::new(vec![g * d, n], wq);
    let wp = Tensor::new(vec![g * d, n], wp);
    let sq = Tensor::new(vec![g, n], sq);
    let sp = Tensor::new(vec![g, n], sp);

    let mixed = rt
        .exec(
            &k.mixed_strip_mvm,
            &[a.clone(), wq.clone(), sq.clone(), wp.clone(), sp.clone()],
        )
        .unwrap();
    let zq = rt.exec(&k.strip_mvm, &[a.clone(), wq, sq]).unwrap();
    let zp = rt.exec(&k.strip_mvm, &[a, wp, sp]).unwrap();
    for ((m1, q), p) in mixed[0].data().iter().zip(zq[0].data()).zip(zp[0].data()) {
        assert!((m1 - (q + p)).abs() < 1e-3);
    }
}

#[test]
fn quantized_accuracy_degrades_monotonically_in_spirit() {
    require_artifacts!();
    // CR 0 (all 8-bit) should be within noise of fp32; CR 1.0 (all 4-bit
    // per-layer + device noise) should be strictly worse.
    let m = manifest();
    let rt = runtime();
    let base = CompressionPlan::for_model(&rt, m, "resnet8").unwrap();
    let at = |cr: f64| {
        base.clone()
            .threshold(ThresholdMode::FixedCr(cr))
            .cluster()
            .align_to_capacity()
            .map(MappingStrategy::Packed)
            .evaluate(EvalOpts::batches(4))
            .unwrap()
    };
    let r0 = at(0.0);
    let r1 = at(1.0);
    assert!(r0.accuracy.top1 > r1.accuracy.top1, "{} !> {}", r0.accuracy.top1, r1.accuracy.top1);
    assert!(r0.cost.energy.system_mj() > r1.cost.energy.system_mj());
    // mixed sits between
    let rm = at(0.6);
    assert!(rm.cost.energy.system_mj() < r0.cost.energy.system_mj());
    assert!(rm.cost.energy.system_mj() > r1.cost.energy.system_mj());
}

#[test]
fn sensitivity_scores_are_finite_and_informative() {
    require_artifacts!();
    let m = manifest();
    let rt = runtime();
    let plan = CompressionPlan::for_model_with(&rt, m, "resnet8", quick_cfg()).unwrap();
    let s = plan.sensitivity_scores().unwrap();
    assert_eq!(s.scores.len(), plan.model().num_strips());
    assert!(s.scores.iter().all(|v| v.is_finite() && *v >= 0.0));
    // scores must not be constant — otherwise clustering is meaningless
    let sorted = s.sorted_scores();
    assert!(sorted[sorted.len() - 1] > sorted[0]);
}

#[test]
fn engine_serves_correct_predictions() {
    require_artifacts!();
    let m = manifest();
    let rt = runtime();
    let info = m.model("resnet8").unwrap();
    let theta = info.load_params(m).unwrap();
    let test = TestSet::load(m).unwrap();

    // Reference predictions through fwd_eval.
    let acc_ref = evaluate_batches(&rt, &info, &theta, &test, 1).unwrap();

    let engine = Engine::pjrt(artifacts_dir(), &info, theta, EngineConfig::default()).unwrap();
    let handle = engine.start().unwrap();
    let elems = 32 * 32 * 3;
    let n = info.entry.batch.eval; // same images as the first eval batch
    let mut correct = 0;
    let pend: Vec<_> = (0..n)
        .map(|j| handle.submit(test.x.data()[j * elems..(j + 1) * elems].to_vec()).unwrap())
        .collect();
    for (j, p) in pend.into_iter().enumerate() {
        let resp = p.wait().unwrap();
        assert_eq!(resp.logits.len(), m.num_classes);
        if resp.class == test.y[j] {
            correct += 1;
        }
    }
    let acc_engine = correct as f64 / n as f64;
    assert!(
        (acc_engine - acc_ref.top1).abs() < 1e-9,
        "engine {acc_engine} vs eval {}",
        acc_ref.top1
    );
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.requests, n as u64);
    assert!(snap.batches >= (n / info.entry.batch.serve) as u64);
    assert_eq!(snap.failed_requests, 0);
}

#[test]
fn threshold_sweep_picks_interior_point() {
    require_artifacts!();
    let m = manifest();
    let rt = runtime();
    let plan = CompressionPlan::for_model_with(&rt, m, "resnet8", quick_cfg())
        .unwrap()
        .threshold(ThresholdMode::Sweep);
    let thr = plan.chosen_threshold().unwrap();
    assert!(thr.fim_evals > 1);
    // near-Pareto choice should compress something but not everything
    // (fim+energy joint objective); allow the extremes but assert validity.
    let c = plan.clustering().unwrap();
    assert!(c.q_hi <= plan.model().num_strips());
}

// ---- new-builder API contract tests ---------------------------------------

#[test]
fn stage_cache_runs_hutchinson_once_across_plans() {
    require_artifacts!();
    // Two plans sharing a sensitivity prefix: the analyzer runs exactly once.
    let m = manifest();
    let rt = runtime();
    let base = CompressionPlan::for_model_with(&rt, m, "resnet8", quick_cfg()).unwrap();
    let p1 = base.clone().threshold(ThresholdMode::FixedCr(0.3)).align_to_capacity();
    let p2 = base.clone().threshold(ThresholdMode::FixedCr(0.7)).align_to_capacity();
    let c1 = p1.clustering().unwrap();
    let c2 = p2.clustering().unwrap();
    assert_ne!(c1.q_hi, c2.q_hi, "distinct operating points");
    assert_eq!(
        base.cache_stats().sensitivity_runs,
        1,
        "hutchinson must run exactly once for a shared prefix"
    );
    assert_eq!(base.cache_stats().clustering_runs, 2);
    // re-resolving an artifact is a pure cache hit
    let _ = p1.clustering().unwrap();
    assert_eq!(base.cache_stats().clustering_runs, 2);
}

#[test]
fn plan_matches_hand_rolled_stage_composition() {
    require_artifacts!();
    // The builder's FixedCr path must be numerically identical to composing
    // the underlying stage functions directly (the pre-builder pipeline).
    let m = manifest();
    let rt = runtime();
    let cfg = quick_cfg();
    let plan = CompressionPlan::for_model_with(&rt, m, "resnet8", cfg.clone())
        .unwrap()
        .threshold(ThresholdMode::FixedCr(0.6))
        .cluster()
        .align_to_capacity()
        .map(MappingStrategy::Packed);
    let r = plan.evaluate(EvalOpts::batches(2)).unwrap();

    // Hand-rolled: sensitivity -> cluster -> align -> quantize -> map ->
    // cost -> evaluate, exactly as Pipeline::run used to compose them.
    let sens = plan.sensitivity_scores().unwrap();
    let model = plan.model();
    let raw = clustering::cluster_at_cr(&sens.scores, 0.6, cfg.quant.hi.bits, cfg.quant.lo.bits);
    let caps: Vec<usize> = model
        .conv_layers()
        .iter()
        .map(|l| cfg.xbar.capacity_strips(l.d, cfg.quant.hi.bits))
        .collect();
    let aligned = clustering::align_to_capacity(
        model,
        &sens.scores,
        &raw,
        cfg.quant.hi.bits,
        cfg.quant.lo.bits,
        |li| caps[li],
    );
    let qm = quant::apply(model, plan.theta(), &aligned.bitmap, &cfg.quant);
    let mapping = xbar::map_model(model, &aligned.bitmap, &cfg.xbar, MappingStrategy::Packed);
    let cost = xbar::cost(&mapping, &cfg.xbar);
    let acc = evaluate_batches(&rt, model, &qm.theta, plan.test(), 2).unwrap();

    assert_eq!(r.q_hi, aligned.q_hi);
    assert_eq!(r.total_strips, aligned.bitmap.bits.len());
    assert!((r.compression_ratio - aligned.bitmap.compression_ratio(cfg.quant.hi.bits)).abs() < 1e-15);
    assert!((r.accuracy.top1 - acc.top1).abs() < 1e-12);
    assert!((r.cost.energy.system_mj() - cost.energy.system_mj()).abs() < 1e-15);
    assert!((r.quant_mse - qm.mse).abs() < 1e-18);
    assert!((r.threshold - aligned.threshold).abs() < 1e-15);
}

#[test]
fn alg1_plan_equals_fixed_cr_at_its_chosen_quantile() {
    require_artifacts!();
    // An Alg1 plan and a FixedCr plan pinned at Alg1's chosen quantile must
    // produce the same clustering and report (modulo the search bookkeeping).
    let m = manifest();
    let rt = runtime();
    let base = CompressionPlan::for_model_with(&rt, m, "resnet8", quick_cfg()).unwrap();
    let alg1 = base.clone().threshold(ThresholdMode::Alg1).align_to_capacity();
    let r1 = alg1.evaluate(EvalOpts::batches(2)).unwrap();
    let q = alg1.chosen_threshold().unwrap().quantile;
    assert!(r1.fim_evals > 0, "alg1 must spend FIM evaluations");

    let fixed = base.clone().threshold(ThresholdMode::FixedCr(q)).align_to_capacity();
    let r2 = fixed.evaluate(EvalOpts::batches(2)).unwrap();
    assert_eq!(r2.fim_evals, 0);
    assert_eq!(r1.q_hi, r2.q_hi);
    assert_eq!(r1.total_strips, r2.total_strips);
    assert!((r1.compression_ratio - r2.compression_ratio).abs() < 1e-15);
    assert!((r1.accuracy.top1 - r2.accuracy.top1).abs() < 1e-12);
    assert!((r1.cost.energy.system_mj() - r2.cost.energy.system_mj()).abs() < 1e-15);
}

#[test]
fn explicit_bitmap_feeds_the_same_tail_as_clustering() {
    require_artifacts!();
    // A bitmap_from plan carrying a clustering's own bitmap must reproduce
    // the clustered plan's report (baselines are just another stage).
    let m = manifest();
    let rt = runtime();
    let base = CompressionPlan::for_model_with(&rt, m, "resnet8", quick_cfg()).unwrap();
    let clustered = base.clone().threshold(ThresholdMode::FixedCr(0.5));
    let rc = clustered.evaluate(EvalOpts::batches(2)).unwrap();
    let bm = (*clustered.bitmap().unwrap()).clone();
    let explicit = base
        .clone()
        .bitmap_from(bm)
        .nominal(ThresholdMode::FixedCr(0.5));
    let re = explicit.evaluate(EvalOpts::batches(2)).unwrap();
    assert_eq!(rc.q_hi, re.q_hi);
    assert!((rc.accuracy.top1 - re.accuracy.top1).abs() < 1e-12);
    assert!((rc.cost.energy.system_mj() - re.cost.energy.system_mj()).abs() < 1e-15);
    assert!((rc.quant_mse - re.quant_mse).abs() < 1e-18);
}

#[test]
fn deploy_smoke_test_classifies_through_engine_handle() {
    require_artifacts!();
    let m = manifest();
    let rt = runtime();
    let plan = CompressionPlan::for_model_with(&rt, m, "resnet8", quick_cfg())
        .unwrap()
        .threshold(ThresholdMode::FixedCr(0.5));
    let handle = plan.deploy(EngineConfig::default()).unwrap();
    let test = plan.test();
    let elems = 32 * 32 * 3;
    let resp = handle.classify(test.x.data()[..elems].to_vec()).unwrap();
    assert_eq!(resp.logits.len(), m.num_classes);
    assert!(resp.class < m.num_classes);
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.failed_requests, 0);
}

#[test]
fn engine_reports_batch_failures_explicitly() {
    require_artifacts!();
    // A wrong-sized image fails its whole batch: the caller gets an error
    // reply (not a hung/dropped channel) and the metrics count the failure.
    let m = manifest();
    let info = m.model("resnet8").unwrap();
    let theta = info.load_params(m).unwrap();
    let engine = Engine::pjrt(artifacts_dir(), &info, theta, EngineConfig::default()).unwrap();
    let handle = engine.start().unwrap();
    let err = handle.classify(vec![0.0; 7]).unwrap_err();
    assert!(err.to_string().contains("batch failed"), "{err}");
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.failed_requests, 1);
    assert_eq!(snap.failed_batches, 1);
    // the engine stays alive and serves well-formed requests afterwards
    let resp = handle.classify(vec![0.0; 32 * 32 * 3]).unwrap();
    assert_eq!(resp.logits.len(), m.num_classes);
}

// ---- hermetic SimXbar backend tests (no artifacts required) ----------------
// The deploy/evaluate pipeline, un-skipped: everything below runs on every
// machine from in-memory fixtures. No `require_artifacts!` here, ever.

/// Root a compression plan on the simulator backend over an in-memory
/// fixture (no manifest on disk).
fn sim_plan(fx: Fixture, scfg: SimXbarConfig, cfg: RunConfig) -> CompressionPlan<'static> {
    CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(scfg),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        cfg,
    )
}

#[test]
fn sim_evaluate_executes_pipeline_without_artifacts() {
    let staged = |seed| {
        sim_plan(fixture::tiny(seed), SimXbarConfig::default(), RunConfig::default())
            .threshold(ThresholdMode::FixedCr(0.6))
            .cluster()
            .align_to_capacity()
            .map(MappingStrategy::Packed)
    };
    let plan = staged(11);
    let r = plan.evaluate(EvalOpts::batches(2)).unwrap();
    assert_eq!(r.accuracy.samples, 8, "two eval batches of 4 must actually execute");
    assert!((0.0..=1.0).contains(&r.accuracy.top1) && r.accuracy.top5 >= r.accuracy.top1);
    assert_eq!(r.total_strips, plan.model().num_strips());
    assert!(r.q_hi > 0 && r.q_hi < r.total_strips, "mixed allocation expected, got {}", r.q_hi);
    assert!(r.cost.energy.system_mj() > 0.0 && r.cost.latency_ms > 0.0);
    assert!(r.utilization_all > 0.0 && r.utilization_all <= 1.0 + 1e-12);
    // a fresh root (same seeds) reproduces the report exactly
    let r2 = staged(11).evaluate(EvalOpts::batches(2)).unwrap();
    assert_eq!(r.accuracy.top1, r2.accuracy.top1);
    assert_eq!((r.q_hi, r.total_strips), (r2.q_hi, r2.total_strips));
    assert_eq!(r.cost.energy.system_mj(), r2.cost.energy.system_mj());
}

#[test]
fn sim_energy_orders_compression_points_and_proxy_runs_once() {
    let base = sim_plan(fixture::tiny(13), SimXbarConfig::default(), RunConfig::default());
    let at = |cr: f64| {
        base.clone()
            .threshold(ThresholdMode::FixedCr(cr))
            .cluster()
            .align_to_capacity()
            .map(MappingStrategy::Packed)
            .evaluate(EvalOpts::batches(1))
            .unwrap()
    };
    let r0 = at(0.0);
    let rm = at(0.6);
    let r1 = at(1.0);
    assert!(r0.cost.energy.system_mj() > rm.cost.energy.system_mj());
    assert!(rm.cost.energy.system_mj() > r1.cost.energy.system_mj());
    // the proxy-sensitivity stage is shared across all three operating points
    assert_eq!(base.cache_stats().sensitivity_runs, 1);
    assert_eq!(base.cache_stats().clustering_runs, 3);
}

#[test]
fn sim_deploy_serves_predictions_matching_evaluate() {
    let base = sim_plan(fixture::tiny(17), SimXbarConfig::default(), RunConfig::default())
        .threshold(ThresholdMode::FixedCr(0.5));
    let r = base.evaluate(EvalOpts::batches(1)).unwrap();
    let handle = base.deploy(EngineConfig::default()).unwrap();
    let test = base.test();
    let elems = 32 * 32 * 3;
    let n = base.model().entry.batch.eval; // same images as the eval batch
    let pend: Vec<_> = (0..n)
        .map(|j| handle.submit(test.x.data()[j * elems..(j + 1) * elems].to_vec()).unwrap())
        .collect();
    let mut correct = 0usize;
    for (j, p) in pend.into_iter().enumerate() {
        let resp = p.wait().unwrap();
        assert_eq!(resp.logits.len(), fixture::NUM_CLASSES);
        if resp.class == test.y[j] {
            correct += 1;
        }
    }
    // the simulator is per-sample deterministic, so serving through the
    // padded dynamic batches must agree with offline evaluation exactly
    assert!(
        (correct as f64 / n as f64 - r.accuracy.top1).abs() < 1e-9,
        "engine {} vs eval {}",
        correct as f64 / n as f64,
        r.accuracy.top1
    );
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.requests, n as u64);
    assert_eq!(snap.failed_requests, 0);
}

#[test]
fn sim_engine_reports_batch_failures_and_recovers() {
    let fx = fixture::tiny(19);
    let spec = BackendSpec::Sim { cfg: SimXbarConfig::default(), strips: None, scenario: None };
    let engine = Engine::new(spec, &fx.model, fx.theta.clone(), EngineConfig::default()).unwrap();
    let handle = engine.start().unwrap();
    let err = handle.classify(vec![0.0; 7]).unwrap_err();
    assert!(err.to_string().contains("batch failed"), "{err}");
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.failed_requests, 1);
    assert_eq!(snap.failed_batches, 1);
    // the engine stays alive and serves well-formed requests afterwards
    let resp = handle.classify(vec![0.0; 32 * 32 * 3]).unwrap();
    assert_eq!(resp.logits.len(), fixture::NUM_CLASSES);
}

#[test]
fn sim_engine_startup_failure_is_typed() {
    // A malformed deployment (wrong-length theta) must fail the readiness
    // handshake with a typed error naming the backend and the reason — not
    // a log line and a dead queue.
    let fx = fixture::tiny(23);
    let spec = BackendSpec::Sim { cfg: SimXbarConfig::default(), strips: None, scenario: None };
    let engine = Engine::new(spec, &fx.model, vec![0.0; 3], EngineConfig::default()).unwrap();
    let err = engine.start().unwrap_err();
    assert_eq!(err.backend, "sim");
    assert!(err.reason.contains("theta length"), "{}", err.reason);
    // the Display form carries both
    let msg = err.to_string();
    assert!(msg.contains("sim") && msg.contains("failed to start"), "{msg}");
}

#[test]
fn sim_pjrt_engine_startup_failure_is_typed() {
    // The PJRT spec against a nonexistent artifacts directory fails the
    // readiness handshake (client failure or missing serve artifact — both
    // surface as a typed StartupError, never a silently dead engine).
    let fx = fixture::tiny(29);
    let mut entry = fx.model.entry.clone();
    entry
        .executables
        .insert("fwd_serve".into(), "does-not-exist.hlo".into());
    let model = ModelInfo::new(entry);
    let theta = vec![0.0f32; model.entry.num_params];
    let engine = Engine::pjrt(
        PathBuf::from("/nonexistent-reram-mpq-artifacts"),
        &model,
        theta,
        EngineConfig::default(),
    )
    .unwrap();
    let err = engine.start().unwrap_err();
    assert_eq!(err.backend, "pjrt");
    assert!(!err.reason.is_empty());
}

#[test]
fn sim_full_net_matches_exact_reference_at_high_fidelity() {
    // End-to-end across the whole network: with a near-lossless DAC, ideal
    // ADC and no noise, the bit-serial strips must reproduce the exact-f32
    // forward on the same quantized parameters.
    let mut cfg = RunConfig::default();
    cfg.quant.device_sigma = 0.0;
    let plan = sim_plan(fixture::tiny(31), SimXbarConfig::high_fidelity(), cfg)
        .threshold(ThresholdMode::FixedCr(0.0)); // every strip 8-bit
    let qm = plan.quantized().unwrap();
    let model = plan.model();
    let theta_t = Tensor::from_vec(qm.theta.clone());
    let xb = plan.test().x.slice_rows(0, 4);
    let sim = SimXbar::from_quantized(SimXbarConfig::high_fidelity(), &qm);
    let exact = SimXbar::new(SimXbarConfig::default()); // no strips: exact f32
    let a = sim.forward(model, FwdKind::Eval, &theta_t, &xb).unwrap();
    let b = exact.forward(model, FwdKind::Eval, &theta_t, &xb).unwrap();
    assert_eq!(a.shape(), b.shape());
    let max_err = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-2, "bit-serial forward deviates from f32 reference: {max_err}");
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}

#[test]
fn parity_pjrt_and_sim_agree_in_argmax() {
    // Backend parity: the native simulator's exact-f32 graph must predict
    // the same classes as the AOT-compiled training graph through PJRT on a
    // small batch. (The PJRT half needs artifacts, so this test self-skips
    // without them; the sim-only coverage lives in the sim_* tests above.)
    require_artifacts!();
    let m = manifest();
    let rt = runtime();
    let info = m.model("resnet8").unwrap();
    let theta = Tensor::from_vec(info.load_params(m).unwrap());
    let test = TestSet::load(m).unwrap();
    let (x, _) = test.batch(0, info.entry.batch.eval);

    let pjrt_logits = rt.forward(&info, FwdKind::Eval, &theta, &x).unwrap();
    let sim = SimXbar::new(SimXbarConfig::default()); // no strips: exact f32
    let sim_logits = sim.forward(&info, FwdKind::Eval, &theta, &x).unwrap();
    assert_eq!(pjrt_logits.shape(), sim_logits.shape());
    let k = pjrt_logits.shape()[1];
    for (i, (a, b)) in pjrt_logits
        .data()
        .chunks_exact(k)
        .zip(sim_logits.data().chunks_exact(k))
        .enumerate()
    {
        assert_eq!(
            argmax(a),
            argmax(b),
            "sample {i}: pjrt logits {a:?} vs sim logits {b:?}"
        );
    }
}

// ---- sharded engine (workers > 1) ------------------------------------------

#[test]
fn sim_sharded_engine_is_bit_identical_to_single_worker() {
    // N concurrent clients against a 4-worker engine must observe logits
    // bit-identical to the single-worker engine: the simulator is
    // per-sample deterministic and padding never leaks across requests, so
    // neither worker count nor batch composition may change a reply.
    let base = sim_plan(fixture::tiny(41), SimXbarConfig::default(), RunConfig::default())
        .threshold(ThresholdMode::FixedCr(0.5));
    let single = base.deploy(EngineConfig::default()).unwrap();
    let sharded = base.deploy(EngineConfig::default().with_workers(4)).unwrap();
    let test = base.test();
    let elems = 32 * 32 * 3;
    let n = 8usize;
    let want: Vec<Vec<f32>> = (0..n)
        .map(|j| {
            single
                .classify(test.x.data()[j * elems..(j + 1) * elems].to_vec())
                .unwrap()
                .logits
        })
        .collect();
    let got: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|j| {
                let h = sharded.clone();
                let img = test.x.data()[j * elems..(j + 1) * elems].to_vec();
                s.spawn(move || h.classify(img).unwrap().logits)
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g, w, "sample {j}: sharded logits differ from single-worker");
    }
    let snap = sharded.metrics.snapshot();
    assert_eq!(snap.requests, n as u64);
    assert_eq!(snap.failed_requests, 0);
}

#[test]
fn sim_sharded_engine_startup_failure_is_typed_not_hung() {
    // A malformed deployment must fail every worker's readiness check and
    // surface the first failure as a typed StartupError — never hang the
    // aggregated handshake waiting for workers that already died.
    let fx = fixture::tiny(43);
    let spec = BackendSpec::Sim { cfg: SimXbarConfig::default(), strips: None, scenario: None };
    let engine = Engine::new(
        spec,
        &fx.model,
        vec![0.0; 5],
        EngineConfig::default().with_workers(3),
    )
    .unwrap();
    let err = engine.start().unwrap_err();
    assert_eq!(err.backend, "sim");
    assert!(err.worker < 3, "worker index {} out of range", err.worker);
    assert!(err.reason.contains("theta length"), "{}", err.reason);
    let msg = err.to_string();
    assert!(msg.contains("sim") && msg.contains("failed to start"), "{msg}");
}

#[test]
fn sim_sharded_engine_drains_pending_ok_replies_on_shutdown() {
    // Dropping every handle while requests are still queued must drain
    // them: each pending reply arrives as a normal Response, never a
    // dropped channel ("engine dropped request").
    let fx = fixture::tiny(47);
    let spec = BackendSpec::Sim { cfg: SimXbarConfig::default(), strips: None, scenario: None };
    let engine = Engine::new(
        spec,
        &fx.model,
        fx.theta.clone(),
        EngineConfig {
            max_wait: Duration::from_millis(1),
            ..EngineConfig::default()
        }
        .with_workers(2),
    )
    .unwrap();
    let handle = engine.start().unwrap();
    let elems = 32 * 32 * 3;
    let pend: Vec<_> = (0..8)
        .map(|j| handle.submit(fx.test.x.data()[j * elems..(j + 1) * elems].to_vec()).unwrap())
        .collect();
    drop(handle);
    for (j, p) in pend.into_iter().enumerate() {
        let resp = p.wait().unwrap_or_else(|e| panic!("request {j} dropped on shutdown: {e}"));
        assert_eq!(resp.logits.len(), fixture::NUM_CLASSES);
    }
}

#[test]
fn sim_sharded_engine_drains_failures_with_batch_errors_on_shutdown() {
    // Same drain path, but with batches that fail to execute: every queued
    // request must be answered with a typed BatchError reply (the batch
    // failure is also counted), not a dropped channel.
    let fx = fixture::tiny(53);
    let spec = BackendSpec::Sim { cfg: SimXbarConfig::default(), strips: None, scenario: None };
    let engine = Engine::new(
        spec,
        &fx.model,
        fx.theta.clone(),
        EngineConfig::default().with_workers(2),
    )
    .unwrap();
    let handle = engine.start().unwrap();
    let metrics = handle.metrics.clone();
    let pend: Vec<_> = (0..6).map(|_| handle.submit(vec![0.0; 7]).unwrap()).collect();
    drop(handle);
    for p in pend {
        let err = p.wait().unwrap_err();
        assert!(err.to_string().contains("batch failed"), "{err}");
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.failed_requests, 6);
    assert!(snap.failed_batches >= 1);
}

#[test]
fn sim_fim_search_modes_require_pjrt_backend() {
    // Alg1/Sweep drive the AOT gsq executables; on the simulator backend
    // they must fail with a clear error instead of a confusing artifact one.
    let plan = sim_plan(fixture::tiny(37), SimXbarConfig::default(), RunConfig::default())
        .threshold(ThresholdMode::Alg1);
    let err = plan.chosen_threshold().unwrap_err();
    assert!(err.to_string().contains("pjrt"), "{err}");
    // FixedCr on the same root keeps working
    let ok = plan
        .clone()
        .threshold(ThresholdMode::FixedCr(0.5))
        .chosen_threshold()
        .unwrap();
    assert_eq!(ok.fim_evals, 0);
}
