//! Integration tests over the real AOT artifacts + PJRT runtime.
//! Require `make artifacts` to have run; they share one runtime because
//! the PJRT client is per-thread expensive.

use std::sync::OnceLock;

use reram_mpq::coordinator::{evaluate_batches, Engine, EngineConfig, Pipeline, ThresholdMode};
use reram_mpq::dataset::TestSet;
use reram_mpq::tensor::Tensor;
use reram_mpq::util::rng::Rng;
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{artifacts_dir, Manifest, RunConfig, Runtime};

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect("run `make artifacts` first"))
}

// PJRT clients are not Send/Sync, so every test builds its own runtime
// (cargo test runs tests on separate threads).
fn runtime() -> Runtime {
    Runtime::new(artifacts_dir()).expect("pjrt cpu client")
}

#[test]
fn manifest_contract_holds() {
    let m = manifest();
    assert!(m.models.contains_key("resnet8"));
    assert!(m.models.contains_key("resnet14"));
    assert!(m.models.contains_key("resnet20"));
    for entry in m.models.values() {
        let info = reram_mpq::model::ModelInfo::new(entry.clone());
        // strips cover exactly the conv params
        let strip_params: usize = info
            .strips()
            .iter()
            .map(|s| info.layer(s.layer).d)
            .sum();
        assert_eq!(strip_params, entry.num_conv_params);
        // params tensor length matches
        assert_eq!(entry.params.shape.iter().product::<usize>(), entry.num_params);
    }
}

#[test]
fn fp32_eval_reproduces_training_accuracy() {
    let m = manifest();
    let rt = runtime();
    let info = m.model("resnet8").unwrap();
    let theta = info.load_params(m).unwrap();
    let test = TestSet::load(m).unwrap();
    let acc = evaluate_batches(&rt, &info, &theta, &test, 4).unwrap();
    // python-side accuracy was measured on the same split; allow slack for
    // the 4-batch subset.
    assert!(
        (acc.top1 - info.entry.fp32_test_acc).abs() < 0.08,
        "rust eval {:.4} vs python {:.4}",
        acc.top1,
        info.entry.fp32_test_acc
    );
    assert!(acc.top5 >= acc.top1);
}

#[test]
fn pallas_fwd_matches_plain_fwd() {
    // The L1-in-L2 composition artifact must agree with the lax-conv graph.
    let m = manifest();
    let rt = runtime();
    let info = m.model("resnet8").unwrap();
    let theta = Tensor::from_vec(info.load_params(m).unwrap());
    let test = TestSet::load(m).unwrap();
    let b = info.entry.batch.serve;
    let (x, _) = test.batch(0, b);

    let plain = rt
        .exec(&info.entry.executables["fwd_serve"], &[theta.clone(), x.clone()])
        .unwrap();
    let pallas = rt
        .exec(&info.entry.executables["fwd_pallas"], &[theta, x])
        .unwrap();
    let max_err = plain[0]
        .data()
        .iter()
        .zip(pallas[0].data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-2, "pallas fwd deviates: {max_err}");
}

#[test]
fn strip_mvm_kernel_matches_rust_oracle() {
    let m = manifest();
    let rt = runtime();
    let k = &m.kernel;
    let (t, d, g, n) = (k.t, k.d, k.g, k.n);
    let mut rng = Rng::seed_from_u64(5);
    let a: Vec<f32> = (0..t * g * d).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..g * d * n).map(|_| (rng.below(255) as f32) - 127.0).collect();
    let s: Vec<f32> = (0..g * n).map(|_| rng.range(0.001, 0.01) as f32).collect();
    let out = rt
        .exec(
            &k.strip_mvm,
            &[
                Tensor::new(vec![t, g * d], a.clone()),
                Tensor::new(vec![g * d, n], w.clone()),
                Tensor::new(vec![g, n], s.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out[0].shape(), &[t, n]);
    let mut want = vec![0.0f64; t * n];
    for ti in 0..t {
        for gi in 0..g {
            for ni in 0..n {
                let mut acc = 0.0f64;
                for di in 0..d {
                    acc += a[ti * g * d + gi * d + di] as f64 * w[(gi * d + di) * n + ni] as f64;
                }
                want[ti * n + ni] += acc * s[gi * n + ni] as f64;
            }
        }
    }
    for (got, want) in out[0].data().iter().zip(&want) {
        assert!((*got as f64 - want).abs() < 1e-2, "{got} vs {want}");
    }
}

#[test]
fn mixed_kernel_equals_sum_of_clusters() {
    // Z = Z_q + expand(Z_p): the mixed executable must equal two separate
    // strip_mvm calls added in Rust (stepwise accumulation, paper §4.3).
    let m = manifest();
    let rt = runtime();
    let k = &m.kernel;
    let (t, d, g, n) = (k.t, k.d, k.g, k.n);
    let mut rng = Rng::seed_from_u64(6);
    let a = Tensor::new(vec![t, g * d], (0..t * g * d).map(|_| rng.normal()).collect());
    // complementary random hi/lo masks at strip granularity
    let mask: Vec<bool> = (0..g * n).map(|_| rng.bool()).collect();
    let mut wq = vec![0.0f32; g * d * n];
    let mut wp = vec![0.0f32; g * d * n];
    for gi in 0..g {
        for di in 0..d {
            for ni in 0..n {
                let v = (rng.below(15) as f32) - 7.0;
                if mask[gi * n + ni] {
                    wq[(gi * d + di) * n + ni] = v;
                } else {
                    wp[(gi * d + di) * n + ni] = v;
                }
            }
        }
    }
    let sq: Vec<f32> = (0..g * n).map(|i| if mask[i] { 0.01 } else { 0.0 }).collect();
    let sp: Vec<f32> = (0..g * n).map(|i| if mask[i] { 0.0 } else { 0.16 }).collect();
    let wq = Tensor::new(vec![g * d, n], wq);
    let wp = Tensor::new(vec![g * d, n], wp);
    let sq = Tensor::new(vec![g, n], sq);
    let sp = Tensor::new(vec![g, n], sp);

    let mixed = rt
        .exec(
            &k.mixed_strip_mvm,
            &[a.clone(), wq.clone(), sq.clone(), wp.clone(), sp.clone()],
        )
        .unwrap();
    let zq = rt.exec(&k.strip_mvm, &[a.clone(), wq, sq]).unwrap();
    let zp = rt.exec(&k.strip_mvm, &[a, wp, sp]).unwrap();
    for ((m1, q), p) in mixed[0].data().iter().zip(zq[0].data()).zip(zp[0].data()) {
        assert!((m1 - (q + p)).abs() < 1e-3);
    }
}

#[test]
fn quantized_accuracy_degrades_monotonically_in_spirit() {
    // CR 0 (all 8-bit) should be within noise of fp32; CR 1.0 (all 4-bit
    // per-layer + device noise) should be strictly worse.
    let m = manifest();
    let rt = runtime();
    let mut pipe = Pipeline::new(&rt, m, "resnet8", RunConfig::default()).unwrap();
    let r0 = pipe
        .run(ThresholdMode::FixedCr(0.0), true, MappingStrategy::Packed, 4)
        .unwrap();
    let r1 = pipe
        .run(ThresholdMode::FixedCr(1.0), true, MappingStrategy::Packed, 4)
        .unwrap();
    assert!(r0.accuracy.top1 > r1.accuracy.top1, "{} !> {}", r0.accuracy.top1, r1.accuracy.top1);
    assert!(r0.cost.energy.system_mj() > r1.cost.energy.system_mj());
    // mixed sits between
    let rm = pipe
        .run(ThresholdMode::FixedCr(0.6), true, MappingStrategy::Packed, 4)
        .unwrap();
    assert!(rm.cost.energy.system_mj() < r0.cost.energy.system_mj());
    assert!(rm.cost.energy.system_mj() > r1.cost.energy.system_mj());
}

#[test]
fn sensitivity_scores_are_finite_and_informative() {
    let m = manifest();
    let rt = runtime();
    let mut cfg = RunConfig::default();
    cfg.sensitivity.probes = 2;
    cfg.sensitivity.calib_batches = 1;
    let mut pipe = Pipeline::new(&rt, m, "resnet8", cfg).unwrap();
    let s = pipe.sensitivity().unwrap().clone();
    assert_eq!(s.scores.len(), pipe.model.num_strips());
    assert!(s.scores.iter().all(|v| v.is_finite() && *v >= 0.0));
    // scores must not be constant — otherwise clustering is meaningless
    let sorted = s.sorted_scores();
    assert!(sorted[sorted.len() - 1] > sorted[0]);
}

#[test]
fn engine_serves_correct_predictions() {
    let m = manifest();
    let rt = runtime();
    let info = m.model("resnet8").unwrap();
    let theta = info.load_params(m).unwrap();
    let test = TestSet::load(m).unwrap();

    // Reference predictions through fwd_eval.
    let acc_ref = evaluate_batches(&rt, &info, &theta, &test, 1).unwrap();

    let engine = Engine::new(artifacts_dir(), &info, theta, EngineConfig::default()).unwrap();
    let handle = engine.start();
    let elems = 32 * 32 * 3;
    let n = info.entry.batch.eval; // same images as the first eval batch
    let mut correct = 0;
    let pend: Vec<_> = (0..n)
        .map(|j| handle.submit(test.x.data()[j * elems..(j + 1) * elems].to_vec()).unwrap())
        .collect();
    for (j, p) in pend.into_iter().enumerate() {
        let resp = p.wait().unwrap();
        assert_eq!(resp.logits.len(), m.num_classes);
        if resp.class == test.y[j] {
            correct += 1;
        }
    }
    let acc_engine = correct as f64 / n as f64;
    assert!(
        (acc_engine - acc_ref.top1).abs() < 1e-9,
        "engine {acc_engine} vs eval {}",
        acc_ref.top1
    );
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.requests, n as u64);
    assert!(snap.batches >= (n / info.entry.batch.serve) as u64);
}

#[test]
fn threshold_sweep_picks_interior_point() {
    let m = manifest();
    let rt = runtime();
    let mut cfg = RunConfig::default();
    cfg.sensitivity.probes = 2;
    cfg.sensitivity.calib_batches = 1;
    let mut pipe = Pipeline::new(&rt, m, "resnet8", cfg).unwrap();
    let (c, evals) = pipe.choose_clustering(ThresholdMode::Sweep).unwrap();
    assert!(evals > 1);
    // near-Pareto choice should compress something but not everything
    // (fim+energy joint objective); allow the extremes but assert validity.
    assert!(c.q_hi <= pipe.model.num_strips());
}
