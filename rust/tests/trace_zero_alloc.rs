//! The zero-alloc steady-state invariant survives the tracing subsystem:
//! with tracing **disabled** (the default), the programmed crossbar walk
//! performs zero heap allocations once its scratch is warm — the span
//! guards must not read the clock, format names, or touch buffers. With
//! tracing **enabled** the walk may allocate (span events), but the
//! numerical output must stay bit-identical.
//!
//! This lives in its own test binary because the counting
//! `#[global_allocator]` is process-global: a shared binary's parallel
//! tests would count each other's allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use reram_mpq::backend::{ProgrammedModel, Scratch, SimXbar, SimXbarConfig, StripPrecision};
use reram_mpq::config::QuantConfig;
use reram_mpq::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry, ModelInfo};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::trace;
use reram_mpq::util::rng::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Counts every allocation and reallocation, then defers to the system
/// allocator. Deallocations are free (releasing warm capacity is not an
/// allocation), so the counter measures exactly what the invariant forbids.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL_ALLOC: CountingAlloc = CountingAlloc;

/// Single-conv-layer model, mirroring the property suite's fixture shape.
fn conv_model(k: usize, d: usize, n: usize) -> ModelInfo {
    let size = k * k * d * n;
    ModelInfo::new(ModelEntry {
        name: "zero-alloc".into(),
        num_params: size,
        num_conv_params: size,
        fp32_test_acc: 1.0,
        params: BinEntry { file: "x".into(), shape: vec![size], dtype: "f32".into() },
        layers: vec![LayerEntry {
            name: "s1.b0.conv1".into(),
            shape: vec![k, k, d, n],
            kind: "conv".into(),
            theta_offset: 0,
            convflat_offset: Some(0),
        }],
        executables: HashMap::new(),
        batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
    })
}

#[test]
fn trace_disabled_walk_is_allocation_free_and_enabling_keeps_bits() {
    let m = conv_model(3, 14, 17);
    let layer = m.layer(0).clone();
    let mut rng = Rng::seed_from_u64(101);
    let theta: Vec<f32> = (0..m.entry.num_params).map(|_| rng.normal() * 0.5).collect();
    let bits: Vec<u8> = (0..m.num_strips()).map(|i| [4u8, 8][i % 2]).collect();
    let qm = quant::apply(
        &m,
        &theta,
        &BitMap { bits },
        &QuantConfig { device_sigma: 0.0, ..QuantConfig::default() },
    );
    let sp = StripPrecision::from_quantized(&qm);
    let t = 4usize;
    let patches: Vec<f32> = (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();

    // threads: 1 — the sharded path spawns scoped threads (stack + handle
    // allocations by design); the invariant is about the walk itself. The
    // 4-bit ADC selects the Packed store, the widest code path (DAC, plane
    // packing, staged prefetch, kernel dispatch).
    let cfg = SimXbarConfig { threads: 1, ..SimXbarConfig::default() }.with_adc(4);
    let prog = ProgrammedModel::program(&m, &qm.theta, &sp, &cfg).unwrap();
    let sim = SimXbar::new(cfg);
    let mut scratch = Scratch::default();
    let mut out = Vec::new();

    // Warm the scratch arena (first calls grow every reusable buffer).
    for _ in 0..2 {
        sim.conv_programmed(&prog, &layer, &patches, t, &mut scratch.conv, &mut out).unwrap();
    }
    let want = out.clone();

    // Steady state, tracing disabled (never initialized): zero allocations.
    let before = ALLOCS.load(Ordering::SeqCst);
    sim.conv_programmed(&prog, &layer, &patches, t, &mut scratch.conv, &mut out).unwrap();
    let grew = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        grew, 0,
        "programmed walk allocated {grew} time(s) in steady state with tracing off"
    );
    assert_eq!(out, want, "steady-state walk must be deterministic");

    // Tracing on: same bits (allocation is allowed — spans buffer events).
    trace::enable();
    sim.conv_programmed(&prog, &layer, &patches, t, &mut scratch.conv, &mut out).unwrap();
    trace::disable();
    assert_eq!(out, want, "tracing must never change the walk's output bits");
    let events = trace::drain();
    assert!(
        events.iter().any(|e| e.name == "xbar.conv"),
        "enabled tracing records the xbar.conv span (got {} events)",
        events.len()
    );

    // And back off: the disabled path is allocation-free again even after
    // the recorder has been initialized (the guard is one atomic load).
    let before = ALLOCS.load(Ordering::SeqCst);
    sim.conv_programmed(&prog, &layer, &patches, t, &mut scratch.conv, &mut out).unwrap();
    let grew = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(grew, 0, "re-disabled walk allocated {grew} time(s)");
    assert_eq!(out, want);
}
