//! Additional cross-module behavioural tests that don't need artifacts:
//! report formatting against paper row shapes, cost-model component
//! relations, CLI/json edge cases, config overrides.

use std::collections::HashMap;

use reram_mpq::config::{Granularity, RunConfig};
use reram_mpq::coordinator::{Accuracy, PipelineReport, ThresholdMode};
use reram_mpq::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry, ModelInfo};
use reram_mpq::quant::BitMap;
use reram_mpq::report;
use reram_mpq::util::cli::Args;
use reram_mpq::util::json::Value;
use reram_mpq::xbar::{self, MappingStrategy, XbarConfig};

fn two_layer_model() -> ModelInfo {
    // stem (K=3, D=3, N=16) + stage-2 conv (K=3, D=32, N=64)
    let l1 = 3 * 3 * 3 * 16;
    let l2 = 3 * 3 * 32 * 64;
    ModelInfo::new(ModelEntry {
        name: "two".into(),
        num_params: l1 + l2,
        num_conv_params: l1 + l2,
        fp32_test_acc: 0.95,
        params: BinEntry { file: "x".into(), shape: vec![l1 + l2], dtype: "f32".into() },
        layers: vec![
            LayerEntry {
                name: "stem.conv".into(),
                shape: vec![3, 3, 3, 16],
                kind: "conv".into(),
                theta_offset: 0,
                convflat_offset: Some(0),
            },
            LayerEntry {
                name: "s2.b0.conv1".into(),
                shape: vec![3, 3, 32, 64],
                kind: "conv".into(),
                theta_offset: l1,
                convflat_offset: Some(l1),
            },
        ],
        executables: HashMap::new(),
        batch: BatchSizes { eval: 128, serve: 8, calib: 32 },
    })
}

fn fake_report(cr: f64, top1: f64, energy_scale: f64) -> PipelineReport {
    let m = two_layer_model();
    let bm = BitMap::uniform(m.num_strips(), 8);
    let cfg = XbarConfig::default();
    let mapping = xbar::map_model(&m, &bm, &cfg, MappingStrategy::Packed);
    let mut cost = xbar::cost(&mapping, &cfg);
    cost.energy.adc_mj *= energy_scale;
    PipelineReport {
        model: "resnet20".into(),
        mode: ThresholdMode::FixedCr(cr),
        compression_ratio: cr,
        q_hi: ((1.0 - cr) * m.num_strips() as f64) as usize,
        total_strips: m.num_strips(),
        accuracy: Accuracy { top1, top5: (top1 + 0.1).min(1.0), samples: 2048 },
        fp32_accuracy: 0.95,
        cost,
        utilization_hi: 0.84,
        utilization_all: 0.8,
        quant_mse: 1e-6,
        threshold: 0.5,
        fim_evals: 10,
    }
}

#[test]
fn table2_row_contains_paper_columns() {
    let r = fake_report(0.74, 0.8463, 1.0);
    let row = report::table2_row("OURS", &r);
    assert!(row.contains("OURS"));
    assert!(row.contains("74%"));
    assert!(row.contains("84.63%"));
    assert!(row.contains("ms"));
    assert!(row.contains("mJ"));
    // header and row have the same number of columns
    let header_cols = report::table2_header().lines().next().unwrap().matches('|').count();
    assert_eq!(row.matches('|').count(), header_cols);
}

#[test]
fn table3_row_reports_energy_breakdown_units() {
    let r = fake_report(0.7, 0.8633, 1.0);
    let row = report::table3_row(&r);
    assert!(row.contains("70%"));
    assert!(row.contains("86.33%"));
    // System and ADC in mJ, Accumulation/Other in uJ like the paper
    assert_eq!(row.matches("mJ").count(), 2);
    assert_eq!(row.matches("uJ").count(), 2);
}

#[test]
fn headline_reports_reductions() {
    let ours = fake_report(0.74, 0.85, 0.4);
    let hap = fake_report(0.74, 0.75, 1.0);
    let h = report::headline(&ours, &hap);
    assert!(h.contains("accuracy 85.00%"));
    assert!(h.contains("ADC energy -60%"), "{h}");
}

#[test]
fn cost_layers_sum_to_total() {
    let m = two_layer_model();
    let bm = BitMap::uniform(m.num_strips(), 8);
    let cfg = XbarConfig::default();
    let mapping = xbar::map_model(&m, &bm, &cfg, MappingStrategy::Packed);
    let cost = xbar::cost(&mapping, &cfg);
    assert_eq!(cost.layers.len(), 2);
    let sum_lat: f64 = cost.layers.iter().map(|l| l.latency_ms).sum();
    assert!((sum_lat - cost.latency_ms).abs() < 1e-9);
    let sum_conv: u64 = cost.layers.iter().map(|l| l.conversions).sum();
    assert_eq!(sum_conv, cost.conversions);
    let sum_adc: f64 = cost.layers.iter().map(|l| l.energy.adc_mj).sum();
    assert!((sum_adc - cost.energy.adc_mj).abs() < 1e-12);
}

#[test]
fn stage2_layers_cost_less_pixels_but_more_cells() {
    // stem runs at 32×32 output; s2 at 8×8 — pixel count drives conversions.
    let m = two_layer_model();
    let bm = BitMap::uniform(m.num_strips(), 8);
    let cfg = XbarConfig::default();
    let mapping = xbar::map_model(&m, &bm, &cfg, MappingStrategy::Packed);
    assert_eq!(mapping.layers[0].out_pixels, 1024);
    assert_eq!(mapping.layers[1].out_pixels, 64);
    // s2 holds far more weights...
    assert!(mapping.layers[1].tiers[0].used_cells > mapping.layers[0].tiers[0].used_cells);
}

#[test]
fn adc_lane_budget_scales_latency_linearly() {
    let m = two_layer_model();
    let bm = BitMap::uniform(m.num_strips(), 8);
    let c1 = XbarConfig { adc_lanes: 64, ..XbarConfig::default() };
    let c2 = XbarConfig { adc_lanes: 128, ..XbarConfig::default() };
    let m1 = xbar::map_model(&m, &bm, &c1, MappingStrategy::Packed);
    let m2 = xbar::map_model(&m, &bm, &c2, MappingStrategy::Packed);
    let l1 = xbar::cost(&m1, &c1).latency_ms;
    let l2 = xbar::cost(&m2, &c2).latency_ms;
    assert!((l1 / l2 - 2.0).abs() < 1e-9, "doubling lanes must halve latency");
}

#[test]
fn device_precision_changes_cell_columns() {
    // 1-bit cells double the cell columns per weight vs 2-bit cells.
    let c1 = XbarConfig { cell_bits: 1, ..XbarConfig::default() };
    let c2 = XbarConfig::default();
    assert_eq!(c1.cells_per_weight(8), 8);
    assert_eq!(c2.cells_per_weight(8), 4);
    assert_eq!(c1.weight_cols_per_array(8), 16);
}

#[test]
fn run_config_partial_json_overrides() {
    let cfg = RunConfig::from_json(
        r#"{"quant": {"lo": {"bits": 2, "granularity": "per_strip"}, "device_sigma": 0.0},
            "xbar": {"rows": 64, "adc_lanes": 32}}"#,
    )
    .unwrap();
    assert_eq!(cfg.quant.lo.bits, 2);
    assert_eq!(cfg.quant.lo.granularity, Granularity::PerStrip);
    assert_eq!(cfg.quant.device_sigma, 0.0);
    assert_eq!(cfg.xbar.rows, 64);
    assert_eq!(cfg.xbar.adc_lanes, 32);
    // untouched fields keep defaults
    assert_eq!(cfg.quant.hi.bits, 8);
    assert_eq!(cfg.xbar.cols, 128);
    assert_eq!(cfg.sensitivity.probes, 8);
}

#[test]
fn run_config_rejects_bad_granularity() {
    assert!(RunConfig::from_json(r#"{"quant":{"hi":{"granularity":"per_banana"}}}"#).is_err());
}

#[test]
fn run_config_json_roundtrip_via_util_json() {
    let cfg = RunConfig::default();
    let text = cfg.to_json();
    // parses as valid JSON and round-trips the key fields
    let v = Value::parse(&text).unwrap();
    assert_eq!(v.get("quant").unwrap().get("hi").unwrap().get("bits").unwrap().usize().unwrap(), 8);
    let cfg2 = RunConfig::from_json(&text).unwrap();
    assert_eq!(cfg2.xbar.rows, cfg.xbar.rows);
    assert_eq!(cfg2.threshold.max_iters, cfg.threshold.max_iters);
}

#[test]
fn cli_mixed_global_and_subcommand_options() {
    let argv: Vec<String> = ["--artifacts", "/tmp/a", "quantize", "--cr", "0.7", "--no-align"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let a = Args::parse(&argv, &["no-align"]).unwrap();
    assert_eq!(a.subcommand.as_deref(), Some("quantize"));
    assert_eq!(a.get("artifacts"), Some("/tmp/a"));
    assert_eq!(a.get_f64("cr").unwrap(), Some(0.7));
    assert!(a.has("no-align"));
    assert!(!a.has("origin"));
}

#[test]
fn bitmap_tracks_pruned_strips_as_compressed() {
    let bm = BitMap { bits: vec![8, 0, 0, 4] };
    assert!((bm.compression_ratio(8) - 0.75).abs() < 1e-12);
    assert_eq!(bm.count_bits(0), 2);
}

#[test]
fn mapping_skips_empty_tiers_entirely() {
    let m = two_layer_model();
    // prune everything -> no tiers, zero cost
    let bm = BitMap::uniform(m.num_strips(), 0);
    let cfg = XbarConfig::default();
    let mapping = xbar::map_model(&m, &bm, &cfg, MappingStrategy::Packed);
    assert_eq!(mapping.total_arrays(), 0);
    let cost = xbar::cost(&mapping, &cfg);
    assert_eq!(cost.conversions, 0);
    assert!(cost.energy.system_mj() < 1e-12);
}

#[test]
fn pipeline_report_serializes_to_valid_json() {
    let r = fake_report(0.74, 0.8463, 1.0);
    let text = r.to_value().to_json();
    let v = Value::parse(&text).unwrap_or_else(|e| panic!("invalid JSON: {e}\n{text}"));
    assert_eq!(v.get("model").unwrap().str().unwrap(), "resnet20");
    assert_eq!(v.get("mode").unwrap().get("kind").unwrap().str().unwrap(), "fixed_cr");
    assert!((v.get("mode").unwrap().get("cr").unwrap().num().unwrap() - 0.74).abs() < 1e-12);
    assert!((v.get("accuracy").unwrap().get("top1").unwrap().num().unwrap() - 0.8463).abs() < 1e-12);
    let system = v.get("cost").unwrap().get("energy").unwrap().get("system_mj").unwrap().num().unwrap();
    assert!((system - r.cost.energy.system_mj()).abs() < 1e-12);
    assert_eq!(
        v.get("cost").unwrap().get("layers").unwrap().arr().unwrap().len(),
        r.cost.layers.len()
    );
}

#[test]
fn nan_threshold_serializes_as_null() {
    // Explicit-bitmap plans (HAP baseline) report threshold = NaN; the JSON
    // output must stay valid.
    let mut r = fake_report(0.74, 0.8, 1.0);
    r.threshold = f64::NAN;
    let text = r.to_value().to_json();
    let v = Value::parse(&text).unwrap_or_else(|e| panic!("invalid JSON: {e}\n{text}"));
    assert_eq!(v.get("threshold").unwrap(), &Value::Null);
}

#[test]
fn threshold_mode_json_kinds() {
    assert_eq!(
        ThresholdMode::Alg1.to_value().get("kind").unwrap().str().unwrap(),
        "alg1"
    );
    assert_eq!(
        ThresholdMode::Sweep.to_value().get("kind").unwrap().str().unwrap(),
        "sweep"
    );
    let f = ThresholdMode::FixedCr(0.5).to_value();
    assert_eq!(f.get("kind").unwrap().str().unwrap(), "fixed_cr");
    assert!((f.get("cr").unwrap().num().unwrap() - 0.5).abs() < 1e-12);
}

#[test]
fn mapping_summary_serializes_per_tier() {
    let m = two_layer_model();
    let bm = BitMap::uniform(m.num_strips(), 8);
    let mapping = xbar::map_model(&m, &bm, &XbarConfig::default(), MappingStrategy::Packed);
    let v = Value::parse(&mapping.to_value().to_json()).unwrap();
    assert_eq!(v.get("strategy").unwrap().str().unwrap(), "packed");
    let tiers = v.get("tiers").unwrap().arr().unwrap();
    assert_eq!(tiers.len(), mapping.summary.len());
    assert_eq!(tiers[0].get("bits").unwrap().usize().unwrap(), 8);
}

#[test]
fn utilization_of_absent_bitwidth_is_zero() {
    let m = two_layer_model();
    let bm = BitMap::uniform(m.num_strips(), 4);
    let mapping = xbar::map_model(&m, &bm, &XbarConfig::default(), MappingStrategy::Packed);
    assert_eq!(mapping.utilization(8), 0.0);
    assert!(mapping.utilization(4) > 0.0);
}
