//! Property-based tests (hand-rolled, seeded — no proptest offline) over
//! the coordinator invariants: clustering, alignment, mapping conservation,
//! quantization bounds, JSON round-trips.

use std::collections::HashMap;

use reram_mpq::backend::{ProgrammedModel, SimXbar, SimXbarConfig, SimdMode, StripPrecision};
use reram_mpq::clustering::{align_to_capacity, cluster, cluster_at_cr};
use reram_mpq::config::QuantConfig;
use reram_mpq::faults::{self, Placement, Scenario, ScenarioSpec};
use reram_mpq::model::{BatchSizes, BinEntry, LayerEntry, ModelEntry, ModelInfo};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::util::json::Value;
use reram_mpq::util::rng::Rng;
use reram_mpq::xbar::{map_model, MappingStrategy, XbarConfig};

const CASES: usize = 40;

/// Random single-conv-layer model.
fn rand_model(rng: &mut Rng) -> ModelInfo {
    let k = [1usize, 3][rng.below(2)];
    let d = [3usize, 8, 16, 32, 64][rng.below(5)];
    let n = 1 + rng.below(64);
    let size = k * k * d * n;
    ModelInfo::new(ModelEntry {
        name: "prop".into(),
        num_params: size,
        num_conv_params: size,
        fp32_test_acc: 1.0,
        params: BinEntry { file: "x".into(), shape: vec![size], dtype: "f32".into() },
        layers: vec![LayerEntry {
            name: ["stem.conv", "s1.b0.conv1", "s2.b1.conv2"][rng.below(3)].into(),
            shape: vec![k, k, d, n],
            kind: "conv".into(),
            theta_offset: 0,
            convflat_offset: Some(0),
        }],
        executables: HashMap::new(),
        batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
    })
}

fn rand_scores(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.uniform() * 10.0).collect()
}

#[test]
fn prop_cluster_at_cr_hits_exact_fraction() {
    let mut rng = Rng::seed_from_u64(11);
    for _ in 0..CASES {
        let n = 1 + rng.below(500);
        let scores = rand_scores(&mut rng, n);
        let cr = rng.uniform();
        let c = cluster_at_cr(&scores, cr, 8, 4);
        let expect_lo = ((cr * n as f64).round() as usize).min(n);
        assert_eq!(c.q_hi, n - expect_lo);
        assert_eq!(c.bitmap.bits.len(), n);
        // hi strips always have scores >= every lo strip's score
        let min_hi = c
            .bitmap
            .bits
            .iter()
            .zip(&scores)
            .filter(|(b, _)| **b == 8)
            .map(|(_, s)| *s)
            .fold(f64::INFINITY, f64::min);
        let max_lo = c
            .bitmap
            .bits
            .iter()
            .zip(&scores)
            .filter(|(b, _)| **b == 4)
            .map(|(_, s)| *s)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(min_hi >= max_lo, "clustering must be threshold-consistent");
    }
}

#[test]
fn prop_threshold_cluster_consistent_with_scores() {
    let mut rng = Rng::seed_from_u64(13);
    for _ in 0..CASES {
        let n = 1 + rng.below(300);
        let scores = rand_scores(&mut rng, n);
        let t = rng.uniform() * 10.0;
        let c = cluster(&scores, t, 8, 4);
        for (b, s) in c.bitmap.bits.iter().zip(&scores) {
            assert_eq!(*b == 8, *s > t);
        }
    }
}

#[test]
fn prop_alignment_makes_q_divisible_and_only_demotes() {
    let mut rng = Rng::seed_from_u64(17);
    for _ in 0..CASES {
        let m = rand_model(&mut rng);
        let n = m.num_strips();
        let scores = rand_scores(&mut rng, n);
        let c = cluster_at_cr(&scores, rng.uniform(), 8, 4);
        let cap = 1 + rng.below(40);
        let aligned = align_to_capacity(&m, &scores, &c, 8, 4, |_| cap);
        if c.q_hi >= cap {
            assert_eq!(aligned.q_hi % cap, 0, "q_hi must align to capacity {cap}");
        } else {
            // sub-capacity clusters are kept rather than wiped
            assert_eq!(aligned.q_hi, c.q_hi);
        }
        assert!(aligned.q_hi <= c.q_hi, "alignment only demotes");
        // demoted strips become lo, never pruned; hi set is a subset
        for (a, b) in aligned.bitmap.bits.iter().zip(&c.bitmap.bits) {
            if *a == 8 {
                assert_eq!(*b, 8);
            } else {
                assert_eq!(*a, 4);
            }
        }
    }
}

#[test]
fn prop_mapping_conserves_strips_and_bounds_utilization() {
    let mut rng = Rng::seed_from_u64(19);
    for case in 0..CASES {
        let m = rand_model(&mut rng);
        let n = m.num_strips();
        // random tier assignment incl. pruning
        let bits: Vec<u8> = (0..n).map(|_| [0u8, 4, 8][rng.below(3)]).collect();
        let bm = BitMap { bits: bits.clone() };
        let cfg = if rng.bool() { XbarConfig::default() } else { XbarConfig::small() };
        for strategy in [MappingStrategy::Origin, MappingStrategy::Packed] {
            let mm = map_model(&m, &bm, &cfg, strategy);
            let placed: usize = mm.layers[0].tiers.iter().map(|t| t.strips).sum();
            let expect = bits.iter().filter(|&&b| b != 0).count();
            assert_eq!(placed, expect, "case {case}: every non-pruned strip is mapped");
            for t in &mm.summary {
                assert!(t.used_cells <= t.provisioned_cells, "cells over-provisioned");
                let u = t.utilization();
                assert!((0.0..=1.0 + 1e-12).contains(&u), "utilization {u} out of range");
            }
        }
        // packed never uses more arrays than origin
        let ao = map_model(&m, &bm, &cfg, MappingStrategy::Origin).total_arrays();
        let ap = map_model(&m, &bm, &cfg, MappingStrategy::Packed).total_arrays();
        assert!(ap <= ao, "case {case}: packed arrays {ap} > origin {ao}");
    }
}

#[test]
fn prop_packed_used_cells_equal_origin_used_cells() {
    // Mapping strategy changes provisioning, never the weights stored.
    let mut rng = Rng::seed_from_u64(23);
    for _ in 0..CASES {
        let m = rand_model(&mut rng);
        let bits: Vec<u8> = (0..m.num_strips()).map(|_| [4u8, 8][rng.below(2)]).collect();
        let bm = BitMap { bits };
        let cfg = XbarConfig::default();
        let uo: u64 = map_model(&m, &bm, &cfg, MappingStrategy::Origin)
            .summary.iter().map(|t| t.used_cells).sum();
        let up: u64 = map_model(&m, &bm, &cfg, MappingStrategy::Packed)
            .summary.iter().map(|t| t.used_cells).sum();
        assert_eq!(uo, up);
    }
}

#[test]
fn prop_quantization_error_bounded_by_half_step_without_noise() {
    let mut rng = Rng::seed_from_u64(29);
    for _ in 0..CASES {
        let m = rand_model(&mut rng);
        let n_params = m.entry.num_params;
        let theta: Vec<f32> = (0..n_params).map(|_| rng.normal()).collect();
        let bits: Vec<u8> = (0..m.num_strips()).map(|_| [4u8, 8][rng.below(2)]).collect();
        let bm = BitMap { bits };
        let cfg = QuantConfig { device_sigma: 0.0, ..QuantConfig::default() };
        let qm = quant::apply(&m, &theta, &bm, &cfg);
        for (i, s) in m.strips().iter().enumerate() {
            let orig = m.strip_values(&theta, *s);
            let deq = m.strip_values(&qm.theta, *s);
            let scale = qm.scales[i];
            for (a, b) in orig.iter().zip(deq.iter()) {
                assert!(
                    (a - b).abs() <= scale * 0.5 + 1e-6,
                    "strip {i}: |{a} - {b}| > {scale}/2"
                );
            }
        }
    }
}

#[test]
fn prop_quantization_is_deterministic_per_seed() {
    let mut rng = Rng::seed_from_u64(31);
    let m = rand_model(&mut rng);
    let theta: Vec<f32> = (0..m.entry.num_params).map(|_| rng.normal()).collect();
    let bm = BitMap::uniform(m.num_strips(), 4);
    let cfg = QuantConfig::default();
    let a = quant::apply(&m, &theta, &bm, &cfg);
    let b = quant::apply(&m, &theta, &bm, &cfg);
    assert_eq!(a.theta, b.theta);
    let cfg2 = QuantConfig { seed: cfg.seed + 1, ..cfg };
    let c = quant::apply(&m, &theta, &bm, &cfg2);
    assert_ne!(a.theta, c.theta, "different seed -> different device noise");
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::seed_from_u64(37);
    for _ in 0..CASES {
        let v = rand_json(&mut rng, 0);
        let text = v.to_json();
        let back = Value::parse(&text).unwrap_or_else(|e| panic!("reparse {text}: {e}"));
        assert_eq!(v, back, "roundtrip failed for {text}");
    }
}

fn rand_json(rng: &mut Rng, depth: usize) -> Value {
    match if depth > 2 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.bool()),
        2 => Value::Num((rng.below(2_000_001) as f64 - 1_000_000.0) / 64.0),
        3 => {
            let n = rng.below(8);
            Value::Str((0..n).map(|_| ['a', '"', '\\', 'é', '\n', 'z'][rng.below(6)]).collect())
        }
        4 => Value::Arr((0..rng.below(4)).map(|_| rand_json(rng, depth + 1)).collect()),
        _ => Value::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), rand_json(rng, depth + 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_capacity_strips_positive_and_monotone_in_cols() {
    let mut rng = Rng::seed_from_u64(41);
    for _ in 0..CASES {
        let d = 1 + rng.below(256);
        let cfg = XbarConfig::default();
        let small = XbarConfig::small();
        for bits in [4u8, 8] {
            let c_big = cfg.capacity_strips(d, bits);
            let c_small = small.capacity_strips(d, bits);
            assert!(c_big >= 1 && c_small >= 1);
            assert!(c_big >= c_small, "bigger arrays hold at least as many strips");
        }
    }
}

// ---- SimXbar bit-serial simulator invariants -------------------------------

/// Random quantized single-layer workload: (quantized theta, per-strip
/// precision, patches, patch-row count).
fn rand_sim_case(
    rng: &mut Rng,
    m: &ModelInfo,
    mixed: bool,
) -> (Vec<f32>, StripPrecision, Vec<f32>, usize) {
    let theta: Vec<f32> = (0..m.entry.num_params).map(|_| rng.normal() * 0.5).collect();
    let bits: Vec<u8> = (0..m.num_strips())
        .map(|_| if mixed { [0u8, 4, 8][rng.below(3)] } else { 8 })
        .collect();
    let bm = BitMap { bits };
    let qcfg = QuantConfig { device_sigma: 0.0, ..QuantConfig::default() };
    let qm = quant::apply(m, &theta, &bm, &qcfg);
    let l = m.layer(0);
    let t = 1 + rng.below(4);
    let patches: Vec<f32> = (0..t * l.k * l.k * l.d).map(|_| rng.normal()).collect();
    (qm.theta.clone(), StripPrecision::from_quantized(&qm), patches, t)
}

#[test]
fn prop_sim_full_precision_noise_off_matches_f32_reference() {
    // The acceptance property: with a near-lossless DAC, ideal ADC and no
    // noise, the bit-serial crossbar result equals a reference f32 conv on
    // the same quantized weights within 1e-4.
    let mut rng = Rng::seed_from_u64(43);
    for case in 0..12 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, case % 2 == 0);
        let cfg = SimXbarConfig { input_bits: 24, ..SimXbarConfig::default() };
        let got = SimXbar::new(cfg)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        // f64-accumulated reference conv over the dequantized weights
        let (k2, d, n) = (layer.k * layer.k, layer.d, layer.n);
        for ti in 0..t {
            for ch in 0..n {
                let mut want = 0.0f64;
                for g in 0..k2 {
                    if sp.bits[g * n + ch] == 0 {
                        continue; // pruned strips store nothing
                    }
                    for dd in 0..d {
                        want += patches[ti * k2 * d + g * d + dd] as f64
                            * theta[layer.theta_index(g, dd, ch)] as f64;
                    }
                }
                let gotv = got[ti * n + ch] as f64;
                assert!(
                    (gotv - want).abs() < 1e-4,
                    "case {case} t={ti} ch={ch}: sim {gotv} vs f32 reference {want}"
                );
            }
        }
    }
}

#[test]
fn prop_sim_phase_decomposition_equals_integer_fast_path() {
    // The explicit input-bit-phase × cell-slice × polarity loop must
    // telescope to the integer fast path exactly when converters are ideal,
    // across strip depths that do and do not span multiple row segments.
    let mut rng = Rng::seed_from_u64(47);
    for case in 0..8 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let base = SimXbarConfig {
            rows: [4usize, 16, 128][rng.below(3)],
            input_bits: 7,
            cell_bits: [1u8, 2, 3][rng.below(3)],
            ..SimXbarConfig::default()
        };
        let fast = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let phased = SimXbar::new(SimXbarConfig { force_phase_loop: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        for (i, (a, b)) in fast.iter().zip(&phased).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * a.abs().max(1.0),
                "case {case} elem {i}: fast {a} vs phased {b}"
            );
        }
    }
}

#[test]
fn prop_sim_packed_phase_loop_is_bit_identical_to_scalar_lanes() {
    // The packed u64 bit-plane popcount path feeds exactly the same column
    // currents to the (optional) ADC as the scalar per-lane scan, so the
    // two must agree bit for bit across geometries, cell widths, mixed
    // precisions and row segmentations.
    let mut rng = Rng::seed_from_u64(59);
    for case in 0..8 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let base = SimXbarConfig {
            rows: [4usize, 16, 128][rng.below(3)],
            input_bits: 7,
            cell_bits: [1u8, 2, 3][rng.below(3)],
            adc_bits: [0u8, 4][rng.below(2)],
            force_phase_loop: true,
            ..SimXbarConfig::default()
        };
        let packed = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        let scalar = SimXbar::new(SimXbarConfig { scalar_lanes: true, ..base })
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        assert_eq!(packed, scalar, "case {case}: packed path must be bit-identical");
    }
}

#[test]
fn prop_sim_tile_sharding_is_bit_identical_for_every_thread_count() {
    // The per-tile MVM shards own contiguous channel ranges with private
    // accumulators and the noise stream is seeded per strip, so any worker
    // count must reproduce the sequential result exactly — including under
    // ADC quantization and conductance noise.
    let mut rng = Rng::seed_from_u64(61);
    for case in 0..8 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let base = SimXbarConfig {
            rows: [8usize, 128][rng.below(2)],
            adc_bits: if case % 3 == 0 { 4 } else { 0 },
            noise_sigma: if case % 2 == 1 { 0.05 } else { 0.0 },
            threads: 1,
            ..SimXbarConfig::default()
        };
        let single = SimXbar::new(base)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap();
        for threads in [2usize, 4, 8] {
            let sharded = SimXbar::new(SimXbarConfig { threads, ..base })
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap();
            assert_eq!(
                single, sharded,
                "case {case}: {threads}-thread conv must be bit-identical"
            );
        }
    }
}

#[test]
fn prop_sim_programmed_path_is_bit_identical_to_repack_per_call() {
    // The program-once tile walk must reproduce the re-quantize-and-repack-
    // per-call reference path bit for bit, across every execution mode the
    // config can select — the exact integer fast path, the packed-ADC phase
    // loop, the noisy scalar lane scan, the forced scalar scan — and every
    // tile-shard count.
    let mut rng = Rng::seed_from_u64(67);
    for case in 0..6 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let corners = [
            // exact: ideal converters, integer fast path
            SimXbarConfig::default(),
            // packed: faithful phase loop over u64 bit-planes, 4b ADC,
            // multi-segment rows
            SimXbarConfig { rows: 16, ..SimXbarConfig::default() }.with_adc(4),
            // analog: seeded conductance noise forces the scalar lane scan
            SimXbarConfig::default().with_adc(4).with_noise(0.05, 7),
            // analog, integral cells: scalar_lanes knob without noise
            SimXbarConfig {
                scalar_lanes: true,
                force_phase_loop: true,
                ..SimXbarConfig::default()
            },
        ];
        for base in corners {
            for threads in [1usize, 2, 4] {
                let cfg = SimXbarConfig { threads, ..base };
                let sim = SimXbar::new(cfg);
                let programmed = sim
                    .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                    .unwrap();
                let reference = sim
                    .conv_bitserial_reference(&m, &layer, &theta, &patches, t, &sp)
                    .unwrap();
                assert_eq!(
                    programmed, reference,
                    "case {case}: programmed walk must be bit-identical \
                     (adc={} noise={} scalar={} threads={threads})",
                    base.adc_bits, base.noise_sigma, base.scalar_lanes
                );
            }
        }
    }
}

/// Single-conv-layer model with an explicit geometry, for cases where the
/// lane/word/channel counts themselves are the property under test.
fn sim_geom_model(k: usize, d: usize, n: usize) -> ModelInfo {
    let size = k * k * d * n;
    ModelInfo::new(ModelEntry {
        name: "prop-simd".into(),
        num_params: size,
        num_conv_params: size,
        fp32_test_acc: 1.0,
        params: BinEntry { file: "x".into(), shape: vec![size], dtype: "f32".into() },
        layers: vec![LayerEntry {
            name: "s1.b0.conv1".into(),
            shape: vec![k, k, d, n],
            kind: "conv".into(),
            theta_offset: 0,
            convflat_offset: Some(0),
        }],
        executables: HashMap::new(),
        batch: BatchSizes { eval: 1, serve: 1, calib: 1 },
    })
}

#[test]
fn prop_sim_simd_walk_is_bit_identical_across_kernels_modes_and_threads() {
    // The SIMD-widened programmed walk (runtime-detected AVX2/NEON) must
    // reproduce both the scalar packed-u64 walk (SimdMode::Off) and the
    // per-lane scalar scan (scalar_lanes) bit for bit — the kernels all
    // produce exact integer column currents, and the ADC + f64 merge runs
    // in one shared order. Exercised across geometries with odd channel
    // counts and non-multiple-of-64 lane counts (remainder words), the
    // exact / packed-ADC / analog-noise execution modes, an active fault
    // scenario, every tile-shard count, and with vector dispatch forced
    // off (the portable fallback a detection miss would select).
    let mut rng = Rng::seed_from_u64(89);
    // (k, d, n): lanes = k²·d. 171 and 126 leave partial remainder words,
    // 67 spans word 0 plus a 3-lane remainder; n = 5, 9, 33 keep the
    // channel counts odd so shard boundaries land mid-strip-table.
    let geoms = [(3usize, 19usize, 5usize), (1, 67, 9), (3, 14, 33)];
    for (case, &(k, d, n)) in geoms.iter().enumerate() {
        let m = sim_geom_model(k, d, n);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let scenario = Scenario::new(
            ScenarioSpec::default().with_stuck(0.2, 17).with_ir_drop(0.3, 23),
        )
        .with_placement(Placement::SensitivityAware);
        assert!(scenario.is_active());
        let corners = [
            // exact: ideal converters, integer fast path
            SimXbarConfig::default(),
            // packed: ADC phase loop over u64 bit-planes, multi-segment rows
            SimXbarConfig { rows: 16, ..SimXbarConfig::default() }.with_adc(4),
            // analog: seeded conductance noise
            SimXbarConfig::default().with_adc(4).with_noise(0.05, 7),
        ];
        for base in corners {
            for faulted in [false, true] {
                for threads in [1usize, 2, 4] {
                    let cfg = SimXbarConfig { threads, ..base };
                    let run = |c: SimXbarConfig| {
                        let sim = SimXbar::new(c);
                        let sim = if faulted {
                            sim.with_scenario(scenario.clone())
                        } else {
                            sim
                        };
                        sim.conv_bitserial(&m, &layer, &theta, &patches, t, &sp).unwrap()
                    };
                    let forced = run(cfg.with_simd(SimdMode::Force));
                    let auto = run(cfg.with_simd(SimdMode::Auto));
                    let off = run(cfg.with_simd(SimdMode::Off));
                    let lanes = run(SimXbarConfig {
                        scalar_lanes: true,
                        ..cfg.with_simd(SimdMode::Off)
                    });
                    let ctx = format!(
                        "case {case} (k={k} d={d} n={n}) adc={} noise={} \
                         faulted={faulted} threads={threads}",
                        base.adc_bits, base.noise_sigma
                    );
                    assert_eq!(forced, off, "{ctx}: forced SIMD vs scalar packed walk");
                    assert_eq!(auto, off, "{ctx}: auto-detected vs scalar packed walk");
                    assert_eq!(off, lanes, "{ctx}: packed walk vs scalar lane scan");
                }
            }
        }
    }
}

#[test]
fn prop_sim_programmed_index_drops_pruned_and_zero_scale_strips() {
    // The compact index must contain exactly the live strips — pruned
    // (bits == 0) and zero-scale strips are absent, per-channel ranges
    // tile the strip table, and taps stay in ascending order (the
    // accumulation-order invariant).
    let mut rng = Rng::seed_from_u64(71);
    for case in 0..CASES {
        let m = rand_model(&mut rng);
        let n = m.num_strips();
        let theta: Vec<f32> = (0..m.entry.num_params).map(|_| rng.normal()).collect();
        let bits: Vec<u8> = (0..n).map(|_| [0u8, 4, 8][rng.below(3)]).collect();
        let mut scales: Vec<f32> = (0..n).map(|_| 0.1 + rng.uniform() as f32).collect();
        for i in 0..n {
            if bits[i] != 0 && rng.below(5) == 0 {
                scales[i] = 0.0; // a dead scale on an otherwise live strip
            }
        }
        let sp = StripPrecision { bits: bits.clone(), scales: scales.clone() };
        let prog =
            ProgrammedModel::program(&m, &theta, &sp, &SimXbarConfig::default()).unwrap();
        let live = (0..n).filter(|&i| bits[i] != 0 && scales[i] > 0.0).count();
        assert_eq!(prog.live_strips, live, "case {case}: live count");
        assert_eq!(prog.live_strips + prog.dropped_strips, n, "case {case}: partition");
        let stored: usize = prog.layers.iter().map(|l| l.strips.len()).sum();
        assert_eq!(stored, live, "case {case}: index stores exactly the live strips");
        for l in &prog.layers {
            let mut covered = 0usize;
            for &(s0, slen) in &l.chan {
                let range = &l.strips[s0 as usize..s0 as usize + slen as usize];
                covered += range.len();
                for s in range {
                    assert!(s.sw > 0.0, "case {case}: zero-scale strip in the index");
                }
                for pair in range.windows(2) {
                    assert!(
                        pair[0].g < pair[1].g,
                        "case {case}: per-channel taps must ascend"
                    );
                }
            }
            assert_eq!(covered, l.strips.len(), "case {case}: channel ranges tile the table");
        }
    }
}

#[test]
fn prop_sim_trace_toggle_never_changes_forward_bits_or_walk_counters() {
    // Tracing is observability, not execution: flipping the recorder on
    // must leave the programmed walk bit-identical, and the always-on walk
    // profile must count the same work either way. (The allocation-free
    // disabled path is asserted separately in tests/trace_zero_alloc.rs,
    // which needs its own binary for the counting global allocator.)
    use reram_mpq::backend::ExecBackend;
    let mut rng = Rng::seed_from_u64(97);
    for case in 0..6 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let base = SimXbarConfig::default();
        let cfg = if case % 2 == 0 { base } else { base.with_adc(4) };
        let sim = SimXbar::new(cfg);
        reram_mpq::trace::disable();
        let p0 = sim.walk_profile().unwrap();
        let off = sim.conv_bitserial(&m, &layer, &theta, &patches, t, &sp).unwrap();
        let p1 = sim.walk_profile().unwrap();
        reram_mpq::trace::enable();
        let on = sim.conv_bitserial(&m, &layer, &theta, &patches, t, &sp).unwrap();
        let p2 = sim.walk_profile().unwrap();
        reram_mpq::trace::disable();
        let _ = reram_mpq::trace::drain();
        assert_eq!(off, on, "case {case}: tracing must never change forward bits");
        let d_off = p1.delta(&p0);
        let d_on = p2.delta(&p1);
        assert_eq!(d_off, d_on, "case {case}: walk counters independent of tracing");
        assert_eq!(d_on.conv_calls, 1, "case {case}: one conv call per delta");
    }
}

// ---- faults/ device-variability scenario invariants ------------------------

#[test]
fn prop_faults_injection_is_deterministic_per_spec_and_seed() {
    // End to end: the same (spec, seed) must program the same faulted
    // crossbars and therefore produce bit-identical conv outputs, on any
    // random workload.
    let mut rng = Rng::seed_from_u64(73);
    for case in 0..8 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let spec = ScenarioSpec::default()
            .with_stuck(0.3, 100 + case as u64)
            .with_ir_drop(0.4, 7)
            .with_drift(2.0, 0.05, 3);
        let run = || {
            SimXbar::new(SimXbarConfig::default())
                .with_scenario(Scenario::new(spec))
                .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                .unwrap()
        };
        assert_eq!(run(), run(), "case {case}: same (spec, seed) must replay bit-identically");
    }

    // Seed sensitivity, at code level on a strip wide enough that an
    // identical redraw is statistically impossible.
    let spec = ScenarioSpec::default().with_stuck(0.5, 1);
    let respun = ScenarioSpec::default().with_stuck(0.5, 2);
    let mut a = vec![33i32; 256];
    let mut b = vec![33i32; 256];
    let (mut swa, mut swb) = (1.0f32, 1.0f32);
    faults::apply_to_strip(&spec, 0, 0, 4, 2, 3, &mut a, &mut swa);
    faults::apply_to_strip(&respun, 0, 0, 4, 2, 3, &mut b, &mut swb);
    assert_ne!(a, b, "a different stuck seed must redraw the fault pattern");
}

#[test]
fn prop_faults_zero_scenario_is_bit_identical_across_modes_and_threads() {
    // A scenario whose every component sits at its zero value must be
    // indistinguishable from no scenario at all — across the exact, packed
    // and analog execution modes and every tile-shard count, with either
    // placement policy.
    let mut rng = Rng::seed_from_u64(79);
    for case in 0..6 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let zero = Scenario::new(
            ScenarioSpec::default()
                .with_stuck(0.0, 5)
                .with_drift(3.0, 0.0, 9)
                .with_ir_drop(0.0, 11)
                .with_read_noise(0.0, 13),
        )
        .with_placement(Placement::SensitivityAware);
        assert!(!zero.is_active(), "zero-magnitude components must be inactive");
        let corners = [
            // exact: ideal converters, integer fast path
            SimXbarConfig::default(),
            // packed: ADC phase loop over u64 bit-planes, multi-segment rows
            SimXbarConfig { rows: 16, ..SimXbarConfig::default() }.with_adc(4),
            // analog: seeded conductance noise forces the scalar lane scan
            SimXbarConfig::default().with_adc(4).with_noise(0.05, 7),
        ];
        for base in corners {
            for threads in [1usize, 2, 4] {
                let cfg = SimXbarConfig { threads, ..base };
                let clean = SimXbar::new(cfg)
                    .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                    .unwrap();
                let faulted = SimXbar::new(cfg)
                    .with_scenario(zero.clone())
                    .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                    .unwrap();
                assert_eq!(
                    clean, faulted,
                    "case {case}: zero scenario must be bit-identical \
                     (adc={} noise={} threads={threads})",
                    base.adc_bits, base.noise_sigma
                );
            }
        }
    }
}

#[test]
fn prop_health_reservation_and_idle_probes_never_change_forward_bits() {
    // Self-healing must be pure observability until something actually
    // degrades: a canary + spare reservation on a zero-degradation scenario
    // (no faults, no evolution) programs extra slots past every walkable
    // strip, so the forward pass stays bit-identical to the unfaulted walk
    // across the exact / packed / analog execution modes and every
    // tile-shard count — and an idle health step probes the canaries,
    // finds zero mismatches, and neither repairs, quarantines, swaps, nor
    // starts a background re-program.
    use reram_mpq::faults::HealthSpec;
    let mut rng = Rng::seed_from_u64(101);
    for case in 0..6 {
        let m = rand_model(&mut rng);
        let layer = m.layer(0).clone();
        let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, true);
        let health = Scenario::new(ScenarioSpec::default())
            .with_placement(Placement::SensitivityAware)
            .with_health(HealthSpec { canaries: 2, spares: 3 });
        assert!(health.is_active(), "a reservation alone activates the scenario");
        let corners = [
            // exact: ideal converters, integer fast path
            SimXbarConfig::default(),
            // packed: ADC phase loop over u64 bit-planes, multi-segment rows
            SimXbarConfig { rows: 16, ..SimXbarConfig::default() }.with_adc(4),
            // analog: seeded conductance noise forces the scalar lane scan
            SimXbarConfig::default().with_adc(4).with_noise(0.05, 7),
        ];
        for base in corners {
            for threads in [1usize, 2, 4] {
                let cfg = SimXbarConfig { threads, ..base };
                let clean = SimXbar::new(cfg)
                    .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                    .unwrap();
                let sim = SimXbar::new(cfg)
                    .with_scenario(health.clone())
                    .with_strips(sp.clone());
                let reserved = sim
                    .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
                    .unwrap();
                assert_eq!(
                    clean, reserved,
                    "case {case}: health reservation must never change forward \
                     bits (adc={} noise={} threads={threads})",
                    base.adc_bits, base.noise_sigma
                );
                // An idle monitor step on the undamaged artifact: canaries
                // replay exactly as programmed, nothing moves.
                let rep = sim
                    .run_health_step(&m, &theta, 5)
                    .expect("an active scenario with a programmed artifact must report");
                assert!(rep.probes >= 1, "case {case}: canaries must be probed");
                assert_eq!(rep.canary_mismatches, 0, "case {case}: {rep:?}");
                assert_eq!(rep.repairs, 0, "case {case}: {rep:?}");
                assert_eq!(rep.quarantined, 0, "case {case}: {rep:?}");
                assert!(!rep.swapped, "case {case}: {rep:?}");
                assert!(!rep.reprogram_started, "case {case}: {rep:?}");
            }
        }
    }
}

#[test]
fn prop_faults_placement_is_a_bijection_over_live_slots() {
    let mut rng = Rng::seed_from_u64(83);
    for case in 0..CASES {
        let nslots = 1 + rng.below(64);
        let live: Vec<usize> = (0..nslots).filter(|_| rng.below(3) != 0).collect();
        let scores: Vec<f64> = (0..live.len()).map(|_| rng.uniform() * 10.0).collect();
        let damage: Vec<f64> = (0..live.len()).map(|_| rng.uniform() * 5.0).collect();
        for placement in [Placement::Naive, Placement::SensitivityAware] {
            let out = faults::assign_slots(placement, Some(&scores), &damage, &live);
            let mut sorted = out.clone();
            sorted.sort_unstable();
            assert_eq!(
                sorted, live,
                "case {case}: {placement:?} assignment must be a bijection onto live slots"
            );
            if placement == Placement::Naive {
                assert_eq!(out, live, "case {case}: naive placement is the identity");
            }
        }
    }
}

#[test]
fn prop_sim_adc_output_is_deterministic_and_actually_quantizes() {
    let mut rng = Rng::seed_from_u64(53);
    let m = rand_model(&mut rng);
    let layer = m.layer(0).clone();
    let (theta, sp, patches, t) = rand_sim_case(&mut rng, &m, false);
    let ideal = SimXbar::new(SimXbarConfig::default())
        .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
        .unwrap();
    let cfg = SimXbarConfig::default().with_adc(4).with_noise(0.1, 7);
    let run = |c: SimXbarConfig| {
        SimXbar::new(c)
            .conv_bitserial(&m, &layer, &theta, &patches, t, &sp)
            .unwrap()
    };
    let a = run(cfg);
    assert_eq!(a, run(cfg), "fixed seed must reproduce bit-identically");
    assert_ne!(a, run(cfg.with_noise(0.1, 8)), "new seed must redraw device noise");
    assert_ne!(a, ideal, "a 4-bit ADC over 128-row columns must cost accuracy");
    // non-idealities distort but do not destroy the computation
    let rms_ideal = (ideal.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
        / ideal.len() as f64)
        .sqrt();
    let rms_err = (a
        .iter()
        .zip(&ideal)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64)
        .sqrt();
    assert!(
        rms_err < rms_ideal,
        "ADC+noise error ({rms_err}) should stay below signal power ({rms_ideal})"
    );
}
