"""AOT pipeline: train the model zoo, lower every graph to HLO *text*,
export weights + dataset + manifest for the Rust coordinator.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` 0.1.6 crate binds) rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Run: `cd python && python -m compile.aot --out-dir ../artifacts`
Python never runs again after this (request path is pure Rust).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, train
from .kernels import strip_mvm

MODELS = ["resnet8", "resnet14", "resnet20"]
EVAL_BATCH = 128
SERVE_BATCH = 8
CALIB_BATCH = 32

# Standalone kernel export shape: 3x3 kernel over 16 channels -> G=9 groups,
# R=144 reduction, 64 output strips-columns, T=128 activation rows.
KERNEL_T, KERNEL_D, KERNEL_G, KERNEL_N = 128, 16, 9, 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_hlo(path: str, fn, *example_args) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)/1e3:.0f} kB)")


def write_bin(path: str, arr: np.ndarray) -> dict:
    """Little-endian f32 raw tensor + shape entry for the manifest."""
    a = np.ascontiguousarray(arr, dtype=np.float32)
    a.tofile(path)
    return {"file": os.path.basename(path), "shape": list(a.shape), "dtype": "f32"}


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_model(name: str, theta: np.ndarray, fp_acc: float, out: str) -> dict:
    p = model.num_params(name)
    pc = model.num_conv_params(name)

    # fwd: logits = f(theta, x) — theta is a graph *parameter* so Rust feeds
    # quantized weights through the same executable.
    for tag, b in (("eval", EVAL_BATCH), ("serve", SERVE_BATCH)):
        write_hlo(
            os.path.join(out, f"{name}_fwd_{tag}.hlo.txt"),
            lambda th, x: (model.forward(name, th, x),),
            spec((p,)),
            spec((b, 32, 32, 3)),
        )

    # hvp: one Hutchinson probe step -> v * Hv over conv params.
    write_hlo(
        os.path.join(out, f"{name}_hvp.hlo.txt"),
        lambda th, x, y, v: (model.hvp_diag_probe(name, th, x, y, v),),
        spec((p,)),
        spec((CALIB_BATCH, 32, 32, 3)),
        spec((CALIB_BATCH, model.NUM_CLASSES)),
        spec((pc,)),
    )

    # gsq: empirical Fisher diagonal over conv params.
    write_hlo(
        os.path.join(out, f"{name}_gsq.hlo.txt"),
        lambda th, x, y: (model.fisher_diag(name, th, x, y),),
        spec((p,)),
        spec((CALIB_BATCH, 32, 32, 3)),
        spec((CALIB_BATCH, model.NUM_CLASSES)),
    )

    params_entry = write_bin(os.path.join(out, f"{name}_params.bin"), theta)

    convflat_off = 0
    layers = []
    for s in model.param_specs(name):
        e = {
            "name": s.name,
            "shape": list(s.shape),
            "kind": s.kind,
            "theta_offset": s.offset,
        }
        if s.quantizable:
            e["convflat_offset"] = convflat_off
            convflat_off += s.size
        layers.append(e)

    return {
        "name": name,
        "num_params": p,
        "num_conv_params": pc,
        "fp32_test_acc": fp_acc,
        "params": params_entry,
        "layers": layers,
        "executables": {
            "fwd_eval": f"{name}_fwd_eval.hlo.txt",
            "fwd_serve": f"{name}_fwd_serve.hlo.txt",
            "hvp": f"{name}_hvp.hlo.txt",
            "gsq": f"{name}_gsq.hlo.txt",
        },
        "batch": {"eval": EVAL_BATCH, "serve": SERVE_BATCH, "calib": CALIB_BATCH},
    }


def export_kernel(out: str) -> dict:
    """Standalone L1 kernel executables for Rust-side kernel benches."""
    t, d, g, n = KERNEL_T, KERNEL_D, KERNEL_G, KERNEL_N
    r = g * d
    write_hlo(
        os.path.join(out, "strip_mvm.hlo.txt"),
        lambda a, w, s: (strip_mvm.strip_mvm(a, w, s, group_size=d),),
        spec((t, r)),
        spec((r, n)),
        spec((g, n)),
    )
    write_hlo(
        os.path.join(out, "mixed_strip_mvm.hlo.txt"),
        lambda a, wq, sq, wp, sp_: (
            strip_mvm.mixed_strip_mvm(a, wq, sq, wp, sp_, group_size=d),
        ),
        spec((t, r)),
        spec((r, n)),
        spec((g, n)),
        spec((r, n)),
        spec((g, n)),
    )
    return {
        "t": t,
        "d": d,
        "g": g,
        "n": n,
        "strip_mvm": "strip_mvm.hlo.txt",
        "mixed_strip_mvm": "mixed_strip_mvm.hlo.txt",
    }


def export_pallas_fwd(name: str, out: str) -> str:
    """Forward with the Pallas kernel inlined (L1-in-L2 composition proof)."""
    p = model.num_params(name)
    fname = f"{name}_fwd_pallas.hlo.txt"
    write_hlo(
        os.path.join(out, fname),
        lambda th, x: (model.forward_pallas(name, th, x),),
        spec((p,)),
        spec((SERVE_BATCH, 32, 32, 3)),
    )
    return fname


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)

    sp = data.splits(seed=args.seed)
    manifest: dict = {
        "version": 1,
        "dataset": {},
        "models": {},
        "kernel": {},
        "num_classes": model.NUM_CLASSES,
    }

    # Dataset export (test + calib; train stays python-side).
    xt, yt = sp["test"]
    xc_, yc = sp["calib"]
    manifest["dataset"]["test_x"] = write_bin(os.path.join(out, "test_x.bin"), xt)
    manifest["dataset"]["test_y"] = write_bin(
        os.path.join(out, "test_y.bin"), yt.astype(np.float32)
    )
    manifest["dataset"]["calib_x"] = write_bin(os.path.join(out, "calib_x.bin"), xc_)
    manifest["dataset"]["calib_y1h"] = write_bin(
        os.path.join(out, "calib_y1h.bin"), data.one_hot(yc)
    )

    ckpt = os.path.join(out, "ckpt")
    for name in MODELS:
        theta, acc = train.train_cached(name, sp, ckpt, seed=args.seed)
        manifest["models"][name] = export_model(name, theta, acc, out)

    manifest["kernel"] = export_kernel(out)
    manifest["models"]["resnet8"]["executables"]["fwd_pallas"] = export_pallas_fwd(
        "resnet8", out
    )

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written; artifacts complete in {out}")


if __name__ == "__main__":
    main()
