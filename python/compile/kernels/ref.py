"""Pure-jnp oracle for the Pallas strip-MVM kernel (no pallas imports)."""
from __future__ import annotations

import jax.numpy as jnp


def strip_mvm_ref(
    a: jnp.ndarray, w: jnp.ndarray, gscale: jnp.ndarray, *, group_size: int
) -> jnp.ndarray:
    """Reference: Z[t,n] = sum_g (A_g @ W_g)[t,n] * gscale[g,n]."""
    t, r = a.shape
    _, n = w.shape
    g = r // group_size
    ag = a.reshape(t, g, group_size)
    wg = w.reshape(g, group_size, n)
    # [t, g, n] partial products per strip group
    parts = jnp.einsum("tgd,gdn->tgn", ag, wg)
    return jnp.sum(parts * gscale[None, :, :], axis=1)


def mixed_strip_mvm_ref(
    a, w_hi, s_hi, w_lo, s_lo, *, group_size: int
) -> jnp.ndarray:
    return strip_mvm_ref(a, w_hi, s_hi, group_size=group_size) + strip_mvm_ref(
        a, w_lo, s_lo, group_size=group_size
    )


def dequantize_ref(codes: jnp.ndarray, gscale: jnp.ndarray, *, group_size: int):
    """Expand quantized codes back to f32 weights: w = codes * scale[strip]."""
    r, n = codes.shape
    g = r // group_size
    return (codes.reshape(g, group_size, n) * gscale[:, None, :]).reshape(r, n)
