"""L1: Pallas strip-MVM kernel — the paper's compute hot-spot.

The crossbar-shaped primitive: an im2col'd activation tile `A [T, R]` times
a weight matrix `W [R, N]` whose reduction dimension is partitioned into
G = R/D *strip groups* of size D (one group per (kh, kw) kernel position —
each column of a group is one of the paper's 1x1xD strip-weights). Each
(group g, output column n) cell carries its own quantization scale
`gscale[g, n]`, so the kernel computes

    Z[t, n] = sum_g  ( sum_d A[t, g*D+d] * W[g*D+d, n] ) * gscale[g, n]

i.e. per-array integer partial sums merged with per-strip rescale — exactly
the shift-and-add merge a ReRAM tile does after its ADCs, and exactly the
paper's stepwise accumulation when called once for the high-bit cluster and
once for the low-bit cluster (`expand()` = the scale ratio folded into
`gscale`; see `mixed_strip_mvm`).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks (T tiles ×
strip groups); each step is a `[bT, D] x [D, N]` MXU matmul with the
VPU applying the per-strip rescale into the VMEM accumulator. Weights are
carried as integer-valued f32 (analog conductances are not int8 registers);
`interpret=True` everywhere because the CPU PJRT plugin cannot execute
Mosaic custom-calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Default tile height; T is padded to a multiple of this.
BLOCK_T = 128


def _kernel(a_ref, w_ref, s_ref, o_ref):
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(a_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += part * s_ref[0, :]


@functools.partial(jax.jit, static_argnames=("group_size", "block_t"))
def strip_mvm(
    a: jnp.ndarray,
    w: jnp.ndarray,
    gscale: jnp.ndarray,
    *,
    group_size: int,
    block_t: int = BLOCK_T,
) -> jnp.ndarray:
    """Strip-grouped scaled MVM.

    a:      [T, R] activations (f32; integer-valued when modelling DAC codes)
    w:      [R, N] weights (f32; integer-valued quantized codes)
    gscale: [G, N] per-(strip-group, output-channel) scale, G = R/group_size
    returns [T, N] f32
    """
    t, r = a.shape
    rw, n = w.shape
    assert r == rw, (r, rw)
    assert r % group_size == 0, (r, group_size)
    g = r // group_size
    assert gscale.shape == (g, n), (gscale.shape, g, n)

    bt = min(block_t, t)
    pad_t = (-t) % bt
    if pad_t:
        a = jnp.pad(a, ((0, pad_t), (0, 0)))
    tp = t + pad_t

    out = pl.pallas_call(
        _kernel,
        grid=(tp // bt, g),
        in_specs=[
            pl.BlockSpec((bt, group_size), lambda i, j: (i, j)),
            pl.BlockSpec((group_size, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, n), jnp.float32),
        interpret=True,
    )(a, w, gscale)
    return out[:t]


def mixed_strip_mvm(
    a: jnp.ndarray,
    w_hi: jnp.ndarray,
    s_hi: jnp.ndarray,
    w_lo: jnp.ndarray,
    s_lo: jnp.ndarray,
    *,
    group_size: int,
) -> jnp.ndarray:
    """Precision-coordinated parallel computation (paper §4.3).

    The high-bit cluster (8-bit codes, per-strip scale `s_hi`) and low-bit
    cluster (4-bit codes, per-strip scale `s_lo`) hold *complementary* strips
    (each is zero where the other is populated). They run as independent
    crossbar programs; the final stepwise accumulation `Z = Z_q + expand(Z_p)`
    aligns the low-bit partials onto the high-bit grid — `expand` being the
    scale ratio already folded into `s_lo`.
    """
    z_q = strip_mvm(a, w_hi, s_hi, group_size=group_size)
    z_p = strip_mvm(a, w_lo, s_lo, group_size=group_size)
    return z_q + z_p


# ---------------------------------------------------------------------------
# Convolution routed through the kernel (for forward_pallas)
# ---------------------------------------------------------------------------

def im2col(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """SAME-padding im2col matching lax.conv_general_dilated.

    x: [B, H, W, C]  ->  [B, Ho, Wo, K*K*C], last axis ordered (kh, kw, c)
    to match `w.reshape(K*K*C, N)` of an HWIO kernel.
    """
    b, h, w, c = x.shape
    ho = -(-h // stride)
    wo = -(-w // stride)
    pad_h = max((ho - 1) * stride + k - h, 0)
    pad_w = max((wo - 1) * stride + k - w, 0)
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (pad_h // 2, pad_h - pad_h // 2),
            (pad_w // 2, pad_w - pad_w // 2),
            (0, 0),
        ),
    )
    cols = []
    for kh in range(k):
        for kw in range(k):
            sl = xp[:, kh : kh + (ho - 1) * stride + 1 : stride,
                    kw : kw + (wo - 1) * stride + 1 : stride, :]
            cols.append(sl)
    return jnp.concatenate(cols, axis=-1)


def conv2d_via_strips(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """2D conv computed as strip-grouped MVM on the Pallas kernel (fp path:
    all strip scales are 1)."""
    k, _, c, n = w.shape
    patches = im2col(x, k, stride)  # [B, Ho, Wo, K*K*C]
    b, ho, wo, r = patches.shape
    a = patches.reshape(b * ho * wo, r)
    wm = w.reshape(r, n)
    gscale = jnp.ones((k * k, n), dtype=jnp.float32)
    z = strip_mvm(a, wm, gscale, group_size=c)
    return z.reshape(b, ho, wo, n)


# ---------------------------------------------------------------------------
# Strip quantization helpers (shared by tests / aot demo tensors)
# ---------------------------------------------------------------------------

def quantize_strips(
    wm: np.ndarray, bits: int, group_size: int
) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-strip quantization of a [R, N] weight matrix.

    Returns (codes [R, N] integer-valued f32, scale [G, N] f32) with
    codes in [-(2^(b-1)-1), 2^(b-1)-1].
    """
    r, n = wm.shape
    g = r // group_size
    qmax = float(2 ** (bits - 1) - 1)
    wg = wm.reshape(g, group_size, n)
    amax = np.abs(wg).max(axis=1)  # [G, N]
    scale = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
    codes = np.rint(wg / scale[:, None, :]).clip(-qmax, qmax)
    return codes.reshape(r, n).astype(np.float32), scale
