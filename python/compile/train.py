"""Build-time trainer for the CIFAR-Syn model zoo.

Runs ONCE inside `make artifacts` (compile path). Adam + cosine decay,
cross-entropy. Checkpoints are cached under artifacts/ckpt/ keyed by a
config digest so re-running aot.py does not retrain unnecessarily.
"""
from __future__ import annotations

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model

EPOCHS = {"resnet8": 14, "resnet14": 14, "resnet20": 14}
BATCH = 128
LR = 2e-3


def _adam_step(theta, m, v, g, lr, t, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    return theta - lr * mh / (jnp.sqrt(vh) + eps), m, v


def train(name: str, train_xy, test_xy, seed: int = 0, epochs: int | None = None):
    """Train `name` on CIFAR-Syn; returns (theta flat f32, test_accuracy)."""
    x, y = train_xy
    y1h = data.one_hot(y)
    n = x.shape[0]
    epochs = epochs or EPOCHS[name]
    steps_per_epoch = n // BATCH
    total = epochs * steps_per_epoch

    theta = jnp.asarray(model.init_params(name, seed))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)

    @jax.jit
    def step(theta, m, v, xb, yb, t):
        l, g = jax.value_and_grad(lambda th: model.loss(name, th, xb, yb))(theta)
        lr = LR * 0.5 * (1 + jnp.cos(jnp.pi * t / total))
        theta, m, v = _adam_step(theta, m, v, g, lr, t)
        return theta, m, v, l

    rng = np.random.default_rng(seed + 99)
    t = 0
    for ep in range(epochs):
        perm = rng.permutation(n)
        for i in range(steps_per_epoch):
            idx = perm[i * BATCH : (i + 1) * BATCH]
            t += 1
            theta, m, v, l = step(theta, m, v, x[idx], y1h[idx], t)
        if (ep + 1) % 4 == 0 or ep == epochs - 1:
            acc = model.accuracy(name, theta, test_xy[0], test_xy[1])
            print(f"[train:{name}] epoch {ep+1}/{epochs} loss={float(l):.4f} test_acc={acc:.4f}")
    acc = model.accuracy(name, theta, test_xy[0], test_xy[1])
    return np.asarray(theta, dtype=np.float32), float(acc)


def _digest(name: str, seed: int) -> str:
    key = json.dumps(
        {
            "name": name,
            "cfg": model.CONFIGS[name],
            "seed": seed,
            "epochs": EPOCHS[name],
            "batch": BATCH,
            "lr": LR,
            "noise": data.NOISE_SIGMA,
        },
        sort_keys=True,
    )
    return hashlib.sha256(key.encode()).hexdigest()[:16]


def train_cached(name: str, splits, ckpt_dir: str, seed: int = 0):
    """Train or load from cache. Returns (theta, test_acc)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tag = _digest(name, seed)
    path = os.path.join(ckpt_dir, f"{name}_{tag}.npz")
    if os.path.exists(path):
        z = np.load(path)
        print(f"[train:{name}] cache hit {path} (acc={float(z['acc']):.4f})")
        return z["theta"].astype(np.float32), float(z["acc"])
    theta, acc = train(name, splits["train"], splits["test"], seed=seed)
    np.savez(path, theta=theta, acc=np.float32(acc))
    return theta, acc
