"""CIFAR-Syn: deterministic synthetic 10-class 32x32x3 image corpus.

Substitute for CIFAR-10 (no network access in this environment — see
DESIGN.md §5). Each class is defined by an (orientation, frequency, color,
blob-layout) signature; per-sample variation comes from heavy signature
jitter, a *distractor* pattern borrowed from another class, contrast
scaling and strong additive Gaussian noise. The jitters are tuned so the
class manifolds genuinely overlap: a small CNN lands near ~90% test
accuracy (CIFAR-10-like) and *degrades* under aggressive quantization —
the regime the paper's experiments live in.
"""
from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
IMG_HW = 32

# Per-class color palettes (RGB weight of the carrier grating), partially
# desaturated so color is a weak cue.
_BASE_PALETTE = np.array(
    [
        [1.00, 0.25, 0.25],
        [0.25, 1.00, 0.25],
        [0.25, 0.25, 1.00],
        [0.95, 0.95, 0.20],
        [0.90, 0.30, 0.90],
        [0.20, 0.90, 0.90],
        [0.95, 0.60, 0.20],
        [0.55, 0.35, 0.95],
        [0.65, 0.85, 0.35],
        [0.80, 0.80, 0.80],
    ],
    dtype=np.float32,
)
_GRAY = np.array([0.6, 0.6, 0.6], dtype=np.float32)
DESATURATION = 0.45  # 0 = full color cue, 1 = no color cue
_PALETTE = (1 - DESATURATION) * _BASE_PALETTE + DESATURATION * _GRAY

NOISE_SIGMA = 0.75      # pixel noise
THETA_JITTER = 0.12     # orientation jitter (rad); class separation is pi/10
FREQ_JITTER = 0.30      # cycles jitter; class separation is 0.8
DISTRACTOR_MAX = 0.40   # max weight of the other-class distractor grating


def _grating(theta: np.ndarray, freq: np.ndarray, phase: np.ndarray) -> np.ndarray:
    """Batch of oriented sinusoidal gratings, shape [B, H, W]."""
    yy, xx = np.meshgrid(
        np.linspace(-1.0, 1.0, IMG_HW), np.linspace(-1.0, 1.0, IMG_HW), indexing="ij"
    )
    xx = xx[None]  # [1, H, W]
    yy = yy[None]
    ct = np.cos(theta)[:, None, None]
    st = np.sin(theta)[:, None, None]
    carrier = xx * ct + yy * st
    return np.sin(2.0 * np.pi * freq[:, None, None] * carrier + phase[:, None, None])


def _blobs(cls: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Class-hinted Gaussian blob layout (weak cue), shape [B, H, W]."""
    b = cls.shape[0]
    yy, xx = np.meshgrid(
        np.linspace(-1.0, 1.0, IMG_HW), np.linspace(-1.0, 1.0, IMG_HW), indexing="ij"
    )
    ang = 2.0 * np.pi * cls / NUM_CLASSES + rng.normal(0.0, 0.7, size=b)
    r = 0.45 + rng.normal(0.0, 0.15, size=b)
    cx = r * np.cos(ang)
    cy = r * np.sin(ang)
    sig = 0.22 + 0.015 * (cls % 3)
    d2 = (xx[None] - cx[:, None, None]) ** 2 + (yy[None] - cy[:, None, None]) ** 2
    return np.exp(-d2 / (2.0 * sig[:, None, None] ** 2))


def _class_params(cls: np.ndarray, rng: np.random.Generator):
    theta = np.pi * cls / NUM_CLASSES + rng.normal(0.0, THETA_JITTER, size=cls.shape[0])
    freq = 2.0 + (cls % 5) * 0.8 + rng.normal(0.0, FREQ_JITTER, size=cls.shape[0])
    phase = rng.uniform(0.0, 2.0 * np.pi, size=cls.shape[0])
    return theta, freq, phase


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` samples. Returns (images [n,32,32,3] f32, labels [n] i32)."""
    rng = np.random.default_rng(seed)
    cls = rng.integers(0, NUM_CLASSES, size=n)

    theta, freq, phase = _class_params(cls, rng)
    g = _grating(theta, freq, phase)  # [n, H, W]

    # Distractor: a grating from a *different* class, mixed in.
    other = (cls + rng.integers(1, NUM_CLASSES, size=n)) % NUM_CLASSES
    ot, of, op = _class_params(other, rng)
    g_dis = _grating(ot, of, op)
    lam = rng.uniform(0.0, DISTRACTOR_MAX, size=n)[:, None, None]
    g = (1.0 - lam) * g + lam * g_dis

    blob = _blobs(cls, rng)
    contrast = rng.uniform(0.55, 1.3, size=n)[:, None, None]

    base = contrast * (0.65 * g + 0.45 * blob)  # [n, H, W]
    color = _PALETTE[cls]  # [n, 3]
    img = base[..., None] * color[:, None, None, :]
    img = img + rng.normal(0.0, NOISE_SIGMA, size=img.shape)
    img = np.clip(img, -2.5, 2.5).astype(np.float32)
    return img, cls.astype(np.int32)


def splits(
    n_train: int = 8192, n_test: int = 2048, n_calib: int = 512, seed: int = 7
) -> dict[str, tuple[np.ndarray, np.ndarray]]:
    """Disjoint-seed train/test/calib splits."""
    return {
        "train": generate(n_train, seed),
        "test": generate(n_test, seed + 1000),
        "calib": generate(n_calib, seed + 2000),
    }


def one_hot(labels: np.ndarray) -> np.ndarray:
    out = np.zeros((labels.shape[0], NUM_CLASSES), dtype=np.float32)
    out[np.arange(labels.shape[0]), labels] = 1.0
    return out
