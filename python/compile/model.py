"""L2: strip-conv ResNet family for CIFAR-Syn, in pure-functional JAX.

Weights live in a single flat f32 vector so the Rust coordinator can feed
quantized parameters into the AOT-compiled forward graph without rebuilding
anything. `param_specs()` is the layout contract: the same (name, shape,
offset, quantizable) table is exported into artifacts/manifest.json and
consumed by rust/src/model/.

Conv weights use HWIO layout `[K, K, D, N]`; a *strip-weight* (the paper's
1x1xD unit) is the D-slice at a fixed (kx, ky, n). GroupNorm is used instead
of BatchNorm so the inference graph has no running-stats plumbing (the paper
quantizes conv weights only; normalization params stay fp32 either way).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

NUM_CLASSES = 10

CONFIGS: dict[str, dict] = {
    # CIFAR-style stage widths 16/32/64. Block counts per stage:
    "resnet8": dict(blocks=(1, 1, 1), width=16),   # shallow — "ResNet18" stand-in
    "resnet14": dict(blocks=(2, 2, 2), width=16),  # deeper — "ResNet50" stand-in
    "resnet20": dict(blocks=(3, 3, 3), width=16),  # Table 2 backbone
}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    kind: str  # "conv" | "gn" | "dense_w" | "dense_b"
    offset: int  # into the flat parameter vector

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    @property
    def quantizable(self) -> bool:
        return self.kind == "conv"


def _stage_widths(width: int) -> tuple[int, int, int]:
    return (width, 2 * width, 4 * width)


def param_specs(model: str) -> list[ParamSpec]:
    """Deterministic flat layout of all parameters for `model`."""
    cfg = CONFIGS[model]
    widths = _stage_widths(cfg["width"])
    specs: list[tuple[str, tuple[int, ...], str]] = []

    def add(name, shape, kind):
        specs.append((name, tuple(int(s) for s in shape), kind))

    add("stem.conv", (3, 3, 3, widths[0]), "conv")
    c_in = widths[0]
    for s, (nblocks, c_out) in enumerate(zip(cfg["blocks"], widths)):
        for b in range(nblocks):
            pfx = f"s{s}.b{b}"
            add(f"{pfx}.gn1.gamma", (c_in,), "gn")
            add(f"{pfx}.gn1.beta", (c_in,), "gn")
            add(f"{pfx}.conv1", (3, 3, c_in, c_out), "conv")
            add(f"{pfx}.gn2.gamma", (c_out,), "gn")
            add(f"{pfx}.gn2.beta", (c_out,), "gn")
            add(f"{pfx}.conv2", (3, 3, c_out, c_out), "conv")
            if c_in != c_out:
                add(f"{pfx}.shortcut", (1, 1, c_in, c_out), "conv")
            c_in = c_out
    add("head.gn.gamma", (c_in,), "gn")
    add("head.gn.beta", (c_in,), "gn")
    add("head.dense.w", (c_in, NUM_CLASSES), "dense_w")
    add("head.dense.b", (NUM_CLASSES,), "dense_b")

    out, off = [], 0
    for name, shape, kind in specs:
        sp = ParamSpec(name, shape, kind, off)
        out.append(sp)
        off += sp.size
    return out


def num_params(model: str) -> int:
    sp = param_specs(model)
    return sp[-1].offset + sp[-1].size


def conv_param_specs(model: str) -> list[ParamSpec]:
    return [s for s in param_specs(model) if s.quantizable]


def num_conv_params(model: str) -> int:
    return sum(s.size for s in conv_param_specs(model))


def unflatten(model: str, theta: jnp.ndarray) -> dict[str, jnp.ndarray]:
    return {
        s.name: theta[s.offset : s.offset + s.size].reshape(s.shape)
        for s in param_specs(model)
    }


def flatten(model: str, params: dict[str, np.ndarray]) -> np.ndarray:
    sps = param_specs(model)
    out = np.zeros(num_params(model), dtype=np.float32)
    for s in sps:
        out[s.offset : s.offset + s.size] = np.asarray(params[s.name]).reshape(-1)
    return out


def init_params(model: str, seed: int = 0) -> np.ndarray:
    """He-init conv/dense, unit gamma / zero beta. Returns the flat vector."""
    rng = np.random.default_rng(seed)
    sps = param_specs(model)
    theta = np.zeros(num_params(model), dtype=np.float32)
    for s in sps:
        if s.kind == "conv":
            fan_in = s.shape[0] * s.shape[1] * s.shape[2]
            w = rng.normal(0.0, np.sqrt(2.0 / fan_in), size=s.shape)
        elif s.kind == "dense_w":
            w = rng.normal(0.0, np.sqrt(1.0 / s.shape[0]), size=s.shape)
        elif s.name.endswith("gamma"):
            w = np.ones(s.shape)
        else:  # beta, dense_b
            w = np.zeros(s.shape)
        theta[s.offset : s.offset + s.size] = w.reshape(-1).astype(np.float32)
    return theta


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _group_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    c = x.shape[-1]
    groups = min(8, c)
    b, h, w, _ = x.shape
    xg = x.reshape(b, h, w, groups, c // groups)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) / jnp.sqrt(var + 1e-5)
    x = xg.reshape(b, h, w, c)
    return x * gamma + beta


def _conv(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _conv_strip_pallas(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Same conv, routed through the L1 Pallas strip-MVM kernel via im2col."""
    from .kernels import strip_mvm

    return strip_mvm.conv2d_via_strips(x, w, stride)


def forward(
    model: str,
    theta: jnp.ndarray,
    x: jnp.ndarray,
    conv_fn: Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray] = _conv,
) -> jnp.ndarray:
    """Logits for a batch. `theta` is the flat parameter vector."""
    cfg = CONFIGS[model]
    widths = _stage_widths(cfg["width"])
    p = unflatten(model, theta)

    h = conv_fn(x, p["stem.conv"], 1)
    c_in = widths[0]
    for s, (nblocks, c_out) in enumerate(zip(cfg["blocks"], widths)):
        for b in range(nblocks):
            pfx = f"s{s}.b{b}"
            stride = 2 if (s > 0 and b == 0) else 1
            y = _group_norm(h, p[f"{pfx}.gn1.gamma"], p[f"{pfx}.gn1.beta"])
            y = jax.nn.relu(y)
            pre = y
            y = conv_fn(y, p[f"{pfx}.conv1"], stride)
            y = _group_norm(y, p[f"{pfx}.gn2.gamma"], p[f"{pfx}.gn2.beta"])
            y = jax.nn.relu(y)
            y = conv_fn(y, p[f"{pfx}.conv2"], 1)
            if c_in != c_out:
                h = conv_fn(pre, p[f"{pfx}.shortcut"], stride)
            h = h + y
            c_in = c_out
    h = _group_norm(h, p["head.gn.gamma"], p["head.gn.beta"])
    h = jax.nn.relu(h)
    h = h.mean(axis=(1, 2))
    return h @ p["head.dense.w"] + p["head.dense.b"]


def forward_pallas(model: str, theta: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Forward with every conv routed through the Pallas strip-MVM kernel —
    proves the L1 kernel composes into the L2 graph (lowers into one HLO)."""
    return forward(model, theta, x, conv_fn=_conv_strip_pallas)


# ---------------------------------------------------------------------------
# Loss / Hessian-vector products / Fisher diagonal
# ---------------------------------------------------------------------------

def loss(model: str, theta: jnp.ndarray, x: jnp.ndarray, y1h: jnp.ndarray) -> jnp.ndarray:
    logits = forward(model, theta, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y1h * logp, axis=-1))


def _gather_conv(model: str, full: jnp.ndarray) -> jnp.ndarray:
    """Concatenate the conv slices of a flat full-parameter-sized vector."""
    parts = [full[s.offset : s.offset + s.size] for s in conv_param_specs(model)]
    return jnp.concatenate(parts)


def _scatter_conv(model: str, theta: jnp.ndarray, conv_flat: jnp.ndarray) -> jnp.ndarray:
    """Overwrite the conv slices of `theta` with values from `conv_flat`."""
    out = theta
    off = 0
    for s in conv_param_specs(model):
        out = out.at[s.offset : s.offset + s.size].set(conv_flat[off : off + s.size])
        off += s.size
    return out


def hvp_diag_probe(
    model: str,
    theta: jnp.ndarray,
    x: jnp.ndarray,
    y1h: jnp.ndarray,
    v: jnp.ndarray,
) -> jnp.ndarray:
    """One Hutchinson step: returns `v * (H v)` restricted to conv params.

    For Rademacher `v`, E[v * Hv] = diag(H); the Rust sensitivity driver
    averages this over probes and sums within each strip to get
    Trace(H_strip). `v` has length num_conv_params(model).
    """

    def loss_conv(conv_flat):
        return loss(model, _scatter_conv(model, theta, conv_flat), x, y1h)

    conv0 = _gather_conv(model, theta)
    grad_fn = jax.grad(loss_conv)
    _, hv = jax.jvp(grad_fn, (conv0,), (v,))
    return v * hv


def fisher_diag(
    model: str, theta: jnp.ndarray, x: jnp.ndarray, y1h: jnp.ndarray
) -> jnp.ndarray:
    """Empirical Fisher diagonal over conv params: E_b[(d log p(y|x)/dθ)^2]."""

    def nll_single(conv_flat, xi, yi):
        logits = forward(model, _scatter_conv(model, theta, conv_flat), xi[None])
        logp = jax.nn.log_softmax(logits)[0]
        return -jnp.sum(yi * logp)

    conv0 = _gather_conv(model, theta)
    per = jax.vmap(lambda xi, yi: jax.grad(nll_single)(conv0, xi, yi))(x, y1h)
    return jnp.mean(per**2, axis=0)


def accuracy(
    model: str, theta: jnp.ndarray, x: np.ndarray, y: np.ndarray, batch: int = 256
) -> float:
    fwd = jax.jit(lambda t, xb: forward(model, t, xb))
    correct = 0
    for i in range(0, x.shape[0] - batch + 1, batch):
        logits = fwd(theta, x[i : i + batch])
        correct += int((np.argmax(np.asarray(logits), axis=-1) == y[i : i + batch]).sum())
    n = (x.shape[0] // batch) * batch
    return correct / max(n, 1)
