"""L1 kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes and value ranges; every case asserts allclose
between the Pallas (interpret) kernel and ref.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, strip_mvm

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


@given(
    t=st.integers(1, 200),
    d=st.integers(1, 32),
    g=st.integers(1, 12),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_strip_mvm_matches_ref(t, d, g, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (t, g * d))
    w = _rand(rng, (g * d, n))
    s = rng.uniform(0.25, 4.0, size=(g, n)).astype(np.float32)
    got = strip_mvm.strip_mvm(jnp.asarray(a), jnp.asarray(w), jnp.asarray(s), group_size=d)
    want = ref.strip_mvm_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(s), group_size=d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@given(
    t=st.integers(1, 64),
    d=st.integers(1, 16),
    g=st.integers(1, 9),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_mixed_strip_mvm_matches_ref(t, d, g, n, seed):
    """Complementary hi/lo clusters; stepwise accumulation must equal the
    single-matmul reference on the dequantized weights."""
    rng = np.random.default_rng(seed)
    a = _rand(rng, (t, g * d))
    w = _rand(rng, (g * d, n))
    # Random strip partition (g, n) -> hi or lo.
    hi_mask = rng.random(size=(g, n)) < 0.5
    codes_hi, s_hi = strip_mvm.quantize_strips(w, 8, d)
    codes_lo, s_lo = strip_mvm.quantize_strips(w, 4, d)
    mh = np.repeat(hi_mask, d, axis=0)
    wq = (codes_hi * mh).astype(np.float32)
    wp = (codes_lo * ~mh).astype(np.float32)
    sq = (s_hi * hi_mask).astype(np.float32)
    sp_ = (s_lo * ~hi_mask).astype(np.float32)

    got = strip_mvm.mixed_strip_mvm(
        jnp.asarray(a), jnp.asarray(wq), jnp.asarray(sq), jnp.asarray(wp), jnp.asarray(sp_), group_size=d
    )
    # Oracle: dequantize each cluster and do one f32 matmul.
    w_deq = np.asarray(
        ref.dequantize_ref(jnp.asarray(wq), jnp.asarray(sq), group_size=d)
    ) + np.asarray(ref.dequantize_ref(jnp.asarray(wp), jnp.asarray(sp_), group_size=d))
    want = a @ w_deq
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


@given(
    bits=st.sampled_from([2, 4, 8]),
    d=st.integers(1, 16),
    g=st.integers(1, 9),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_strips_roundtrip_bound(bits, d, g, n, seed):
    """|w - dequant(quant(w))| <= scale/2 elementwise (symmetric uniform)."""
    rng = np.random.default_rng(seed)
    w = _rand(rng, (g * d, n), scale=2.0)
    codes, scale = strip_mvm.quantize_strips(w, bits, d)
    qmax = 2 ** (bits - 1) - 1
    assert np.abs(codes).max() <= qmax
    w_deq = np.asarray(ref.dequantize_ref(jnp.asarray(codes), jnp.asarray(scale), group_size=d))
    err = np.abs(w - w_deq).reshape(g, d, n)
    # strict half-LSB bound, with relative slack for f32 rounding at the
    # exact midpoints
    bound = np.broadcast_to(scale[:, None, :] * 0.5 * (1 + 1e-5) + 1e-6, err.shape)
    np.testing.assert_array_less(err, bound)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [1, 3])
def test_conv_via_strips_matches_lax(stride, k):
    import jax

    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 16, 16, 8))
    w = _rand(rng, (k, k, 8, 12))
    got = strip_mvm.conv2d_via_strips(jnp.asarray(x), jnp.asarray(w), stride)
    want = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_strip_mvm_zero_scale_kills_contribution():
    rng = np.random.default_rng(5)
    d, g, n = 4, 3, 6
    a = _rand(rng, (10, g * d))
    w = _rand(rng, (g * d, n))
    s = np.ones((g, n), dtype=np.float32)
    s[1, :] = 0.0
    got = np.asarray(strip_mvm.strip_mvm(jnp.asarray(a), jnp.asarray(w), jnp.asarray(s), group_size=d))
    w_masked = w.copy()
    w_masked[d : 2 * d, :] = 0.0
    np.testing.assert_allclose(got, a @ w_masked, rtol=1e-4, atol=1e-4)
