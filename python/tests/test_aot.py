"""AOT interchange tests: HLO text lowering works for every exported graph
shape (without the expensive training step)."""
import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import strip_mvm


def _lower_text(fn, *specs):
    lowered = jax.jit(fn).lower(*specs)
    return aot.to_hlo_text(lowered)


def test_fwd_lowers_to_hlo_text():
    name = "resnet8"
    p = model.num_params(name)
    txt = _lower_text(
        lambda th, x: (model.forward(name, th, x),),
        aot.spec((p,)),
        aot.spec((4, 32, 32, 3)),
    )
    assert txt.startswith("HloModule")
    assert "f32[4,10]" in txt  # logits output shape appears


def test_hvp_lowers_to_hlo_text():
    name = "resnet8"
    p, pc = model.num_params(name), model.num_conv_params(name)
    txt = _lower_text(
        lambda th, x, y, v: (model.hvp_diag_probe(name, th, x, y, v),),
        aot.spec((p,)),
        aot.spec((4, 32, 32, 3)),
        aot.spec((4, 10)),
        aot.spec((pc,)),
    )
    assert txt.startswith("HloModule")
    assert f"f32[{pc}]" in txt


def test_kernel_lowers_to_hlo_text():
    t, d, g, n = 32, 4, 3, 8
    txt = _lower_text(
        lambda a, w, s: (strip_mvm.strip_mvm(a, w, s, group_size=d),),
        aot.spec((t, g * d)),
        aot.spec((g * d, n)),
        aot.spec((g, n)),
    )
    assert txt.startswith("HloModule")


def test_hlo_text_ids_are_reassignable():
    """The text must parse back through xla_client (proxy for the Rust-side
    text parser accepting it — 64-bit-id protos would fail here)."""
    from jax._src.lib import xla_client as xc

    txt = _lower_text(
        lambda x: (x * 2.0 + 1.0,),
        aot.spec((8,)),
    )
    # round-trip through the HLO text parser
    comp = xc._xla.hlo_module_from_text(txt)
    assert comp is not None


def test_write_bin_roundtrip(tmp_path):
    arr = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    entry = aot.write_bin(str(tmp_path / "t.bin"), arr)
    assert entry == {"file": "t.bin", "shape": [2, 3, 4], "dtype": "f32"}
    back = np.fromfile(tmp_path / "t.bin", dtype="<f4").reshape(2, 3, 4)
    np.testing.assert_array_equal(arr, back)
