"""L2 model tests: layout contract, forward shapes, pallas-path equivalence,
HVP vs finite differences, Fisher diagonal sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model


@pytest.mark.parametrize("name", list(model.CONFIGS))
def test_param_layout_contiguous(name):
    sps = model.param_specs(name)
    off = 0
    for s in sps:
        assert s.offset == off
        off += s.size
    assert off == model.num_params(name)
    # conv-flat offsets are the concat order of quantizable specs
    assert model.num_conv_params(name) == sum(s.size for s in sps if s.quantizable)


@pytest.mark.parametrize("name", list(model.CONFIGS))
def test_forward_shapes(name):
    th = jnp.asarray(model.init_params(name, 1))
    x = jnp.zeros((4, 32, 32, 3), jnp.float32)
    out = model.forward(name, th, x)
    assert out.shape == (4, model.NUM_CLASSES)
    assert np.all(np.isfinite(np.asarray(out)))


def test_flatten_unflatten_roundtrip():
    name = "resnet8"
    th = model.init_params(name, 2)
    params = {k: np.asarray(v) for k, v in model.unflatten(name, jnp.asarray(th)).items()}
    th2 = model.flatten(name, params)
    np.testing.assert_array_equal(th, th2)


def test_forward_pallas_matches_forward():
    name = "resnet8"
    rng = np.random.default_rng(0)
    th = jnp.asarray(model.init_params(name, 3))
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 3)).astype(np.float32))
    a = model.forward(name, th, x)
    b = model.forward_pallas(name, th, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def _embed_conv(name, v):
    """Scatter a conv-flat vector into a full-parameter-sized vector."""
    v_full = np.zeros(model.num_params(name), dtype=np.float32)
    off = 0
    for s in model.conv_param_specs(name):
        v_full[s.offset : s.offset + s.size] = np.asarray(v)[off : off + s.size]
        off += s.size
    return jnp.asarray(v_full)


def test_hvp_probe_matches_full_param_jvp():
    """The conv-restricted probe graph must agree with the unrestricted
    jvp-of-grad over the full parameter vector (an independent code path
    through the scatter/gather machinery). f32 finite differences are too
    noisy at this Hessian scale to be a useful oracle — the full-jvp is the
    autodiff ground truth."""
    name = "resnet8"
    rng = np.random.default_rng(4)
    th = jnp.asarray(model.init_params(name, 4))
    x = jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32))
    y1h = jnp.asarray(data.one_hot(rng.integers(0, 10, size=8).astype(np.int32)))
    pc = model.num_conv_params(name)
    v = jnp.asarray(rng.choice([-1.0, 1.0], size=pc).astype(np.float32))

    probe = model.hvp_diag_probe(name, th, x, y1h, v)
    vhv = float(jnp.sum(probe))  # v*(Hv) summed == v^T H v

    v_full = _embed_conv(name, v)
    grad_fn = jax.grad(lambda t: model.loss(name, t, x, y1h))
    _, hv_full = jax.jvp(grad_fn, (th,), (v_full,))
    vhv_full = float(v_full @ hv_full)
    assert abs(vhv - vhv_full) <= 1e-3 * max(1.0, abs(vhv_full)), (vhv, vhv_full)


def test_hvp_probe_hessian_symmetry():
    """v2^T H v1 == v1^T H v2. For Rademacher v, Hv = v * (v ⊙ Hv), so the
    probe output lets us recover Hv and check the symmetry of H."""
    name = "resnet8"
    rng = np.random.default_rng(8)
    th = jnp.asarray(model.init_params(name, 8))
    x = jnp.asarray(rng.normal(size=(4, 32, 32, 3)).astype(np.float32))
    y1h = jnp.asarray(data.one_hot(rng.integers(0, 10, size=4).astype(np.int32)))
    pc = model.num_conv_params(name)
    v1 = jnp.asarray(rng.choice([-1.0, 1.0], size=pc).astype(np.float32))
    v2 = jnp.asarray(rng.choice([-1.0, 1.0], size=pc).astype(np.float32))

    hv1 = v1 * model.hvp_diag_probe(name, th, x, y1h, v1)  # v1*(v1⊙Hv1) = Hv1
    hv2 = v2 * model.hvp_diag_probe(name, th, x, y1h, v2)
    a = float(v2 @ hv1)
    b = float(v1 @ hv2)
    assert abs(a - b) <= 1e-2 * max(1.0, abs(a), abs(b)), (a, b)


def test_fisher_diag_nonnegative_and_shaped():
    name = "resnet8"
    rng = np.random.default_rng(5)
    th = jnp.asarray(model.init_params(name, 5))
    x = jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32))
    y1h = jnp.asarray(data.one_hot(rng.integers(0, 10, size=8).astype(np.int32)))
    f = model.fisher_diag(name, th, x, y1h)
    assert f.shape == (model.num_conv_params(name),)
    assert float(f.min()) >= 0.0
    assert float(f.max()) > 0.0


def test_dataset_determinism_and_balance():
    x1, y1 = data.generate(512, seed=11)
    x2, y2 = data.generate(512, seed=11)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = data.generate(512, seed=12)
    assert not np.array_equal(x1, x3)
    # all classes present
    assert len(np.unique(y1)) == data.NUM_CLASSES
    assert x1.dtype == np.float32 and x1.shape == (512, 32, 32, 3)
