//! Quickstart: the canonical `CompressionPlan` chain — build a staged
//! compression plan, evaluate it offline, then deploy the exact same stages
//! to the serving engine.
//!
//!     cargo run --release --example quickstart
//!
//! Prefers the AOT artifacts + PJRT backend when `make artifacts` has run;
//! otherwise it falls back to the native crossbar simulator on an in-memory
//! fixture, so the quickstart works on a fresh clone too.

use reram_mpq::backend::SimXbarConfig;
use reram_mpq::coordinator::{
    CompressionPlan, EvalOpts, Executor, ModelState, ThresholdMode,
};
use reram_mpq::fixture;
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{artifacts_dir, Manifest, Result, RunConfig, Runtime};

/// Artifact-free variant: the same staged chain on `SimXbar`.
fn sim_quickstart() -> Result<()> {
    println!("== quickstart (sim backend: no AOT artifacts found) ==");
    let fx = fixture::tiny(0);
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(SimXbarConfig::default()),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        RunConfig::default(),
    )
    .threshold(ThresholdMode::FixedCr(0.7))
    .cluster()
    .align_to_capacity()
    .map(MappingStrategy::Packed);
    let report = plan.evaluate(EvalOpts::batches(2))?;
    println!(
        "evaluate:     top-1 {:.1}% at CR {:.0}% ({} hi / {} strips)",
        report.accuracy.top1 * 100.0,
        report.compression_ratio * 100.0,
        report.q_hi,
        report.total_strips
    );
    let handle = plan.deploy(Default::default())?;
    let resp = handle.classify(plan.test().x.data()[..32 * 32 * 3].to_vec())?;
    println!("serving:      first test image -> class {}", resp.class);
    println!("(run `make artifacts` for the PJRT path on the real checkpoints)");
    Ok(())
}

fn main() -> Result<()> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return sim_quickstart();
    }
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::new(dir)?;

    // Stage the plan: 70% of strips in 4-bit crossbars, dynamic crossbar
    // alignment, packed mapping. Nothing runs until a terminal is called.
    let plan = CompressionPlan::for_model(&runtime, &manifest, "resnet20")?
        .threshold(ThresholdMode::FixedCr(0.7))
        .cluster()
        .align_to_capacity()
        .map(MappingStrategy::Packed);

    // Terminal 1 — evaluate: quantize, map, cost, measure accuracy.
    let report = plan.evaluate(EvalOpts::batches(4))?;

    println!("== quickstart: sensitivity-aware mixed-precision quantization ==");
    println!("model:        {}", report.model);
    println!("fp32 top-1:   {:.2}%", report.fp32_accuracy * 100.0);
    println!(
        "quantized:    {:.2}% top-1 at CR {:.0}% ({} hi / {} strips)",
        report.accuracy.top1 * 100.0,
        report.compression_ratio * 100.0,
        report.q_hi,
        report.total_strips
    );
    println!(
        "crossbars:    {:.2}% bit utilization (8-bit arrays), {:.2}% overall",
        report.utilization_hi * 100.0,
        report.utilization_all * 100.0
    );
    println!(
        "per image:    {:.3} mJ system energy ({:.3} mJ ADC), {:.3} ms latency",
        report.cost.energy.system_mj(),
        report.cost.energy.adc_mj,
        report.cost.latency_ms
    );

    // Terminal 2 — deploy: the same quantized stages serve live requests
    // (the quantization artifact is reused from the evaluate above).
    let handle = plan.deploy(Default::default())?;
    let image = plan.test().x.data()[..32 * 32 * 3].to_vec();
    let resp = handle.classify(image)?;
    println!(
        "serving:      first test image -> class {} in {} us",
        resp.class, resp.latency_us
    );

    // Exploring a second operating point shares the computed prefix: the
    // sensitivity scores are NOT recomputed for this plan.
    let report90 = plan
        .clone()
        .threshold(ThresholdMode::FixedCr(0.9))
        .evaluate(EvalOpts::batches(4))?;
    println!(
        "CR 90%:       {:.2}% top-1, {:.3} mJ (sensitivity runs: {})",
        report90.accuracy.top1 * 100.0,
        report90.cost.energy.system_mj(),
        plan.cache_stats().sensitivity_runs
    );
    Ok(())
}
