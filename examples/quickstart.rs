//! Quickstart: compress a pre-trained model with the sensitivity-aware
//! mixed-precision pipeline and print accuracy + hardware cost.
//!
//!     cargo run --release --example quickstart
//!
//! (Run `make artifacts` first.)

use reram_mpq::coordinator::{Pipeline, ThresholdMode};
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{artifacts_dir, Manifest, Result, RunConfig, Runtime};

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::new(dir)?;

    // Compress the ResNet20 backbone at 70% compression (70% of strips in
    // 4-bit crossbars), with dynamic crossbar alignment + packed mapping.
    let mut pipe = Pipeline::new(&runtime, &manifest, "resnet20", RunConfig::default())?;
    let report = pipe.run(
        ThresholdMode::FixedCr(0.7),
        /*align=*/ true,
        MappingStrategy::Packed,
        /*eval_batches=*/ 4,
    )?;

    println!("== quickstart: sensitivity-aware mixed-precision quantization ==");
    println!("model:        {}", report.model);
    println!("fp32 top-1:   {:.2}%", report.fp32_accuracy * 100.0);
    println!(
        "quantized:    {:.2}% top-1 at CR {:.0}% ({} hi / {} strips)",
        report.accuracy.top1 * 100.0,
        report.compression_ratio * 100.0,
        report.q_hi,
        report.total_strips
    );
    println!(
        "crossbars:    {:.2}% bit utilization (8-bit arrays), {:.2}% overall",
        report.utilization_hi * 100.0,
        report.utilization_all * 100.0
    );
    println!(
        "per image:    {:.3} mJ system energy ({:.3} mJ ADC), {:.3} ms latency",
        report.cost.energy.system_mj(),
        report.cost.energy.adc_mj,
        report.cost.latency_ms
    );
    Ok(())
}
