//! Hermetic demo of the native crossbar-simulator backend: the complete
//! sensitivity → clustering → quantize → map → evaluate → deploy pipeline
//! with **no AOT artifacts and no XLA** — everything below runs from an
//! in-memory fixture on `SimXbar`.
//!
//!     cargo run --release --example sim_backend
//!
//! Compare `examples/quickstart.rs`, which prefers the PJRT artifacts when
//! they exist and falls back to this same hermetic path when they don't.

use reram_mpq::backend::SimXbarConfig;
use reram_mpq::coordinator::{
    CompressionPlan, EvalOpts, Executor, ModelState, ThresholdMode,
};
use reram_mpq::fixture;
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{Result, RunConfig};

fn main() -> Result<()> {
    let fx = fixture::tiny(42);
    println!("== sim backend: bit-serial crossbar simulation, no artifacts ==");
    println!(
        "fixture:      {} ({} params, {} strips, {} test images)",
        fx.model.name(),
        fx.model.entry.num_params,
        fx.model.num_strips(),
        fx.test.len()
    );

    // Root the plan on the simulator: 2-bit cells, 8-bit DAC, ideal ADC.
    let scfg = SimXbarConfig::default();
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(scfg),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        RunConfig::default(),
    )
    .threshold(ThresholdMode::FixedCr(0.7))
    .cluster()
    .align_to_capacity()
    .map(MappingStrategy::Packed);

    // Offline terminal: the quantized strips execute bit-serially on the
    // simulated crossbars (cell slicing, input-bit phases).
    let report = plan.evaluate(EvalOpts::batches(2))?;
    println!(
        "evaluate:     top-1 {:.1}% at CR {:.0}% ({} hi / {} strips), {:.3} mJ/img",
        report.accuracy.top1 * 100.0,
        report.compression_ratio * 100.0,
        report.q_hi,
        report.total_strips,
        report.cost.energy.system_mj()
    );

    // Fidelity knobs: the same plan evaluated with a 4-bit ADC and ReRAM
    // conductance noise — the non-idealities the paper's §1 cites.
    let noisy = plan.evaluate_on(
        Executor::Sim(scfg.with_adc(4).with_noise(0.1, 7)),
        EvalOpts::batches(2),
    )?;
    println!(
        "non-ideal:    top-1 {:.1}% with 4-bit ADC + sigma=0.1 conductance noise",
        noisy.accuracy.top1 * 100.0
    );

    // Online terminal: the deploy path serves through the same simulator
    // (readiness handshake included — a bad deployment would fail here with
    // a typed StartupError, not a dead queue).
    let handle = plan.deploy(Default::default())?;
    let image = plan.test().x.data()[..32 * 32 * 3].to_vec();
    let resp = handle.classify(image)?;
    println!(
        "serving:      first test image -> class {} in {} us",
        resp.class, resp.latency_us
    );
    println!(
        "stage cache:  sensitivity(proxy) runs = {}",
        plan.cache_stats().sensitivity_runs
    );
    Ok(())
}
