//! Edge deployment scenario (the paper's §1/§6 motivation): given a power
//! budget and an accuracy floor, find the mixed-precision operating point.
//!
//! Sweeps compression ratios (one plan per CR, all sharing the sensitivity
//! prefix), builds the accuracy-energy Pareto front, then answers: "what is
//! the lowest-energy configuration that keeps top-1 within `max_drop` of
//! fp32?" — the question an IoT/wearable integrator actually asks.
//!
//!     cargo run --release --example edge_power_budget

use reram_mpq::coordinator::{CompressionPlan, EvalOpts, PipelineReport, ThresholdMode};
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{artifacts_dir, Manifest, Result, Runtime};

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::new(dir)?;
    let base = CompressionPlan::for_model(&runtime, &manifest, "resnet8")?;

    let max_drop = 0.06; // accept up to 6 points of top-1 drop
    let opts = EvalOpts::batches(8);

    println!("== edge power budget explorer (resnet8, ResNet18 stand-in) ==");
    println!("accuracy floor: fp32 − {:.0} points", max_drop * 100.0);
    println!();
    println!("| CR    | top-1   | energy/img | latency/img | ok |");
    println!("|-------|---------|------------|-------------|----|");

    let mut reports: Vec<PipelineReport> = Vec::new();
    for cr in [0.0, 0.2, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let r = base
            .clone()
            .threshold(ThresholdMode::FixedCr(cr))
            .cluster()
            .align_to_capacity()
            .map(MappingStrategy::Packed)
            .evaluate(opts)?;
        let ok = r.accuracy.top1 >= r.fp32_accuracy - max_drop;
        println!(
            "| {:>4.0}% | {:>6.2}% | {:>7.3} mJ | {:>8.3} ms | {}  |",
            cr * 100.0,
            r.accuracy.top1 * 100.0,
            r.cost.energy.system_mj(),
            r.cost.latency_ms,
            if ok { "y" } else { "n" }
        );
        reports.push(r);
    }

    // Pareto front (maximize accuracy, minimize energy).
    let mut front: Vec<&PipelineReport> = Vec::new();
    for r in &reports {
        let dominated = reports.iter().any(|o| {
            o.accuracy.top1 >= r.accuracy.top1
                && o.cost.energy.system_mj() < r.cost.energy.system_mj()
                && (o.accuracy.top1 > r.accuracy.top1
                    || o.cost.energy.system_mj() < r.cost.energy.system_mj())
        });
        if !dominated {
            front.push(r);
        }
    }
    println!();
    println!(
        "pareto front CRs: {:?}",
        front
            .iter()
            .map(|r| format!("{:.0}%", r.compression_ratio * 100.0))
            .collect::<Vec<_>>()
    );

    let pick = reports
        .iter()
        .filter(|r| r.accuracy.top1 >= r.fp32_accuracy - max_drop)
        .min_by(|a, b| a.cost.energy.system_mj().total_cmp(&b.cost.energy.system_mj()));
    match pick {
        Some(r) => {
            let base_r = &reports[0];
            println!(
                "\noperating point: CR {:.0}% — {:.2}% top-1, {:.3} mJ/img ({:.0}% energy saved vs 8-bit), {:.3} ms/img",
                r.compression_ratio * 100.0,
                r.accuracy.top1 * 100.0,
                r.cost.energy.system_mj(),
                (1.0 - r.cost.energy.system_mj() / base_r.cost.energy.system_mj()) * 100.0,
                r.cost.latency_ms
            );
        }
        None => println!("\nno configuration meets the accuracy floor"),
    }
    Ok(())
}
