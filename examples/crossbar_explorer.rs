//! Hardware co-design explorer: how do array geometry and ADC provisioning
//! change the cost of the *same* compressed model?
//!
//! Fixes one sensitivity clustering (resnet14 @ 80% CR) and sweeps the
//! crossbar configuration — array size, cell precision, ADC sharing —
//! reporting utilization, energy and latency under both mappers. Every
//! geometry is a plan sharing the same sensitivity prefix through the stage
//! cache; the Hutchinson analyzer runs exactly once for the whole sweep.
//!
//!     cargo run --release --example crossbar_explorer

use reram_mpq::coordinator::{CompressionPlan, ThresholdMode};
use reram_mpq::xbar::{self, MappingStrategy, XbarConfig};
use reram_mpq::{artifacts_dir, Manifest, Result, RunConfig, Runtime};

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::new(dir)?;
    let cfg = RunConfig::default();
    let base = CompressionPlan::for_model_with(&runtime, &manifest, "resnet14", cfg.clone())?;

    println!("== crossbar design-space explorer (resnet14 @ 80% CR) ==");
    println!("| rows x cols | cell | cols/ADC | mapper | util(8b) | energy/img | latency/img | arrays |");
    println!("|-------------|------|----------|--------|----------|------------|-------------|--------|");

    for (rows, cols) in [(32, 32), (64, 64), (128, 128), (256, 256)] {
        for cell_bits in [1u8, 2, 4] {
            for cols_per_adc in [1usize, 2, 8] {
                let xcfg = XbarConfig {
                    rows,
                    cols,
                    cell_bits,
                    cols_per_adc,
                    ..XbarConfig::default()
                };
                let mut geo_cfg = cfg.clone();
                geo_cfg.xbar = xcfg;
                for strategy in [MappingStrategy::Origin, MappingStrategy::Packed] {
                    // ORIGIN keeps the raw clustering; OUR re-aligns it to
                    // this geometry's capacity before packing.
                    let mut plan = base
                        .clone()
                        .with_config(geo_cfg.clone())
                        .threshold(ThresholdMode::FixedCr(0.8))
                        .cluster()
                        .map(strategy);
                    if strategy == MappingStrategy::Packed {
                        plan = plan.align_to_capacity();
                    }
                    let mapping = plan.mapping()?;
                    let cost = xbar::cost(&mapping, &xcfg);
                    println!(
                        "| {:>4}x{:<6} | {}bit | {:>8} | {:<6} | {:>7.2}% | {:>7.3} mJ | {:>8.3} ms | {:>6} |",
                        rows,
                        cols,
                        cell_bits,
                        cols_per_adc,
                        match strategy {
                            MappingStrategy::Origin => "ORIGIN",
                            MappingStrategy::Packed => "OUR",
                        },
                        mapping.utilization(cfg.quant.hi.bits) * 100.0,
                        cost.energy.system_mj(),
                        cost.latency_ms,
                        mapping.total_arrays()
                    );
                }
            }
        }
    }
    println!();
    println!(
        "(hutchinson sensitivity ran {} time(s) for the whole sweep — the",
        base.cache_stats().sensitivity_runs
    );
    println!(" stage cache shares the prefix; larger arrays amplify the ORIGIN→OUR");
    println!(" utilization gap — Table 4's trend; 1-bit cells double the cell-columns");
    println!(" per weight; ADC sharing trades conversion parallelism for periphery area.)");
    Ok(())
}
