//! Hardware co-design explorer: how do array geometry and ADC provisioning
//! change the cost of the *same* compressed model?
//!
//! Fixes one sensitivity clustering (resnet14 @ 80% CR) and sweeps the
//! crossbar configuration — array size, cell precision, ADC sharing —
//! reporting utilization, energy and latency under both mappers. This is
//! the design-space exploration a CIM architect runs before tape-out.
//!
//!     cargo run --release --example crossbar_explorer

use reram_mpq::clustering;
use reram_mpq::coordinator::{Pipeline, ThresholdMode};
use reram_mpq::xbar::{self, MappingStrategy, XbarConfig};
use reram_mpq::{artifacts_dir, Manifest, Result, RunConfig, Runtime};

fn main() -> Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::new(dir)?;
    let cfg = RunConfig::default();
    let mut pipe = Pipeline::new(&runtime, &manifest, "resnet14", cfg.clone())?;

    let (clustering, _) = pipe.choose_clustering(ThresholdMode::FixedCr(0.8))?;
    let sens = pipe.sensitivity()?.clone();

    println!("== crossbar design-space explorer (resnet14 @ 80% CR) ==");
    println!("| rows x cols | cell | cols/ADC | mapper | util(8b) | energy/img | latency/img | arrays |");
    println!("|-------------|------|----------|--------|----------|------------|-------------|--------|");

    for (rows, cols) in [(32, 32), (64, 64), (128, 128), (256, 256)] {
        for cell_bits in [1u8, 2, 4] {
            for cols_per_adc in [1usize, 2, 8] {
                let xcfg = XbarConfig {
                    rows,
                    cols,
                    cell_bits,
                    cols_per_adc,
                    ..XbarConfig::default()
                };
                // Re-align the clustering to this geometry's capacity.
                let caps: Vec<usize> = pipe
                    .model
                    .conv_layers()
                    .iter()
                    .map(|l| xcfg.capacity_strips(l.d, cfg.quant.hi.bits))
                    .collect();
                let aligned = clustering::align_to_capacity(
                    &pipe.model,
                    &sens.scores,
                    &clustering,
                    cfg.quant.hi.bits,
                    cfg.quant.lo.bits,
                    |li| caps[li],
                );
                for strategy in [MappingStrategy::Origin, MappingStrategy::Packed] {
                    let bm = if strategy == MappingStrategy::Packed {
                        &aligned.bitmap
                    } else {
                        &clustering.bitmap
                    };
                    let mapping = xbar::map_model(&pipe.model, bm, &xcfg, strategy);
                    let cost = xbar::cost(&mapping, &xcfg);
                    println!(
                        "| {:>4}x{:<6} | {}bit | {:>8} | {:<6} | {:>7.2}% | {:>7.3} mJ | {:>8.3} ms | {:>6} |",
                        rows,
                        cols,
                        cell_bits,
                        cols_per_adc,
                        match strategy {
                            MappingStrategy::Origin => "ORIGIN",
                            MappingStrategy::Packed => "OUR",
                        },
                        mapping.utilization(cfg.quant.hi.bits) * 100.0,
                        cost.energy.system_mj(),
                        cost.latency_ms,
                        mapping.total_arrays()
                    );
                }
            }
        }
    }
    println!();
    println!("(larger arrays amplify the ORIGIN→OUR utilization gap — Table 4's trend;");
    println!(" 1-bit cells double the cell-columns per weight; ADC sharing trades");
    println!(" conversion parallelism for periphery area at equal conversion count.)");
    Ok(())
}
