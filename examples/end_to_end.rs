//! End-to-end driver — exercises every layer of the stack on the real
//! workload and proves they compose (the run recorded in EXPERIMENTS.md):
//!
//!   1. load the AOT artifacts (JAX/Pallas → HLO text → PJRT),
//!   2. Hutchinson strip-sensitivity analysis through the `hvp` executable,
//!   3. FIM-guided threshold search (Algorithm 1 *and* the §5 sweep) — two
//!      plans forked from one root, sharing the sensitivity stage,
//!   4. dynamic clustering + crossbar-capacity alignment,
//!   5. mixed-precision quantization + NeuroSim-lite mapping/cost,
//!   6. full-test-set accuracy through the `fwd_eval` executable,
//!   7. batched serving through the plan's `deploy` terminal,
//!   8. the L1 Pallas kernel executed standalone and checked in Rust.
//!
//!     cargo run --release --example end_to_end

use std::time::Instant;

use reram_mpq::coordinator::{CompressionPlan, EvalOpts, ThresholdMode};
use reram_mpq::tensor::Tensor;
use reram_mpq::util::rng::Rng;
use reram_mpq::xbar::MappingStrategy;
use reram_mpq::{artifacts_dir, Manifest, Result, RunConfig, Runtime};

fn main() -> Result<()> {
    let t_start = Instant::now();
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::new(dir)?;
    let cfg = RunConfig::default();

    println!("== end-to-end: {} ==", runtime.platform());
    println!("hardware (Table 1): {}", cfg.xbar.to_value().to_json());

    // ---- 1+2: sensitivity analysis --------------------------------------
    let base = CompressionPlan::for_model_with(&runtime, &manifest, "resnet20", cfg.clone())?;
    let t0 = Instant::now();
    let sens = base.sensitivity_scores()?;
    let sorted = sens.sorted_scores();
    println!(
        "[sensitivity] {} strips, {} probes, {:.1}s; median score {:.3e}, p99 {:.3e}",
        sorted.len(),
        sens.probes,
        t0.elapsed().as_secs_f64(),
        sorted[sorted.len() / 2],
        sorted[sorted.len() * 99 / 100]
    );

    // ---- 3: threshold search (both modes, one shared prefix) -------------
    let t0 = Instant::now();
    let alg1 = base.clone().threshold(ThresholdMode::Alg1);
    let c_alg1 = alg1.clustering()?;
    println!(
        "[alg1 ] chose CR {:.1}% (q_hi={}) after {} FIM evals, {:.1}s",
        c_alg1.compression_ratio(8) * 100.0,
        c_alg1.q_hi,
        alg1.chosen_threshold()?.fim_evals,
        t0.elapsed().as_secs_f64()
    );
    let t0 = Instant::now();
    let sweep = base.clone().threshold(ThresholdMode::Sweep);
    let c_sweep = sweep.clustering()?;
    println!(
        "[sweep] chose CR {:.1}% (q_hi={}) after {} FIM evals, {:.1}s (sensitivity runs so far: {})",
        c_sweep.compression_ratio(8) * 100.0,
        c_sweep.q_hi,
        sweep.chosen_threshold()?.fim_evals,
        t0.elapsed().as_secs_f64(),
        base.cache_stats().sensitivity_runs
    );

    // ---- 4+5+6: full plan at the sweep's operating point ------------------
    let t0 = Instant::now();
    let report = sweep
        .clone()
        .align_to_capacity()
        .map(MappingStrategy::Packed)
        .evaluate(EvalOpts::full())?;
    println!(
        "[pipeline] CR {:.1}%: top1 {:.2}% (fp32 {:.2}%), {:.3} mJ/img, {:.3} ms/img, util(hi) {:.1}%, {:.1}s",
        report.compression_ratio * 100.0,
        report.accuracy.top1 * 100.0,
        report.fp32_accuracy * 100.0,
        report.cost.energy.system_mj(),
        report.cost.latency_ms,
        report.utilization_hi * 100.0,
        t0.elapsed().as_secs_f64()
    );

    // ---- 7: serving through the deploy terminal ---------------------------
    let handle = sweep.deploy(Default::default())?;
    let _ = handle.classify(vec![0.0; 32 * 32 * 3])?; // warm the executable
    let test = sweep.test();
    let n = 256.min(test.len());
    let elems = 32 * 32 * 3;
    let t0 = Instant::now();
    let mut correct = 0;
    let mut i = 0;
    while i < n {
        let hi = (i + 32).min(n);
        let pend: Vec<_> = (i..hi)
            .map(|j| handle.submit(test.x.data()[j * elems..(j + 1) * elems].to_vec()))
            .collect::<Result<_>>()?;
        for (j, p) in (i..hi).zip(pend) {
            if p.wait()?.class == test.y[j] {
                correct += 1;
            }
        }
        i = hi;
    }
    let dt = t0.elapsed().as_secs_f64();
    let snap = handle.metrics.snapshot();
    println!(
        "[serve] {n} reqs in {:.2}s = {:.0} req/s, acc {:.2}%, mean batch fill {:.2}, mean batch latency {:.0}us",
        dt,
        n as f64 / dt,
        correct as f64 / n as f64 * 100.0,
        snap.mean_batch_fill,
        snap.mean_latency_us
    );

    // ---- 8: the L1 Pallas kernel, standalone ------------------------------
    let k = &manifest.kernel;
    let (t, d, g, nk) = (k.t, k.d, k.g, k.n);
    let mut rng = Rng::seed_from_u64(1);
    let a: Vec<f32> = (0..t * g * d).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..g * d * nk).map(|_| (rng.below(15) as f32) - 7.0).collect();
    let s: Vec<f32> = (0..g * nk).map(|_| rng.range(0.01, 0.1) as f32).collect();
    let out = runtime.exec(
        &k.strip_mvm,
        &[
            Tensor::new(vec![t, g * d], a.clone()),
            Tensor::new(vec![g * d, nk], w.clone()),
            Tensor::new(vec![g, nk], s.clone()),
        ],
    )?;
    // Rust-side oracle.
    let mut want = vec![0.0f32; t * nk];
    for ti in 0..t {
        for gi in 0..g {
            for ni in 0..nk {
                let mut acc = 0.0f32;
                for di in 0..d {
                    acc += a[ti * g * d + gi * d + di] * w[(gi * d + di) * nk + ni];
                }
                want[ti * nk + ni] += acc * s[gi * nk + ni];
            }
        }
    }
    let max_err = out[0]
        .data()
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("[kernel] strip_mvm [{t}x{}]x[{}x{nk}] max|err| vs rust oracle = {max_err:.2e}", g * d, g * d);
    assert!(max_err < 1e-3, "kernel mismatch");

    println!("== end-to-end complete in {:.1}s ==", t_start.elapsed().as_secs_f64());
    Ok(())
}
