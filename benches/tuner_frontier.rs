//! Bench: the auto-tuner substrate — pure Pareto-frontier maintenance, the
//! degenerate serial CR sweep, and the parallel driver fan-out, all on the
//! hermetic in-memory fixture (no AOT artifacts):
//!
//!     cargo bench --bench tuner_frontier
//!
//! Emits `BENCH_tuner_frontier.json`; CI's `bench-smoke` runs this in quick
//! mode and gates it against `benches/baseline.json`.

use reram_mpq::coordinator::{CompressionPlan, EvalOpts, Executor, ModelState};
use reram_mpq::tuner::{
    self, Axes, Frontier, Objectives, SearchState, TuneConfig, TuneShared, TABLE3_CRS,
};
use reram_mpq::util::bench::Bench;
use reram_mpq::util::rng::Rng;
use reram_mpq::{fixture, RunConfig};

fn main() {
    let b = Bench::from_env();
    let cfg = RunConfig::default();
    let opts = EvalOpts::batches(2);

    // 1. pure frontier maintenance: insert + prune over a seeded synthetic
    // point cloud (no model evaluation at all).
    let mut rng = Rng::seed_from_u64(9);
    let cloud: Vec<(String, Objectives)> = (0..1024)
        .map(|i| {
            (
                format!("p{i}"),
                Objectives {
                    top1: rng.uniform(),
                    compression: rng.uniform(),
                    storage_bytes: rng.below(1 << 20) as u64,
                },
            )
        })
        .collect();
    let mut frontier_size = 0usize;
    b.run("tuner frontier insert+prune (1024 synthetic points)", || {
        let mut f = Frontier::default();
        for (k, o) in &cloud {
            f.insert(k, *o);
        }
        frontier_size = f.len();
        f
    });
    assert!(frontier_size > 0);
    b.annotate(
        "tuner frontier insert+prune (1024 synthetic points)",
        &[("frontier_size", frontier_size as f64)],
    );

    // 2. the degenerate Table 3 case: serial CR sweep on one shared plan
    // (after the first iteration every stage is a cache hit — this times
    // the sweep the `table3` experiment actually runs).
    let fx = fixture::tiny(21);
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(Default::default()),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        cfg.clone(),
    );
    b.run("tuner sweep_cr serial (fixture, Table 3 points)", || {
        tuner::sweep_cr(&plan, TABLE3_CRS, opts).expect("sweep_cr")
    });

    // 3. the parallel driver: fresh state per iteration, 2 workers, each
    // rooting its own plan + stage cache (programs + evaluates every
    // candidate from scratch — the cold-start cost a real tune pays).
    let shared = TuneShared::from_fixture(fixture::tiny(21), cfg);
    let axes = Axes::cr_axis(TABLE3_CRS, 8, 4).expect("axes");
    let tcfg = TuneConfig { workers: 2, opts, ..TuneConfig::default() };
    let mut last = None;
    b.run("tuner parallel run, 2 workers (fixture, cr axis)", || {
        let mut st = SearchState::new(0, axes.fingerprint(0));
        let out = tuner::run(&shared, &axes, &tcfg, &mut st).expect("tune");
        last = Some(out);
    });
    let out = last.unwrap();
    assert_eq!(out.evals, TABLE3_CRS.len());
    assert!(!out.frontier.is_empty(), "tune must yield a non-empty frontier");
    for a in out.frontier.points() {
        for c in out.frontier.points() {
            assert!(
                !a.objectives.dominates(&c.objectives),
                "frontier holds a dominated point"
            );
        }
    }
    b.annotate(
        "tuner parallel run, 2 workers (fixture, cr axis)",
        &[
            ("frontier_size", out.frontier.len() as f64),
            ("prefix_hits", out.cache.prefix_hits() as f64),
        ],
    );

    b.emit_json("tuner_frontier").expect("bench json");
}
