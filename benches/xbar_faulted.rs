//! Bench: the device-variability scenario engine — what fault injection and
//! sensitivity-aware placement add to crossbar programming time, and proof
//! that the *request-path* tile walk stays as fast as the healthy one (the
//! scenario is a post-programming transform; the walk never re-checks it).
//! Fully hermetic (in-memory fixture, no AOT artifacts):
//!
//!     cargo bench --bench xbar_faulted
//!
//! Emits `BENCH_xbar_faulted.json`; CI's `bench-smoke` runs this in quick
//! mode and gates it against `benches/baseline.json`.

use reram_mpq::backend::{ProgrammedModel, SimXbar, SimXbarConfig, StripPrecision};
use reram_mpq::faults::{Placement, Scenario, ScenarioSpec};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::sensitivity;
use reram_mpq::util::bench::Bench;
use reram_mpq::util::rng::Rng;
use reram_mpq::{fixture, RunConfig};
use std::sync::Arc;

fn main() {
    let b = Bench::from_env();
    let fx = fixture::tiny(1);
    let model = &fx.model;
    let mut cfg = RunConfig::default();
    cfg.quant.device_sigma = 0.0;
    let bits: Vec<u8> = (0..model.num_strips())
        .map(|i| if i % 2 == 0 { 8 } else { 4 })
        .collect();
    let qm = quant::apply(model, &fx.theta, &BitMap { bits }, &cfg.quant);
    let sp = StripPrecision::from_quantized(&qm);
    let scfg = SimXbarConfig::default().with_threads(1);

    let spec = ScenarioSpec::default()
        .with_stuck(0.05, 101)
        .with_ir_drop(0.2, 202)
        .with_drift(1.0, 0.01, 303);
    let scores = Arc::new(sensitivity::magnitude_proxy(model, &fx.theta).scores);
    let aware = Scenario::new(spec)
        .with_placement(Placement::SensitivityAware)
        .with_scores(scores);

    // 1. programming cost: healthy vs faulted + sensitivity-aware placement
    b.run("xbar program-once healthy (tiny, all layers)", || {
        ProgrammedModel::program(model, &qm.theta, &sp, &scfg).expect("program")
    });
    b.run("xbar program-once faulted+placed (tiny, all layers)", || {
        ProgrammedModel::program_with(model, &qm.theta, &sp, &scfg, Some(&aware)).expect("program")
    });

    // 2. the request path: the faulted programmed walk on the widest layer
    // (must match the healthy walk — faults live in the tiles, not the walk)
    let layer = model
        .conv_layers()
        .iter()
        .max_by_key(|l| l.k * l.k * l.d)
        .expect("fixture has conv layers")
        .clone();
    let mut rng = Rng::seed_from_u64(7);
    let t = 16usize;
    let patches: Vec<f32> =
        (0..t * layer.k * layer.k * layer.d).map(|_| rng.normal()).collect();
    let sim = SimXbar::new(scfg).with_scenario(aware.clone());
    let _ = sim
        .conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
        .expect("conv");
    b.run("xbar faulted programmed conv, ideal ADC (tiny widest layer)", || {
        sim.conv_bitserial(model, &layer, &qm.theta, &patches, t, &sp)
            .expect("conv")
    });

    // Overhead summary for the console (the JSON carries the raw means).
    let ms = b.measurements();
    let mean = |name: &str| {
        ms.iter()
            .find(|m| m.name == name)
            .map(|m| m.mean.as_secs_f64())
    };
    if let (Some(h), Some(f)) = (
        mean("xbar program-once healthy (tiny, all layers)"),
        mean("xbar program-once faulted+placed (tiny, all layers)"),
    ) {
        if h > 0.0 {
            println!("  fault injection + placement programming overhead: {:.2}x", f / h);
        }
    }

    b.emit_json("xbar_faulted").expect("bench json");
}
