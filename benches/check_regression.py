#!/usr/bin/env python3
"""Perf gate over the bench-JSON pipeline.

Compares `BENCH_*.json` files (emitted by `rust/src/util/bench.rs`; schema
per record: name / iters / mean_ns / stddev_ns / min_ns / git_sha) against
the committed `benches/baseline.json` and fails when any measurement's mean
regresses by more than the tolerance (default 30%).

Baseline entries whose `mean_ns` is null are *bootstrap* entries: they pin
the measurement name into the pipeline (so a silently renamed/dropped bench
is noticed) without gating its timing yet. An entry may also carry a
`max_regress` field overriding the global tolerance for that entry alone —
used to hold throughput-critical benches (e.g. serve_throughput after the
program-once refactor) to "improves or holds, within noise" instead of the
default 30%.

A bootstrap (or missing) row contributes **nothing** to the gate — a
baseline that is all-null makes the whole perf gate a silent no-op even
though CI prints "perf gate: ... 0 regression(s)". The summary therefore
always reports `ungated rows: N/M` (bootstrap + missing out of all baseline
rows), and `--strict` turns N > 0 into a failure: use it wherever the
baseline is known to carry real means for every row, e.g. against a
baseline the CI runner itself just refreshed:

    BENCH_QUICK=1 cargo bench --bench xbar_hotpath
    BENCH_QUICK=1 cargo bench --bench sim_backend
    python3 benches/check_regression.py --update BENCH_*.json
    # ... re-run the benches, then gate for real:
    python3 benches/check_regression.py --require-all --strict BENCH_*.json

Usage:
    python3 benches/check_regression.py [--baseline benches/baseline.json]
        [--tolerance 0.30] [--update] [--require-all] [--strict]
        BENCH_*.json

Exit status: 0 when no gated measurement regresses (and, under --strict,
no row went ungated), 1 otherwise. Stdlib only — runs on a bare CI runner.
"""

import argparse
import json
import sys


def load_current(paths):
    """name -> mean_ns across every BENCH_*.json given."""
    current = {}
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        for rec in doc.get("results", []):
            current[rec["name"]] = float(rec["mean_ns"])
    return current


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="benches/baseline.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=None,
        help="allowed fractional mean regression (default: baseline's, else 0.30)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline's mean_ns from the current runs instead of gating",
    )
    ap.add_argument(
        "--require-all",
        action="store_true",
        help="fail when a baseline name is missing from the current runs "
        "(use where every baseline bench is known to run, e.g. CI's "
        "hermetic runner) — so a renamed/dropped bench breaks the gate "
        "instead of silently shrinking it",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="fail when any baseline row is ungated (null-mean bootstrap or "
        "not measured) — guards against an all-null baseline turning the "
        "whole perf gate into a silent no-op",
    )
    ap.add_argument("bench_json", nargs="+", help="BENCH_*.json files to check")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    tolerance = args.tolerance
    if tolerance is None:
        tolerance = float(baseline.get("tolerance", 0.30))
    base = {r["name"]: r for r in baseline.get("results", [])}
    current = load_current(args.bench_json)

    if args.update:
        for rec in baseline.get("results", []):
            if rec["name"] in current:
                rec["mean_ns"] = current[rec["name"]]
        known = {r["name"] for r in baseline.get("results", [])}
        for name, mean in sorted(current.items()):
            if name not in known:
                baseline.setdefault("results", []).append(
                    {"name": name, "mean_ns": mean}
                )
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"baseline updated: {args.baseline} ({len(current)} measurements)")
        return 0

    regressions = []
    bootstraps = []
    missing = []
    gated = 0
    for name, rec in sorted(base.items()):
        if name not in current:
            # Environment-dependent rows (e.g. pjrt-only benches on an
            # artifact-less runner) are reported, not failed — unless
            # --require-all says every baseline name must be present.
            missing.append(name)
            print(f"note: baseline '{name}' not measured in this run")
            continue
        mean = current[name]
        ref = rec.get("mean_ns")
        if ref is None:
            bootstraps.append(name)
            print(f"bootstrap {name}: mean {mean / 1e6:.3f} ms (no gate yet)")
            continue
        gated += 1
        tol = float(rec.get("max_regress", tolerance))
        ratio = mean / ref if ref > 0 else float("inf")
        status = "ok"
        if ratio > 1.0 + tol:
            status = "REGRESSION"
            regressions.append((name, ref, mean, ratio))
        print(
            f"{status:>10} {name}: {mean / 1e6:.3f} ms vs baseline "
            f"{ref / 1e6:.3f} ms ({ratio:.0%} of baseline, tol {tol:.0%})"
        )
    for name in sorted(set(current) - set(base)):
        print(f"note: new measurement '{name}' not in baseline (add via --update)")

    ungated = len(bootstraps) + len(missing)
    print(
        f"perf gate: {gated} gated, {len(bootstraps)} bootstrap, "
        f"{len(missing)} missing, {len(regressions)} regression(s), "
        f"tolerance {tolerance:.0%}"
    )
    print(f"ungated rows: {ungated}/{len(base)}")
    failed = False
    if args.strict and ungated > 0:
        print(
            f"::error::--strict: {ungated} of {len(base)} baseline rows are "
            "ungated (null-mean bootstrap or unmeasured) — the perf gate is "
            "not actually gating them; refresh the baseline with --update "
            "from a trusted run",
            file=sys.stderr,
        )
        failed = True
    if args.require_all and missing:
        for name in missing:
            print(
                f"::error::bench '{name}' is in the baseline but was not "
                "measured (renamed or dropped?)",
                file=sys.stderr,
            )
        failed = True
    if regressions:
        for name, ref, mean, ratio in regressions:
            print(
                f"::error::bench '{name}' regressed {ratio - 1.0:+.1%} "
                f"({ref / 1e6:.3f} ms -> {mean / 1e6:.3f} ms)",
                file=sys.stderr,
            )
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
