//! Bench: regenerate paper Table 4 (bit utilization, ORIGIN vs OUR mapper,
//! 128×128 and 32×32 arrays) and time the mapping stage.
//!
//!     cargo bench --bench table4_utilization

mod common;

use reram_mpq::experiments::{self, Lab};
use reram_mpq::util::bench::Bench;
use reram_mpq::RunConfig;

fn main() {
    let c = common::ctx();
    let cfg = RunConfig::default();
    let lab = Lab::new(&c.runtime, &c.manifest, cfg);

    let mut rows = None;
    Bench::from_env().run("table4: utilization ORIGIN vs OUR (resnet14 @80%)", || {
        rows = Some(experiments::table4(&lab).expect("table4"));
    });
    let rows = rows.unwrap();
    println!();
    println!("{}", experiments::render_table4(&rows));

    // Shape assertions: OUR ≥ ORIGIN on both sizes, larger improvement on
    // the larger array (paper §5.4).
    let o128 = rows.iter().find(|r| r.method == "ORIGIN" && r.size.0 == 128).unwrap();
    let u128 = rows.iter().find(|r| r.method == "OUR" && r.size.0 == 128).unwrap();
    let o32 = rows.iter().find(|r| r.method == "ORIGIN" && r.size.0 == 32).unwrap();
    let u32 = rows.iter().find(|r| r.method == "OUR" && r.size.0 == 32).unwrap();
    assert!(u128.utilization > o128.utilization, "OUR must beat ORIGIN on 128x128");
    assert!(u32.utilization > o32.utilization, "OUR must beat ORIGIN on 32x32");
    assert!(
        (u128.utilization - o128.utilization) > (u32.utilization - o32.utilization),
        "large arrays should gain more from packing"
    );
}
