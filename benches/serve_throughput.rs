//! Serving front-end throughput over TCP loopback — fully hermetic: the
//! in-memory fixture model on the sim backend, the real server (dynamic
//! micro-batching + admission control) on an ephemeral port, and the real
//! protocol client as the load generator.
//!
//!     cargo bench --bench serve_throughput
//!
//! Emits `BENCH_serve_throughput.json`; each record carries `req_per_s`,
//! `p50_ns`, and `p99_ns` extras next to the standard mean/stddev fields,
//! plus the per-connection tail spread (`conn_p99_min_ns` /
//! `conn_p99_max_ns`) and the deepest admission queue the server reported
//! (`max_queue_depth`), so the perf pipeline sees request-rate, tail
//! latency, and fairness/backpressure, not just wall-clock per iteration.

use std::net::TcpListener;
use std::time::Duration;

use reram_mpq::backend::SimXbarConfig;
use reram_mpq::coordinator::{CompressionPlan, EngineConfig, Executor, ModelState};
use reram_mpq::fixture;
use reram_mpq::serve::{bench_client, BatchPolicy, ServeConfig, Server};
use reram_mpq::util::bench::Bench;
use reram_mpq::RunConfig;

fn main() -> reram_mpq::Result<()> {
    let b = Bench::from_env();
    let quick = std::env::var("BENCH_QUICK").as_deref() == Ok("1");
    let requests = if quick { 64 } else { 256 };

    let fx = fixture::tiny(5);
    let elems = 32 * 32 * 3;
    let images: Vec<Vec<f32>> = (0..fx.test.len())
        .map(|j| fx.test.x.data()[j * elems..(j + 1) * elems].to_vec())
        .collect();
    let plan = CompressionPlan::from_state(
        ModelState {
            exec: Executor::Sim(SimXbarConfig::default()),
            model: fx.model,
            theta: fx.theta,
            test: fx.test,
            calib: fx.calib,
        },
        RunConfig::default(),
    );
    let handle = plan.deploy_fp32(EngineConfig::default().with_workers(2))?;
    let server = Server::start(
        TcpListener::bind("127.0.0.1:0")?,
        handle,
        ServeConfig {
            policy: BatchPolicy {
                max_batch: 8,
                flush_after: Duration::from_millis(2),
                queue: 512,
            },
            ..ServeConfig::default()
        },
    )?;
    let addr = server.local_addr().to_string();

    for conns in [2usize, 4] {
        let name = format!("serve throughput, {conns} conns over tcp loopback");
        let mut last = None;
        b.run(&name, || {
            // 0 retries: the bench measures raw shed/served throughput;
            // backoff sleeps would distort the timing.
            let report = bench_client(&addr, conns, requests, &images, 0).unwrap();
            assert_eq!(report.failed, 0, "failed frames during bench: {report:?}");
            last = Some(report);
        });
        if let Some(report) = last {
            // Per-connection tail spread + deepest queue the server ever
            // reported back: a fairness/backpressure signal next to the
            // aggregate percentiles.
            let conn_p99_min = report.per_conn.iter().map(|c| c.p99_us).min().unwrap_or(0);
            let conn_p99_max = report.per_conn.iter().map(|c| c.p99_us).max().unwrap_or(0);
            b.annotate(
                &name,
                &[
                    ("req_per_s", report.req_per_s()),
                    ("p50_ns", report.p50_us as f64 * 1e3),
                    ("p99_ns", report.p99_us as f64 * 1e3),
                    ("rejected", report.rejected as f64),
                    ("degraded", report.degraded as f64),
                    ("retries", report.retries as f64),
                    ("conn_p99_min_ns", conn_p99_min as f64 * 1e3),
                    ("conn_p99_max_ns", conn_p99_max as f64 * 1e3),
                    ("max_queue_depth", report.max_queue_depth as f64),
                ],
            );
            println!(
                "  {conns} conns: {:.1} req/s, p50 {} us, p99 {} us (per-conn p99 {}..{} us), rejected {}, max queue depth {}",
                report.req_per_s(),
                report.p50_us,
                report.p99_us,
                conn_p99_min,
                conn_p99_max,
                report.rejected,
                report.max_queue_depth
            );
        }
    }
    b.emit_json("serve_throughput")?;
    Ok(())
}
