//! Bench: regenerate paper Figure 8 (accuracy degradation vs compression
//! ratio, shallow vs deep backbone) under the staged plan API.
//!
//!     cargo bench --bench fig8_depth_robustness

mod common;

use reram_mpq::experiments::{self, Lab};
use reram_mpq::util::bench::Bench;
use reram_mpq::RunConfig;

fn main() {
    let c = common::ctx();
    let cfg = RunConfig::default();
    let opts = common::opts();
    let lab = Lab::new(&c.runtime, &c.manifest, cfg);

    let mut rows = None;
    Bench::from_env().run("fig8: CR sweep, resnet8 vs resnet14", || {
        rows = Some(
            experiments::fig8(&lab, opts, experiments::FIG8_CRS).expect("fig8"),
        );
    });
    let rows = rows.unwrap();
    println!();
    println!("{}", experiments::render_fig8(&rows));

    // Shape assertion: accuracy at low CR should exceed accuracy at extreme
    // CR for both models (degradation exists).
    for label in ["ResNet18*", "ResNet50*"] {
        let series: Vec<f64> = rows
            .iter()
            .filter(|(l, _, _)| l == label)
            .map(|(_, _, r)| r.accuracy.top1)
            .collect();
        assert!(
            series.first().unwrap() > series.last().unwrap(),
            "{label}: accuracy must degrade from CR 0% to 100%"
        );
    }
}
