//! Bench: the L3 hot paths in isolation — mapper, cost model, quantizer,
//! PJRT forward execution and the standalone Pallas kernel. This is the
//! profile that drives the §Perf optimization loop.
//!
//!     cargo bench --bench xbar_hotpath
//!
//! Without AOT artifacts the bench degrades to its hermetic subset
//! (quantizer / mapper / cost model over the in-memory fixture) instead of
//! aborting — CI's `bench-smoke` job runs exactly that on a bare runner.
//! Every measurement is emitted to `BENCH_xbar_hotpath.json` and gated
//! against `benches/baseline.json`.

mod common;

use reram_mpq::coordinator::{CompressionPlan, ThresholdMode};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::tensor::Tensor;
use reram_mpq::util::bench::Bench;
use reram_mpq::util::rng::Rng;
use reram_mpq::xbar::{self, MappingStrategy, XbarConfig};
use reram_mpq::{fixture, RunConfig};

fn main() {
    let bench = Bench::from_env();
    if !common::have_artifacts() {
        eprintln!("xbar_hotpath: no AOT artifacts — running the hermetic subset");
        hermetic(&bench);
        bench.emit_json("xbar_hotpath").expect("bench json");
        return;
    }
    full(&bench);
    bench.emit_json("xbar_hotpath").expect("bench json");
}

/// Artifact-free subset: quantizer, mapper and cost model over the
/// in-memory fixture (the PJRT forward/kernel rows need `make artifacts`).
fn hermetic(bench: &Bench) {
    let cfg = RunConfig::default();
    let fx = fixture::tiny(1);
    let model = &fx.model;
    let bits: Vec<u8> = (0..model.num_strips())
        .map(|i| if i % 2 == 0 { 8 } else { 4 })
        .collect();
    let bm = BitMap { bits };
    let xcfg = XbarConfig::default();

    bench.run("quant::apply (fixture)", || {
        quant::apply(model, &fx.theta, &bm, &cfg.quant)
    });
    bench.run("xbar::map_model packed (fixture)", || {
        xbar::map_model(model, &bm, &xcfg, MappingStrategy::Packed)
    });
    bench.run("xbar::map_model origin (fixture)", || {
        xbar::map_model(model, &bm, &xcfg, MappingStrategy::Origin)
    });
    let mapping = xbar::map_model(model, &bm, &xcfg, MappingStrategy::Packed);
    bench.run("xbar::cost (fixture)", || xbar::cost(&mapping, &xcfg));
}

fn full(bench: &Bench) {
    let c = common::ctx();
    let cfg = RunConfig::default();

    let plan = CompressionPlan::for_model_with(&c.runtime, &c.manifest, "resnet20", cfg.clone())
        .expect("plan")
        .threshold(ThresholdMode::FixedCr(0.7))
        .cluster();
    let clustering = plan.clustering().expect("clustering");
    let bm = clustering.bitmap.clone();
    let model = plan.model();
    let theta = plan.theta();
    let xcfg = XbarConfig::default();

    // 1. quantizer — current (buffer-reusing) vs the pre-§Perf per-strip
    // allocating loop, reproduced here for the before/after record.
    bench.run("quant::apply (resnet20, 272k params)", || {
        quant::apply(model, theta, &bm, &cfg.quant)
    });
    bench.run("quant_apply_allocating (pre-perf baseline)", || {
        // old loop shape: three fresh Vecs per strip
        let mut out = theta.to_vec();
        for (i, s) in model.strips().iter().enumerate() {
            let bits = bm.bits[i];
            let vals = model.strip_values(&out, *s);
            if bits == 0 {
                model.set_strip_values(&mut out, *s, &vec![0.0; vals.len()]);
                continue;
            }
            let scale = quant::symmetric_scale(&vals, bits);
            let deq = quant::fake_quantize(&vals, bits, scale);
            model.set_strip_values(&mut out, *s, &deq);
        }
        out
    });

    // 2. mapper (both strategies)
    bench.run("xbar::map_model packed (resnet20)", || {
        xbar::map_model(model, &bm, &xcfg, MappingStrategy::Packed)
    });
    bench.run("xbar::map_model origin (resnet20)", || {
        xbar::map_model(model, &bm, &xcfg, MappingStrategy::Origin)
    });

    // 3. cost model
    let mapping = xbar::map_model(model, &bm, &xcfg, MappingStrategy::Packed);
    bench.run("xbar::cost (resnet20)", || xbar::cost(&mapping, &xcfg));

    // 4. PJRT forward (one eval batch = 128 images)
    let exe = model.entry.executables.get("fwd_eval").unwrap().clone();
    let theta_t = Tensor::from_vec(theta.to_vec());
    let (xb, _) = plan.test().batch(0, model.entry.batch.eval);
    bench.run("pjrt fwd_eval (resnet20, batch 128)", || {
        c.runtime.exec(&exe, &[theta_t.clone(), xb.clone()]).expect("exec")
    });

    // 5. standalone Pallas strip-MVM kernel
    let k = &c.manifest.kernel;
    let mut rng = Rng::seed_from_u64(3);
    let a = Tensor::new(
        vec![k.t, k.g * k.d],
        (0..k.t * k.g * k.d).map(|_| rng.normal()).collect(),
    );
    let w = Tensor::new(
        vec![k.g * k.d, k.n],
        (0..k.g * k.d * k.n).map(|_| (rng.below(255) as f32) - 127.0).collect(),
    );
    let s = Tensor::new(
        vec![k.g, k.n],
        (0..k.g * k.n).map(|_| rng.range(0.001, 0.01) as f32).collect(),
    );
    bench.run("pjrt strip_mvm kernel (128x144x64)", || {
        c.runtime
            .exec(&k.strip_mvm, &[a.clone(), w.clone(), s.clone()])
            .expect("kernel")
    });

    // 6. the mixed-precision kernel (two clusters + stepwise accumulation)
    let wq = w.clone();
    let sq = s.clone();
    bench.run("pjrt mixed_strip_mvm kernel", || {
        c.runtime
            .exec(
                &k.mixed_strip_mvm,
                &[a.clone(), wq.clone(), sq.clone(), w.clone(), s.clone()],
            )
            .expect("kernel")
    });
}
