//! Shared bench setup: artifacts + runtime + fast eval options.
#![allow(dead_code)]

use reram_mpq::experiments::ExpOpts;
use reram_mpq::{artifacts_dir, Manifest, Runtime};

pub struct Ctx {
    pub manifest: Manifest,
    pub runtime: Runtime,
}

pub fn ctx() -> Ctx {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir).expect("run `make artifacts` first");
    let runtime = Runtime::new(dir).expect("pjrt cpu client");
    Ctx { manifest, runtime }
}

/// Whether the AOT artifacts exist. Benches that can degrade to a hermetic
/// subset check this instead of aborting — CI's `bench-smoke` job runs on
/// a bare runner with no artifacts at all.
pub fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Benches evaluate on a few batches — the cost model and mapper dominate
/// what the tables measure; accuracy numbers for the record come from the
/// CLI/EXPERIMENTS runs on the full test set.
pub fn opts() -> ExpOpts {
    let eval_batches = std::env::var("BENCH_EVAL_BATCHES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    ExpOpts { eval_batches }
}
