//! Bench: the native crossbar-simulator hot paths — exact-f32 forward,
//! bit-serial integer forward, and the faithful phase-loop conv with ADC +
//! conductance noise. Fully hermetic (no artifacts), so this is the one
//! bench that runs on a fresh clone:
//!
//!     cargo bench --bench sim_backend

use reram_mpq::backend::{ExecBackend, FwdKind, SimXbar, SimXbarConfig, StripPrecision};
use reram_mpq::quant::{self, BitMap};
use reram_mpq::tensor::Tensor;
use reram_mpq::util::bench::Bench;
use reram_mpq::{fixture, RunConfig};

fn main() {
    let bench = Bench::from_env();
    let fx = fixture::tiny(1);
    let model = &fx.model;
    let theta_t = Tensor::from_vec(fx.theta.clone());
    let xb = fx.test.x.slice_rows(0, model.entry.batch.eval);

    // 1. exact f32 native forward (fp32 reference deployments)
    let exact = SimXbar::new(SimXbarConfig::default());
    bench.run("sim exact-f32 forward (tiny, batch 4)", || {
        exact.forward(model, FwdKind::Eval, &theta_t, &xb).expect("forward")
    });

    // 2. bit-serial integer forward on mixed 4/8-bit strips (the serving
    // fast path: ideal converters)
    let mut cfg = RunConfig::default();
    cfg.quant.device_sigma = 0.0;
    let bits: Vec<u8> = (0..model.num_strips())
        .map(|i| if i % 2 == 0 { 8 } else { 4 })
        .collect();
    let qm = quant::apply(model, &fx.theta, &BitMap { bits }, &cfg.quant);
    let qtheta_t = Tensor::from_vec(qm.theta.clone());
    let sim = SimXbar::from_quantized(SimXbarConfig::default(), &qm);
    bench.run("sim bit-serial forward, ideal ADC (tiny, batch 4)", || {
        sim.forward(model, FwdKind::Eval, &qtheta_t, &xb).expect("forward")
    });

    // 3. the faithful phase loop with a 4-bit ADC and conductance noise —
    // one image, since every input-bit phase converts separately
    let noisy = SimXbar::new(SimXbarConfig::default().with_adc(4).with_noise(0.1, 3))
        .with_strips(StripPrecision::from_quantized(&qm));
    let x1 = fx.test.x.slice_rows(0, 1);
    bench.run("sim phase-loop forward, 4b ADC + noise (1 image)", || {
        noisy.forward(model, FwdKind::Eval, &qtheta_t, &x1).expect("forward")
    });
}
